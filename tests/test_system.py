"""End-to-end system behaviour: the full paper pipeline (§II-§V) and the
framework loop (train -> checkpoint -> restore -> serve)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_smoke_config, supported_shapes
from repro.core import engine
from repro.data import SyntheticLMData, make_batch
from repro.distributed import compression
from repro.ft import Supervisor
from repro.serve import Request, ServeConfig, ServingEngine
from repro.train import TrainConfig, make_train_step
from repro.train.step import train_state_init

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def test_paper_pipeline_end_to_end(key):
    """Eq. 4 chain on real operands: binary -> ln LUT -> tau -> stochastic
    bits -> pop-count -> binary product, within the paper's error budget."""
    cfg = engine.EngineConfig(nbit=4096)
    x_int, y_int = 700, 300
    p_est, product = engine.sc_multiply(key, x_int, y_int, cfg)
    true_product = x_int * y_int                     # in [0, 2^20)
    # nbit=4096 -> sigma ~ 0.7% of full scale
    assert abs(int(product) - true_product) < 0.03 * (1 << 20)
    # and the probability estimate matches the encoded product
    p_true = (x_int / 1024) * (y_int / 1024)
    assert abs(float(p_est) - p_true) < 0.03


def test_mac_pipeline_matches_dot_product(key):
    """§III-C vectored MAC: sum of per-MUL pop-counts ~ dot(w, x)."""
    from repro.core import popcount
    cfg = engine.EngineConfig(nbit=2048)
    w = jnp.array([100, 300, 500, 700, 900])
    x = jnp.array([900, 700, 500, 300, 100])
    states = engine.mac_rows(key, w, x, cfg)
    total = int(popcount.csa_fa_popcount(states))
    est = total / cfg.nbit * (1024 * 1024)           # decode the MAC sum
    true = float(jnp.sum(w * x))
    assert abs(est - true) / true < 0.05


def test_train_checkpoint_serve_roundtrip(tmp_path, key):
    """Train a few steps -> checkpoint -> restore -> serve: tokens from the
    restored engine match tokens from the live engine."""
    cfg = get_smoke_config("qwen2-0.5b").replace(**F32)
    tcfg = TrainConfig()
    state = train_state_init(key, cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=4)
    sup = Supervisor(ckpt_dir=str(tmp_path), ckpt_every=4)
    state, _ = sup.run(state, step, 8,
                       make_batch=lambda i: make_batch(data, i))

    from repro import checkpoint
    restored, extra, at = checkpoint.restore(str(tmp_path), state)
    assert at == 8

    def serve_with(params):
        eng = ServingEngine(params, cfg, ServeConfig(slots=1, max_len=32))
        eng.submit(Request(rid=0, prompt=[5, 7, 9], max_new_tokens=4))
        return eng.run_until_drained()[0].generated

    assert serve_with(state["params"]) == serve_with(restored["params"])


def test_shard_map_compression_on_pod_mesh(key):
    """compressed_grads wires shard_map over a pod axis (size 1 on CPU —
    semantics identical, collectives degenerate) and returns grads close to
    the uncompressed path (int8 quantization error only)."""
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    w0 = jax.random.normal(key, (8, 8))

    def grad_fn(params, batch):
        loss = jnp.mean((batch @ params["w"]) ** 2)
        return loss, jax.grad(
            lambda p: jnp.mean((batch @ p["w"]) ** 2))(params)

    batch = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 8))
    ef = compression.init_error_feedback({"w": w0}, n_pods=1)
    fn = compression.compressed_grads(grad_fn, mesh)
    loss, grads, new_ef = fn({"w": w0}, batch, {"w": ef["w"]})
    _, exact = grad_fn({"w": w0}, batch[0])
    err = np.abs(np.asarray(grads["w"]) - np.asarray(exact["w"])).max()
    scale = np.abs(np.asarray(exact["w"])).max()
    assert err < scale / 64                  # int8 grid error
    assert new_ef["w"].shape == (1, 8, 8)


def test_supported_shapes_matrix():
    """The 40-cell matrix: every arch runs 3 LM shapes; only ssm/hybrid run
    long_500k (documented skip for full-attention archs)."""
    from repro.configs import ARCH_IDS
    total_live = 0
    for arch in ARCH_IDS:
        if arch == "paper-sc":
            continue
        cfg = get_smoke_config(arch)
        shapes = supported_shapes(cfg)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        total_live += len(shapes)
    assert total_live == 32                  # + 8 documented skips = 40


def test_shape_configs_match_assignment():
    assert (SHAPES["train_4k"].seq_len, SHAPES["train_4k"].global_batch) \
        == (4096, 256)
    assert (SHAPES["prefill_32k"].seq_len,
            SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (SHAPES["decode_32k"].seq_len,
            SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (SHAPES["long_500k"].seq_len,
            SHAPES["long_500k"].global_batch) == (524288, 1)
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].kind == "decode"
