"""Every model family under the paged engine (the serve half of the zoo
refactor): MoE / SSM / hybrid decode through ``PagedServingEngine`` via
the per-family cache plan, match the fixed-slot engine token for token,
and keep the per-request rng invariants (batch composition, chunking,
eviction/resume) on stochastic substrates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm, params as P
from repro.serve import (PagedServeConfig, PagedServingEngine, Request,
                         ServeConfig, ServingEngine)
from repro.serve.kv_cache import CachePlan

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)
FAMILY_ARCHS = ["moonshot-v1-16b-a3b", "mamba2-370m", "zamba2-7b"]


def _cfg(arch, **kw):
    return get_smoke_config(arch).replace(**F32, **kw)


def _params(key, cfg):
    return P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)


def _run_paged(params, cfg, reqs, *, slots=2, seed=7, num_blocks=0,
               submit_after=None, **kw):
    defaults = dict(slots=slots, max_len=48, block_size=4, prefill_chunk=3,
                    seed=seed, num_blocks=num_blocks)
    defaults.update(kw)
    eng = PagedServingEngine(params, cfg, PagedServeConfig(**defaults))
    late = dict(submit_after or {})
    for r in reqs:
        eng.submit(r)
    ticks = 0
    while eng.scheduler.has_work() or late:
        for t in [t for t in sorted(late) if ticks >= t]:
            eng.submit(late.pop(t))
        eng.step()
        ticks += 1
        assert ticks < 500
    return eng, {r.rid: r.generated for r in eng.finished}


# ---------------------------------------------------------------------------
# Cache plan
# ---------------------------------------------------------------------------


def test_cache_plan_per_family():
    plans = {a: CachePlan.for_config(_cfg(a)) for a in
             ["qwen2-0.5b"] + FAMILY_ARCHS}
    assert plans["qwen2-0.5b"].has_paged
    assert not plans["qwen2-0.5b"].has_state
    assert plans["moonshot-v1-16b-a3b"].has_paged       # MoE pages like dense
    assert plans["mamba2-370m"].has_state
    assert not plans["mamba2-370m"].has_paged
    hz = plans["zamba2-7b"]
    assert hz.has_paged and hz.has_state                # both cache kinds
    cfg = _cfg("zamba2-7b")
    assert hz.state_layers == lm.n_backbone_layers(cfg)
    assert hz.paged_layers == lm.n_shared_invocations(cfg)
    pages = lm.init_paged_cache(cfg, 8, 4, slots=2)
    assert set(pages) == {"ssm", "k", "v"}
    assert pages["k"].shape[0] == hz.paged_layers
    assert pages["ssm"]["state"].shape[:2] == (hz.state_layers, 2)


# ---------------------------------------------------------------------------
# Paged == fixed-slot, per family (exact backend, greedy)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_paged_matches_fixed_slot_greedy(arch, key):
    cfg = _cfg(arch)
    params = _params(key, cfg)
    prompts = {0: [5, 9, 17, 3], 1: [40, 2, 8, 30, 7]}
    fe = ServingEngine(params, cfg, ServeConfig(slots=2, max_len=48))
    for rid, p in prompts.items():
        fe.submit(Request(rid=rid, prompt=list(p), max_new_tokens=5))
    got_f = {r.rid: r.generated for r in fe.run_until_drained()}
    _, got_p = _run_paged(
        params, cfg,
        [Request(rid=r, prompt=list(p), max_new_tokens=5)
         for r, p in prompts.items()])
    assert got_p == got_f


# ---------------------------------------------------------------------------
# RNG invariants on a stochastic substrate, per family
# ---------------------------------------------------------------------------

REQ0 = dict(rid=0, prompt=[5, 9, 17, 3], max_new_tokens=6, temperature=0.8)
REQ1 = dict(rid=1, prompt=[40, 2, 8, 30, 7, 11, 2, 4], max_new_tokens=6,
            temperature=0.3)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_batch_composition_invariance_stochastic(arch, key):
    """Tokens are a function of (request key, position) alone — solo,
    batched, and mid-stream admission all agree bit for bit, for every
    cache-plan family."""
    cfg = _cfg(arch, sc_backend="moment", sc_nbit=256)
    params = _params(key, cfg)
    _, solo = _run_paged(params, cfg, [Request(**REQ0)], slots=1)
    _, full = _run_paged(params, cfg,
                         [Request(**REQ0), Request(**REQ1)], slots=2)
    _, mid = _run_paged(params, cfg, [Request(**REQ1)], slots=2,
                        submit_after={3: Request(**REQ0)})
    assert solo[0] == full[0] == mid[0]


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_eviction_resume_reproduces_tokens(arch, key):
    """A tight pool forces an eviction mid-decode; the resumed request
    re-feeds its context (rebuilding KV blocks AND/OR recurrent state
    from position 0) and must emit the same tokens as a roomy pool."""
    cfg = _cfg(arch, sc_backend="moment", sc_nbit=256)
    params = _params(key, cfg)
    mk = lambda: [
        Request(rid=0, prompt=[5, 9, 17, 3, 8, 2, 30, 11, 7, 6],
                max_new_tokens=16, temperature=0.6),
        Request(rid=1, prompt=[40, 2, 8, 30, 7, 11, 2, 4, 9, 9],
                max_new_tokens=16, temperature=0.6)]
    roomy_e, roomy = _run_paged(params, cfg, mk(), prefill_chunk=4)
    tight_e, tight = _run_paged(params, cfg, mk(), prefill_chunk=4,
                                num_blocks=13)
    assert tight_e.evictions > 0, "pool was meant to force an eviction"
    assert roomy_e.evictions == 0
    assert roomy == tight


def test_ssm_chunk_width_invariance(key):
    """The recurrent paged feed makes an SSM row's tokens independent of
    the prefill chunking — different prefill_chunk, same bits."""
    cfg = _cfg("mamba2-370m", sc_backend="moment", sc_nbit=256)
    params = _params(key, cfg)
    req = dict(rid=0, prompt=[5, 9, 17, 3, 8, 2, 30, 11], max_new_tokens=6,
               temperature=0.7)
    outs = [_run_paged(params, cfg, [Request(**req)], slots=1,
                       prefill_chunk=c)[1] for c in (2, 3, 8)]
    assert outs[0] == outs[1] == outs[2]


def test_ssm_state_resets_on_slot_reuse(key):
    """A request admitted into a slot a previous request used must not
    inherit its predecessor's recurrent state: serving B after A in one
    engine equals serving B alone."""
    cfg = _cfg("mamba2-370m", sc_backend="moment", sc_nbit=256)
    params = _params(key, cfg)
    a = dict(rid=0, prompt=[5, 9, 17], max_new_tokens=3)
    b = dict(rid=1, prompt=[40, 2, 8, 30], max_new_tokens=5,
             temperature=0.5)
    _, alone = _run_paged(params, cfg, [Request(**b)], slots=1)
    _, after = _run_paged(params, cfg, [Request(**a)], slots=1,
                          submit_after={1: Request(**b)})
    assert after[1] == alone[1]
