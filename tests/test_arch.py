"""Array-level architecture simulator (repro.arch): spec/tiler/schedule/
accounting invariants, closed-form agreement, the registered ``array``
backend, trace collection, and the serve-engine hook."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import arch, sc
from repro.configs import get_smoke_config
from repro.core import costmodel as cm
from repro.models import lm, params as P
from repro.serve import Request, ServeConfig, ServingEngine

NBIT = 1024            # 2^10 — the paper's 10-bit evaluation point


# --------------------------------------------------------------------------
# Spec
# --------------------------------------------------------------------------


def test_spec_totals_and_mapping():
    s = arch.ArraySpec(banks=2, subarrays_per_bank=4, rows_per_subarray=8,
                       row_length=256)
    assert s.subarrays == 8 and s.rows == 64 and s.cells == 64 * 256
    assert s.rows_per_product(1024) == 4
    assert s.products_per_subarray(1024) == 2
    assert s.products_per_wave(1024) == 16


def test_spec_rejects_bad_geometry():
    with pytest.raises(ValueError, match="positive int"):
        arch.ArraySpec(banks=0)
    with pytest.raises(ValueError, match="cross-subarray"):
        arch.ArraySpec(rows_per_subarray=2).products_per_subarray(1024)


# --------------------------------------------------------------------------
# Tiler
# --------------------------------------------------------------------------


def test_tiler_conserves_products():
    plan = arch.tile_matmul(8, 32, 8, NBIT)
    assert plan.products == 8 * 32 * 8
    tiles = list(arch.iter_tiles(plan))
    assert sum(t.products for t in tiles) == plan.products
    assert sum(t.cells for t in tiles) == plan.cells_touched
    spec = plan.spec
    for t in tiles:
        assert t.rows <= spec.rows_per_subarray
        assert 0 <= t.bank < spec.banks
        assert 0 <= t.subarray < spec.subarrays_per_bank


def test_tiler_wave_split():
    spec = arch.ArraySpec(banks=1, subarrays_per_bank=2, rows_per_subarray=8)
    # 5 products at 4 rows each; 2 per subarray per wave, 4 per wave -> 2 waves
    plan = arch.tile_matmul(5, 1, 1, NBIT, spec)
    assert (plan.waves, plan.full_waves, plan.tail_products) == (2, 1, 1)
    assert plan.tail_subarrays == 1
    waves = {}
    for t in arch.iter_tiles(plan):
        waves.setdefault(t.wave, 0)
        waves[t.wave] += t.products
    assert waves == {0: 4, 1: 1}


def test_tiler_rejects_empty_dims():
    with pytest.raises(ValueError, match="positive"):
        arch.tile_matmul(0, 4, 4, NBIT)


def test_occupancy_full_when_wave_aligned():
    spec = arch.ArraySpec(banks=1, subarrays_per_bank=1, rows_per_subarray=4)
    plan = arch.tile_matmul(1, 1, 1, NBIT, spec)   # exactly fills the chip
    assert arch.occupancy(plan) == 1.0


# --------------------------------------------------------------------------
# Schedule + accounting vs the closed-form §V model
# --------------------------------------------------------------------------


def test_single_mul_trace_matches_closed_form_cycles():
    rec = arch.schedule_call(1, 1, 1, NBIT)
    assert rec.report.cycles == cm.cycles_scpim_apc(10)
    assert [c.op for c in rec.trace] == ["PRESET", "PULSE_X", "PULSE_Y",
                                         "READ", "POPCOUNT", "MERGE"]


def test_single_mul_trace_matches_closed_form_energy():
    rec = arch.schedule_call(1, 1, 1, NBIT)
    expect, _ = cm.energy_scpim(10, "apc")
    np.testing.assert_allclose(rec.report.energy_pj, expect, rtol=1e-12)


def test_trace_reproduces_headline_ratios():
    """Acceptance: ≈4x vs SC and ≈18x vs PIM emerge from the trace."""
    cycles = arch.schedule_call(1, 1, 1, NBIT).report.cycles
    assert 3.0 <= cm.cycles_sc(10) / cycles <= 5.0
    assert 15.0 <= cm.cycles_pim(8) / cycles <= 21.0


def test_no_merge_when_product_fits_one_row():
    rec = arch.schedule_call(1, 1, 1, 256)
    assert "MERGE" not in [c.op for c in rec.trace]
    assert rec.report.cycles == cm.cycles_scpim_apc(8)   # 2^8 = 256 bits


def test_waves_serialize_cycles():
    spec = arch.ArraySpec(banks=1, subarrays_per_bank=1, rows_per_subarray=4)
    one = arch.schedule_call(1, 1, 1, NBIT, spec).report.cycles
    three = arch.schedule_call(3, 1, 1, NBIT, spec).report.cycles
    assert three == 3 * one           # same subarray reused -> 3 full waves


def test_parallel_products_do_not_add_cycles():
    base = arch.schedule_call(1, 1, 1, NBIT).report
    wave = arch.schedule_call(4, 2, 4, NBIT).report    # still one wave
    assert wave.cycles == base.cycles
    assert wave.products == 32
    np.testing.assert_allclose(wave.energy_pj, 32 * base.energy_pj,
                               rtol=1e-12)


def test_schedule_rejects_row_length_mismatch():
    plan = arch.tile_matmul(1, 1, 1, NBIT,
                            arch.ArraySpec(row_length=128))
    with pytest.raises(ValueError, match="row_length"):
        arch.compile_schedule(plan, cm.DEFAULT_PARAMS)


def test_accounting_utilization_bounds():
    rep = arch.schedule_call(8, 32, 8, NBIT).report
    assert 0.0 < rep.subarray_util <= 1.0
    assert 0.0 < rep.cell_occupancy <= 1.0
    assert rep.cycles_by_op["READ"] > 0
    assert rep.energy_by_op["PRESET"] > rep.energy_by_op["POPCOUNT"]


def test_merge_reports_adds_cycles_and_energy():
    a = arch.schedule_call(1, 1, 1, NBIT).report
    merged = arch.merge_reports([a, a, a])
    assert merged.cycles == 3 * a.cycles
    np.testing.assert_allclose(merged.energy_pj, 3 * a.energy_pj)
    assert merged.products == 3 * a.products
    scaled = arch.scaled(a, 3)
    assert scaled.cycles == merged.cycles


def test_cost_params_sweep_changes_trace():
    slow = cm.CostParams(sa_read_cycles=8)
    plan = arch.tile_matmul(1, 1, 1, NBIT)
    base = arch.account(arch.compile_schedule(plan), plan.spec)
    swept = arch.account(arch.compile_schedule(plan, slow), plan.spec, slow)
    assert swept.cycles == base.cycles + 6


# --------------------------------------------------------------------------
# The registered backend
# --------------------------------------------------------------------------


def test_array_backend_registered_lazily():
    assert "array" in sc.available_backends()
    assert sc.get_backend("array") is not None


def test_array_backend_round_trip(key):
    x = jax.random.normal(key, (8, 32), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 8), jnp.float32)
    y = sc.sc_dot(key, x, w, sc.ScConfig(backend="array", nbit=NBIT))
    assert y.shape == (8, 8)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_array_backend_mean_agrees_with_exact(key):
    """Acceptance: mean agrees with ``exact`` within sampling tolerance at
    n = 2^10 stochastic bits."""
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (4, 16), jnp.float32)
    w = jax.random.normal(kw, (16, 4), jnp.float32)
    cfg = sc.ScConfig(backend="array", nbit=NBIT)
    n_rep = 32
    outs = jax.vmap(lambda k_: sc.sc_dot(k_, x, w, cfg))(
        jax.random.split(key, n_rep))
    mean = np.asarray(outs.mean(axis=0))
    sigma = np.asarray(outs.std(axis=0))
    exact = np.asarray(x @ w)
    tol = 5 * sigma / np.sqrt(n_rep) + 0.02 * np.abs(exact).max()
    assert (np.abs(mean - exact) < tol).mean() > 0.9


def test_array_backend_straight_through_gradient(key):
    x = jax.random.normal(key, (4, 16), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 4), jnp.float32)
    cfg = sc.ScConfig(backend="array", nbit=NBIT)

    def loss(x_, w_):
        return jnp.sum(sc.sc_dot(key, x_, w_, cfg) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    y = sc.sc_dot(key, x, w, cfg)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * (y @ w.T)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(2 * (x.T @ y)),
                               rtol=1e-4, atol=1e-4)


def test_array_backend_respects_ambient_spec(key):
    x = jax.random.normal(key, (2, 8), jnp.float32)
    w = jax.random.normal(key, (8, 2), jnp.float32)
    tiny = arch.ArraySpec(banks=1, subarrays_per_bank=1, rows_per_subarray=4)
    with arch.use_spec(tiny), arch.collect() as records:
        sc.sc_dot(key, x, w, sc.ScConfig(backend="array", nbit=NBIT))
    assert records[0].plan.spec == tiny
    assert records[0].plan.waves == 32      # 2*8*2 products, 1 per wave


def test_array_backend_validates_spec_even_untraced(key):
    x = jax.random.normal(key, (2, 8), jnp.float32)
    w = jax.random.normal(key, (8, 2), jnp.float32)
    bad = arch.ArraySpec(rows_per_subarray=1)
    with arch.use_spec(bad):
        with pytest.raises(ValueError, match="cross-subarray"):
            sc.sc_dot(key, x, w, sc.ScConfig(backend="array", nbit=NBIT))


# --------------------------------------------------------------------------
# Trace collection
# --------------------------------------------------------------------------


def test_collector_records_once_per_compiled_shape(key):
    cfg = sc.ScConfig(backend="array", nbit=256)
    x = jax.random.normal(key, (4, 8), jnp.float32)
    w = jax.random.normal(key, (8, 4), jnp.float32)
    f = jax.jit(lambda k_, x_, w_: sc.sc_dot(k_, x_, w_, cfg))
    with arch.collect() as records:
        for i in range(3):
            f(jax.random.fold_in(key, i), x, w).block_until_ready()
    assert len(records) == 1            # jit cache: one record per shape
    assert records[0].shape == (4, 8, 4)


def test_nested_collectors_both_hear(key):
    cfg = sc.ScConfig(backend="array", nbit=256)
    x = jnp.ones((2, 4), jnp.float32)
    w = jnp.ones((4, 2), jnp.float32)
    with arch.collect() as outer:
        with arch.collect() as inner:
            sc.sc_dot(key, x, w, cfg)
        sc.sc_dot(key, x, w, cfg)
    assert len(inner) == 1 and len(outer) == 2


def test_summarize_is_json_ready(key):
    with arch.collect() as records:
        sc.sc_dot(key, jnp.ones((2, 4)), jnp.ones((4, 2)),
                  sc.ScConfig(backend="array", nbit=256))
    import json
    s = arch.summarize(records, arch.DEFAULT_SPEC)
    json.dumps(s)                       # must not raise
    assert s["calls"] == 1
    assert s["aggregate"]["cycles"] > 0


# --------------------------------------------------------------------------
# Model stack + serve engine end-to-end
# --------------------------------------------------------------------------


def test_lm_forward_on_array_backend_traces_all_dense_sites(key):
    cfg = get_smoke_config("paper-sc").replace(
        sc_backend="array", param_dtype=jnp.float32, act_dtype=jnp.float32)
    params = P.init_params(key, lm.lm_param_specs(cfg), jnp.float32)
    toks = jax.random.randint(key, (1, 8), 2, cfg.vocab)
    with arch.collect() as records:
        logits = lm.forward(params, toks, cfg, rng=jax.random.PRNGKey(1))
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one record per dense() site in the scanned block (logits head is
    # exact-path): wq wk wv wo + mlp wi wo
    assert len(records) == len(arch.dense_workload(cfg, 8))
    assert all(r.report.cycles > 0 for r in records)


def test_serve_engine_arch_trace_hook(key):
    cfg = get_smoke_config("paper-sc").replace(
        sc_backend="array", param_dtype=jnp.float32, act_dtype=jnp.float32)
    params = P.init_params(key, lm.lm_param_specs(cfg), jnp.float32)
    engine = ServingEngine(params, cfg, ServeConfig(slots=1, max_len=32),
                           collect_arch_trace=True)
    try:
        engine.submit(Request(rid=0, prompt=[3, 7, 11], max_new_tokens=2))
        finished = engine.run_until_drained()
        assert len(finished) == 1
        rep = engine.arch_report()
        assert rep is not None and rep.cycles > 0 and rep.energy_pj > 0
    finally:
        engine.close()
    assert engine.arch_collector not in arch.trace._LISTENERS


def test_serve_engine_without_hook_has_no_report(key):
    cfg = get_smoke_config("qwen2-0.5b").replace(
        param_dtype=jnp.float32, act_dtype=jnp.float32)
    params = P.init_params(key, lm.lm_param_specs(cfg), jnp.float32)
    engine = ServingEngine(params, cfg, ServeConfig(slots=1, max_len=32))
    assert engine.arch_report() is None
    engine.close()                      # no-op, must not raise


def test_serve_engine_hook_requires_array_backend(key):
    """collect_arch_trace on a non-array backend installs nothing (there
    would be no dispatches to hear) and leaves the listener list clean."""
    cfg = get_smoke_config("qwen2-0.5b").replace(
        param_dtype=jnp.float32, act_dtype=jnp.float32)
    params = P.init_params(key, lm.lm_param_specs(cfg), jnp.float32)
    before = list(arch.trace._LISTENERS)
    engine = ServingEngine(params, cfg, ServeConfig(slots=1, max_len=32),
                           collect_arch_trace=True)
    assert engine.arch_collector is None
    assert arch.trace._LISTENERS == before
    del engine                          # __del__ path must not raise


# --------------------------------------------------------------------------
# Workload extraction
# --------------------------------------------------------------------------


def test_dense_workload_covers_families():
    for arch_id in ("paper-sc", "qwen3-14b", "moonshot-v1-16b-a3b",
                    "mamba2-370m", "zamba2-7b"):
        cfg = get_smoke_config(arch_id)
        sites = arch.dense_workload(cfg, tokens=16)
        assert sites, arch_id
        assert all(s.products > 0 for s in sites)


def test_dense_workload_hybrid_multiplicity_matches_lm():
    """Hybrid layer counts must come from the lm assembly, not a copy."""
    cfg = get_smoke_config("zamba2-7b")
    sites = {s.label: s for s in arch.dense_workload(cfg, tokens=4)}
    assert sites["ssm.wz"].count == lm.n_backbone_layers(cfg)
    assert sites["shared.attn.wq"].count == lm.n_shared_invocations(cfg)


def test_price_workload_totals_consistent():
    cfg = get_smoke_config("paper-sc")
    sites = arch.dense_workload(cfg, tokens=8)
    per_site, total = arch.price_workload(sites, NBIT)
    assert total.cycles == sum(r.cycles for _, r in per_site)
    assert total.products == sum(s.products for s in sites)
