"""Docs stay navigable: every relative markdown link in README + docs/
resolves. (Snippet EXECUTION is the CI docs job — tools/check_docs.py
without --links-only — kept out of tier-1 to avoid re-importing jax under
a forced 8-device platform here.)"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_docs


def test_doc_files_exist():
    for relpath in check_docs.LINK_FILES:
        assert os.path.exists(os.path.join(check_docs.REPO, relpath)), relpath


def test_markdown_links_resolve():
    errors = []
    for relpath in check_docs.LINK_FILES:
        errors += check_docs.check_links(relpath)
    assert not errors, "\n".join(errors)


def test_snippet_extraction_finds_python_blocks():
    for relpath in check_docs.SNIPPET_FILES:
        snippets = check_docs.extract_snippets(relpath)
        assert snippets, f"{relpath}: no python snippets found"
        for _, src in snippets:
            compile(src, relpath, "exec")     # syntax-checks every block
