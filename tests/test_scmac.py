"""SC substrate framework features through the public ``repro.sc`` API:
backends, encoding, moments, gradients.  (Formerly exercised the
``core/scmac`` shim; the shim is gone, the coverage stays.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import sc


def _xw(key, m=32, k=128, n=16):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    return x, w


def test_exact_mode_is_plain_matmul(key):
    x, w = _xw(key)
    cfg = sc.ScConfig(backend="exact")
    out = sc.sc_dot(key, x, w, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), rtol=1e-5)


def test_encode_reconstructs_input(key):
    v = jax.random.normal(key, (64, 64)) * 3.0
    s, p, scale = sc.encoding.encode(v, sc.ScConfig(quantize=False))
    np.testing.assert_allclose(np.asarray(s * p * scale), np.asarray(v),
                               rtol=1e-5, atol=1e-6)
    assert float(p.max()) <= 1.0 and float(p.min()) >= 0.0


def test_encode_quantizes_to_operand_grid(key):
    v = jax.random.normal(key, (64,))
    cfg = sc.ScConfig(operand_bits=10)
    _, p, _ = sc.encoding.encode(v, cfg)
    grid = np.asarray(p) * 1024
    np.testing.assert_allclose(grid, np.round(grid), atol=1e-4)


@pytest.mark.parametrize("backend", ["bitexact", "moment"])
def test_stochastic_backends_unbiased(key, backend):
    """Both SC backends estimate x@w with zero-centered error (Fig. 7a
    lifted to the MAC level)."""
    x, w = _xw(key, m=16, k=256, n=8)
    cfg = sc.ScConfig(backend=backend, nbit=1024)
    outs = jax.vmap(
        lambda k_: sc.sc_dot(k_, x, w, cfg))(jax.random.split(key, 64))
    mean = np.asarray(outs.mean(axis=0))
    exact = np.asarray(x @ w)
    resid = np.abs(mean - exact)
    # SE of the mean = sigma/sqrt(64); allow 5 SE + operand-quantization bias
    sigma = np.asarray(outs.std(axis=0))
    tol = 5 * sigma / np.sqrt(64) + 0.02 * np.abs(exact).max()
    assert (resid < tol).mean() > 0.97


def test_moment_matches_bitexact_variance(key):
    """The beyond-paper moment backend must reproduce the bitexact
    variance (that is its contract: identical first/second moments)."""
    x, w = _xw(key, m=8, k=128, n=4)
    keys = jax.random.split(key, 128)
    var = {}
    for backend in ("bitexact", "moment"):
        cfg = sc.ScConfig(backend=backend, nbit=256)
        outs = jax.vmap(lambda k_: sc.sc_dot(k_, x, w, cfg))(keys)
        var[backend] = np.asarray(outs.std(axis=0))
    ratio = var["moment"] / np.maximum(var["bitexact"], 1e-9)
    # elementwise sigmas agree within sampling slack
    assert 0.7 < np.median(ratio) < 1.4


def test_variance_shrinks_with_nbit(key):
    x, w = _xw(key, m=8, k=64, n=4)
    keys = jax.random.split(key, 96)
    sig = {}
    for nbit in (256, 4096):
        cfg = sc.ScConfig(backend="moment", nbit=nbit)
        outs = jax.vmap(lambda k_: sc.sc_dot(k_, x, w, cfg))(keys)
        sig[nbit] = float(np.asarray(outs.std(axis=0)).mean())
    assert sig[4096] < sig[256] / 2.5  # expect ~4x


def test_straight_through_gradients_match_exact(key):
    x, w = _xw(key, m=8, k=32, n=4)
    cfg = sc.ScConfig(backend="moment", nbit=1024)

    def loss_sc(x_, w_):
        return jnp.sum(sc.sc_dot(key, x_, w_, cfg) ** 2)

    # STE backward: d/dx sum(f(x@w)^2) evaluated with the *stochastic*
    # forward value but exact-product jacobian
    gx, gw = jax.grad(loss_sc, argnums=(0, 1))(x, w)
    y = sc.sc_dot(key, x, w, cfg)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * (y @ w.T)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(2 * (x.T @ y)),
                               rtol=1e-4, atol=1e-4)


def test_sc_dot_batched_lead_dims_shape(key):
    """(b, l, d) x (d, f) flattens the lead dims through the backend and
    restores them — the shape contract models/layers.dense leans on."""
    x = jax.random.normal(key, (2, 6, 32))
    w = jax.random.normal(key, (32, 16))
    y = sc.sc_dot(key, x, w, sc.ScConfig(backend="moment"))
    assert y.shape == (2, 6, 16)


def test_unknown_backend_rejected(key):
    x, w = _xw(key, m=4, k=8, n=2)
    with pytest.raises(ValueError, match="unknown SC backend"):
        sc.sc_dot(key, x, w, sc.ScConfig(backend="bogus"))


@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
@settings(max_examples=30, deadline=None)
def test_scale_invariance(seed, scale):
    """Per-tensor max-abs encoding makes the SC error RELATIVE: scaling the
    inputs scales the output by the same factor (same key => same draw)."""
    key = jax.random.PRNGKey(seed)
    x, w = _xw(key, m=4, k=32, n=4)
    cfg = sc.ScConfig(backend="moment", nbit=512, quantize=False)
    base = sc.sc_dot(key, x, w, cfg)
    scaled = sc.sc_dot(key, x * scale, w, cfg)
    np.testing.assert_allclose(np.asarray(scaled), np.asarray(base) * scale,
                               rtol=2e-3, atol=1e-5 * scale)
