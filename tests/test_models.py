"""Model-component correctness: attention equivalences, SSD oracle,
MoE dispatch, RoPE, decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import attention, layers, lm, moe, ssm
from repro.models import params as P

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _qkv(key, b=2, s=64, h=8, kv=2, hd=16):
    kq, kk, kvk = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(kk, (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(kvk, (b, s, kv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [16, 24, 64, 128])
def test_blockwise_equals_full_attention(key, chunk):
    """Flash-style online softmax is exact for any chunking, including
    chunk sizes that do not divide the sequence."""
    q, k, v = _qkv(key)
    full = attention.full_attention(q, k, v)
    block = attention.blockwise_attention(q, k, v, chunk=chunk)
    np.testing.assert_allclose(np.asarray(block), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_blockwise_noncausal(key):
    q, k, v = _qkv(key, s=32)
    full = attention.full_attention(q, k, v, causal=False)
    block = attention.blockwise_attention(q, k, v, causal=False, chunk=8)
    np.testing.assert_allclose(np.asarray(block), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_full(key):
    """One-token decode over a cache == last row of full causal attention."""
    b, s, h, kv, hd = 2, 16, 8, 2, 16
    q, k, v = _qkv(key, b, s, h, kv, hd)
    full = attention.full_attention(q, k, v)
    lengths = jnp.full((b,), s, jnp.int32)
    dec = attention.decode_attention(q[:, -1:], k, v, lengths)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_gqa_grouping_no_replication(key):
    """GQA: kv head j serves q heads [j*g, (j+1)*g) — verify against an
    explicit head-replicated reference."""
    b, s, h, kv, hd = 1, 8, 4, 2, 8
    q, k, v = _qkv(key, b, s, h, kv, hd)
    out = attention.full_attention(q, k, v)
    k_rep = jnp.repeat(k, h // kv, axis=2)
    v_rep = jnp.repeat(v, h // kv, axis=2)
    ref = attention.full_attention(q, k_rep, v_rep)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_rope_preserves_norm_and_relativity(key):
    x = jax.random.normal(key, (1, 16, 2, 32))
    pos = jnp.arange(16)[None]
    y = layers.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(key, (1, 1, 1, 32))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 32))
    def dot_at(p, d):
        rq = layers.apply_rope(q, jnp.array([[p]]), 10000.0)
        rk = layers.apply_rope(kk, jnp.array([[p + d]]), 10000.0)
        return float(jnp.sum(rq * rk))
    np.testing.assert_allclose(dot_at(0, 3), dot_at(7, 3), rtol=1e-4)


# ---------------------------------------------------------------------------
# SSD (Mamba2) against a naive recurrence oracle
# ---------------------------------------------------------------------------


def _ssd_naive(x, dt, A, B, C):
    """Direct recurrence: H_t = exp(dt_t A) H_{t-1} + dt_t B_t (x) x_t;
    y_t = C_t . H_t."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    H = np.zeros((b, h, n, p), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    xn, dtn = np.asarray(x, np.float64), np.asarray(dt, np.float64)
    An, Bn, Cn = np.asarray(A, np.float64), np.asarray(B, np.float64), \
        np.asarray(C, np.float64)
    for t in range(s):
        decay = np.exp(dtn[:, t] * An)                      # (b,h)
        H = decay[:, :, None, None] * H + np.einsum(
            "bn,bh,bhp->bhnp", Bn[:, t], dtn[:, t], xn[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", Cn[:, t], H)
    return ys


@pytest.mark.parametrize("s,chunk", [(32, 8), (30, 8), (16, 16), (7, 4)])
def test_ssd_chunked_matches_naive_recurrence(key, s, chunk):
    b, h, p, n = 2, 3, 4, 5
    kx, kd, kb, kc = jax.random.split(key, 4)
    x = jax.random.normal(kx, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(kd, (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 9), (h,)) * 0.3)
    B = jax.random.normal(kb, (b, s, n), jnp.float32)
    C = jax.random.normal(kc, (b, s, n), jnp.float32)
    y, Hf = ssm.ssd_chunked(x, dt, A, B, C, chunk)
    y_ref = _ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)


def test_ssm_prefill_then_decode_matches_full_pass(key):
    """Running s tokens chunked (prefill) then one more token recurrently
    equals running s+1 tokens in one pass — the SSD duality contract."""
    cfg = get_smoke_config("mamba2-370m").replace(**F32)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    toks = jax.random.randint(key, (1, 9), 2, cfg.vocab)
    # full pass over all 9 tokens
    logits_all = lm.forward(params, toks, cfg)
    # prefill on 8, decode token 9
    logits_p, cache, lengths = lm.prefill(params, toks[:, :8], cfg,
                                          max_len=16)
    logits_d, _ = lm.decode_step(params, cache, toks[:, 8], lengths, cfg)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_all[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_attention_prefill_then_decode_matches_full_pass(key):
    cfg = get_smoke_config("yi-6b").replace(**F32)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    toks = jax.random.randint(key, (2, 9), 2, cfg.vocab)
    logits_all = lm.forward(params, toks, cfg)
    logits_p, cache, lengths = lm.prefill(params, toks[:, :8], cfg,
                                          max_len=16)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_all[:, 7]),
                               rtol=2e-3, atol=2e-3)
    logits_d, _ = lm.decode_step(params, cache, toks[:, 8], lengths, cfg)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_all[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_hybrid_prefill_then_decode_matches_full_pass(key):
    cfg = get_smoke_config("zamba2-7b").replace(**F32)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    toks = jax.random.randint(key, (1, 9), 2, cfg.vocab)
    logits_all = lm.forward(params, toks, cfg)
    _, cache, lengths = lm.prefill(params, toks[:, :8], cfg, max_len=16)
    logits_d, _ = lm.decode_step(params, cache, toks[:, 8], lengths, cfg)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_all[:, -1]),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def test_moe_matches_dense_reference(key):
    """Capacity-buffer dispatch == direct per-token expert evaluation when
    capacity is not exceeded."""
    cfg = get_smoke_config("moonshot-v1-16b-a3b").replace(
        capacity_factor=8.0, **F32)   # capacity ample -> no drops
    p = P.init_params(key, moe.moe_specs(cfg), jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    out = moe.moe_ffn(x, p, cfg)

    # reference: evaluate every expert densely, weight by renormalized gates
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->besf", x, p["wi"])
    g_, u_ = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(g_) * u_
    y_all = jnp.einsum("besf,efd->besd", act, p["wo"])       # (b,e,s,d)
    onehot = jax.nn.one_hot(eidx, cfg.n_experts)             # (b,s,k,e)
    w = (gates[..., None] * onehot).sum(2)                   # (b,s,e)
    ref_out = jnp.einsum("bse,besd->bsd", w, y_all)
    if cfg.shared_expert:
        ref_out = ref_out + layers.mlp(x, p["shared"], cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens(key):
    """With capacity_factor << 1 overflow tokens are dropped (output 0
    contribution) instead of corrupting other slots."""
    cfg = get_smoke_config("moonshot-v1-16b-a3b").replace(
        capacity_factor=0.01, **F32)
    p = P.init_params(key, moe.moe_specs(cfg), jnp.float32)
    x = jax.random.normal(key, (1, 32, cfg.d_model), jnp.float32)
    out = moe.moe_ffn(x, p, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_load_balancing_loss(key):
    probs = jax.nn.softmax(jax.random.normal(key, (2, 8, 4)), -1)
    _, eidx = jax.lax.top_k(probs, 2)
    lbl = float(moe.load_balancing_loss(probs, eidx, 4))
    assert lbl >= 1.0 - 1e-6     # minimum at perfect balance is 1.0


# ---------------------------------------------------------------------------
# Misc model plumbing
# ---------------------------------------------------------------------------


def test_tied_vs_untied_unembed(key):
    cfg_tied = get_smoke_config("qwen2-0.5b").replace(**F32)
    cfg_untied = cfg_tied.replace(tie_embeddings=False)
    pt = P.init_params(key, lm.lm_param_specs(cfg_tied), jnp.float32)
    pu = P.init_params(key, lm.lm_param_specs(cfg_untied), jnp.float32)
    assert "unembed" not in pt and "unembed" in pu


def test_lm_loss_chunking_matches_direct(key):
    """Sequence-chunked loss == direct full-logits cross-entropy."""
    cfg = get_smoke_config("yi-6b").replace(**F32)
    params = P.init_params(key, lm.lm_param_specs(cfg), jnp.float32)
    toks = jax.random.randint(key, (2, 64), 2, cfg.vocab)
    batch = {"inputs": toks, "labels": jnp.roll(toks, -1, 1)}
    loss = float(lm.lm_loss(params, batch, cfg))
    logits = lm.forward(params, toks, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], -1)
    np.testing.assert_allclose(loss, float(nll.mean()), rtol=1e-5)


def test_sc_mode_flows_through_model(key):
    """paper-sc config routes matmuls through the SC engine: stochastic
    forward (different rng -> different logits), exact mode deterministic."""
    cfg = get_smoke_config("paper-sc").replace(**F32)
    params = P.init_params(key, lm.lm_param_specs(cfg), jnp.float32)
    toks = jax.random.randint(key, (1, 16), 2, cfg.vocab)
    l1 = lm.forward(params, toks, cfg, rng=jax.random.PRNGKey(1))
    l2 = lm.forward(params, toks, cfg, rng=jax.random.PRNGKey(2))
    assert float(jnp.abs(l1 - l2).max()) > 0       # stochastic substrate
    exact = cfg.replace(sc_mode="exact")
    e1 = lm.forward(params, toks, exact, rng=jax.random.PRNGKey(1))
    e2 = lm.forward(params, toks, exact, rng=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    # SC logits stay close to exact logits (moment-matched noise)
    assert float(jnp.abs(l1 - e1).mean()) < 1.0
