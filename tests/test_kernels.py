"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
asserted against the pure-jnp oracles in kernels/ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import sc
from repro.kernels import ref
from repro.kernels.sc_mac import sc_mac_fused
from repro.kernels.sc_mul import NSLICES, sc_mul_bitexact, sc_mul_popcount
from repro.sc.encoding import to_fx16

# ---------------------------------------------------------------------------
# sc_mul: bit-exact against the oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,w,block_m", [
    (8, 1, 8), (8, 4, 8), (16, 8, 8), (32, 2, 16), (8, 32, 4), (64, 4, 32),
])
def test_sc_mul_kernel_matches_ref_exactly(key, m, w, block_m):
    kx, ky, kp = jax.random.split(key, 3)
    px = to_fx16(jax.random.uniform(kp, (m,)))
    py = to_fx16(jax.random.uniform(jax.random.fold_in(kp, 1), (m,)))
    rx = jax.random.bits(kx, (m, NSLICES, w), jnp.uint32)
    ry = jax.random.bits(ky, (m, NSLICES, w), jnp.uint32)
    out_k = sc_mul_popcount(px, py, rx, ry, block_m=block_m, interpret=True)
    out_r = ref.sc_mul_popcount_ref(px, py, rx, ry)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_sc_mul_bias_edges(key):
    """p=0 -> all bits dead; p=1(0xFFFF) -> survival = partner's draw."""
    m, w = 8, 4
    rx = jax.random.bits(key, (m, NSLICES, w), jnp.uint32)
    ry = jax.random.bits(jax.random.fold_in(key, 1), (m, NSLICES, w),
                         jnp.uint32)
    zeros = jnp.zeros((m,), jnp.uint32)
    out = sc_mul_popcount(zeros, to_fx16(jnp.ones(m) * 0.5), rx, ry,
                          block_m=8, interpret=True)
    assert int(jnp.sum(out)) == 0


@given(seed=st.integers(0, 2**16), p1=st.floats(0.05, 0.95),
       p2=st.floats(0.05, 0.95))
@settings(max_examples=20, deadline=None)
def test_sc_mul_bernoulli_bias_is_correct(seed, p1, p2):
    """The Horner-ladder construction yields P(bit=1) = p to fixed-point
    resolution: pop-count fraction ~ p1*p2 within binomial noise."""
    key = jax.random.PRNGKey(seed)
    nbit = 32 * 64          # 2048 cells
    est = sc_mul_bitexact(
        key, jnp.array([p1]), jnp.array([p2]), nbit=nbit, block_m=8)
    sigma = np.sqrt(p1 * p2 * (1 - p1 * p2) / nbit)
    assert abs(float(est[0]) - p1 * p2) < 6 * sigma + 2e-4


def test_sc_mul_wrapper_pads_irregular_batch(key):
    est = sc_mul_bitexact(key, jnp.full((5,), 0.5), jnp.full((5,), 0.5),
                          nbit=256, block_m=8)
    assert est.shape == (5,)


# ---------------------------------------------------------------------------
# sc_mac: fused kernel vs analytic oracle (allclose)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 512, 128, 128, 128, 512),   # single tile
    (256, 1024, 128, 128, 128, 512),  # multi-tile all axes
    (64, 128, 64, 32, 32, 64),        # small blocks, multi-step k
    (8, 16, 8, 8, 8, 16),             # tiny
])
def test_sc_mac_fused_matches_ref(key, m, k, n, bm, bn, bk):
    kx, kw, kn = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (m, k), jnp.float32, -1.0, 1.0)
    w = jax.random.uniform(kw, (k, n), jnp.float32, -1.0, 1.0)
    noise = jax.random.normal(kn, (m, n), jnp.float32)
    out = sc_mac_fused(x, w, noise, nbit=1024, block_m=bm, block_n=bn,
                       block_k=bk, interpret=True)
    expect = ref.sc_mac_ref(x, w, noise, nbit=1024)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sc_mac_fused_dtype_sweep(key, dtype):
    """bf16 operands upcast in the MXU accumulate path (f32 accumulators)."""
    x = jax.random.uniform(key, (32, 64), jnp.float32, -1, 1).astype(dtype)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (64, 32), jnp.float32,
                           -1, 1).astype(dtype)
    noise = jax.random.normal(jax.random.fold_in(key, 2), (32, 32),
                              jnp.float32)
    out = sc_mac_fused(x.astype(jnp.float32), w.astype(jnp.float32), noise,
                       nbit=512, block_m=32, block_n=32, block_k=64,
                       interpret=True)
    expect = ref.sc_mac_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                            noise, nbit=512)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-2)


def test_pallas_moment_backend_irregular_shapes(key):
    """The pallas_moment backend pads to block multiples and un-pads the
    output."""
    x = jax.random.normal(key, (100, 300))
    w = jax.random.normal(jax.random.fold_in(key, 1), (300, 50))
    cfg = sc.ScConfig(backend="pallas_moment", nbit=4096,
                      block_m=64, block_n=64, block_k=128)
    out = sc.sc_dot(jax.random.fold_in(key, 2), x, w, cfg)
    assert out.shape == (100, 50)
    err = np.abs(np.asarray(out) - np.asarray(x @ w))
    scale = np.abs(np.asarray(x @ w)).max()
    assert err.mean() < 0.1 * scale


def test_pallas_moment_statistics_match_array_level(key):
    """Pallas moment kernel and the array-level moment backend draw from
    the same distribution: identical mean (exact product) and matching
    sigma."""
    x = jax.random.normal(key, (16, 128))
    w = jax.random.normal(jax.random.fold_in(key, 1), (128, 16))
    keys = jax.random.split(jax.random.fold_in(key, 2), 64)
    pcfg = sc.ScConfig(backend="pallas_moment", nbit=256,
                       block_m=16, block_n=16, block_k=128)
    mcfg = sc.ScConfig(backend="moment", nbit=256)
    fused = jax.vmap(lambda k_: sc.sc_dot(k_, x, w, pcfg))(keys)
    core = jax.vmap(lambda k_: sc.sc_dot(k_, x, w, mcfg))(keys)
    np.testing.assert_allclose(np.asarray(fused.mean(0)),
                               np.asarray(core.mean(0)), atol=0.5)
    s_f = np.asarray(fused.std(0)).mean()
    s_c = np.asarray(core.std(0)).mean()
    assert 0.7 < s_f / s_c < 1.4


def test_box_muller_produces_standard_normals(key):
    """The in-kernel PRNG epilogue's Box-Muller transform (CPU-checkable
    half of the TPU-only sc_mac_fused_prng path)."""
    from repro.kernels.sc_mac import _box_muller
    ka, kb = jax.random.split(key)
    bits_a = jax.random.bits(ka, (64, 4096), jnp.uint32)
    bits_b = jax.random.bits(kb, (64, 4096), jnp.uint32)
    z = np.asarray(_box_muller(bits_a, bits_b)).ravel()
    assert abs(z.mean()) < 0.02
    assert abs(z.std() - 1.0) < 0.02
    # tail sanity: P(|z|>2) ~ 4.6 %
    assert 0.03 < (np.abs(z) > 2).mean() < 0.06


def test_popcount32_ref_is_correct():
    v = jnp.array([0, 1, 0xFFFFFFFF, 0xAAAAAAAA, 0x12345678], jnp.uint32)
    got = np.asarray(ref.popcount32_ref(v))
    expect = np.array([bin(int(x)).count("1") for x in np.asarray(v)])
    np.testing.assert_array_equal(got, expect)
