"""Fused paged-attention kernel: fused == unfused to float tolerance
(greedy-identical end to end), SC-sampled QK^T pinned to (request,
position) across batch/chunk/block-size/eviction permutations, the
`attn` autotune kind, and the chunk_decode_attention edge cases the
masking predicate must honour."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import paged_attention as pa
from repro.models import attention, lm, params as P
from repro.sc import autotune, ctr_rng
from repro.serve import PagedServeConfig, PagedServingEngine, Request

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _cfg(**kw):
    return get_smoke_config("qwen2-0.5b").replace(**F32, **kw)


def _rand_paged(rng, *, b, sc, h, kvh, hd, bs, nb):
    """Random pool + shuffled block tables (page ids deliberately not
    contiguous, so in-kernel gather is actually exercised)."""
    P_ = b * nb + 2
    k_pages = jnp.asarray(rng.normal(size=(P_, bs, kvh, hd)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(P_, bs, kvh, hd)), jnp.float32)
    bt = jnp.asarray(rng.permutation(P_)[:b * nb].reshape(b, nb), jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, sc, h, hd)), jnp.float32)
    return q, k_pages, v_pages, bt


def _unfused(q, k_pages, v_pages, bt, lengths):
    return attention.chunk_decode_attention(
        q, attention.paged_gather(k_pages, bt),
        attention.paged_gather(v_pages, bt), lengths)


def _token_keys(key, b, sc):
    """(b, sc) independent raw token keys, like decode_paged derives."""
    rk = jax.vmap(jax.random.split, in_axes=(0, None))(
        jax.random.split(key, b), sc)
    return jax.vmap(jax.vmap(ctr_rng.raw_key))(rk)


# ---------------------------------------------------------------------------
# Deterministic fused kernel == unfused reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_size,sc", [(4, 1), (4, 5), (8, 1), (8, 3)])
def test_fused_matches_unfused(block_size, sc):
    """Across >= 2 block sizes, width-1 decode AND chunked prefill: the
    fused kernel reproduces gather + chunk_decode_attention, including
    length-0 rows and fills landing exactly on a block boundary."""
    rng = np.random.default_rng(0)
    b, h, kvh, hd, nb = 4, 4, 2, 8, 4
    q, kp, vp, bt = _rand_paged(rng, b=b, sc=sc, h=h, kvh=kvh, hd=hd,
                                bs=block_size, nb=nb)
    maxlen = block_size * nb - sc
    lengths = jnp.asarray(
        [0, block_size, min(2 * block_size, maxlen), maxlen], jnp.int32)
    ref = _unfused(q, kp, vp, bt, lengths)
    got = pa.paged_attention_fused(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_row_padding_is_inert():
    """block_q larger than the row count pads query rows; padding must
    not leak into real rows."""
    rng = np.random.default_rng(1)
    q, kp, vp, bt = _rand_paged(rng, b=2, sc=1, h=2, kvh=1, hd=8,
                                bs=4, nb=3)
    lengths = jnp.asarray([3, 7], jnp.int32)
    ref = pa.paged_attention_fused(q, kp, vp, bt, lengths, block_q=2)
    got = pa.paged_attention_fused(q, kp, vp, bt, lengths, block_q=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_fused_grouped_heads_match_per_head_reference():
    """GQA row layout: each query head must read ITS kv head's pages."""
    rng = np.random.default_rng(2)
    q, kp, vp, bt = _rand_paged(rng, b=1, sc=2, h=6, kvh=3, hd=8,
                                bs=4, nb=3)
    lengths = jnp.asarray([5], jnp.int32)
    ref = _unfused(q, kp, vp, bt, lengths)
    got = pa.paged_attention_fused(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# chunk_decode_attention edge behaviour (the PR-4 masking off-by-one class)
# ---------------------------------------------------------------------------


def test_chunk_decode_length_zero_is_causal_prefill():
    """lengths == 0 with the whole sequence as one chunk IS causal
    attention: predicate t <= 0 + i."""
    rng = np.random.default_rng(3)
    b, t, h, kvh, hd = 2, 6, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kvh, hd)), jnp.float32)
    got = attention.chunk_decode_attention(
        q, k, v, jnp.zeros((b,), jnp.int32))
    ref = attention.full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_chunk_decode_single_token_cache():
    """A one-slot cache at length 0: the only key is the query's own
    position, so the output is exactly that V row (softmax over one)."""
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, 1, 2, 4)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, 1, 4)), jnp.float32)
    out = attention.chunk_decode_attention(
        q, k, v, jnp.zeros((1,), jnp.int32))
    ref = jnp.broadcast_to(v[:, :, 0][:, :, None], q.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_chunk_decode_mask_boundary_is_inclusive():
    """Row r token i attends positions up to AND INCLUDING lengths[r]+i,
    and nothing past it — checked against a brute-force softmax at fills
    sitting exactly on block boundaries (the off-by-one class)."""
    rng = np.random.default_rng(5)
    b, sc, L, h, hd = 3, 2, 16, 2, 4
    q = jnp.asarray(rng.normal(size=(b, sc, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, L, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, L, h, hd)), jnp.float32)
    lengths = jnp.asarray([0, 4, 8], jnp.int32)   # block-size-4 boundaries
    got = np.asarray(attention.chunk_decode_attention(q, k, v, lengths))
    qn, kn, vn = (np.asarray(x, np.float64) for x in (q, k, v))
    for r in range(b):
        for i in range(sc):
            last = int(lengths[r]) + i            # inclusive
            for hh in range(h):
                lg = kn[r, : last + 1, hh] @ qn[r, i, hh] / np.sqrt(hd)
                w = np.exp(lg - lg.max())
                w /= w.sum()
                ref = w @ vn[r, : last + 1, hh]
                np.testing.assert_allclose(got[r, i, hh], ref,
                                           rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# SC-sampled QK^T: pinned-counter reproducibility
# ---------------------------------------------------------------------------


def test_sc_kernel_matches_host_twin_bitwise():
    """The kernel's pre-mask SC logits for one (row, head) equal the
    host-side twin bit-for-bit — the anchor for every invariance."""
    rng = np.random.default_rng(6)
    b, sc, h, kvh, hd, bs, nb = 2, 3, 4, 2, 8, 4, 4
    q, kp, vp, bt = _rand_paged(rng, b=b, sc=sc, h=h, kvh=kvh, hd=hd,
                                bs=bs, nb=nb)
    keys = _token_keys(jax.random.PRNGKey(7), b, sc)
    keys4 = pa.split_keys4(keys)
    r, i0, head = 1, 2, 3
    kh = head // (h // kvh)
    gathered = attention.paged_gather(kp, bt)
    host = pa.sc_qk_logits_host(
        keys[r, i0], q[r, i0, head], gathered[r, :, kh],
        np.arange(nb * bs), head, h, nbit=128)
    parts = []
    for j in range(nb):
        page = int(bt[r, j])
        t_abs = (jnp.uint32(j * bs)
                 + jax.lax.broadcasted_iota(jnp.uint32, (1, bs, hd), 1))
        d_idx = jax.lax.broadcasted_iota(jnp.uint32, (1, bs, hd), 2)
        c0 = ((t_abs * jnp.uint32(h) + jnp.uint32(head)) * jnp.uint32(hd)
              + d_idx)
        parts.append(np.asarray(pa._sc_logits(
            q[r, i0, head][None], kp[page, :, kh, :], keys4[r, i0][None],
            c0, nbit=128, levels=1 << 10, quantize=True, lane=4)[0]))
    assert np.array_equal(np.concatenate(parts), np.asarray(host))


def test_sc_tiling_never_changes_bits():
    rng = np.random.default_rng(7)
    b, sc = 2, 3
    q, kp, vp, bt = _rand_paged(rng, b=b, sc=sc, h=4, kvh=2, hd=8,
                                bs=4, nb=4)
    keys = _token_keys(jax.random.PRNGKey(8), b, sc)
    L = jnp.asarray([5, 9], jnp.int32)
    a = pa.paged_attention_fused_sc(keys, q, kp, vp, bt, L, nbit=128)
    c = pa.paged_attention_fused_sc(keys, q, kp, vp, bt, L, nbit=128,
                                    block_q=4, lane_words=1)
    assert np.array_equal(np.asarray(a), np.asarray(c))


def test_sc_batch_permutation_invariance():
    """Reordering the batch permutes the outputs bitwise — no token's
    draw depends on its neighbours."""
    rng = np.random.default_rng(8)
    b, sc = 3, 2
    q, kp, vp, bt = _rand_paged(rng, b=b, sc=sc, h=4, kvh=2, hd=8,
                                bs=4, nb=4)
    keys = _token_keys(jax.random.PRNGKey(9), b, sc)
    L = jnp.asarray([0, 5, 9], jnp.int32)
    out = pa.paged_attention_fused_sc(keys, q, kp, vp, bt, L, nbit=128)
    perm = jnp.asarray([2, 0, 1])
    out_p = pa.paged_attention_fused_sc(
        keys[perm], q[perm], kp, vp, bt[perm], L[perm], nbit=128)
    assert np.array_equal(np.asarray(out_p), np.asarray(out)[perm])


def test_sc_chunk_width_invariance():
    """A token's SC attention output is identical whether it decodes in
    a width-2 chunk or as two width-1 ticks (keys ride the token, the
    counter rides the kv position)."""
    rng = np.random.default_rng(9)
    b, sc, h, kvh, hd, bs, nb = 1, 2, 4, 2, 8, 4, 4
    q, kp, vp, bt = _rand_paged(rng, b=b, sc=sc, h=h, kvh=kvh, hd=hd,
                                bs=bs, nb=nb)
    keys = _token_keys(jax.random.PRNGKey(10), b, sc)
    L = jnp.asarray([6], jnp.int32)
    chunk = pa.paged_attention_fused_sc(keys, q, kp, vp, bt, L, nbit=128)
    solo0 = pa.paged_attention_fused_sc(
        keys[:, :1], q[:, :1], kp, vp, bt, L, nbit=128)
    solo1 = pa.paged_attention_fused_sc(
        keys[:, 1:], q[:, 1:], kp, vp, bt, L + 1, nbit=128)
    assert np.array_equal(np.asarray(chunk[:, 0]), np.asarray(solo0[:, 0]))
    assert np.array_equal(np.asarray(chunk[:, 1]), np.asarray(solo1[:, 0]))


def test_sc_block_size_invariance():
    """The same logical cache stored under block sizes 4 and 8 yields
    the same attention (logits are bitwise-pinned; the online-softmax
    accumulation order differs, so outputs compare to float tolerance
    and the argmax — the token the engine would emit — must agree)."""
    rng = np.random.default_rng(10)
    b, sc, h, kvh, hd = 1, 1, 4, 2, 8
    T = 16
    kc = jnp.asarray(rng.normal(size=(b, T, kvh, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, T, kvh, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, sc, h, hd)), jnp.float32)
    keys = _token_keys(jax.random.PRNGKey(11), b, sc)
    L = jnp.asarray([11], jnp.int32)
    outs = []
    for bs in (4, 8):
        nb = T // bs
        kp = kc.reshape(nb, bs, kvh, hd)
        vp = vc.reshape(nb, bs, kvh, hd)
        # identity table but through a shuffled pool
        perm = np.asarray([2, 0, 3, 1][:nb])
        inv = np.argsort(perm)
        bt = jnp.asarray(inv[None], jnp.int32)
        outs.append(np.asarray(pa.paged_attention_fused_sc(
            keys, q, kp[perm], vp[perm], bt, L, nbit=128)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)
    assert np.argmax(outs[0]) == np.argmax(outs[1])


# ---------------------------------------------------------------------------
# Autotune `attn` kernel kind
# ---------------------------------------------------------------------------


def test_attn_cache_key_is_disjoint_from_matmul_keys():
    ak = autotune.attn_cache_key(8, 16, 64, 1024)
    assert ak.startswith("attn|")
    assert ak != autotune.cache_key(8, 16, 64, 1024)
    assert autotune.attn_cache_key(8, 16, 64, 0) != ak


def test_attn_tile_cache_hit_miss_and_malformed():
    stored = autotune.AttnTile(block_q=4, lane_words=8)
    entry = dict(stored.kwargs(), wall_us=1.0)
    cache = {autotune.attn_cache_key(8, 16, 64, 1024): entry}
    assert autotune.get_attn_tile(8, 16, 64, 1024, cache=cache) == stored
    # miss -> heuristic
    assert autotune.get_attn_tile(8, 16, 64, 512, cache=cache) == \
        autotune.heuristic_attn_tile(8, 16, 64, 512)
    # malformed / non-positive entries -> heuristic, not a crash
    bad = {autotune.attn_cache_key(8, 16, 64, 1024): {"block_q": "huge"}}
    assert autotune.get_attn_tile(8, 16, 64, 1024, cache=bad) == \
        autotune.heuristic_attn_tile(8, 16, 64, 1024)
    zero = {autotune.attn_cache_key(8, 16, 64, 1024):
            {"block_q": 0, "lane_words": 16}}
    assert autotune.get_attn_tile(8, 16, 64, 1024, cache=zero) == \
        autotune.heuristic_attn_tile(8, 16, 64, 1024)


def test_attn_heuristic_respects_vmem_cap_and_det_mode():
    det = autotune.heuristic_attn_tile(8, 16, 64, 0)
    assert det.lane_words == 1          # deterministic: no rng words
    big = autotune.heuristic_attn_tile(64, 64, 128, 4096)
    assert (big.block_q * 64 * 128 * big.lane_words
            <= autotune._MAX_TILE_WORDS)
    assert big.block_q >= 1 and big.lane_words >= 1
    for t in autotune.candidate_attn_tiles(8, 16, 64, 1024):
        assert t.block_q * 16 * 64 * t.lane_words <= \
            autotune._MAX_TILE_WORDS


def test_attn_cache_roundtrips_through_disk(tmp_path):
    path = str(tmp_path / "cache.json")
    stored = autotune.AttnTile(block_q=16, lane_words=4)
    autotune.save_cache(
        {autotune.attn_cache_key(6, 4, 16, 128): stored.kwargs()}, path)
    old = os.environ.get("REPRO_SC_AUTOTUNE_CACHE")
    os.environ["REPRO_SC_AUTOTUNE_CACHE"] = path
    autotune.reset_cache()
    try:
        assert autotune.get_attn_tile(6, 4, 16, 128) == stored
    finally:
        if old is None:
            os.environ.pop("REPRO_SC_AUTOTUNE_CACHE")
        else:
            os.environ["REPRO_SC_AUTOTUNE_CACHE"] = old
        autotune.reset_cache()


# ---------------------------------------------------------------------------
# Model / engine integration
# ---------------------------------------------------------------------------


def _decode_paged_once(cfg, key):
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    prompt = jnp.asarray([[5, 9, 17, 3, 8]], jnp.int32)
    _, cache, lengths = lm.prefill(params, prompt, cfg, max_len=32)
    tok = jnp.asarray([[7]], jnp.int32)
    bs = 4
    nb = 32 // bs
    pages = lm.init_paged_cache(cfg, nb + 2, bs)
    bt = jnp.asarray([[1 + i for i in range(nb)]], jnp.int32)

    def put(pool, full):
        def one(pg, fl):
            return attention.paged_scatter(
                pg, bt, fl[:, :5], jnp.zeros((1,), jnp.int32),
                jnp.asarray([5], jnp.int32))
        return jax.vmap(one)(pool, full)

    pages = {"k": put(pages["k"], cache["k"]),
             "v": put(pages["v"], cache["v"])}
    logits, _ = lm.decode_paged(params, pages, bt, tok, lengths,
                                jnp.ones((1,), jnp.int32), cfg)
    return logits


def test_decode_paged_fused_matches_unfused(key):
    ref = _decode_paged_once(_cfg(), key)
    got = _decode_paged_once(_cfg(paged_attn="fused"), key)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert np.argmax(got) == np.argmax(ref)


def test_decode_paged_rejects_unknown_mode_and_keyless_fused_sc(key):
    cfg = _cfg(paged_attn="fused_sc", sc_nbit=64)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    pages = lm.init_paged_cache(cfg, 4, 4)
    bt = jnp.asarray([[1, 2]], jnp.int32)
    args = (params, pages, bt, jnp.asarray([[3]], jnp.int32),
            jnp.zeros((1,), jnp.int32), jnp.ones((1,), jnp.int32))
    with pytest.raises(ValueError, match="fused_sc"):
        lm.decode_paged(*args, _cfg(paged_attn="fused_sc", sc_nbit=64))
    with pytest.raises(ValueError, match="paged_attn"):
        lm.decode_paged(*args, _cfg(paged_attn="bogus"),
                        rng=jnp.zeros((1, 2), jnp.uint32))


def _run_paged(params, cfg, reqs, *, slots, seed=7, num_blocks=0,
               submit_after=None, **kw):
    defaults = dict(slots=slots, max_len=32, block_size=4,
                    prefill_chunk=3, seed=seed, num_blocks=num_blocks)
    defaults.update(kw)
    eng = PagedServingEngine(params, cfg, PagedServeConfig(**defaults))
    late = dict(submit_after or {})
    for r in reqs:
        eng.submit(r)
    ticks = 0
    while eng.scheduler.has_work() or late:
        for t in [t for t in sorted(late) if ticks >= t]:
            eng.submit(late.pop(t))
        eng.step()
        ticks += 1
        assert ticks < 500
    return eng, {r.rid: r.generated for r in eng.finished}


REQ0 = dict(rid=0, prompt=[5, 9, 17, 3], max_new_tokens=5, temperature=0.8)
REQ1 = dict(rid=1, prompt=[40, 2, 8, 30, 7, 11], max_new_tokens=5,
            temperature=0.0)


def test_engine_fused_greedy_matches_unfused(key):
    """The serve engine with paged_attn='fused' emits the same greedy
    tokens as the reference path — the end-to-end equivalence."""
    cfg = _cfg()
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    reqs = lambda: [Request(**REQ1),
                    Request(rid=2, prompt=[12, 33, 7], max_new_tokens=4,
                            temperature=0.0)]
    _, ref = _run_paged(params, cfg, reqs(), slots=2)
    _, got = _run_paged(params, cfg.replace(paged_attn="fused"), reqs(),
                        slots=2)
    assert got == ref


def test_engine_records_decode_latency(key):
    cfg = _cfg(paged_attn="fused")
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    eng, _ = _run_paged(params, cfg, [Request(**REQ1)], slots=1)
    # max_new=5 -> 4 decode ticks; the jit tick drops, 3 land in the
    # histogram the latency view reads from
    assert eng.metrics.histogram("serve_decode_ms_per_token").count() >= 2, \
        "decode ticks must be timed"
    lat = eng.decode_latency_ms()
    assert set(lat) == {"decode_p50_ms", "decode_p95_ms"}
    assert 0 < lat["decode_p50_ms"] <= lat["decode_p95_ms"] * (1 + 1e-9)
    fresh = PagedServingEngine(params, cfg, PagedServeConfig(
        slots=1, max_len=32, block_size=4, prefill_chunk=3))
    assert fresh.decode_latency_ms() is None


def test_engine_fused_sc_batch_composition_invariance(key):
    """paged_attn='fused_sc' rides the paged==contiguous rng contract:
    same request + same key => same tokens served alone, batched, or
    admitted mid-stream — even though attention logits are stochastic."""
    cfg = _cfg(paged_attn="fused_sc", sc_nbit=64)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    _, solo = _run_paged(params, cfg, [Request(**REQ0)], slots=1)
    _, full = _run_paged(params, cfg,
                         [Request(**REQ0), Request(**REQ1)], slots=2)
    _, mid = _run_paged(params, cfg, [Request(**REQ1)], slots=2,
                        submit_after={2: Request(**REQ0)})
    assert solo[0] == full[0] == mid[0]


def test_engine_fused_sc_eviction_resume_reproduces_tokens(key):
    """A forced eviction + re-prefill reproduces the roomy-pool tokens
    under fused_sc: the attention draw is pinned to (request, position),
    so recomputed K/V land on identical stochastic logits."""
    cfg = _cfg(paged_attn="fused_sc", sc_nbit=64)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    # 8 + 12 = 20 tokens/seq = 5 blocks each; the 8-usable-block pool
    # cannot hold both, so one sequence must evict and resume.
    mk = lambda: [
        Request(rid=0, prompt=[5, 9, 17, 3, 8, 2, 30, 11],
                max_new_tokens=12, temperature=0.6),
        Request(rid=1, prompt=[40, 2, 8, 30, 7, 11, 2, 4],
                max_new_tokens=12, temperature=0.6)]
    roomy_e, roomy = _run_paged(params, cfg, mk(), slots=2, max_len=32,
                                prefill_chunk=4)
    tight_e, tight = _run_paged(params, cfg, mk(), slots=2, max_len=32,
                                prefill_chunk=4, num_blocks=9)
    assert tight_e.evictions > 0, "pool was meant to force an eviction"
    assert roomy_e.evictions == 0
    assert roomy == tight
