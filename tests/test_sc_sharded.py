"""Mesh-sharded SC substrate: rules resolution, shard accounting, and the
single-device degradations. Multi-device equivalence (8 simulated CPU
devices) runs in a subprocess so this process keeps the single real CPU
device (tests/_sharded_subprocess.py)."""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import arch, sc
from repro.arch.accounting import merge_concurrent_reports, merge_reports
from repro.sharding import sc_shard_rules

KEY = jax.random.PRNGKey(0)
X = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
W = jax.random.normal(jax.random.PRNGKey(2), (8, 5))


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# Rules resolution
# ---------------------------------------------------------------------------


def test_resolve_rules_drops_size_one_axes():
    r = sc.resolve_rules(_mesh11(), m=4, k=8)
    assert r.batch == () and r.contract == ()


def test_resolve_rules_drops_absent_axes():
    mesh = _mesh11()
    r = sc.resolve_rules(mesh, m=4, k=8,
                         rules=sc.ScShardRules(batch=("nope",),
                                               contract=("missing",)))
    assert r.batch == () and r.contract == ()


def test_shard_counts_trivial_mesh():
    assert sc.shard_counts(_mesh11(), 4, 8) == (1, 1)


def test_sc_shard_rules_adapts_to_mesh():
    rules = sc_shard_rules(_mesh11())
    assert rules.batch == ("data",)        # pod absent, dropped
    assert rules.contract == ("model",)


# ---------------------------------------------------------------------------
# Trivial-mesh equivalence: no live axis => exactly sc_dot, same bits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["exact", "moment", "bitexact"])
def test_trivial_mesh_identical_bits(backend):
    cfg = sc.ScConfig(backend=backend, nbit=256)
    y_ref = sc.sc_dot(KEY, X, W, cfg)
    y_sh = sc.sc_dot_sharded(KEY, X, W, cfg, mesh=_mesh11())
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_sh))


def test_leading_dims_flatten_like_sc_dot():
    x3 = X.reshape(2, 2, 8)
    cfg = sc.ScConfig(backend="moment", nbit=1024)
    y_ref = sc.sc_dot(KEY, x3, W, cfg)
    y_sh = sc.sc_dot_sharded(KEY, x3, W, cfg, mesh=_mesh11())
    assert y_sh.shape == (2, 2, 5)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_sh))


# ---------------------------------------------------------------------------
# Concurrent-shard accounting
# ---------------------------------------------------------------------------


def _report(m=32, k=256, n=64, nbit=1024):
    return arch.schedule_call(m, k, n, nbit).report


def test_merge_concurrent_empty():
    r = merge_concurrent_reports([])
    assert r.cycles == 0 and r.products == 0


def test_merge_concurrent_identical_shards():
    one = _report()
    merged = merge_concurrent_reports([one] * 8)
    assert merged.cycles == one.cycles              # makespan: slowest shard
    assert merged.products == 8 * one.products      # work adds
    assert merged.energy_pj == pytest.approx(8 * one.energy_pj)
    assert merged.subarray_util == pytest.approx(one.subarray_util)
    assert merged.cell_occupancy == pytest.approx(one.cell_occupancy)


def test_merge_concurrent_uneven_shards_idle_tail():
    fast, slow = _report(m=8), _report(m=64)
    merged = merge_concurrent_reports([fast, slow])
    assert merged.cycles == max(fast.cycles, slow.cycles)
    # the fast shard idles while the slow one finishes => combined
    # utilization below the slow shard's own
    assert merged.subarray_util < slow.subarray_util + 1e-12


def test_serial_vs_concurrent_merge():
    one = _report()
    serial = merge_reports([one] * 4)
    conc = merge_concurrent_reports([one] * 4)
    assert serial.cycles == 4 * conc.cycles
    assert serial.energy_pj == pytest.approx(conc.energy_pj)
    assert serial.products == conc.products


def test_callrecord_shard_stamp_and_effective_report():
    cfg = sc.ScConfig(backend="array", nbit=1024)
    with sc.shard_scope(4), arch.collect() as recs:
        sc.sc_dot(KEY, X, W, cfg)
    (rec,) = recs
    assert rec.shards == 4
    eff = rec.effective_report
    assert eff.products == 4 * rec.report.products
    assert eff.cycles == rec.report.cycles
    # collectors aggregate the effective (concurrency-aware) reports
    agg = arch.summarize(recs)["aggregate"]
    assert agg["products"] == eff.products
    assert rec.as_dict()["shards"] == 4


# ---------------------------------------------------------------------------
# Sharded workload pricing
# ---------------------------------------------------------------------------


def test_shard_site_ceil_division():
    s = arch.MatmulSite("mlp.wi", m=10, k=30, n=7, count=2)
    piece = arch.shard_site(s, data=4, model=8)
    assert (piece.m, piece.k, piece.n) == (3, 4, 7)
    assert piece.count == 2


def test_price_workload_sharded_degenerate_matches_unsharded():
    sites = [arch.MatmulSite("a", 32, 256, 64, 2)]
    _, t1 = arch.price_workload(sites, nbit=1024)
    _, t2 = arch.price_workload_sharded(sites, nbit=1024, data=1, model=1)
    assert t1 == t2


def test_price_workload_sharded_makespan_strictly_less():
    sites = [arch.MatmulSite("a", 32, 256, 64, 2)]
    _, t1 = arch.price_workload(sites, nbit=1024)
    _, t8 = arch.price_workload_sharded(sites, nbit=1024, data=2, model=4)
    assert t8.cycles < t1.cycles
    assert t8.products == t1.products
    assert t8.energy_pj == pytest.approx(t1.energy_pj)


# ---------------------------------------------------------------------------
# Multi-device equivalence (simulated 8-device mesh, subprocess)
# ---------------------------------------------------------------------------


def test_multidevice_sharded_equivalence():
    """Numerics + grads + arch overlap + serve engine on a forced
    8-device host platform (see tests/_sharded_subprocess.py)."""
    script = os.path.join(os.path.dirname(__file__),
                          "_sharded_subprocess.py")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(__file__), "..", "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL-SHARDED-OK" in proc.stdout
