"""Checkpointing (atomicity, elastic restore) + fault-tolerance supervisor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_smoke_config
from repro.data import SyntheticLMData, make_batch
from repro.ft import FaultInjector, StragglerMonitor, Supervisor, WorkerFailure
from repro.train import TrainConfig, make_train_step
from repro.train.step import train_state_init

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _tree(key):
    return {"a": jax.random.normal(key, (4, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.25)}}


def test_save_restore_roundtrip(tmp_path, key):
    tree = _tree(key)
    checkpoint.save(str(tmp_path), 7, tree, extra={"data_step": 7})
    restored, extra, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 7 and extra["data_step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_latest_step_ignores_tmp_and_partial(tmp_path, key):
    tree = _tree(key)
    checkpoint.save(str(tmp_path), 3, tree)
    checkpoint.save(str(tmp_path), 9, tree)
    # a crashed mid-save leaves a .tmp dir -> must be ignored
    os.makedirs(tmp_path / "step_00000012.tmp")
    # a dir without META.json (interrupted rename) -> ignored
    os.makedirs(tmp_path / "step_00000011")
    assert checkpoint.latest_step(str(tmp_path)) == 9


def test_save_overwrites_same_step(tmp_path, key):
    t1 = _tree(key)
    t2 = jax.tree.map(lambda v: v + 1, t1)
    checkpoint.save(str(tmp_path), 1, t1)
    checkpoint.save(str(tmp_path), 1, t2)
    restored, _, _ = checkpoint.restore(str(tmp_path), t1)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(t2["a"]))


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path / "nope"), {"a": jnp.zeros(1)})


def test_restore_resharded_on_local_mesh(tmp_path, key):
    """Elastic restore: device_put with shardings from the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jax.random.normal(key, (8, 8))}
    checkpoint.save(str(tmp_path), 2, tree)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _, step = checkpoint.restore_resharded(str(tmp_path), tree, sh)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# Supervisor
# ---------------------------------------------------------------------------


def _train_setup(tmp_path, ckpt_every=5):
    cfg = get_smoke_config("qwen2-0.5b").replace(**F32)
    tcfg = TrainConfig()
    state = train_state_init(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=4)
    return state, step, (lambda i: make_batch(data, i)), str(tmp_path)


def test_supervisor_recovers_from_injected_failure(tmp_path):
    """Crash at step 12 -> restore from step-10 checkpoint -> final state is
    IDENTICAL to an uninterrupted run (deterministic data + step replay)."""
    n = 16
    state0, step, batch_fn, ckpt_a = _train_setup(tmp_path / "a")
    sup_clean = Supervisor(ckpt_dir=ckpt_a, ckpt_every=5)
    clean_state, clean_hist = sup_clean.run(state0, step, n,
                                            make_batch=batch_fn)

    state0b, _, _, ckpt_b = _train_setup(tmp_path / "b")
    injector = FaultInjector(fail_at_steps=(12,))
    sup_fail = Supervisor(ckpt_dir=ckpt_b, ckpt_every=5, injector=injector)
    failed_state, hist = sup_fail.run(state0b, step, n, make_batch=batch_fn)

    assert len(hist["recoveries"]) == 1
    assert hist["recoveries"][0][0] == 10     # resumed from step-10 ckpt
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        clean_state["params"], failed_state["params"])


def test_supervisor_restart_before_first_checkpoint(tmp_path):
    state, step, batch_fn, ckpt = _train_setup(tmp_path)
    injector = FaultInjector(fail_at_steps=(2,))
    sup = Supervisor(ckpt_dir=ckpt, ckpt_every=100, injector=injector)
    final, hist = sup.run(state, step, 5, make_batch=batch_fn)
    assert hist["recoveries"] == [(2, 0)]     # restarted from scratch
    assert len(hist["loss"]) >= 5


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    state, step, batch_fn, ckpt = _train_setup(tmp_path)

    class AlwaysFail(FaultInjector):
        def check(self, step):
            raise WorkerFailure("flaky node")

    sup = Supervisor(ckpt_dir=ckpt, ckpt_every=5, injector=AlwaysFail(),
                     max_restarts=3)
    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run(state, step, 10, make_batch=batch_fn)


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=3.0, ema_decay=0.5)
    assert not mon.observe(0, 1.0)      # first step builds the EMA
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 10.0)         # 10x the EMA -> straggler
    assert mon.events[0][0] == 2
    assert not mon.observe(3, 1.0)


def test_heartbeat_staleness():
    sup = Supervisor(ckpt_dir="/tmp/x", heartbeat_timeout_s=1e9)
    sup.heartbeat()
    assert not sup.heartbeat_stale()
    sup.heartbeat_timeout_s = 0.0
    assert sup.heartbeat_stale()


# ---------------------------------------------------------------------------
# Serve-fleet supervision: degraded-shard drain + resume (PR-10)
# ---------------------------------------------------------------------------

from repro.ft import (ChaosMonkey, EngineHealth, FleetSupervisor,  # noqa: E402
                      HealthMonitor)
from repro.models import lm, params as P  # noqa: E402
from repro.serve import Request, ServeOptions, build_engine  # noqa: E402

_OPTS = ServeOptions(paged=True, slots=2, max_len=48, block_size=4,
                     prefill_chunk=3, seed=0)


@pytest.fixture(scope="module")
def serve_setup():
    cfg = get_smoke_config("qwen2-0.5b").replace(**F32)
    params = P.init_params(jax.random.PRNGKey(1), lm.lm_param_specs(cfg),
                           cfg.param_dtype)
    return params, cfg


def _fleet(params, cfg, **kw):
    kw.setdefault("shards", 2)
    return FleetSupervisor(lambda s: build_engine(params, cfg, _OPTS), **kw)


def _reqs(rid0, n=2, max_new=3):
    return [Request(rid=rid0 + j, prompt=[3 + j, 9, 17, 3, 11, 5],
                    max_new_tokens=max_new,
                    temperature=0.8 if j % 2 else 0.0)
            for j in range(n)]


def test_windowed_monitor_judges_deltas_not_lifetime():
    """Readmission depends on windowed verdicts: counters are monotonic,
    so a lifetime monitor would blacklist a once-degraded shard forever."""
    win = HealthMonitor(window=True)
    assert win.observe(EngineHealth(ticks=2, errors=1, error_rate=0.5))
    # same lifetime errors, more ticks: the DELTA is clean -> healthy
    assert not win.observe(EngineHealth(ticks=6, errors=1, error_rate=1 / 6))
    life = HealthMonitor()
    assert life.observe(EngineHealth(ticks=6, errors=1, error_rate=1 / 6))


def test_fleet_degrade_drain_resume_readmit_cycle(serve_setup):
    params, cfg = serve_setup
    fleet = _fleet(params, cfg, cooldown=2)
    for r in _reqs(0, n=4, max_new=4):
        fleet.submit(r)
    fleet.step()
    ckpts = fleet.degrade(1)
    assert not fleet.healthy[1] and fleet.drains == 1
    assert len(ckpts) == 2                      # round-robin put 2 on shard 1
    assert not fleet.engines[1].scheduler.has_work()   # drained empty
    assert fleet.resumed == len(ckpts)          # all re-homed on shard 0
    assert fleet.metrics.value("ft_shard_drains_total", shard="1") == 1
    assert fleet.metrics.value("ft_requests_resumed_total", shard="0") \
        == len(ckpts)
    # idempotent per incident: a second degrade is a no-op
    assert fleet.degrade(1) == [] and fleet.drains == 1
    # cooldown polls readmit the shard; the windowed monitor then judges
    # it on post-readmission deltas only
    fleet.poll()
    fleet.poll()
    assert fleet.healthy[1] and fleet.readmissions == 1
    assert fleet.metrics.value("ft_shard_readmissions_total", shard="1") == 1
    done = fleet.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    assert all(len(r.generated) == 4 for r in done)


def test_degrade_with_no_healthy_target_raises(serve_setup):
    params, cfg = serve_setup
    fleet = _fleet(params, cfg)
    fleet.degrade(0)
    with pytest.raises(RuntimeError, match="no healthy shard"):
        fleet.degrade(1)


def test_stale_heartbeat_drains_the_silent_shard(serve_setup):
    params, cfg = serve_setup
    fleet = _fleet(params, cfg)
    for r in _reqs(10, n=2):
        fleet.submit(r)
    fleet.last_heartbeat[1] = -1e18             # shard 1 went silent
    fleet.poll()
    assert not fleet.healthy[1] and fleet.healthy[0]
    assert fleet.drains == 1
    done = fleet.run_until_drained()
    assert sorted(r.rid for r in done) == [10, 11]


def test_chaos_telemetry_drives_the_drain(serve_setup):
    """ChaosMonkey bumps serve_errors_total — exactly what a crash loop
    emits — and the windowed monitor turns it into a drain on the next
    poll, with zero client-visible failures."""
    params, cfg = serve_setup
    fleet = _fleet(params, cfg,
                   chaos=ChaosMonkey(at_tick=2, shard=1, errors=2))
    for r in _reqs(20, n=4, max_new=4):
        fleet.submit(r)
    done = fleet.run_until_drained()
    assert fleet.drains == 1 and fleet.resumed >= 1
    assert sorted(r.rid for r in done) == [20, 21, 22, 23]


def test_warm_resume_restores_kv_instead_of_reprefilling(serve_setup):
    """A drained mid-flight request resumes WARM on a fresh engine: the
    KV payload scatters into the pool, the target never re-feeds the
    prompt, and the tokens match an uninterrupted run exactly."""
    params, cfg = serve_setup
    req = Request(rid=0, prompt=[5, 9, 17, 3, 11, 5], max_new_tokens=6)
    ref_eng = build_engine(params, cfg, _OPTS)
    ref_eng.submit(Request(rid=0, prompt=list(req.prompt),
                           max_new_tokens=6))
    ref = ref_eng.run_until_drained()[0].generated

    src = build_engine(params, cfg, _OPTS)
    src.submit(req)
    for _ in range(4):                          # past prefill, mid-decode
        src.step()
    ckpts = src.drain()
    assert len(ckpts) == 1 and ckpts[0]["kv"] is not None
    assert ckpts[0]["fed"] > 0

    dst = build_engine(params, cfg, _OPTS)
    assert dst.restore(ckpts[0]) is True        # warm path taken
    done = dst.run_until_drained()
    assert done[0].generated == ref
    # warm resume never re-prefills the prompt: the only prefill-counted
    # tokens on the target are the pending tail, strictly fewer than the
    # prompt itself
    refed = dst.metrics.value("serve_prefill_tokens_total") or 0
    assert refed < len(req.prompt)


def test_cold_resume_from_waiting_queue_recomputes(serve_setup):
    """Requests drained from the waiting queue (never admitted) resume
    cold — a plain re-submit, same tokens by the rng contract."""
    params, cfg = serve_setup
    src = build_engine(params, cfg, _OPTS.replace(slots=1))
    reqs = _reqs(30, n=3, max_new=3)
    for r in reqs:
        src.submit(r)
    src.step()                                  # admits rid 30 only
    ckpts = src.drain()
    assert len(ckpts) == 3
    assert sum(c["kv"] is not None for c in ckpts) == 1     # only the row
    dst = build_engine(params, cfg, _OPTS.replace(slots=1))
    warm = [dst.restore(c) for c in ckpts]
    assert warm.count(True) == 1
    done = dst.run_until_drained()
    ref_eng = build_engine(params, cfg, _OPTS.replace(slots=1))
    for r in _reqs(30, n=3, max_new=3):
        ref_eng.submit(r)
    ref = {r.rid: r.generated for r in ref_eng.run_until_drained()}
    assert {r.rid: r.generated for r in done} == ref


@pytest.mark.slow
def test_chaos_sweep_token_identity_50_seeds(serve_setup):
    """50 deterministic chaos episodes — injection tick and victim shard
    vary per seed — against an unfaulted reference fleet sharing the
    engine seed.  Every request finishes and every token (greedy AND
    sampled rows) matches the reference bit-for-bit: drain/resume is
    invisible to clients.  Engines are reused across episodes (fresh
    rids), so the sweep pays jit compilation once."""
    params, cfg = serve_setup
    fleet = _fleet(params, cfg, cooldown=2)
    ref = _fleet(params, cfg)
    for ep in range(50):
        while not all(fleet.healthy):      # supervisor idles between
            fleet.poll()                   # incidents; cooldowns elapse
        for r in _reqs(100 + ep * 10, n=2, max_new=3):
            fleet.submit(r)
        for r in _reqs(100 + ep * 10, n=2, max_new=3):
            ref.submit(r)
        fleet.chaos = ChaosMonkey(at_tick=fleet.ticks + 1 + ep % 3,
                                  shard=ep % 2, errors=2)
        fleet.run_until_drained()
        ref.run_until_drained()
    got = {r.rid: r.generated for r in fleet.finished}
    want = {r.rid: r.generated for r in ref.finished}
    assert len(got) == 100                       # nothing lost, ever
    assert got == want                           # token identity
    assert fleet.drains >= 40                    # the sweep really chaosed
    assert fleet.resumed >= 10
