"""Checkpointing (atomicity, elastic restore) + fault-tolerance supervisor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_smoke_config
from repro.data import SyntheticLMData, make_batch
from repro.ft import FaultInjector, StragglerMonitor, Supervisor, WorkerFailure
from repro.train import TrainConfig, make_train_step
from repro.train.step import train_state_init

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _tree(key):
    return {"a": jax.random.normal(key, (4, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.25)}}


def test_save_restore_roundtrip(tmp_path, key):
    tree = _tree(key)
    checkpoint.save(str(tmp_path), 7, tree, extra={"data_step": 7})
    restored, extra, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 7 and extra["data_step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_latest_step_ignores_tmp_and_partial(tmp_path, key):
    tree = _tree(key)
    checkpoint.save(str(tmp_path), 3, tree)
    checkpoint.save(str(tmp_path), 9, tree)
    # a crashed mid-save leaves a .tmp dir -> must be ignored
    os.makedirs(tmp_path / "step_00000012.tmp")
    # a dir without META.json (interrupted rename) -> ignored
    os.makedirs(tmp_path / "step_00000011")
    assert checkpoint.latest_step(str(tmp_path)) == 9


def test_save_overwrites_same_step(tmp_path, key):
    t1 = _tree(key)
    t2 = jax.tree.map(lambda v: v + 1, t1)
    checkpoint.save(str(tmp_path), 1, t1)
    checkpoint.save(str(tmp_path), 1, t2)
    restored, _, _ = checkpoint.restore(str(tmp_path), t1)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(t2["a"]))


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path / "nope"), {"a": jnp.zeros(1)})


def test_restore_resharded_on_local_mesh(tmp_path, key):
    """Elastic restore: device_put with shardings from the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jax.random.normal(key, (8, 8))}
    checkpoint.save(str(tmp_path), 2, tree)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _, step = checkpoint.restore_resharded(str(tmp_path), tree, sh)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# Supervisor
# ---------------------------------------------------------------------------


def _train_setup(tmp_path, ckpt_every=5):
    cfg = get_smoke_config("qwen2-0.5b").replace(**F32)
    tcfg = TrainConfig()
    state = train_state_init(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=4)
    return state, step, (lambda i: make_batch(data, i)), str(tmp_path)


def test_supervisor_recovers_from_injected_failure(tmp_path):
    """Crash at step 12 -> restore from step-10 checkpoint -> final state is
    IDENTICAL to an uninterrupted run (deterministic data + step replay)."""
    n = 16
    state0, step, batch_fn, ckpt_a = _train_setup(tmp_path / "a")
    sup_clean = Supervisor(ckpt_dir=ckpt_a, ckpt_every=5)
    clean_state, clean_hist = sup_clean.run(state0, step, n,
                                            make_batch=batch_fn)

    state0b, _, _, ckpt_b = _train_setup(tmp_path / "b")
    injector = FaultInjector(fail_at_steps=(12,))
    sup_fail = Supervisor(ckpt_dir=ckpt_b, ckpt_every=5, injector=injector)
    failed_state, hist = sup_fail.run(state0b, step, n, make_batch=batch_fn)

    assert len(hist["recoveries"]) == 1
    assert hist["recoveries"][0][0] == 10     # resumed from step-10 ckpt
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        clean_state["params"], failed_state["params"])


def test_supervisor_restart_before_first_checkpoint(tmp_path):
    state, step, batch_fn, ckpt = _train_setup(tmp_path)
    injector = FaultInjector(fail_at_steps=(2,))
    sup = Supervisor(ckpt_dir=ckpt, ckpt_every=100, injector=injector)
    final, hist = sup.run(state, step, 5, make_batch=batch_fn)
    assert hist["recoveries"] == [(2, 0)]     # restarted from scratch
    assert len(hist["loss"]) >= 5


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    state, step, batch_fn, ckpt = _train_setup(tmp_path)

    class AlwaysFail(FaultInjector):
        def check(self, step):
            raise WorkerFailure("flaky node")

    sup = Supervisor(ckpt_dir=ckpt, ckpt_every=5, injector=AlwaysFail(),
                     max_restarts=3)
    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run(state, step, 10, make_batch=batch_fn)


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=3.0, ema_decay=0.5)
    assert not mon.observe(0, 1.0)      # first step builds the EMA
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 10.0)         # 10x the EMA -> straggler
    assert mon.events[0][0] == 2
    assert not mon.observe(3, 1.0)


def test_heartbeat_staleness():
    sup = Supervisor(ckpt_dir="/tmp/x", heartbeat_timeout_s=1e9)
    sup.heartbeat()
    assert not sup.heartbeat_stale()
    sup.heartbeat_timeout_s = 0.0
    assert sup.heartbeat_stale()
