"""Block-level prefix cache: hash-chain lookup, refcounted sharing,
copy-on-write, LRU eviction — and the property harness driving random
submit/feed/release/evict interleavings against the bookkeeping
invariants (``PagedKVCache.check_invariants``).

The harness has two entry points sharing one op driver:

* a hypothesis ``@given`` test (via ``_hypothesis_compat`` — skips
  cleanly when hypothesis is absent), and
* a deterministic seeded sweep (plain pytest, 200+ interleavings) so the
  invariants are exercised in every environment, dev extras or not.
"""

import random

import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_smoke_config
from repro.models import lm, params as P
from repro.serve import (PagedCacheConfig, PagedServeConfig,
                         PagedServingEngine, PagedKVCache, Request)
from repro.serve.kv_cache import _chain_hash

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _cfg(**kw):
    return get_smoke_config("qwen2-0.5b").replace(**F32, **kw)


def _params(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    return P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)


def _kv(num_blocks=9, block_size=2, max_len=16, cache=True):
    return PagedKVCache(
        PagedCacheConfig(num_blocks=num_blocks, block_size=block_size,
                         max_len=max_len),
        enable_prefix_cache=cache)


# ---------------------------------------------------------------------------
# Chain hash + lookup unit behavior
# ---------------------------------------------------------------------------


def test_chain_hash_is_prefix_addressed():
    h1 = _chain_hash(None, [5, 9])
    assert h1 == _chain_hash(None, [5, 9])
    assert h1 != _chain_hash(None, [5, 10])
    # the chain binds the WHOLE prefix: same block tokens under different
    # parents hash differently
    assert _chain_hash(h1, [7, 7]) != _chain_hash(None, [7, 7])
    # and token boundaries can't alias across values ([1,23] vs [12,3])
    assert _chain_hash(None, [1, 23]) != _chain_hash(None, [12, 3])


def test_adopt_prefix_hits_full_blocks_only():
    kv = _kv()
    kv.ensure(0, 5)                       # 3 blocks, last one partial
    kv.note_filled(0, [5, 9, 17, 3, 8], 5)
    assert len(kv.hash_to_block) == 2     # only the 2 FULL blocks register
    kv.release(0)
    assert len(kv.cached) == 2            # registered blocks park on LRU
    assert kv.pool.free_blocks == 6       # the partial block freed outright
    # identical context: both full blocks hit; the partial tail re-feeds
    assert kv.adopt_prefix(1, [5, 9, 17, 3, 8]) == 4
    assert len(kv.tables[1]) == 2
    assert not kv.cached                  # hits left the LRU list
    # diverging second block: only the first hits
    assert kv.adopt_prefix(2, [5, 9, 99, 99, 1]) == 2
    kv.check_invariants()


def test_adopt_prefix_caps_below_full_context():
    """A fully-cached prompt still re-feeds its LAST token (the engine
    needs its logits), through the adopted final block — the write there
    is what exercises copy-on-write."""
    kv = _kv()
    toks = [5, 9, 17, 3]                  # exactly 2 full blocks
    kv.ensure(0, 4)
    kv.note_filled(0, toks, 4)
    kv.release(0)
    got = kv.adopt_prefix(1, list(toks))
    assert got == 3                       # capped at len-1 ...
    assert len(kv.tables[1]) == 2         # ... but BOTH blocks adopted
    cow = kv.make_writable(1, 3, 4)
    assert len(cow) == 1                  # the registered block copies out
    kv.check_invariants()


def test_shared_release_keeps_neighbours_blocks():
    """THE refcount regression (PR-4 latent bug): releasing one of two
    prefix-sharing sequences must not free blocks the other still maps."""
    kv = _kv()
    toks = [5, 9, 17, 3, 8, 2]
    kv.ensure(0, 6)
    kv.note_filled(0, toks, 6)
    assert kv.adopt_prefix(1, toks + [7, 7]) == 6
    shared = list(kv.tables[1])
    assert shared == kv.tables[0]
    assert all(kv.refcounts[b] == 2 for b in shared)
    kv.release(0)                         # the DONOR leaves first
    assert kv.tables[1] == shared         # adopter's table intact
    assert all(kv.refcounts[b] == 1 for b in shared)
    assert kv.pool.free_blocks == 5       # nothing shared hit the freelist
    kv.check_invariants()
    kv.release(1)
    assert len(kv.cached) == 3            # now ref-0: parked, not freed
    kv.check_invariants()


def test_lru_eviction_unregisters_oldest_first():
    kv = _kv(num_blocks=7, block_size=2, max_len=8)
    for sid, toks in enumerate(([5, 9], [17, 3], [8, 2])):
        kv.ensure(sid, 2)
        kv.note_filled(sid, toks, 2)
    old, mid, new = (kv.tables[s][0] for s in (0, 1, 2))
    for sid in (0, 1, 2):
        kv.release(sid)
    assert list(kv.cached) == [old, mid, new]
    assert kv.pool.free_blocks == 3
    kv.ensure(9, 8)                       # needs 4: 3 free + evict oldest
    assert old not in kv.cached and kv.block_hash.get(old) is None
    assert mid in kv.cached and new in kv.cached
    assert kv.adopt_prefix(10, [17, 3, 1]) == 2   # mid's content survives
    kv.check_invariants()


def test_cache_off_is_plain_pool():
    kv = _kv(cache=False)
    kv.ensure(0, 6)
    kv.note_filled(0, [1, 2, 3, 4, 5, 6], 6)
    assert not kv.hash_to_block
    assert kv.adopt_prefix(1, [1, 2, 3, 4, 5, 6]) == 0
    assert kv.make_writable(0, 0, 6) == []
    free_before = kv.pool.free_blocks
    assert kv.release(0) == 3
    assert kv.pool.free_blocks == free_before + 3   # straight to freelist
    kv.check_invariants()


# ---------------------------------------------------------------------------
# Property harness: random interleavings preserve the invariants
# ---------------------------------------------------------------------------

# Token templates with deliberately overlapping prefixes, so random
# admissions constantly share, diverge mid-block, and re-hit the LRU.
_TEMPLATES = ([5, 9, 17, 3, 8, 2, 30, 11],
              [5, 9, 17, 3, 1, 1, 2, 7],
              [5, 9, 40, 40, 8, 2],
              [12, 33, 7, 9])


class _HostModel:
    """Drives one PagedKVCache through scheduler-shaped op sequences,
    mirroring just enough sequence state (tokens, fed) to issue realistic
    adopt/feed/release calls.  ``check_invariants`` runs after every op —
    a violation surfaces as an AssertionError naming the broken clause.
    """

    def __init__(self, rng: random.Random, chunk=3):
        self.rng = rng
        self.kv = _kv(num_blocks=rng.choice((6, 8, 11)), block_size=2,
                      max_len=16)
        self.chunk = chunk
        self.live: dict[int, dict] = {}   # sid -> {tokens, fed}
        self.next_sid = 0
        self.cows = 0

    def _tokens(self):
        t = list(self.rng.choice(_TEMPLATES))
        if self.rng.random() < 0.5:       # mutate the tail: mid-block forks
            t = t[:self.rng.randrange(2, len(t))] + [self.rng.randrange(50)]
        return t[:self.kv.cfg.max_len]

    def op_admit(self):
        sid, self.next_sid = self.next_sid, self.next_sid + 1
        toks = self._tokens()
        cached = self.kv.adopt_prefix(sid, toks)
        assert cached < len(toks)         # at least one token left to feed
        if not self.kv.has_room(sid, min(len(toks), cached + self.chunk)):
            self.kv.release(sid)          # rollback, like the scheduler
            return
        self.live[sid] = dict(tokens=toks, fed=cached)

    def op_feed(self):
        if not self.live:
            return
        sid = self.rng.choice(sorted(self.live))
        s = self.live[sid]
        want = min(len(s["tokens"]) - s["fed"], self.chunk)
        if want == 0 or not self.kv.ensure(sid, s["fed"] + want):
            return
        cow = self.kv.make_writable(sid, s["fed"], s["fed"] + want)
        if cow is None:
            return                        # pool too tight for the copies
        self.cows += len(cow)
        # COW must never leave a written-span block shared or registered
        bs = self.kv.cfg.block_size
        table = self.kv.tables[sid]
        for i in range(s["fed"] // bs, -(-(s["fed"] + want) // bs)):
            assert self.kv.refcounts[table[i]] == 1
            assert table[i] not in self.kv.block_hash
        s["fed"] += want
        self.kv.note_filled(sid, s["tokens"], s["fed"])

    def op_release(self):
        if not self.live:
            return
        sid = self.rng.choice(sorted(self.live))
        self.kv.release(sid)
        del self.live[sid]

    def run(self, n_ops: int):
        ops = (self.op_admit, self.op_feed, self.op_feed, self.op_release)
        for _ in range(n_ops):
            self.rng.choice(ops)()
            self.kv.check_invariants()
        for sid in sorted(self.live):
            self.kv.release(sid)
            self.kv.check_invariants()
        # full drain partitions the pool into freelist + LRU only
        n = self.kv.cfg.num_blocks - 1
        assert self.kv.pool.free_blocks + len(self.kv.cached) == n


def _drive(seed: int, n_ops: int = 40) -> int:
    m = _HostModel(random.Random(seed))
    m.run(n_ops)
    return m.cows


def test_interleavings_deterministic_sweep():
    """200+ seeded random interleavings (the always-on stand-in for the
    hypothesis sweep): every op sequence preserves refcount/partition/
    hash-map invariants, and the sweep as a whole exercises COW."""
    cows = sum(_drive(seed) for seed in range(220))
    assert cows > 0, "sweep never hit a copy-on-write — templates too tame"


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=0, max_value=10**9))
def test_interleavings_hypothesis(seed):
    """The same driver under hypothesis (skips when not installed)."""
    _drive(seed)


# ---------------------------------------------------------------------------
# Engine-level: bit-identity, COW under serving, eviction regression
# ---------------------------------------------------------------------------


_SHARED = [5, 9, 17, 3, 8, 2, 30, 11]


def _serve(params, cfg, reqs, **kw):
    base = dict(slots=2, max_len=64, block_size=4, prefill_chunk=3)
    scfg = PagedServeConfig(**{**base, **kw})
    eng = PagedServingEngine(params, cfg, scfg)
    for r in reqs:
        eng.submit(r)
    ticks = 0
    while eng.scheduler.has_work():
        eng.step()
        eng.kv.check_invariants()
        ticks += 1
        assert ticks < 500
    return eng, {r.rid: r.generated for r in eng.finished}


def _reqs(temps=(0.0, 0.0, 0.7)):
    return [Request(rid=i, prompt=_SHARED + [20 + i, 21 + i],
                    max_new_tokens=5, temperature=t)
            for i, t in enumerate(temps)]


def test_tokens_bit_identical_cache_on_vs_off_stochastic():
    """The tentpole contract: per-request tokens (greedy AND sampled) are
    bit-identical with prefix caching on vs off on a stochastic backend.
    Both runs use content-chain rng — caching only changes WHERE context
    KV comes from, and content-derived SC keys make that invisible."""
    cfg = _cfg(sc_backend="moment", sc_nbit=512)
    params = _params(cfg)
    e_off, off = _serve(params, cfg, _reqs(), rng_mode="content")
    e_on, on = _serve(params, cfg, _reqs(), prefix_cache=True)
    assert on == off
    # slots=2: the first two admit together before any block registers,
    # so the LATE request is the one that adopts the full shared prefix
    hits = e_on.metrics.value("serve_prefix_cache_hit_tokens_total")
    assert hits and hits >= (len(_SHARED) // 4) * 4
    assert e_on.metrics.value("serve_prefill_tokens_total") < \
        e_off.metrics.value("serve_prefill_tokens_total")


def test_cow_fires_when_prompt_is_block_multiple():
    """A fully-cached block-multiple prompt adopts every block and
    re-feeds its last token through copy-on-write: the shared block is
    never written in place, and outputs still match the uncached run."""
    cfg = _cfg(sc_backend="moment", sc_nbit=512)
    prompt = _SHARED[:8]                   # 8 tokens = 2 full 4-blocks
    reqs = lambda: [Request(rid=i, prompt=list(prompt), max_new_tokens=4)
                    for i in range(2)]
    params = _params(cfg)
    # slots=1 serialises the two requests, so the second finds the whole
    # prompt registered and must COW its final adopted block
    _, off = _serve(params, cfg, reqs(), rng_mode="content", slots=1)
    e_on, on = _serve(params, cfg, reqs(), prefix_cache=True, slots=1)
    assert on == off
    assert e_on.metrics.value("serve_prefix_cache_cow_total") >= 1


def test_eviction_of_prefix_sharing_victim_regression():
    """Engine-level refcount regression: under pool pressure the LIFO
    victim shares prefix blocks with the surviving row — eviction must
    only drop the victim's REFERENCES, and every request must still
    produce its roomy-pool tokens after resume."""
    cfg = _cfg(sc_backend="moment", sc_nbit=512)
    params = _params(cfg)
    mk = lambda: [Request(rid=i, prompt=_SHARED + [20 + i], max_new_tokens=12)
                  for i in range(2)]
    roomy_e, roomy = _serve(params, cfg, mk(), prefix_cache=True, max_len=28)
    # 9+12=21 tokens/seq = 6 blocks each at bs=4; 7 usable blocks even with
    # the prefix's 2 shared can't hold both tails: someone evicts + resumes.
    tight_e, tight = _serve(params, cfg, mk(), prefix_cache=True, max_len=28,
                            num_blocks=8)
    assert tight_e.evictions > 0, "pool was meant to force an eviction"
    assert roomy_e.evictions == 0
    assert tight == roomy
    tight_e.kv.check_invariants()


def test_resumed_victim_readopts_its_own_blocks():
    """An evicted request's registered blocks park on the LRU; on
    re-admission it adopts them back instead of re-prefilling from
    scratch (recompute eviction becomes nearly free with the cache on)."""
    cfg = _cfg()
    params = _params(cfg)
    mk = lambda: [Request(rid=i, prompt=_SHARED + [20 + i], max_new_tokens=12)
                  for i in range(2)]
    e, _ = _serve(params, cfg, mk(), prefix_cache=True, max_len=28,
                  num_blocks=8)
    assert e.evictions > 0
    lookups = e.metrics.value("serve_prefix_cache_lookups_total")
    hits = e.metrics.value("serve_prefix_cache_hit_tokens_total")
    assert lookups >= 3                   # initial admissions + re-admission
    assert hits > len(_SHARED) - 4        # resume re-adopted cached blocks


def test_null_block_never_shared_or_cached():
    kv = _kv()
    kv.ensure(0, 6)
    kv.note_filled(0, [1, 2, 3, 4, 5, 6], 6)
    kv.release(0)
    assert 0 not in kv.cached and 0 not in kv.refcounts
    assert 0 not in kv.block_hash
    with pytest.raises(ValueError):
        kv.pool.free([0])
