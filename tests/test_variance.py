"""Hardware-variance robustness (§IV-B, Fig. 8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conversion, engine, variance

CFG = engine.EngineConfig(nbit=1024)
ITERS = 300


def _sigma(fn, key, n=ITERS):
    keys = jax.random.split(key, n)
    p = jax.vmap(fn)(keys)
    return float(jnp.std(p)), float(jnp.mean(p))


def test_fig8a_ic_variance_does_not_degrade_accuracy(key):
    """MUL uncertainty is flat in sigma(I_c) up to 10 % (Fig. 8a)."""
    x, y = 400, 700
    sig = {}
    for s_ic in (0.0, 0.05, 0.10):
        sig[s_ic], _ = _sigma(
            lambda k: variance.sc_mul_with_ic_variance(k, x, y, CFG, s_ic),
            jax.random.fold_in(key, int(s_ic * 100)))
    assert sig[0.10] < 1.5 * sig[0.0]
    assert sig[0.05] < 1.5 * sig[0.0]


def test_fig8b_sc_flat_but_log_multiplier_degrades(key):
    """Circuit variance: SC+PIM stays flat; the antilog stage of the
    logarithm multiplier amplifies its input noise (Fig. 8b)."""
    x, y = 400, 700
    sc_sig, log_sig = {}, {}
    for s in (0.04, 0.10):
        sc_sig[s], _ = _sigma(
            lambda k: variance.sc_mul_with_circuit_variance(k, x, y, CFG, s),
            jax.random.fold_in(key, int(s * 1000)))
        log_sig[s], _ = _sigma(
            lambda k: variance.log_multiplier(k, x, y, CFG.conv, s),
            jax.random.fold_in(key, 7000 + int(s * 1000)))
    # SC grows mildly; log-mult grows sharply and ends far above SC
    assert sc_sig[0.10] < 2.0 * sc_sig[0.04]
    assert log_sig[0.10] > 2.0 * log_sig[0.04]
    assert log_sig[0.10] > 3.0 * sc_sig[0.10]


def test_ic_variance_small_spread_keeps_mean_unbiased(key):
    """At small I_c spread the mean stays on target. (At sigma(I_c) = 10 %
    the Delta = 60.9 double exponential introduces a Jensen-effect mean
    shift that the paper's sigma-metric — Fig. 8a, reproduced flat in
    test_fig8a — does not capture; recorded in DESIGN.md as a model
    observation, so this test pins BOTH behaviours.)"""
    x, y = 400, 700
    p_true = float(conversion.quantized_product_probability(x, y, CFG.conv))
    _, mean_small = _sigma(
        lambda k: variance.sc_mul_with_ic_variance(k, x, y, CFG, 0.005), key)
    assert abs(mean_small - p_true) < 0.01
    # the documented bias at 10 % static spread (survival pushed toward the
    # bimodal regime): mean moves AWAY from the target, sigma stays flat
    _, mean_big = _sigma(
        lambda k: variance.sc_mul_with_ic_variance(k, x, y, CFG, 0.10),
        jax.random.fold_in(key, 1))
    assert mean_big > p_true + 0.05


def test_log_multiplier_exact_without_noise(key):
    x, y = 400, 700
    p = variance.log_multiplier(key, x, y, CFG.conv, 0.0)
    expect = (400 / 1024) * (700 / 1024)
    np.testing.assert_allclose(float(p), expect, rtol=1e-5)


def test_mul_uncertainty_metric():
    p_est = jnp.array([0.1, 0.2, 0.3])
    assert float(variance.mul_uncertainty(p_est, p_est)) == 0.0
    s = float(variance.mul_uncertainty(p_est, jnp.array([0.2, 0.2, 0.2])))
    assert s == pytest.approx(float(jnp.std(p_est - 0.2)))
