"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates its REDUCED config and runs one forward + train step on
CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.train import TrainConfig, make_train_step
from repro.train.step import train_state_init

B, S = 2, 32


def _batch(key, cfg):
    ki, kl = jax.random.split(key)
    if cfg.frontend == "embeddings":
        inputs = jax.random.normal(ki, (B, S, cfg.d_model), cfg.act_dtype)
    else:
        inputs = jax.random.randint(ki, (B, S), 0, cfg.vocab)
    return {"inputs": inputs,
            "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch, key):
    cfg = get_smoke_config(arch).replace(param_dtype=jnp.float32,
                                         act_dtype=jnp.float32)
    from repro.models import params as P
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    batch = _batch(key, cfg)
    logits = lm.forward(params, batch["inputs"], cfg,
                        rng=jax.random.fold_in(key, 1))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, key):
    cfg = get_smoke_config(arch).replace(param_dtype=jnp.float32,
                                         act_dtype=jnp.float32)
    tcfg = TrainConfig()
    state = train_state_init(key, cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    new_state, metrics = step(state, _batch(key, cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0
    assert int(new_state["opt"]["step"]) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }
    if arch == "paper-sc":
        assert cfg.sc_mode != "exact" and cfg.sc_nbit == 1024
        return
    nl, d, h, kv, ff, v = expected[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (nl, d, h, kv, ff, v)
    if arch == "moonshot-v1-16b-a3b":
        assert (cfg.n_experts, cfg.top_k) == (64, 6)
    if arch == "llama4-maverick-400b-a17b":
        assert (cfg.n_experts, cfg.top_k) == (128, 1)
    if arch == "zamba2-7b":
        assert cfg.ssm_state == 64 and cfg.family == "hybrid"
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128 and cfg.family == "ssm"
    if arch == "qwen2-0.5b":
        assert cfg.qkv_bias
    if arch == "qwen3-14b":
        assert cfg.qk_norm


def test_smoke_decode_and_prefill_all_archs(key):
    """Prefill then decode for a couple of representative archs of each
    family; logits finite and cache threads correctly."""
    for arch in ("qwen2-0.5b", "moonshot-v1-16b-a3b", "zamba2-7b",
                 "mamba2-370m"):
        cfg = get_smoke_config(arch).replace(param_dtype=jnp.float32,
                                             act_dtype=jnp.float32)
        from repro.models import params as P
        params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
        toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
        logits, cache, lengths = lm.prefill(params, toks, cfg, max_len=16)
        assert logits.shape == (2, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, cache = lm.decode_step(params, cache, nxt, lengths, cfg)
        assert logits2.shape == (2, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits2)))
