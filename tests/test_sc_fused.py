"""The fused SC engine + its autotuner.

The load-bearing property is BIT equality: ``pallas_fused`` must produce
the same floats as ``pallas_bitexact`` for the same key (shared
counter-based stream, exact integer accumulation), for every operand
grid, for ragged shapes, for per-row keys, and regardless of what tile
the autotuner picked.  The autotuner itself is pure performance state:
cache hits, misses, malformed entries, and version bumps may change
wall-clock, never bits.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sc
from repro.configs import get_smoke_config
from repro.models import layers
from repro.sc import autotune

_NBIT = 64      # 2 packed words per product: fast but fully exercised


def _xw(key, m, k, n):
    kx, kw = jax.random.split(key)
    return (jax.random.normal(kx, (m, k), jnp.float32),
            jax.random.normal(kw, (k, n), jnp.float32))


# ---------------------------------------------------------------------------
# bit equality with the packed three-stage engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("operand_bits", [4, 6, 8])
@pytest.mark.parametrize("m,k,n", [(8, 32, 8), (5, 17, 3), (1, 9, 13)])
def test_fused_bit_equals_packed(key, operand_bits, m, k, n):
    """Same key => same bits as pallas_bitexact, across operand grids and
    ragged (non-block-multiple) shapes."""
    x, w = _xw(key, m, k, n)
    kw = dict(nbit=_NBIT, operand_bits=operand_bits)
    yb = sc.sc_dot(key, x, w,
                   sc.ScConfig(backend="pallas_bitexact", **kw))
    yf = sc.sc_dot(key, x, w, sc.ScConfig(backend="pallas_fused", **kw))
    np.testing.assert_array_equal(np.asarray(yb), np.asarray(yf))


def test_fused_differs_across_keys(key):
    """Sanity: the stream actually depends on the key."""
    x, w = _xw(key, 4, 16, 4)
    cfg = sc.ScConfig(backend="pallas_fused", nbit=_NBIT)
    y1 = sc.sc_dot(jax.random.PRNGKey(1), x, w, cfg)
    y2 = sc.sc_dot(jax.random.PRNGKey(2), x, w, cfg)
    assert float(jnp.abs(y1 - y2).max()) > 0


def test_fused_unbiased_estimate(key):
    """The fused engine estimates x @ w with zero-centered error."""
    x, w = _xw(key, 4, 32, 4)
    cfg = sc.ScConfig(backend="pallas_fused", nbit=256)
    outs = jnp.stack([sc.sc_dot(k_, x, w, cfg)
                      for k_ in jax.random.split(key, 48)])
    exact = np.asarray(x @ w)
    sigma = np.asarray(outs.std(axis=0))
    tol = 5 * sigma / np.sqrt(48) + 0.02 * np.abs(exact).max()
    assert (np.abs(np.asarray(outs.mean(0)) - exact) < tol).mean() > 0.9


def test_fused_tile_choice_never_changes_bits(key):
    """Outputs are invariant to the autotuned tiling — the property that
    makes the cache safe to regenerate on any machine."""
    x, w = _xw(key, 6, 24, 5)
    cfg = sc.ScConfig(backend="pallas_fused", nbit=_NBIT)
    base = sc.sc_dot(key, x, w, cfg)
    from repro.kernels import sc_fused
    from repro.sc import ctr_rng, encoding
    scx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    scw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    kx, ky = jax.random.split(key)
    outs = []
    for tile in (autotune.FusedTile(4, 4, 16, 1),
                 autotune.FusedTile(8, 8, 32, 2)):
        spx = encoding.pad_to(encoding.pad_to(x / scx, tile.block_m, 0),
                              tile.block_k, 1)
        spw = encoding.pad_to(encoding.pad_to(w / scw, tile.block_k, 0),
                              tile.block_n, 1)
        keys = jnp.broadcast_to(jnp.concatenate(
            [ctr_rng.raw_key(kx), ctr_rng.raw_key(ky)])[None],
            (spx.shape[0], 4))
        total = sc_fused.sc_fused_popcount(
            keys, spx, spw, k_orig=24, n_orig=5, nbit=_NBIT, levels=1024,
            quantize=True, **tile.kwargs())
        outs.append(total[:6, :5].astype(jnp.float32) / _NBIT * (scx * scw))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(base))


# ---------------------------------------------------------------------------
# per-row keys (the serve engine's batch-invariance path)
# ---------------------------------------------------------------------------


def test_rows_mode_equals_per_row_single_calls(key):
    """sc_dot_rows row i == sc_dot on row i alone (bits AND scale), so
    outputs are invariant to batch composition."""
    m, k, n = 5, 24, 6
    x, w = _xw(key, m, k, n)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        key, jnp.arange(m, dtype=jnp.uint32))
    cfg = sc.ScConfig(backend="pallas_fused", nbit=_NBIT)
    rows = sc.sc_dot_rows(keys, x, w, cfg)
    singles = jnp.concatenate(
        [sc.sc_dot(keys[i], x[i:i + 1], w, cfg) for i in range(m)])
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(singles))
    # shuffling the batch permutes, never changes, each row's output
    perm = jnp.array([3, 0, 4, 1, 2])
    shuffled = sc.sc_dot_rows(keys[perm], x[perm], w, cfg)
    np.testing.assert_array_equal(np.asarray(shuffled),
                                  np.asarray(rows[perm]))


def test_rows_mode_vmap_fallback_unchanged(key):
    """Backends without a native rows path fall back to the per-row vmap
    and still match their single-key calls."""
    m, k, n = 4, 16, 4
    x, w = _xw(key, m, k, n)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        key, jnp.arange(m, dtype=jnp.uint32))
    cfg = sc.ScConfig(backend="moment", nbit=_NBIT)
    rows = sc.sc_dot_rows(keys, x, w, cfg)
    singles = jnp.concatenate(
        [sc.sc_dot(keys[i], x[i:i + 1], w, cfg) for i in range(m)])
    np.testing.assert_allclose(np.asarray(rows), np.asarray(singles),
                               rtol=1e-6, atol=1e-6)


def test_rows_mode_straight_through_gradients(key):
    m, k, n = 4, 16, 4
    x, w = _xw(key, m, k, n)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        key, jnp.arange(m, dtype=jnp.uint32))
    cfg = sc.ScConfig(backend="pallas_fused", nbit=_NBIT)

    def loss(x_, w_):
        return jnp.sum(sc.sc_dot_rows(keys, x_, w_, cfg) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    y = sc.sc_dot_rows(keys, x, w, cfg)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * (y @ w.T)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(2 * (x.T @ y)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dense() fast-path routing
# ---------------------------------------------------------------------------


def test_fast_backend_mapping():
    assert sc.fast_backend("pallas_bitexact", 1024) == "pallas_fused"
    assert sc.fast_backend("pallas_bitexact", 48) == "pallas_bitexact"
    assert sc.fast_backend("moment", 1024) == "moment"
    assert sc.fast_backend("exact") == "exact"
    assert "pallas_fused" in sc.available_backends()


def test_dense_upgrades_bitexact_to_fused(key):
    """dense(sc_backend='pallas_bitexact') routes through the fused engine
    and — because the two are bit-identical — matches a direct
    pallas_fused sc_dot call, single-key and per-row-key alike."""
    cfg = get_smoke_config("paper-sc").replace(
        sc_backend="pallas_bitexact", sc_nbit=_NBIT,
        param_dtype=jnp.float32, act_dtype=jnp.float32)
    x = jax.random.normal(key, (3, 16), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8), jnp.float32)
    y = layers.dense(x, w, cfg, key=key)
    direct = sc.sc_dot(key, x, w,
                       sc.ScConfig(backend="pallas_fused", nbit=_NBIT))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(direct))
    # per-row keys (the paged serve path): row i sees keys[i] only
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        key, jnp.arange(3, dtype=jnp.uint32))
    y_rows = layers.dense(x, w, cfg, key=keys)
    direct_rows = sc.sc_dot_rows(
        keys, x, w, sc.ScConfig(backend="pallas_fused", nbit=_NBIT))
    np.testing.assert_array_equal(np.asarray(y_rows),
                                  np.asarray(direct_rows))


# ---------------------------------------------------------------------------
# autotuner: cache semantics (never numerics)
# ---------------------------------------------------------------------------


def test_heuristic_fallback_on_cache_miss():
    tile = autotune.get_tile(13, 40, 7, 1024, cache={})
    assert tile == autotune.heuristic_tile(13, 40, 7, 1024)
    # deterministic: same signature, same tile
    assert tile == autotune.get_tile(13, 40, 7, 1024, cache={})
    # blocks stay VMEM-bounded
    assert (tile.block_m * tile.block_n * tile.block_k * tile.lane_words
            <= autotune._MAX_TILE_WORDS)


def test_cache_hit_returns_stored_tile(tmp_path):
    path = str(tmp_path / "cache.json")
    stored = autotune.FusedTile(4, 8, 16, 2)
    entry = dict(stored.kwargs())
    entry["wall_us"] = 12.5          # extra fields tolerated
    autotune.save_cache({autotune.cache_key(8, 32, 8, 1024): entry}, path)
    cache = autotune.load_cache(path)
    assert autotune.get_tile(8, 32, 8, 1024, cache=cache) == stored
    # a different signature in the same cache still falls back
    assert autotune.get_tile(8, 32, 8, 512, cache=cache) == \
        autotune.heuristic_tile(8, 32, 8, 512)


def test_cache_version_bump_invalidates(tmp_path):
    path = str(tmp_path / "cache.json")
    entry = dict(autotune.FusedTile(4, 8, 16, 2).kwargs())
    with open(path, "w") as f:
        json.dump({"version": autotune.CACHE_VERSION + 1,
                   "entries": {autotune.cache_key(8, 32, 8, 1024): entry}},
                  f)
    assert autotune.load_cache(path) == {}      # stale table ignored
    with open(path, "w") as f:
        json.dump({"version": autotune.CACHE_VERSION,
                   "entries": {autotune.cache_key(8, 32, 8, 1024): entry}},
                  f)
    assert autotune.load_cache(path) != {}      # current version applies


def test_malformed_cache_and_entries_fall_back(tmp_path):
    path = str(tmp_path / "cache.json")
    with open(path, "w") as f:
        f.write("{not json")
    assert autotune.load_cache(path) == {}
    assert autotune.load_cache(str(tmp_path / "absent.json")) == {}
    bad = {autotune.cache_key(8, 32, 8, 1024): {"block_m": "huge"}}
    assert autotune.get_tile(8, 32, 8, 1024, cache=bad) == \
        autotune.heuristic_tile(8, 32, 8, 1024)
    # non-positive blocks would zero the kernel grid: heuristic, not crash
    zero = {autotune.cache_key(8, 32, 8, 1024): dict(
        block_m=0, block_n=8, block_k=32, lane_words=16)}
    assert autotune.get_tile(8, 32, 8, 1024, cache=zero) == \
        autotune.heuristic_tile(8, 32, 8, 1024)


def test_cache_hit_vs_miss_same_bits(key, tmp_path, monkeypatch):
    """THE determinism contract: a cache entry (hit) and no entry (miss,
    heuristic) produce bitwise identical sc_dot outputs."""
    m, k, n = 6, 20, 4
    x, w = _xw(key, m, k, n)
    cfg = sc.ScConfig(backend="pallas_fused", nbit=_NBIT)
    monkeypatch.setenv(autotune._CACHE_ENV,
                       str(tmp_path / "absent.json"))
    autotune.reset_cache()
    try:
        miss = sc.sc_dot(key, x, w, cfg)        # heuristic tile
        path = str(tmp_path / "cache.json")
        tile = autotune.FusedTile(4, 4, 4, 1)   # deliberately different
        assert tile != autotune.heuristic_tile(m, k, n, _NBIT)
        autotune.save_cache(
            {autotune.cache_key(m, k, n, _NBIT): tile.kwargs()}, path)
        monkeypatch.setenv(autotune._CACHE_ENV, path)
        autotune.reset_cache()
        assert autotune.get_tile(m, k, n, _NBIT) == tile    # really a hit
        hit = sc.sc_dot(key, x, w, cfg)
        np.testing.assert_array_equal(np.asarray(miss), np.asarray(hit))
    finally:
        autotune.reset_cache()


def test_shipped_cache_loads_and_is_current_version():
    """The repo ships a valid, version-current autotune table."""
    assert os.path.exists(autotune.DEFAULT_CACHE_PATH)
    with open(autotune.DEFAULT_CACHE_PATH) as f:
        payload = json.load(f)
    assert payload["version"] == autotune.CACHE_VERSION
    entries = autotune.load_cache(autotune.DEFAULT_CACHE_PATH)
    assert entries, "shipped cache must carry measured entries"
    for key_, entry in entries.items():
        tile = autotune.FusedTile(
            block_m=int(entry["block_m"]), block_n=int(entry["block_n"]),
            block_k=int(entry["block_k"]),
            lane_words=int(entry["lane_words"]))
        assert (tile.block_m * tile.block_n * tile.block_k
                * tile.lane_words <= autotune._MAX_TILE_WORDS), key_


# ---------------------------------------------------------------------------
# sharding: trivial mesh reproduces sc_dot bit-for-bit
# ---------------------------------------------------------------------------


def test_fused_sharded_trivial_mesh_bit_equal(key):
    """On a 1-device mesh every axis drops and sc_dot_sharded must equal
    sc_dot exactly (same key, same bits) — the multi-device equivalence
    runs in tests/_sharded_subprocess.py."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x, w = _xw(key, 8, 32, 8)
    cfg = sc.ScConfig(backend="pallas_fused", nbit=_NBIT)
    y_ref = sc.sc_dot(key, x, w, cfg)
    y_sh = sc.sc_dot_sharded(key, x, w, cfg, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_sh))
