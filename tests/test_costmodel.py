"""Cost-model tests: the paper's §V headline numbers must emerge."""

import pytest

from repro.core import costmodel as cm


def test_fig9a_speedup_vs_sc():
    """'up to 4x improvement in performance compared with conventional SC'."""
    r = cm.headline_ratios(10)
    assert 3.0 <= r["speedup_vs_sc"] <= 5.0


def test_fig9a_speedup_vs_pim():
    """'18x speedup over implementing MUL with only in-memory bitwise
    Boolean logic operations'."""
    r = cm.headline_ratios(10)
    assert 15.0 <= r["speedup_vs_pim"] <= 21.0


def test_fig10_energy_saving_vs_sc():
    """'consumes 58 % less energy compared with the SC method'."""
    r = cm.headline_ratios(10)
    assert 0.45 <= r["energy_saving_vs_sc"] <= 0.70


def test_fig11_area_order_of_magnitude():
    """'area overhead is smaller by about one order of magnitude'."""
    r = cm.headline_ratios(10)
    assert 5.0 <= r["area_ratio_sc_over_ours"] <= 20.0


def test_fig9b_scpim_cycles_flat_in_bitlength():
    """SC+PIM cycle count is ~flat vs operand bits (parallel generation)."""
    c8 = cm.cycles_scpim_apc(8)
    c12 = cm.cycles_scpim_apc(12)
    assert c12 <= 4 * c8  # sublinear growth (rows grow, pulses don't)


def test_fig9b_pim_cycles_grow_fast():
    """PIM MUL cycles grow super-linearly with bit length (quadratic
    shift-add; the paper says 'can increase exponentially')."""
    assert cm.cycles_pim(8) == 143  # DRISA anchor
    assert cm.cycles_pim(16) >= 4 * cm.cycles_pim(8) * 0.9
    # crossover: SC+PIM advantage grows with bit length
    adv10 = cm.cycles_pim(10) / cm.cycles_scpim_apc(10)
    adv16 = cm.cycles_pim(16) / cm.cycles_scpim_apc(16)
    assert adv16 > adv10


def test_fig10_init_dominates_scpim_energy():
    """The preset (initialization) step costs more than the SC pulses
    (stronger + longer pulse) — paper Fig. 10 discussion."""
    _, bd = cm.energy_scpim(10, "apc")
    assert bd["init"] > bd["sc_pulses"] / 2
    assert bd["init"] > bd["conversion"]


def test_fig10_sc_buffering_dominates():
    """~88 % of conventional-SC energy is buffering-related."""
    total, bd = cm.energy_sc(10)
    assert bd["buffering"] / total > 0.80


def test_fig11_sng_dominates_sc_area():
    """SNG occupies 95 % of conventional SC area."""
    total, bd = cm.area_sc(10)
    assert bd["sng"] / total == pytest.approx(0.95, abs=0.01)


def test_fig11_lut_shrinks_with_bitlength():
    a10, bd10 = cm.area_scpim(10)
    a8, bd8 = cm.area_scpim(8)
    assert bd8["lut"] == pytest.approx(bd10["lut"] / 4)


def test_csa_variant_trades_cycles_for_area():
    """CSA pop-count: smaller area than APC variant, more cycles."""
    a_apc, _ = cm.area_scpim(10, "apc")
    a_csa, _ = cm.area_scpim(10, "csa")
    assert a_csa < a_apc
    assert cm.cycles_scpim_csa(10, 100) > cm.cycles_scpim_apc(10)


def test_csa_amortizes_with_mac_length():
    assert cm.cycles_scpim_csa(10, 1000) < cm.cycles_scpim_csa(10, 10)


def test_full_comparison_structure():
    table = cm.full_comparison()
    assert set(table) == {"SC+PIM (APC)", "SC+PIM (CSA)", "SC", "PIM"}
    for v in table.values():
        assert v.cycles > 0 and v.energy_pj > 0 and v.area_um2 > 0


# --------------------------- CostParams dataclass ---------------------------


def test_default_params_mirror_module_constants():
    p = cm.DEFAULT_PARAMS
    assert p.row_length == cm.ROW_LENGTH
    assert p.sa_read_cycles == cm.SA_READ_CYCLES
    assert p.drisa_8bit_cycles == cm.DRISA_8BIT_CYCLES
    assert p.apc_energy_pj == cm.APC_ENERGY_PJ
    assert p.sng_area_fraction == cm.SNG_AREA_FRACTION


def test_cost_params_hashable_and_frozen():
    p = cm.CostParams()
    assert hash(p) == hash(cm.CostParams())
    assert {p: 1}[cm.CostParams()] == 1          # usable as a dict key
    with pytest.raises(Exception):
        p.row_length = 512                       # frozen


def test_cost_params_sweep_is_pure():
    """A swept instance changes results without touching the defaults —
    the thread-safety property the module-global knobs never had."""
    slow_sng = cm.CostParams(sng_bits_per_cycle=32)
    assert cm.cycles_sc(10, slow_sng) > cm.cycles_sc(10)
    assert cm.cycles_sc(10) == cm.cycles_sc(10, cm.DEFAULT_PARAMS)
    # ratios move accordingly; defaults untouched
    r = cm.headline_ratios(10, slow_sng)
    assert r["speedup_vs_sc"] > cm.headline_ratios(10)["speedup_vs_sc"]


def test_cost_params_row_length_sweep():
    """Longer rows -> fewer rows per MUL -> shallower merge tree."""
    long_rows = cm.CostParams(row_length=1024)
    assert cm.cycles_scpim_apc(10, long_rows) < cm.cycles_scpim_apc(10)
    assert long_rows.rows_per_mul(10) == 1
    assert long_rows.merge_cycles(1) == 0


def test_cost_params_row_length_reaches_csa_path():
    """The CSA pop-count folds per-MUL rows, so row_length must sweep it
    too (fewer rows per MUL -> fewer 3:2 fold passes)."""
    long_rows = cm.CostParams(row_length=1024)
    assert cm.cycles_scpim_csa(10, 100, long_rows) < cm.cycles_scpim_csa(10, 100)
    e_long, _ = cm.energy_scpim(10, "csa", 100, long_rows)
    e_base, _ = cm.energy_scpim(10, "csa", 100)
    assert e_long < e_base


def test_cost_params_derived_energy_helpers():
    p = cm.DEFAULT_PARAMS
    assert p.preset_energy_pj_per_cell() > p.pulse_energy_pj_per_cell()
    total, bd = cm.energy_scpim(10, "apc")
    assert bd["init"] == pytest.approx(1024 * p.preset_energy_pj_per_cell())
    assert bd["conversion"] == pytest.approx(
        2 * p.conversion_energy_pj_per_operand())
