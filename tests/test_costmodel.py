"""Cost-model tests: the paper's §V headline numbers must emerge."""

import pytest

from repro.core import costmodel as cm


def test_fig9a_speedup_vs_sc():
    """'up to 4x improvement in performance compared with conventional SC'."""
    r = cm.headline_ratios(10)
    assert 3.0 <= r["speedup_vs_sc"] <= 5.0


def test_fig9a_speedup_vs_pim():
    """'18x speedup over implementing MUL with only in-memory bitwise
    Boolean logic operations'."""
    r = cm.headline_ratios(10)
    assert 15.0 <= r["speedup_vs_pim"] <= 21.0


def test_fig10_energy_saving_vs_sc():
    """'consumes 58 % less energy compared with the SC method'."""
    r = cm.headline_ratios(10)
    assert 0.45 <= r["energy_saving_vs_sc"] <= 0.70


def test_fig11_area_order_of_magnitude():
    """'area overhead is smaller by about one order of magnitude'."""
    r = cm.headline_ratios(10)
    assert 5.0 <= r["area_ratio_sc_over_ours"] <= 20.0


def test_fig9b_scpim_cycles_flat_in_bitlength():
    """SC+PIM cycle count is ~flat vs operand bits (parallel generation)."""
    c8 = cm.cycles_scpim_apc(8)
    c12 = cm.cycles_scpim_apc(12)
    assert c12 <= 4 * c8  # sublinear growth (rows grow, pulses don't)


def test_fig9b_pim_cycles_grow_fast():
    """PIM MUL cycles grow super-linearly with bit length (quadratic
    shift-add; the paper says 'can increase exponentially')."""
    assert cm.cycles_pim(8) == 143  # DRISA anchor
    assert cm.cycles_pim(16) >= 4 * cm.cycles_pim(8) * 0.9
    # crossover: SC+PIM advantage grows with bit length
    adv10 = cm.cycles_pim(10) / cm.cycles_scpim_apc(10)
    adv16 = cm.cycles_pim(16) / cm.cycles_scpim_apc(16)
    assert adv16 > adv10


def test_fig10_init_dominates_scpim_energy():
    """The preset (initialization) step costs more than the SC pulses
    (stronger + longer pulse) — paper Fig. 10 discussion."""
    _, bd = cm.energy_scpim(10, "apc")
    assert bd["init"] > bd["sc_pulses"] / 2
    assert bd["init"] > bd["conversion"]


def test_fig10_sc_buffering_dominates():
    """~88 % of conventional-SC energy is buffering-related."""
    total, bd = cm.energy_sc(10)
    assert bd["buffering"] / total > 0.80


def test_fig11_sng_dominates_sc_area():
    """SNG occupies 95 % of conventional SC area."""
    total, bd = cm.area_sc(10)
    assert bd["sng"] / total == pytest.approx(0.95, abs=0.01)


def test_fig11_lut_shrinks_with_bitlength():
    a10, bd10 = cm.area_scpim(10)
    a8, bd8 = cm.area_scpim(8)
    assert bd8["lut"] == pytest.approx(bd10["lut"] / 4)


def test_csa_variant_trades_cycles_for_area():
    """CSA pop-count: smaller area than APC variant, more cycles."""
    a_apc, _ = cm.area_scpim(10, "apc")
    a_csa, _ = cm.area_scpim(10, "csa")
    assert a_csa < a_apc
    assert cm.cycles_scpim_csa(10, 100) > cm.cycles_scpim_apc(10)


def test_csa_amortizes_with_mac_length():
    assert cm.cycles_scpim_csa(10, 1000) < cm.cycles_scpim_csa(10, 10)


def test_full_comparison_structure():
    table = cm.full_comparison()
    assert set(table) == {"SC+PIM (APC)", "SC+PIM (CSA)", "SC", "PIM"}
    for v in table.values():
        assert v.cycles > 0 and v.energy_pj > 0 and v.area_um2 > 0
