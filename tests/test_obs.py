"""Unified serve/substrate telemetry (``repro.obs``).

Three layers under test: the registry/tracer primitives themselves, the
substrate hooks (sc dispatch counters, autotune hit/miss, arch pricing
folded into spans — all default-off), and the serving integration — a
drained paged run must emit a parseable metrics snapshot, a Prometheus
exposition, and a trace JSONL whose span counts MATCH the engine's
lifecycle events exactly.
"""

import json
import math
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.configs import get_smoke_config
from repro.ft import supervisor
from repro.models import lm, params as P
from repro.sc import autotune
from repro.sc.config import ScConfig
from repro.sc.registry import sc_dot, sc_dot_rows
from repro.serve import PagedServeConfig, PagedServingEngine, Request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import obs_report  # noqa: E402

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _cfg(**kw):
    return get_smoke_config("qwen2-0.5b").replace(**F32, **kw)


# ---------------------------------------------------------------------------
# Metrics registry primitives
# ---------------------------------------------------------------------------


def test_counter_inc_and_labels():
    reg = obs.MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc()
    c.inc(2, kind="a")
    c.inc(kind="a")
    assert c.value() == 1
    assert c.value(kind="a") == 3
    assert c.value(kind="missing") == 0
    assert reg.value("req_total", kind="a") == 3
    assert reg.value("nope") is None


def test_counter_rejects_negative():
    c = obs.MetricsRegistry().counter("x_total")
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_disabled_registry_records_nothing():
    reg = obs.MetricsRegistry(enabled=False)
    c = reg.counter("x_total")
    g = reg.gauge("depth")
    h = reg.histogram("lat_ms")
    c.inc(5)
    g.set(3)
    h.observe(1.0)
    assert c.value() == 0 and g.value() is None and h.count() == 0
    reg.enable()
    c.inc(5)
    assert c.value() == 5


def test_gauge_set_add():
    g = obs.MetricsRegistry().gauge("depth")
    g.set(4)
    g.add(-1)
    assert g.value() == 3


def test_registry_idempotent_and_kind_mismatch_raises():
    reg = obs.MetricsRegistry()
    a = reg.counter("x_total", "help")
    assert reg.counter("x_total") is a
    with pytest.raises(ValueError, match="already registered as counter"):
        reg.gauge("x_total")


def test_histogram_percentiles_bounded_by_buckets():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0, 8.0))
    assert h.percentile(50) is None
    for v in (0.5, 1.5, 1.5, 3.0, 20.0):
        h.observe(v)
    assert h.count() == 5
    assert h.sum() == pytest.approx(26.5)
    p50 = h.percentile(50)
    assert 1.0 <= p50 <= 2.0          # covering-bucket bound
    # overflow bucket clamps to the observed max, never +inf
    assert h.percentile(99) <= 20.0
    assert h.percentile(0) >= 0.5


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError, match="ascending"):
        obs.MetricsRegistry().histogram("h", buckets=(2.0, 1.0))


def test_snapshot_shape_and_exposition_parse():
    reg = obs.MetricsRegistry()
    reg.counter("req_total", "requests").inc(3, kind="a")
    reg.gauge("depth", "queue").set(2)
    h = reg.histogram("lat_ms", "latency", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    snap = reg.snapshot()
    assert snap["counters"] == {"req_total{kind=a}": 3}
    assert snap["gauges"] == {"depth": 2}
    hs = snap["histograms"]["lat_ms"]
    assert hs["count"] == 2 and hs["sum"] == pytest.approx(5.5)
    assert hs["min"] == 0.5 and hs["max"] == 5.0
    assert json.loads(reg.snapshot_json()) == snap
    # the exposition round-trips through the report tool's parser
    parsed = obs_report.parse_exposition(reg.exposition())
    assert parsed["counters"] == {"req_total{kind=a}": 3}
    assert parsed["gauges"] == {"depth": 2}
    assert parsed["histograms"]["lat_ms"] == {"count": 2, "sum": 5.5}
    text = reg.exposition()
    assert "# TYPE req_total counter" in text
    assert 'lat_ms_bucket{le="+Inf"} 2' in text


def test_registry_thread_safety_smoke():
    reg = obs.MetricsRegistry()
    c = reg.counter("n_total")

    def worker():
        for _ in range(1000):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value() == 4000


# ---------------------------------------------------------------------------
# Tracer primitives
# ---------------------------------------------------------------------------


def test_tracer_span_nesting_and_attr():
    tr = obs.Tracer()
    with tr.span("outer", a=1):
        tr.event("ev", b=2)
        with tr.span("inner"):
            tr.attr(c=3)              # folds into the INNERMOST open span
    assert tr.counts() == {"outer": 1, "ev": 1, "inner": 1}
    by_name = {s.name: s for s in tr.spans}
    assert by_name["outer"].parent_id is None
    assert by_name["ev"].parent_id == by_name["outer"].span_id
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert by_name["inner"].attrs == {"c": 3}
    assert by_name["outer"].dur_ns >= by_name["inner"].dur_ns >= 0
    assert by_name["ev"].dur_ns == 0


def test_null_tracer_is_inert():
    with obs.NULL_TRACER.span("x"):
        obs.NULL_TRACER.event("y")
        obs.NULL_TRACER.attr(z=1)
    assert obs.NULL_TRACER.spans == []


def test_tracer_jsonl_roundtrip_and_chrome(tmp_path):
    tr = obs.Tracer()
    with tr.span("tick", n=1):
        tr.event("sub")
    path = tr.write_jsonl(str(tmp_path / "t.jsonl"))
    rows = obs.read_jsonl(path)
    assert [r["name"] for r in rows] == ["sub", "tick"]
    chrome = obs.to_chrome(rows)
    events = chrome["traceEvents"]
    assert events[0]["ph"] == "M"               # process_name metadata
    phs = {e["name"]: e["ph"] for e in events[1:]}
    assert phs == {"sub": "i", "tick": "X"}
    tick = next(e for e in events if e["name"] == "tick")
    assert tick["dur"] > 0 and tick["args"]["n"] == 1


def test_install_tracer_slot():
    assert obs.current_tracer() is None
    tr = obs.install_tracer(obs.Tracer())
    try:
        assert obs.current_tracer() is tr
        # conditional uninstall of a DIFFERENT tracer leaves it in place
        obs.uninstall_tracer(obs.Tracer())
        assert obs.current_tracer() is tr
    finally:
        obs.uninstall_tracer(tr)
    assert obs.current_tracer() is None


# ---------------------------------------------------------------------------
# Substrate hooks: sc dispatch, autotune, arch pricing (default-off)
# ---------------------------------------------------------------------------


@pytest.fixture
def global_obs():
    """Enable the default registry + install a tracer, restore after."""
    reg = obs.enable()
    reg.clear()
    tr = obs.install_tracer(obs.Tracer())
    try:
        yield reg, tr
    finally:
        obs.uninstall_tracer(tr)
        obs.disable()
        reg.clear()


def test_sc_dispatch_counters_and_span(global_obs):
    reg, tr = global_obs
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (4, 8))
    w = jax.random.uniform(key, (8, 4))
    cfg = ScConfig(backend="array", nbit=64)
    sc_dot(key, x, w, cfg)
    keys = jnp.broadcast_to(jax.random.key_data(key)[None], (4, 2))
    sc_dot_rows(keys, x, w, cfg)
    snap = reg.snapshot()["counters"]
    assert snap["sc_dispatch_total{backend=array,entry=sc_dot}"] == 1
    assert snap["sc_dispatch_total{backend=array,entry=sc_dot_rows}"] == 1
    # arch pricing only records under an installed arch-trace collector
    assert "arch_sc_dot_calls_total" not in snap
    spans = [s for s in tr.spans if s.name == "sc.dispatch"]
    assert len(spans) == 2
    assert spans[0].attrs["backend"] == "array"
    assert spans[0].attrs["m"] == 4 and spans[0].attrs["k"] == 8


def test_sc_dispatch_silent_when_disabled():
    reg = obs.default_registry()
    assert not reg.enabled      # the process-global default-off contract
    before = dict(reg.snapshot()["counters"])
    key = jax.random.PRNGKey(0)
    sc_dot(key, jax.random.uniform(key, (2, 4)),
           jax.random.uniform(key, (4, 2)), ScConfig(backend="array",
                                                     nbit=64))
    assert reg.snapshot()["counters"] == before


def test_arch_pricing_folds_into_dispatch_span(global_obs):
    from repro.arch import trace as arch_trace
    reg, tr = global_obs
    key = jax.random.PRNGKey(0)
    with arch_trace.collect():
        sc_dot(key, jax.random.uniform(key, (4, 8)),
               jax.random.uniform(key, (8, 4)),
               ScConfig(backend="array", nbit=64))
    snap = reg.snapshot()["counters"]
    assert snap["arch_sc_dot_calls_total"] == 1
    assert snap["arch_cycles_total"] > 0
    assert snap["arch_energy_pj_total"] > 0
    span = next(s for s in tr.spans if s.name == "sc.dispatch")
    assert span.attrs["arch_cycles"] == snap["arch_cycles_total"]
    assert span.attrs["arch_energy_pj"] > 0
    assert span.attrs["arch_shards"] == 1


def test_autotune_lookup_counters(global_obs):
    reg, tr = global_obs
    entry = {"block_m": 4, "block_n": 4, "block_k": 16, "lane_words": 8}
    cache = {autotune.cache_key(8, 32, 8, 256): entry}
    tile = autotune.get_tile(8, 32, 8, 256, cache=cache)     # hit
    assert tile == autotune.FusedTile(4, 4, 16, 8)
    autotune.get_tile(9, 32, 8, 256, cache=cache)            # miss
    autotune.get_attn_tile(8, 4, 8, 0, cache={})             # attn miss
    snap = reg.snapshot()["counters"]
    assert snap["sc_autotune_lookups_total{kind=matmul,result=hit}"] == 1
    assert snap["sc_autotune_lookups_total{kind=matmul,result=miss}"] == 1
    assert snap["sc_autotune_lookups_total{kind=attn,result=miss}"] == 1


# ---------------------------------------------------------------------------
# Serving integration: lifecycle counters + span accounting
# ---------------------------------------------------------------------------


def _requests(n, *, max_new=4):
    prompts = [[5, 9, 17, 3], [40, 2, 8, 30, 7, 11], [12, 33, 7],
               [3, 4, 5, 6, 7]]
    return [Request(rid=i, prompt=list(prompts[i % len(prompts)]),
                    max_new_tokens=max_new, temperature=0.0)
            for i in range(n)]


def _drain(params, cfg, reqs, *, slots=2, prefill_chunk=3, metrics=None,
           tracer=None, num_blocks=0):
    eng = PagedServingEngine(params, cfg, PagedServeConfig(
        slots=slots, max_len=32, block_size=4, prefill_chunk=prefill_chunk,
        num_blocks=num_blocks), metrics=metrics, tracer=tracer)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    eng.close()
    return eng


@pytest.fixture(scope="module")
def serve_setup():
    cfg = _cfg()
    params = P.init_params(jax.random.PRNGKey(0), lm.lm_param_specs(cfg),
                           cfg.param_dtype)
    return cfg, params


def test_engine_emits_matching_spans_and_counters(serve_setup, tmp_path):
    """The acceptance assertion: drain a paged run with obs on, and the
    trace JSONL's span counts equal the engine's lifecycle events while
    the metrics exposition parses and carries the required series."""
    cfg, params = serve_setup
    metrics = obs.MetricsRegistry()
    tracer = obs.Tracer()
    reqs = _requests(3)
    chunk = 3
    eng = _drain(params, cfg, reqs, prefill_chunk=chunk, metrics=metrics,
                 tracer=tracer)
    n = len(reqs)
    counts = tracer.counts()
    # one submit/admit/finish event per request (no evictions here)
    assert counts["request.submit"] == n
    assert counts["request.admit"] == n
    assert counts["request.finish"] == n
    assert "request.evict" not in counts
    # one engine.tick span per tick, labeled prefill|decode, sums match
    assert counts["engine.tick"] == eng.ticks
    assert (metrics.value("serve_ticks_total", kind="prefill")
            + metrics.value("serve_ticks_total", kind="decode")) == eng.ticks
    # one prefill.chunk event per context chunk: ceil(plen / chunk) each
    want_chunks = sum(math.ceil(len(r.prompt) / chunk) for r in reqs)
    assert counts["prefill.chunk"] == want_chunks
    assert metrics.value("serve_prefill_tokens_total") == sum(
        len(r.prompt) for r in reqs)
    # counters match the finished requests
    tokens = sum(len(r.generated) for r in eng.finished)
    assert metrics.value("serve_tokens_generated_total") == tokens
    assert metrics.value("serve_requests_finished_total") == n
    assert metrics.value("serve_kv_blocks_allocated_total") == \
        metrics.value("serve_kv_blocks_freed_total") > 0
    assert metrics.value("serve_queue_depth") == 0
    assert metrics.value("serve_active_requests") == 0
    # tick spans carry kind/live/width attrs; decode ticks the wall time
    ticks = [s for s in tracer.spans if s.name == "engine.tick"]
    assert all(s.attrs["kind"] in ("prefill", "decode") for s in ticks)
    decode = [s for s in ticks if s.attrs["kind"] == "decode"]
    assert decode and all("decode_ms_per_token" in s.attrs for s in decode)
    assert all(s.attrs["width"] == 1 for s in decode)
    # the jit tick is excluded from the histogram but counted
    assert metrics.value("serve_decode_jit_ticks_total") == 1
    assert metrics.histogram("serve_decode_ms_per_token").count() == \
        len(decode) - 1
    # artifacts: exposition parses, snapshot is JSON, JSONL round-trips
    prom = tmp_path / "m.prom"
    prom.write_text(metrics.exposition())
    parsed = obs_report.load_snapshot(str(prom))
    assert parsed["counters"]["serve_requests_finished_total"] == n
    names = obs_report.metric_names(parsed)
    for required in ("serve_requests_submitted_total", "serve_ticks_total",
                     "serve_decode_ms_per_token", "serve_kv_blocks_free"):
        assert required in names
    jsonl = tracer.write_jsonl(str(tmp_path / "t.jsonl"))
    rows = obs.read_jsonl(jsonl)
    assert len(rows) == len(tracer.spans)
    assert len(obs.to_chrome(rows)["traceEvents"]) == len(rows) + 1


def test_engine_eviction_spans_and_counters(serve_setup):
    """A forced-eviction run emits request.evict events equal to the
    eviction counter, and admits = submits + evictions (resumes
    re-admit)."""
    cfg, params = serve_setup
    metrics = obs.MetricsRegistry()
    tracer = obs.Tracer()
    reqs = [Request(rid=i, prompt=[7 + i] * 8, max_new_tokens=12,
                    temperature=0.0) for i in range(2)]
    # 8 + 12 = 20 tokens/seq = 5 blocks each; 8 usable blocks force
    # eviction pressure between the two rows (cf. test_paged_attention's
    # eviction-resume test geometry)
    eng = _drain(params, cfg, reqs, prefill_chunk=4, metrics=metrics,
                 tracer=tracer, num_blocks=9)
    assert eng.evictions > 0
    counts = tracer.counts()
    assert counts["request.evict"] == eng.evictions
    assert metrics.value("serve_evictions_total") == eng.evictions
    assert metrics.value("serve_requests_admitted_total") == \
        len(reqs) + eng.evictions
    assert counts["request.admit"] == len(reqs) + eng.evictions
    resumed = [s for s in tracer.spans
               if s.name == "request.admit" and s.attrs["resumed"]]
    assert len(resumed) == eng.evictions


@pytest.mark.parametrize("max_new,expect_none", [(2, True), (3, True),
                                                 (4, False)])
def test_decode_latency_edge_cases(serve_setup, max_new, expect_none):
    """max_new=N -> N-1 width-1 decode ticks (the chunk-aligned prompt
    prefills in one full-width tick), first dropped as the jit tick: 0 or
    1 recorded samples must yield None, 2+ the percentile dict."""
    cfg, params = serve_setup
    reqs = [Request(rid=0, prompt=[12, 33, 7], max_new_tokens=max_new,
                    temperature=0.0)]
    eng = _drain(params, cfg, reqs, slots=1)
    recorded = eng.metrics.histogram("serve_decode_ms_per_token").count()
    assert recorded == max_new - 2
    lat = eng.decode_latency_ms()
    if expect_none:
        assert lat is None
    else:
        assert set(lat) == {"decode_p50_ms", "decode_p95_ms"}
        assert 0 < lat["decode_p50_ms"] <= lat["decode_p95_ms"] * (1 + 1e-9)


def test_decode_latency_zero_ticks():
    """An engine that never decoded reports None (zero-sample guard)."""
    cfg = _cfg()
    params = P.init_params(jax.random.PRNGKey(0), lm.lm_param_specs(cfg),
                           cfg.param_dtype)
    eng = PagedServingEngine(params, cfg, PagedServeConfig(
        slots=1, max_len=32, block_size=4, prefill_chunk=3))
    assert eng.decode_latency_ms() is None
    eng.close()


def test_engines_default_to_private_registries(serve_setup):
    """Two engines must not mix series: each owns its registry unless the
    caller passes a shared one."""
    cfg, params = serve_setup
    a = _drain(params, cfg, _requests(1))
    b = _drain(params, cfg, _requests(2))
    assert a.metrics is not b.metrics
    assert a.metrics.value("serve_requests_finished_total") == 1
    assert b.metrics.value("serve_requests_finished_total") == 2


# ---------------------------------------------------------------------------
# Fleet-health view (ft.supervisor over the registry)
# ---------------------------------------------------------------------------


def test_engine_health_reads_registry(serve_setup):
    cfg, params = serve_setup
    eng = _drain(params, cfg, _requests(2))
    h = supervisor.engine_health(eng.metrics)
    assert h.finished == 2 and h.errors == 0
    assert h.ticks > 0 and h.error_rate == 0.0
    assert h.queue_depth == 0 and h.active_requests == 0
    snap = eng.health_snapshot()
    assert snap["finished"] == 2 and snap["error_rate"] == 0.0


def test_engine_health_fresh_registry_is_healthy():
    h = supervisor.engine_health(obs.MetricsRegistry())
    assert h == supervisor.EngineHealth()
    assert not supervisor.HealthMonitor().observe(h)


def test_health_monitor_error_rate_and_backlog_patience():
    mon = supervisor.HealthMonitor(max_error_rate=0.1, max_queue_depth=4,
                                   patience=2)
    ok = supervisor.EngineHealth(ticks=10, errors=0, error_rate=0.0,
                                 queue_depth=2)
    assert not mon.observe(ok)
    bad = supervisor.EngineHealth(ticks=10, errors=5, error_rate=0.5)
    assert mon.observe(bad) and mon.events[-1][0] == "error_rate"
    # one hot tick is load...
    backlog = supervisor.EngineHealth(queue_depth=9)
    assert not mon.observe(backlog)
    # ...a sustained one is a stall
    assert mon.observe(backlog) and mon.events[-1][0] == "queue_backlog"
    # recovery resets the streak
    assert not mon.observe(ok)
    assert not mon.observe(backlog)


def test_health_monitor_observe_registry():
    reg = obs.MetricsRegistry()
    reg.counter("serve_ticks_total").inc(10, kind="decode")
    reg.counter("serve_errors_total").inc(3)
    mon = supervisor.HealthMonitor(max_error_rate=0.1)
    assert mon.observe_registry(reg)


# ---------------------------------------------------------------------------
# tools/obs_report.py CLI
# ---------------------------------------------------------------------------


def _snap_file(tmp_path, name, counters, gauges=None):
    p = tmp_path / name
    p.write_text(json.dumps({"counters": counters, "gauges": gauges or {},
                             "histograms": {}}))
    return str(p)


def test_obs_report_require_missing_fails(tmp_path, capsys):
    p = _snap_file(tmp_path, "m.json", {"a_total": 1})
    assert obs_report.main([p, "--require", "a_total"]) == 0
    assert obs_report.main([p, "--require", "b_total"]) == 1
    assert "b_total" in capsys.readouterr().err


def test_obs_report_require_strips_labels(tmp_path):
    p = _snap_file(tmp_path, "m.json", {"ticks_total{kind=decode}": 3})
    assert obs_report.main([p, "--require", "ticks_total"]) == 0


def test_obs_report_diff(tmp_path, capsys):
    a = _snap_file(tmp_path, "a.json", {"n_total": 2}, {"depth": 1})
    b = _snap_file(tmp_path, "b.json", {"n_total": 5, "new_total": 1},
                   {"depth": 0})
    assert obs_report.main([b, a]) == 0
    out = capsys.readouterr().out
    assert "2 -> 5" in out and "(+3)" in out
    assert "new_total" in out and "new (1)" in out
    assert "1 -> 0" in out


def test_obs_report_chrome_cli(tmp_path):
    tr = obs.Tracer()
    with tr.span("tick"):
        pass
    jsonl = tr.write_jsonl(str(tmp_path / "t.jsonl"))
    out = str(tmp_path / "t.json")
    assert obs_report.main(["--chrome", jsonl, "-o", out]) == 0
    payload = json.load(open(out))
    assert any(e.get("name") == "tick" for e in payload["traceEvents"])


def test_obs_report_cli_subprocess(tmp_path):
    """The tool runs as a script (the CI smoke job invokes it that way)."""
    reg = obs.MetricsRegistry()
    reg.counter("serve_requests_finished_total").inc(4)
    p = tmp_path / "m.prom"
    p.write_text(reg.exposition())
    tool = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "obs_report.py")
    res = subprocess.run(
        [sys.executable, tool, str(p), "--require",
         "serve_requests_finished_total"],
        capture_output=True, text=True)
    assert res.returncode == 0, res.stderr
    assert "serve_requests_finished_total" in res.stdout
