"""DeviceProfile: validation, frozen map determinism, exact fault census,
and the sigma=0/BER=0 ideality contract at the physics layer."""

import numpy as np
import pytest

from repro.arch import accounting
from repro.core import physics

TINY = physics.DEVICE_PROFILES["tiny"]


# ---------------------------------------------------------------------------
# Profile dataclass
# ---------------------------------------------------------------------------


def test_default_profile_is_ideal():
    p = physics.DeviceProfile()
    assert p.is_ideal and not p.has_faults


def test_nominal_offsets_alone_keep_ideality():
    # at the operating point I = I_c the rate multiplier is exactly 1
    # for every cell when sigma_* = 0, whatever the nominal values
    assert physics.DeviceProfile(delta=50.0, i_c_ua=90.0).is_ideal


def test_any_nonideality_breaks_ideality():
    assert not physics.DeviceProfile(sigma_ic=0.01).is_ideal
    assert not physics.DeviceProfile(ber_retention=1e-4).is_ideal
    assert physics.DeviceProfile(ber_retention=1e-4).has_faults


@pytest.mark.parametrize("bad", [
    dict(sigma_delta=-0.1), dict(ber_stuck0=-1e-3),
    dict(ber_stuck0=0.6, ber_stuck1=0.6), dict(map_cells=0),
])
def test_invalid_profiles_rejected(bad):
    with pytest.raises(ValueError):
        physics.DeviceProfile(**bad)


def test_named_profiles_resolve():
    assert physics.resolve_profile(None) is None
    assert physics.resolve_profile("tiny") is TINY
    assert physics.resolve_profile(TINY) is TINY
    with pytest.raises(KeyError, match="unknown device profile"):
        physics.named_profile("nope")


def test_profile_is_hashable_jit_static():
    assert hash(TINY) == hash(TINY.replace())


# ---------------------------------------------------------------------------
# Frozen maps: bit-reproducible, seed-keyed, wrap-around
# ---------------------------------------------------------------------------


def test_cell_maps_deterministic_and_seed_keyed():
    a = physics.cell_maps(TINY)
    b = physics.cell_maps(TINY.replace())         # fresh equal profile
    np.testing.assert_array_equal(a.rate, b.rate)
    np.testing.assert_array_equal(a.stuck0, b.stuck0)
    c = physics.cell_maps(TINY.replace(seed=1))
    assert not np.array_equal(a.rate, c.rate)


def test_cell_maps_realize_the_profiled_spread():
    prof = physics.DeviceProfile(sigma_ic=0.05, map_cells=1 << 14)
    maps = physics.cell_maps(prof)
    rel = np.asarray(maps.i_c_ua) / prof.i_c_ua - 1.0
    assert abs(float(rel.std()) - 0.05) < 0.005   # ~N(0, sigma_ic)
    # the exponent shift is symmetric around 0, so the MEDIAN rate is ~1
    # (the mean is not: rate = exp(-delta*(1 - ic/ic_c)) is heavy-tailed)
    np.testing.assert_allclose(np.median(np.asarray(maps.rate)), 1.0,
                               atol=0.05)


def test_ideal_maps_have_unit_rate_and_no_faults():
    maps = physics.cell_maps(physics.DeviceProfile(map_cells=1 << 10))
    np.testing.assert_array_equal(np.asarray(maps.rate),
                                  np.ones(1 << 10, np.float32))
    assert int(maps.cum0[-1]) == 0 and int(maps.cum1[-1]) == 0


def test_cell_span_wraps_round_robin():
    prof = TINY.replace(map_cells=8)
    idx = physics.cell_span(prof, 20, start=5)
    np.testing.assert_array_equal(idx[:3], [5, 6, 7])
    np.testing.assert_array_equal(idx, (np.arange(20) + 5) % 8)


# ---------------------------------------------------------------------------
# Exact fault census (what arch_bit_errors_total / CI gates rely on)
# ---------------------------------------------------------------------------


def test_stuck_counts_match_brute_force():
    prof = physics.DeviceProfile(ber_stuck0=0.02, ber_stuck1=0.01,
                                 map_cells=1 << 10)
    maps = physics.cell_maps(prof)
    for n_cells, start in [(100, 0), (1 << 10, 0), (5000, 777), (3, 1023)]:
        idx = physics.cell_span(prof, n_cells, start)
        want = (int(np.asarray(maps.stuck0)[idx].sum()),
                int(np.asarray(maps.stuck1)[idx].sum()))
        assert physics.stuck_counts(prof, n_cells, start) == want


def test_census_is_exact_and_deterministic():
    cells = 3 * (1 << 14) + 17                    # >1 full map wrap
    a = accounting.bit_error_census(TINY, cells)
    assert a == accounting.bit_error_census(TINY, cells)
    s0, s1 = physics.stuck_counts(TINY, cells)
    assert (a["stuck0"], a["stuck1"]) == (s0, s1)
    assert a["retention"] == int(round(TINY.ber_retention * cells))
    z = accounting.bit_error_census(physics.DeviceProfile(), cells)
    assert (z["stuck0"], z["stuck1"], z["retention"]) == (0, 0, 0)


def test_mul_cell_params_tile_the_map():
    prof = physics.DeviceProfile(sigma_delta=0.1, map_cells=1 << 12)
    delta, ic = physics.mul_cell_params(prof, n_muls=4, nbit=64)
    assert delta.shape == (4, 64) and ic.shape == (4, 64)
    maps = physics.cell_maps(prof)
    np.testing.assert_array_equal(np.asarray(delta)[0],
                                  np.asarray(maps.delta)[:64])
