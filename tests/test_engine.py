"""Bit-exact MRAM engine tests, incl. the paper's Fig. 7 statistics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conversion, engine

CFG = engine.EngineConfig(nbit=1024)


def test_preset_all_ones():
    s = engine.preset((4, 128))
    assert s.dtype == jnp.uint8
    assert int(s.sum()) == 4 * 128


def test_pulse_zero_duration_is_noop(key):
    s = engine.preset((2, 256))
    s2 = engine.apply_pulse(key, s, 0.0)
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))


def test_pulse_only_switches_toward_zero(key):
    """A stochastic pulse can only clear bits, never set them (Fig. 5
    polarity) — cells at 0 stay 0."""
    s = jnp.zeros((2, 256), jnp.uint8)
    s2 = engine.apply_pulse(key, s, 0.5)
    assert int(s2.sum()) == 0


def test_sc_multiply_shapes_and_range(key):
    x = jnp.array([100, 512, 1023])
    y = jnp.array([512, 512, 1023])
    p_est, prod = engine.sc_multiply(key, x, y, CFG)
    assert p_est.shape == (3,) and prod.shape == (3,)
    assert np.all(np.asarray(p_est) >= 0) and np.all(np.asarray(p_est) <= 1)


def test_sc_multiply_mean_is_unbiased(key):
    """E[p_est] = P_X·P_Y: the error distribution is zero-centered
    (paper Fig. 7a). Averaged over many iterations the bias must be well
    below the single-MUL sigma."""
    x, y = 400, 700
    iters = 400
    keys = jax.random.split(key, iters)
    p_est, _ = jax.vmap(lambda k: engine.sc_multiply(k, x, y, CFG))(keys)
    p_true = float(conversion.quantized_product_probability(x, y, CFG.conv))
    bias = float(jnp.mean(p_est)) - p_true
    sigma = float(jnp.std(p_est))
    assert abs(bias) < 3 * sigma / np.sqrt(iters) + 1e-4


@pytest.mark.slow
def test_fig7a_sigma_at_nbit_1000(key):
    """Paper Fig. 7a: with nbit=1000, tau_X=0.3 ns, tau_Y=0.4 ns the MUL
    uncertainty is sigma ~ 1.6 % (binomial: sqrt(p(1-p)/n) with
    p = e^-0.7 ~ 0.497 -> 1.58 %)."""
    cfg = engine.EngineConfig(nbit=1000)
    iters = 1000
    keys = jax.random.split(key, iters)
    p = jax.vmap(
        lambda k: engine.readout(
            engine.sc_multiply_states(k, 0.3, 0.4, cfg)))(keys)
    sigma = float(jnp.std(p))
    assert 0.013 < sigma < 0.019  # 1.6 % +/- measurement slack
    # zero-centered error (no intrinsic bias)
    p_true = float(np.exp(-0.7))
    assert abs(float(jnp.mean(p)) - p_true) < 0.002


@pytest.mark.slow
def test_fig7b_sigma_scales_inverse_sqrt_nbit(key):
    """sigma halves per 4x nbit (binomial counting statistics)."""
    sigmas = {}
    for nbit in (256, 1024, 4096):
        cfg = engine.EngineConfig(nbit=nbit)
        keys = jax.random.split(jax.random.fold_in(key, nbit), 400)
        p = jax.vmap(
            lambda k: engine.readout(
                engine.sc_multiply_states(k, 0.3, 0.4, cfg)))(keys)
        sigmas[nbit] = float(jnp.std(p))
    r1 = sigmas[256] / sigmas[1024]
    r2 = sigmas[1024] / sigmas[4096]
    assert 1.6 < r1 < 2.5 and 1.6 < r2 < 2.5


def test_fig7b_sigma_independent_of_input(key):
    """sigma is nearly flat in tau_Y (Fig. 7b): binomial sigma depends only
    weakly on p around the operating range."""
    cfg = engine.EngineConfig(nbit=1024)
    sig = []
    for tau_y in (0.2, 0.4, 0.6):
        keys = jax.random.split(jax.random.fold_in(key, int(tau_y * 10)), 300)
        p = jax.vmap(
            lambda k: engine.readout(
                engine.sc_multiply_states(k, 0.3, tau_y, cfg)))(keys)
        sig.append(float(jnp.std(p)))
    assert max(sig) / min(sig) < 1.6


def test_mac_rows_states_shape(key):
    w = jnp.array([10, 20, 30, 40])
    x = jnp.array([50, 60, 70, 80])
    states = engine.mac_rows(key, w, x, CFG)
    assert states.shape == (4, CFG.nbit)
    assert states.dtype == jnp.uint8


def test_rows_per_mul():
    assert engine.EngineConfig(nbit=1024, row_length=256).rows_per_mul == 4
    assert engine.EngineConfig(nbit=100, row_length=256).rows_per_mul == 1
