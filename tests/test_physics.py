"""Unit + property tests for the Eq. 3 switching physics."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import physics


def test_operating_point_collapses_to_exp():
    """At I = I_c the inner exponential is 1: P_usw = exp(-tau)."""
    tau = jnp.linspace(0.01, 5.0, 64)
    p = physics.p_unswitched(tau, physics.I_C_UA)
    np.testing.assert_allclose(np.asarray(p), np.exp(-np.asarray(tau)),
                               rtol=1e-6)


def test_preset_pulse_switches_deterministically():
    """The preset pulse (over-driven, long) leaves P_usw ~ 0."""
    p = physics.p_unswitched(physics.PRESET_TAU_NS,
                             physics.I_C_UA * physics.PRESET_I_FACTOR)
    assert float(p) < 1e-12


@given(tau=st.floats(0.01, 10.0), i=st.floats(40.0, 120.0))
@settings(max_examples=200, deadline=None)
def test_p_unswitched_in_unit_interval(tau, i):
    p = float(physics.p_unswitched(tau, i))
    assert 0.0 <= p <= 1.0


@given(tau=st.floats(0.01, 5.0),
       i1=st.floats(40.0, 119.0), di=st.floats(0.5, 20.0))
@settings(max_examples=200, deadline=None)
def test_monotone_decreasing_in_current(tau, i1, di):
    """Stronger current -> more switching -> lower survival."""
    p1 = float(physics.p_unswitched(tau, i1))
    p2 = float(physics.p_unswitched(tau, i1 + di))
    assert p2 <= p1 + 1e-12


@given(tau1=st.floats(0.01, 5.0), dt=st.floats(0.01, 5.0),
       i=st.floats(60.0, 100.0))
@settings(max_examples=200, deadline=None)
def test_monotone_decreasing_in_duration(tau1, dt, i):
    p1 = float(physics.p_unswitched(tau1, i))
    p2 = float(physics.p_unswitched(tau1 + dt, i))
    assert p2 <= p1 + 1e-12


@given(p=st.floats(1e-6, 1.0 - 1e-6))
@settings(max_examples=200, deadline=None)
def test_tau_inversion_roundtrip(p):
    """tau_for_probability inverts Eq. 3 at the operating point."""
    tau = physics.tau_for_probability(p)
    p_back = float(physics.p_unswitched(tau, physics.I_C_UA))
    assert abs(p_back - p) < 1e-5


def test_two_pulse_and_equals_product():
    """Survival of two sequential pulses multiplies (independent events) —
    the algebraic identity the whole MUL design rests on."""
    ta, tb = 0.3, 0.4
    pa = physics.p_unswitched(ta, physics.I_C_UA)
    pb = physics.p_unswitched(tb, physics.I_C_UA)
    pab = physics.p_unswitched(ta + tb, physics.I_C_UA)
    np.testing.assert_allclose(float(pa * pb), float(pab), rtol=1e-6)


def test_scale_to_half_switching_targets_half():
    tau = jnp.array([0.1, 0.2, 0.3, 0.4])
    scale, scaled = physics.scale_to_half_switching(tau)
    mean_p = float(jnp.exp(-jnp.mean(scaled)))
    np.testing.assert_allclose(mean_p, 0.5, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(scaled), np.asarray(tau * scale))


def test_switching_energy_scales_with_tau_and_current():
    e1 = float(physics.switching_energy_aj(1.0, 80.0))
    e2 = float(physics.switching_energy_aj(2.0, 80.0))
    e3 = float(physics.switching_energy_aj(1.0, 160.0))
    np.testing.assert_allclose(e2, 2 * e1, rtol=1e-6)
    np.testing.assert_allclose(e3, 4 * e1, rtol=1e-6)


def test_p_usw_monotone_in_tau_on_dense_grid():
    """Deterministic (no-hypothesis) edge sweep: survival is strictly
    non-increasing in pulse duration across the whole DTC range, at weak,
    operating, and over-driven currents."""
    tau = jnp.linspace(1e-3, 16.0, 512)
    for i_ua in (40.0, physics.I_C_UA, physics.I_C_UA * 1.25):
        p = np.asarray(physics.p_unswitched(tau, i_ua))
        assert np.all(np.diff(p) <= 1e-12), i_ua
        assert np.all((p >= 0.0) & (p <= 1.0))


def test_p_usw_monotone_in_current_on_dense_grid():
    i = jnp.linspace(40.0, 120.0, 512)
    for tau in (0.01, 0.5, physics.PRESET_TAU_NS):
        p = np.asarray(physics.p_unswitched(tau, i))
        assert np.all(np.diff(p) <= 1e-12), tau


def test_preset_survival_below_1e26():
    """The over-driven preset pulse leaves P_usw < 1e-26 — every cell is
    deterministically initialized before the stochastic pulses (§III-B)."""
    p = physics.p_unswitched(physics.PRESET_TAU_NS,
                             physics.I_C_UA * physics.PRESET_I_FACTOR)
    assert float(p) < 1e-26


def test_per_cell_ic_array_broadcasts():
    ic = jnp.array([70.0, 80.0, 90.0])
    p = physics.p_unswitched(0.5, 80.0, i_c_ua=ic)
    assert p.shape == (3,)
    # higher I_c relative to drive -> less switching -> higher survival
    assert float(p[2]) > float(p[1]) > float(p[0])
