"""Unit tests for the roofline HLO analyzer on synthetic HLO text."""

import numpy as np

from repro.launch import hlo_analysis as H

SIMPLE = """
HloModule jit_f

ENTRY %main (p0: f32[128,256], p1: f32[256,64]) -> f32[128,64] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = f32[256,64]{1,0} parameter(1)
  ROOT %dot.1 = f32[128,64]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_dot_flops_counted():
    c = H.analyze_hlo(SIMPLE)
    assert c.flops == 2 * 128 * 64 * 256
    # io bytes: operands + output
    assert c.bytes == 4 * (128 * 256 + 256 * 64 + 128 * 64)


COLLECTIVE = """
HloModule jit_f

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={}
  ROOT %ag = f32[2048]{0} all-gather(%ar), dimensions={0}
}
"""


def test_collective_bytes_by_kind():
    c = H.analyze_hlo(COLLECTIVE)
    assert c.coll_by_kind["all-reduce"] == 4096
    assert c.coll_by_kind["all-gather"] == 4096   # operand bytes, not output
    assert c.coll_bytes == 8192


LOOP = """
HloModule jit_f

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %iter = s32[] get-tuple-element(%arg), index=0
  %limit = s32[] constant(48)
  ROOT %lt = pred[] compare(%iter, %limit), direction=LT
}

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %iter = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %next = s32[] add(%iter, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%next, %d)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %p0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplies_body():
    c = H.analyze_hlo(LOOP)
    # dot inside a 48-trip while: flops x 48 (the scan-over-layers pattern)
    assert c.flops == 48 * 2 * 8 * 8 * 8
    assert c.unresolved_loops == 0


FUSION_SLICE = """
HloModule jit_f

%fused (fp0: f32[48,64,64], fp1: s32[]) -> f32[64,64] {
  %fp0 = f32[48,64,64]{2,1,0} parameter(0)
  %fp1 = s32[] parameter(1)
  %zero = s32[] constant(0)
  ROOT %ds = f32[1,64,64]{2,1,0} dynamic-slice(%fp0, %fp1, %zero, %zero), dynamic_slice_sizes={1,64,64}
}

ENTRY %main (p0: f32[48,64,64], p1: s32[]) -> f32[64,64] {
  %p0 = f32[48,64,64]{2,1,0} parameter(0)
  %p1 = s32[] parameter(1)
  ROOT %f = f32[1,64,64]{2,1,0} fusion(%p0, %p1), kind=kLoop, calls=%fused
}
"""


def test_fusion_operand_charged_at_slice_size():
    """The scan-over-layers pattern: a fusion that reads one (1, 64, 64)
    slice of a stacked (48, 64, 64) operand is charged the SLICE bytes,
    not the full stack (what TPU HBM actually streams)."""
    c = H.analyze_hlo(FUSION_SLICE)
    slice_bytes = 4 * 64 * 64
    full_bytes = 48 * slice_bytes
    assert c.bytes_by_opcode["fusion"] < full_bytes
    assert c.bytes_by_opcode["fusion"] >= 2 * slice_bytes  # in + out


def test_roofline_terms_and_bound():
    cost = H.HloCost(flops=197e12, bytes=819e9 * 2, coll_by_kind={})
    rf = H.roofline_from_cost(cost, chips=1, model_flops=100e12)
    np.testing.assert_allclose(rf.compute_s, 1.0)
    np.testing.assert_allclose(rf.memory_s, 2.0)
    assert rf.bound == "memory"
    np.testing.assert_allclose(rf.useful_fraction, 100 / 197, rtol=1e-6)


def test_roofline_collective_bound():
    cost = H.HloCost(flops=1.0, bytes=1.0, coll_by_kind={"all-reduce": 50e9})
    rf = H.roofline_from_cost(cost, chips=1)
    assert rf.bound == "collective"
    np.testing.assert_allclose(rf.collective_s, 1.0)


def test_param_counts():
    from repro.configs import get_config
    cfg = get_config("yi-6b")
    n = H.param_count(cfg)
    assert 5.5e9 < n < 7.0e9          # "yi-6b"
    moe = get_config("moonshot-v1-16b-a3b")
    total, active = H.param_count(moe), H.active_param_count(moe)
    # NOTE: the ASSIGNED hyperparameters (48L x 64e x d_ff=1408) yield ~28B
    # total — larger than the model card's name tag; the assignment's
    # numbers govern. Active ~3.6B matches the "a3b" tag.
    assert 20e9 < total < 32e9
    assert 2e9 < active < 4.5e9       # "a3b"
    assert active < total


def test_model_flops_includes_attention():
    from repro.configs import SHAPES, get_config
    cfg = get_config("yi-6b")
    f_train = H.model_flops_estimate(cfg, SHAPES["train_4k"])
    f_prefill = H.model_flops_estimate(cfg, SHAPES["prefill_32k"])
    n = H.param_count(cfg)
    # train: at least the 6*N*D weight term
    assert f_train > 6.0 * n * 4096 * 256
    # prefill at 32k: attention term must exceed the weight term
    weight_term = 2.0 * n * 32768 * 32
    assert f_prefill > 1.5 * weight_term
    # ssm arch: no attention term
    m = get_config("mamba2-370m")
    f = H.model_flops_estimate(m, SHAPES["prefill_32k"])
    assert f == 2.0 * H.param_count(m) * 32768 * 32
