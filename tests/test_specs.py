"""Production sharding trees, pinned on an ABSTRACT 16x16 / 2x16x16 mesh —
validates the exact layouts the dry-run compiles with, without needing 512
devices."""

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch import specs as S

POD = S.abstract_mesh((16, 16), ("data", "model"))
MULTI = S.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def spec_of(sharding):
    return sharding.spec


def test_param_shardings_fsdp_x_tp():
    cfg = get_config("yi-6b")
    sh = S.param_shardings(cfg, POD)
    # attention wq (d_model=4096, heads 32*128=4096): leading stacked-layer
    # axis never shards; embed->data, heads->model
    assert spec_of(sh["blocks"]["attn"]["wq"]) == P(None, "data", "model")
    # embedding (vocab 64000, embed): vocab->model, embed->data
    assert spec_of(sh["embed"]["table"]) == P("model", "data")
    # norms FSDP-shard their embed axis
    assert spec_of(sh["final_norm"]) == P("data")


def test_param_shardings_indivisible_dims_replicate():
    cfg = get_config("qwen2-0.5b")           # 14 heads, kv=2 on a 16 axis
    sh = S.param_shardings(cfg, POD)
    # qkv bias (stacked (24, 896)): layer axis None, heads axis -> model
    assert spec_of(sh["blocks"]["attn"]["bq"]) == P(None, "model")
    # kv-head dims that DON'T divide replicate per-tensor: wk kv_embed
    # = 2*64 = 128 -> divides 16, so it shards; zamba2 conv (k=4) does not
    z = S.param_shardings(get_config("zamba2-7b"), POD)
    assert spec_of(z["blocks"]["ssm"]["conv_x"])[1] is None


def test_moe_param_shardings_ep():
    cfg = get_config("llama4-maverick-400b-a17b")
    sh = S.param_shardings(cfg, POD)
    # experts -> model (EP), embed -> data (FSDP), expert_mlp replicated
    # (leading stacked-layer axis never shards)
    assert spec_of(sh["blocks"]["ffn"]["wi"]) == P(None, "model", "data", None)
    assert spec_of(sh["blocks"]["ffn"]["wo"]) == P(None, "model", None, "data")


def test_cache_shardings_decode_seq_over_model():
    cfg = get_config("qwen3-14b")
    sh = S.cache_shardings(cfg, POD, batch=128, max_len=32768)
    # (layers, batch, seq, kv, hd): batch->data, seq->model (the fleet-wide
    # decode fix), kv replicated (8 % 16 != 0 anyway)
    assert spec_of(sh["k"]) == P(None, ("data",), "model", None, None)


def test_cache_shardings_long_context_all_axes():
    cfg = get_config("mamba2-370m")
    sh = S.cache_shardings(cfg, POD, batch=1, max_len=524288)
    # ssm cache: no seq axis; state shards heads over model
    assert spec_of(sh["ssm"]["state"]) == P(None, None, "model", None, None)


def test_cache_shardings_hybrid_long500k():
    cfg = get_config("zamba2-7b")
    sh = S.cache_shardings(cfg, POD, batch=1, max_len=524288)
    # batch=1 -> the shared-attn KV cache seq shards over EVERY axis
    assert spec_of(sh["shared_k"]) == P(None, None, ("data", "model"),
                                        None, None)


def test_batch_shardings_multipod():
    cfg = get_config("yi-6b")
    sh = S.batch_shardings(cfg, MULTI, batch=256)
    assert spec_of(sh["inputs"]) == P(("pod", "data"), None)


def test_input_specs_shapes():
    cfg = get_config("yi-6b")
    t = S.input_specs(cfg, SHAPES["train_4k"])
    assert t["batch"]["inputs"].shape == (256, 4096)
    d = S.input_specs(cfg, SHAPES["decode_32k"])
    assert d["tokens"].shape == (128,)
    assert d["cache"]["k"].shape == (32, 128, 32768, 4, 128)
    # embeddings frontend (stub modality): 3-D float inputs
    mg = get_config("musicgen-large")
    e = S.input_specs(mg, SHAPES["prefill_32k"])
    assert e["inputs"].shape == (32, 32768, 2048)


def test_logits_sharding_vocab_tp():
    cfg = get_config("yi-6b")
    sh = S.logits_sharding(cfg, POD, batch=32, with_seq=False)
    assert spec_of(sh) == P(("data",), "model")
