"""Optional-hypothesis shim: property tests skip cleanly when the package
is absent (it is a dev-only dependency, see requirements-dev.txt).

Usage in test modules:

    from _hypothesis_compat import given, settings, st

With hypothesis installed these are the real decorators; without it,
``@given(...)`` replaces the test with a zero-argument function that calls
``pytest.skip`` — so the rest of the module's tests still collect and run.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
