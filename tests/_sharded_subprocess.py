"""Multi-device checks for the mesh-sharded SC substrate.

Run by tests/test_sc_sharded.py in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process must keep seeing the single real CPU device — see conftest.py).
Everything rides one interpreter so the jax startup cost is paid once.
Prints ``ALL-SHARDED-OK`` as the success sentinel.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import arch, sc

assert len(jax.devices()) == 8, jax.devices()

key = jax.random.PRNGKey(0)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
w = jax.random.normal(jax.random.PRNGKey(2), (32, 6))
exact = np.asarray(x @ w)

mesh24 = jax.make_mesh((2, 4), ("data", "model"))
mesh18 = jax.make_mesh((1, 8), ("data", "model"))
mesh81 = jax.make_mesh((8, 1), ("data", "model"))

# --- identical keys => identical bits when no axis actually splits -------
# On a 1x8 mesh with rules naming only the (size-1) data axis, resolve_rules
# drops everything and the sharded entry point must reproduce single-device
# sc_dot bit-for-bit with the same key.
trivial = sc.ScShardRules(batch=("data",), contract=())
for backend in ("moment", "bitexact", "pallas_fused"):
    cfg = sc.ScConfig(backend=backend, nbit=512)
    y_ref = sc.sc_dot(key, x, w, cfg)
    y_sh = sc.sc_dot_sharded(key, x, w, cfg, mesh=mesh18, rules=trivial)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_sh),
                                  err_msg=f"{backend}: 1xN trivial mesh")

# --- moment backend matches the contraction to tolerance on every mesh ---
cfg_m = sc.ScConfig(backend="moment", nbit=1 << 16)
for mesh in (mesh24, mesh18, mesh81):
    y = np.asarray(sc.sc_dot_sharded(key, x, w, cfg_m, mesh=mesh))
    # noise std per output ~ scale_x*scale_w*sqrt(K p(1-p))/sqrt(nbit)
    assert np.max(np.abs(y - exact)) < 0.5, (dict(mesh.shape),
                                             np.max(np.abs(y - exact)))
    # deterministic given (key, mesh, rules)
    y2 = np.asarray(sc.sc_dot_sharded(key, x, w, cfg_m, mesh=mesh))
    np.testing.assert_array_equal(y, y2)

# --- bitexact: reproducible bits, unbiased contraction -------------------
cfg_b = sc.ScConfig(backend="bitexact", nbit=4096)
yb = np.asarray(sc.sc_dot_sharded(key, x, w, cfg_b, mesh=mesh24))
yb2 = np.asarray(sc.sc_dot_sharded(key, x, w, cfg_b, mesh=mesh24))
np.testing.assert_array_equal(yb, yb2)
assert np.max(np.abs(yb - exact)) < 1.0

# --- pallas_fused shards and stays bit-identical to pallas_bitexact ------
# Every shard folds the same key, sees the same local operand block, and
# draws the same counter-based stream in both engines, so the psum-merged
# outputs agree bit-for-bit even across a real 2x4 mesh split.
cfg_f = sc.ScConfig(backend="pallas_fused", nbit=64)
yf = np.asarray(sc.sc_dot_sharded(key, x, w, cfg_f, mesh=mesh24))
yf2 = np.asarray(sc.sc_dot_sharded(key, x, w, cfg_f, mesh=mesh24))
np.testing.assert_array_equal(yf, yf2)
yp = np.asarray(sc.sc_dot_sharded(
    key, x, w, sc.ScConfig(backend="pallas_bitexact", nbit=64),
    mesh=mesh24))
np.testing.assert_array_equal(yf, yp)
assert np.max(np.abs(yf - exact)) < 4.0

# --- STE gradients ride through the psum merge ---------------------------
def loss(x, w):
    return sc.sc_dot_sharded(key, x, w, cfg_m, mesh=mesh24).sum()

gx, gw = jax.grad(loss, (0, 1))(x, w)
g = jnp.ones(exact.shape)
np.testing.assert_allclose(np.asarray(gx), np.asarray(g @ w.T),
                           rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ g),
                           rtol=1e-5, atol=1e-5)

# ... and under jit, exactly like the model stack runs it
gx_j = jax.jit(jax.grad(loss))(x, w)
np.testing.assert_allclose(np.asarray(gx_j), np.asarray(gx),
                           rtol=1e-6, atol=1e-6)

# --- array backend: per-shard records merge as concurrent banks ----------
xa = jax.random.normal(jax.random.PRNGKey(3), (32, 256))
wa = jax.random.normal(jax.random.PRNGKey(4), (256, 64))
cfg_a = sc.ScConfig(backend="array", nbit=1024)
with arch.collect() as recs_single:
    sc.sc_dot(key, xa, wa, cfg_a)
with arch.collect() as recs_shard:
    sc.sc_dot_sharded(key, xa, wa, cfg_a, mesh=mesh24)
(single,) = recs_single
(shard,) = recs_shard
assert shard.shards == 8, shard.shards
assert shard.shape == (16, 64, 64), shard.shape
merged = shard.effective_report
assert merged.cycles < single.report.cycles, \
    (merged.cycles, single.report.cycles)
assert merged.products == single.report.products
assert abs(merged.energy_pj - single.report.energy_pj) \
    < 1e-6 * single.report.energy_pj

# --- serve engine: slots map to shards, per-slot temperatures intact -----
from repro.configs import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import lm, params as params_lib
from repro.serve import Request, ServeConfig, ServingEngine
from repro.sharding import sc_shard_rules

cfg = get_smoke_config("paper-sc").replace(
    param_dtype=jnp.float32, act_dtype=jnp.float32,
    sc_backend="moment", sc_nbit=4096)
params = params_lib.init_params(
    jax.random.PRNGKey(0), lm.lm_param_specs(cfg), cfg.param_dtype)
mesh = make_local_mesh(2)                       # (data=4, model=2)

def run_engine(seed):
    eng = ServingEngine(params, cfg,
                        ServeConfig(slots=4, max_len=64, seed=seed),
                        mesh=mesh, shard_rules=sc_shard_rules(mesh))
    for rid, t in enumerate([0.0, 0.9, 0.0]):
        eng.submit(Request(rid=rid, prompt=[5, 6, 7, 8],
                           max_new_tokens=3, temperature=t))
    fin = eng.run_until_drained()
    return {r.rid: list(r.generated) for r in fin}

g_a = run_engine(seed=0)
g_b = run_engine(seed=0)
assert g_a == g_b, "same seed must reproduce on the mesh"
g_c = run_engine(seed=7)
# greedy slots ignore the engine rng entirely at the sampling step; the
# sampled slot (rid=1) re-draws. (The substrate rng changes with the seed
# too, so only the sampling invariance is asserted: greedy outputs depend
# solely on logits, which the new seed perturbs within the moment noise.)
assert len(g_c) == 3 and all(len(v) == 3 for v in g_c.values())

# slot grid must align with the data span
try:
    ServingEngine(params, cfg, ServeConfig(slots=3, max_len=64),
                  mesh=mesh, shard_rules=sc_shard_rules(mesh))
except ValueError:
    pass
else:
    raise AssertionError("slots=3 on a data=4 mesh must be rejected")

print("ALL-SHARDED-OK")
