"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the single real
CPU device (the 512-device flag is exclusively dryrun.py's)."""

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running statistical test")
