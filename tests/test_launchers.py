"""Entrypoint tests: the production launchers run end-to-end on CPU."""

import numpy as np

from repro.launch import serve as serve_launch
from repro.launch import train as train_launch


def test_train_launcher_end_to_end(tmp_path):
    state, history = train_launch.main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    assert len(history["loss"]) == 6
    assert all(np.isfinite(history["loss"]))
    # checkpoint landed
    from repro import checkpoint
    assert checkpoint.latest_step(str(tmp_path)) == 6


def test_train_launcher_resume(tmp_path):
    train_launch.main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "4", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"])
    state, history = train_launch.main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
        "--resume"])
    assert len(history["loss"]) == 2          # resumed at step 4, ran to 6


def test_train_launcher_with_injected_failure(tmp_path):
    state, history = train_launch.main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
        "--inject-failure-at", "4"])
    assert len(history["recoveries"]) == 1
    assert all(np.isfinite(history["loss"]))


def test_serve_launcher_end_to_end():
    finished = serve_launch.main([
        "--arch", "qwen2-0.5b", "--smoke", "--requests", "4", "--slots", "2",
        "--max-len", "48", "--max-new", "4"])
    assert len(finished) == 4
    assert all(len(r.generated) >= 1 for r in finished)


def test_train_launcher_sc_mode(tmp_path):
    """The --sc-mode flag routes the whole model through the SC engine."""
    state, history = train_launch.main([
        "--arch", "paper-sc", "--smoke", "--steps", "4", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--sc-mode", "moment"])
    assert all(np.isfinite(history["loss"]))
