"""Serving engine: continuous batching, greedy-decode reference equality."""

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm, params as P
from repro.serve import Request, ServeConfig, ServingEngine

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _engine(key, slots=2, max_len=64, arch="qwen2-0.5b"):
    cfg = get_smoke_config(arch).replace(**F32)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    return ServingEngine(params, cfg, ServeConfig(slots=slots,
                                                  max_len=max_len)), \
        params, cfg


def _greedy_reference(params, cfg, prompt, n_new):
    """Token-by-token greedy decode via full forward passes (no cache)."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits = lm.forward(params, jnp.asarray([toks], jnp.int32), cfg)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_greedy_generation_matches_cacheless_reference(key):
    engine, params, cfg = _engine(key, slots=1)
    prompt = [5, 9, 17, 3]
    n_new = 6
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=n_new))
    finished = engine.run_until_drained()
    assert len(finished) == 1
    ref = _greedy_reference(params, cfg, prompt, n_new)
    got = finished[0].generated[:n_new]
    # EOS may cut generation short; compare the emitted prefix
    assert got == ref[:len(got)]
    assert len(got) >= 1


def test_continuous_batching_drains_queue(key):
    engine, _, cfg = _engine(key, slots=2)
    for rid in range(5):
        engine.submit(Request(rid=rid, prompt=[3 + rid, 7, 11],
                              max_new_tokens=4))
    finished = engine.run_until_drained()
    assert len(finished) == 5
    assert sorted(r.rid for r in finished) == list(range(5))
    for r in finished:
        assert 1 <= len(r.generated) <= 4


def test_batched_decode_matches_solo_decode(key):
    """Two requests decoded in the same slot grid produce the same tokens
    as each decoded alone (slots are independent)."""
    p1, p2 = [5, 9, 17], [40, 2, 8, 30]
    engine, params, cfg = _engine(key, slots=2)
    engine.submit(Request(rid=0, prompt=p1, max_new_tokens=4))
    engine.submit(Request(rid=1, prompt=p2, max_new_tokens=4))
    both = {r.rid: r.generated for r in engine.run_until_drained()}

    for rid, prompt in ((0, p1), (1, p2)):
        solo_engine, _, _ = _engine(key, slots=1)
        solo_engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=4))
        solo = solo_engine.run_until_drained()[0].generated
        assert both[rid] == solo


def test_slot_reuse_after_finish(key):
    engine, _, cfg = _engine(key, slots=1)
    engine.submit(Request(rid=0, prompt=[4, 5], max_new_tokens=2))
    engine.submit(Request(rid=1, prompt=[6, 7], max_new_tokens=2))
    finished = engine.run_until_drained()
    assert [r.rid for r in finished] == [0, 1]


def test_max_len_cap_terminates(key):
    engine, _, cfg = _engine(key, slots=1, max_len=12)
    engine.submit(Request(rid=0, prompt=[3, 4, 5], max_new_tokens=1000))
    finished = engine.run_until_drained(max_ticks=64)
    assert len(finished) == 1      # capped by max_len, not max_ticks


def test_serving_ssm_arch_matches_reference(key):
    """Continuous batching over the attention-free mamba2 cache (conv tails
    + SSD state splice) matches cacheless greedy decode."""
    engine, params, cfg = _engine(key, slots=2, arch="mamba2-370m")
    prompt = [7, 11, 13]
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    got = engine.run_until_drained()[0].generated
    ref = _greedy_reference(params, cfg, prompt, 4)
    assert got == ref[:len(got)] and len(got) >= 1


def test_serving_hybrid_arch_drains(key):
    engine, params, cfg = _engine(key, slots=2, arch="zamba2-7b")
    for rid in range(3):
        engine.submit(Request(rid=rid, prompt=[5 + rid, 9], max_new_tokens=3))
    finished = engine.run_until_drained()
    assert len(finished) == 3
    for r in finished:
        assert all(0 <= t < cfg.vocab for t in r.generated)
