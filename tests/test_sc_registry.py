"""The unified SC substrate: registry round-trip, backend equivalence,
config aliasing, and the dense() -> Pallas end-to-end acceptance path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sc
from repro.configs import get_smoke_config
from repro.kernels.sc_mul import sc_mul_bitexact
from repro.models import layers, lm, params as P

ALL_BACKENDS = ("exact", "moment", "bitexact", "pallas_moment",
                "pallas_bitexact", "pallas_fused")
# small, block-aligned shape every backend (incl. O(M·K·N·nbit) ones) can run
_CFG = dict(nbit=256, block_m=8, block_n=8, block_k=32)


def _xw(key, m=8, k=32, n=8):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    return x, w


def test_all_core_backends_registered():
    assert set(ALL_BACKENDS) <= set(sc.available_backends())


def test_unknown_backend_rejected(key):
    x, w = _xw(key)
    with pytest.raises(ValueError, match="unknown SC backend"):
        sc.sc_dot(key, x, w, sc.ScConfig(backend="bogus"))


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_registry_round_trip(key, backend):
    """Every backend dispatches through the single sc_dot entry point and
    produces a finite (M, N) estimate of x @ w."""
    x, w = _xw(key)
    y = sc.sc_dot(key, x, w, sc.ScConfig(backend=backend, **_CFG))
    assert y.shape == (8, 8)
    assert bool(jnp.all(jnp.isfinite(y)))
    if backend == "exact":
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=1e-5)


@pytest.mark.parametrize("backend",
                         ["moment", "bitexact", "pallas_moment",
                          "pallas_bitexact", "pallas_fused"])
def test_backends_agree_with_exact_in_expectation(key, backend):
    """All stochastic backends estimate x @ w with zero-centered error."""
    x, w = _xw(key, m=4, k=32, n=4)
    cfg = sc.ScConfig(backend=backend, **_CFG)
    n_rep = 48
    if backend.startswith("pallas"):
        outs = jnp.stack([sc.sc_dot(k_, x, w, cfg)
                          for k_ in jax.random.split(key, n_rep)])
    else:
        outs = jax.vmap(lambda k_: sc.sc_dot(k_, x, w, cfg))(
            jax.random.split(key, n_rep))
    mean = np.asarray(outs.mean(axis=0))
    exact = np.asarray(x @ w)
    sigma = np.asarray(outs.std(axis=0))
    # 5 SE of the mean + operand-quantization bias slack
    tol = 5 * sigma / np.sqrt(n_rep) + 0.02 * np.abs(exact).max()
    assert (np.abs(mean - exact) < tol).mean() > 0.9


def test_moment_matches_pallas_moment_on_shared_seed(key):
    """On block-aligned shapes the jnp moment backend and the fused Pallas
    kernel consume the SAME noise draw per key -> identical outputs to
    float tolerance (the strongest moment-match statement)."""
    x, w = _xw(key, m=16, k=64, n=16)
    core = sc.sc_dot(key, x, w, sc.ScConfig(backend="moment", nbit=256))
    fused = sc.sc_dot(key, x, w, sc.ScConfig(
        backend="pallas_moment", nbit=256, block_m=16, block_n=16,
        block_k=64))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(core),
                               rtol=1e-4, atol=1e-4)


def test_bitexact_matches_pallas_bitexact_moments(key):
    """Binomial-draw core and the packed Pallas engine sample the same
    per-product distribution: first/second moments agree over shared
    seeds."""
    x, w = _xw(key, m=4, k=16, n=4)
    keys = jax.random.split(key, 64)
    cfg_core = sc.ScConfig(backend="bitexact", nbit=256)
    cfg_pal = sc.ScConfig(backend="pallas_bitexact", nbit=256)
    core = jax.vmap(lambda k_: sc.sc_dot(k_, x, w, cfg_core))(keys)
    pal = jnp.stack([sc.sc_dot(k_, x, w, cfg_pal) for k_ in keys])
    exact = np.asarray(x @ w)
    se = np.asarray(core.std(0)) / np.sqrt(64)
    # both unbiased around the exact product
    assert (np.abs(np.asarray(core.mean(0)) - exact)
            < 5 * se + 0.02 * np.abs(exact).max()).mean() > 0.9
    assert (np.abs(np.asarray(pal.mean(0)) - exact)
            < 5 * se + 0.02 * np.abs(exact).max()).mean() > 0.9
    # matching spread
    ratio = np.asarray(pal.std(0)) / np.maximum(np.asarray(core.std(0)),
                                                1e-9)
    assert 0.6 < np.median(ratio) < 1.6


@pytest.mark.parametrize("backend", ["moment", "pallas_moment"])
def test_straight_through_gradients_at_dispatch_boundary(key, backend):
    """The custom_vjp lives on sc_dot, so even the Pallas kernels (which
    have no differentiation rules) train with the exact-product
    jacobian."""
    x, w = _xw(key)
    cfg = sc.ScConfig(backend=backend, **_CFG)

    def loss(x_, w_):
        return jnp.sum(sc.sc_dot(key, x_, w_, cfg) ** 2)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    y = sc.sc_dot(key, x, w, cfg)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * (y @ w.T)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(2 * (x.T @ y)),
                               rtol=1e-4, atol=1e-4)


def test_packed_engine_agrees_with_bitexact_backend_stats(key):
    """The raw packed-engine entry point (kernels.sc_mul.sc_mul_bitexact,
    the survivor of the deleted ops.py shim) estimates the same products
    the registry's bitexact backend builds its MACs from."""
    probs = jnp.array([0.1, 0.25, 0.5, 0.7, 0.9, 0.33, 0.66, 0.05])
    keys = jax.random.split(key, 64)
    ests = jax.vmap(lambda k_: sc_mul_bitexact(
        k_, probs, probs[::-1], nbit=2048))(keys)
    true = np.asarray(probs * probs[::-1])
    sigma = np.sqrt(true * (1 - true) / 2048)
    np.testing.assert_allclose(np.asarray(ests.mean(0)), true,
                               atol=5 * np.max(sigma) / np.sqrt(64) + 1e-3)


def test_model_config_backend_aliasing():
    cfg = get_smoke_config("paper-sc")
    assert cfg.sc_backend == "moment" and cfg.sc_mode == "moment"
    up = cfg.replace(sc_backend="pallas_moment")
    assert up.sc_mode == "pallas_moment"
    legacy = up.replace(sc_mode="exact")
    assert legacy.sc_backend == "exact"


def test_dense_reaches_pallas_kernel_end_to_end(key):
    """Acceptance: dense() reaches the fused Pallas kernel via
    ScConfig(backend="pallas_moment") — both at the layer level and
    through a full LM forward."""
    cfg = get_smoke_config("paper-sc").replace(
        sc_backend="pallas_moment", param_dtype=jnp.float32,
        act_dtype=jnp.float32)
    # layer level
    x = jax.random.normal(key, (2, 8, 64), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32), jnp.float32)
    y = layers.dense(x, w, cfg, key=key)
    assert y.shape == (2, 8, 32)
    exact = layers.dense(x, w, cfg.replace(sc_backend="exact"))
    err = float(jnp.abs(y - exact).mean())
    assert 0.0 < err < 0.2 * float(jnp.abs(exact).max())
    # full model: stochastic forward, close to exact logits
    params = P.init_params(key, lm.lm_param_specs(cfg), jnp.float32)
    toks = jax.random.randint(key, (1, 16), 2, cfg.vocab)
    l1 = lm.forward(params, toks, cfg, rng=jax.random.PRNGKey(1))
    l2 = lm.forward(params, toks, cfg, rng=jax.random.PRNGKey(2))
    assert float(jnp.abs(l1 - l2).max()) > 0     # stochastic substrate
    e1 = lm.forward(params, toks, cfg.replace(sc_backend="exact"))
    assert float(jnp.abs(l1 - e1).mean()) < 1.0  # moment-matched noise


def test_ideal_device_profile_is_bit_identical_everywhere(key):
    """Acceptance (PR-10): a DeviceProfile with sigma=0 and BER=0 changes
    NOTHING — every backend (including the arch ``array`` backend, the
    only one that realizes non-ideal devices) returns bit-identical
    outputs with ``device=ideal`` vs ``device=None``."""
    from repro.core import physics
    x, w = _xw(key, m=4, k=32, n=4)
    ideal = physics.DeviceProfile()
    assert ideal.is_ideal
    for backend in ALL_BACKENDS + ("array",):
        cfg = sc.ScConfig(backend=backend, **_CFG)
        y0 = sc.sc_dot(key, x, w, cfg)
        y1 = sc.sc_dot(key, x, w, cfg.replace(device=ideal))
        np.testing.assert_array_equal(
            np.asarray(y0), np.asarray(y1),
            err_msg=f"{backend}: ideal profile broke bit identity")


def test_nonideal_profile_perturbs_only_the_array_backend(key):
    """The fault model lives in the array backend alone: functional
    backends model the ideal device by construction."""
    from repro.core import physics
    x, w = _xw(key, m=4, k=32, n=4)
    tiny = physics.DEVICE_PROFILES["tiny"]
    acfg = sc.ScConfig(backend="array", **_CFG)
    ya0 = sc.sc_dot(key, x, w, acfg)
    ya1 = sc.sc_dot(key, x, w, acfg.replace(device=tiny))
    assert float(jnp.abs(ya0 - ya1).max()) > 0
    bcfg = sc.ScConfig(backend="bitexact", **_CFG)
    np.testing.assert_array_equal(
        np.asarray(sc.sc_dot(key, x, w, bcfg)),
        np.asarray(sc.sc_dot(key, x, w, bcfg.replace(device=tiny))))
