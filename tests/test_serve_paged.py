"""Paged continuous-batching serve path: block pool, paged == contiguous
attention, batch-composition invariance, eviction/resume determinism, and
the arch-collector lifecycle fixes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import attention, lm, params as P
from repro.serve import (PagedCacheConfig, PagedServeConfig,
                         PagedServingEngine, PagedKVCache, Request,
                         ServeConfig, ServingEngine)
from repro.serve.kv_cache import BlockPool, blocks_for, default_num_blocks

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _cfg(arch="qwen2-0.5b", **kw):
    return get_smoke_config(arch).replace(**F32, **kw)


def _params(key, cfg):
    return P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)


def _paged_engine(params, cfg, collect=False, **kw):
    defaults = dict(slots=2, max_len=64, block_size=4, prefill_chunk=3)
    defaults.update(kw)
    return PagedServingEngine(params, cfg, PagedServeConfig(**defaults),
                              collect_arch_trace=collect)


# ---------------------------------------------------------------------------
# Block pool / host bookkeeping
# ---------------------------------------------------------------------------


def test_block_pool_alloc_free_roundtrip():
    pool = BlockPool(num_blocks=5)              # blocks 1..4 allocatable
    assert pool.free_blocks == 4
    got = pool.alloc(3)
    assert len(got) == 3 and 0 not in got
    assert pool.alloc(2) is None                # only 1 left: no partial
    assert pool.free_blocks == 1
    pool.free(got)
    assert pool.free_blocks == 4
    with pytest.raises(ValueError):
        pool.free([0])                          # null block is unpoolable
    only = pool.alloc(4)
    pool.free(only)
    with pytest.raises(ValueError):
        pool.free([only[0]])                    # double free


def test_paged_cache_ensure_grow_release():
    kv = PagedKVCache(PagedCacheConfig(num_blocks=9, block_size=4,
                                       max_len=32))
    assert kv.cfg.blocks_per_seq == 8
    assert kv.ensure(7, 5)                      # 5 tokens -> 2 blocks
    assert len(kv.tables[7]) == 2
    assert kv.ensure(7, 8)                      # same 2 blocks
    assert len(kv.tables[7]) == 2
    assert kv.ensure(7, 9)                      # grows to 3
    assert len(kv.tables[7]) == 3
    row = kv.table_row(7)
    assert len(row) == 8 and row[3:] == [0] * 5  # null-padded
    assert not kv.ensure(8, 32)                 # 8 blocks > 5 free
    assert 8 not in kv.tables or kv.tables[8] == []   # nothing leaked
    assert kv.release(7) == 3
    assert kv.ensure(8, 32)
    assert blocks_for(1, 4) == 1 and blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2
    assert default_num_blocks(4, 64, 16) == 1 + 4 * 4


def test_paged_cache_tables_are_disjoint():
    kv = PagedKVCache(PagedCacheConfig(num_blocks=9, block_size=4,
                                       max_len=16))
    kv.ensure(0, 16)
    kv.ensure(1, 16)
    assert not set(kv.tables[0]) & set(kv.tables[1])
    assert 0 not in kv.tables[0] + kv.tables[1]


# ---------------------------------------------------------------------------
# Paged == contiguous attention (the lookup-level equivalence proof)
# ---------------------------------------------------------------------------


def _pages_from_prefill(cfg, cache, lengths, block_size, num_blocks):
    """Scatter a contiguous prefill cache into a page pool (row 0 only)."""
    s = int(lengths[0])
    nb = -(-cache["k"].shape[2] // block_size)
    pages = lm.init_paged_cache(cfg, num_blocks, block_size)
    bt = jnp.asarray([[1 + i for i in range(nb)]], jnp.int32)

    def put(pool, full):
        def one(pg, fl):
            return attention.paged_scatter(
                pg, bt, fl[:, :s], jnp.zeros((1,), jnp.int32),
                jnp.asarray([s], jnp.int32))
        return jax.vmap(one)(pool, full)

    return ({"k": put(pages["k"], cache["k"]),
             "v": put(pages["v"], cache["v"])}, bt)


@pytest.mark.parametrize("block_size", [2, 4, 8, 16])
def test_paged_attention_matches_contiguous(key, block_size):
    """decode over gathered pages == decode over the contiguous cache,
    across block sizes (incl. one partially filled block)."""
    cfg = _cfg()
    params = _params(key, cfg)
    prompt = jnp.asarray([[5, 9, 17, 3, 8]], jnp.int32)
    logits0, cache, lengths = lm.prefill(params, prompt, cfg, max_len=32)
    tok = jnp.argmax(logits0, -1).astype(jnp.int32)
    ref, _ = lm.decode_step(params, cache, tok, lengths, cfg)
    nb = -(-32 // block_size)
    pages, bt = _pages_from_prefill(cfg, cache, lengths, block_size, nb + 2)
    got, _ = lm.decode_paged(params, pages, bt, tok[:, None], lengths,
                             jnp.ones((1,), jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_gather_reconstructs_contiguous_layout(key):
    """paged_gather(bt) of a scattered cache == the contiguous original —
    the storage-level statement of the same equivalence."""
    cfg = _cfg()
    params = _params(key, cfg)
    prompt = jnp.asarray([[5, 9, 17, 3, 8, 2, 30]], jnp.int32)
    _, cache, lengths = lm.prefill(params, prompt, cfg, max_len=16)
    pages, bt = _pages_from_prefill(cfg, cache, lengths, 4, 8)
    s = int(lengths[0])
    for name in ("k", "v"):
        gathered = jax.vmap(
            lambda pg: attention.paged_gather(pg, bt))(pages[name])
        np.testing.assert_array_equal(
            np.asarray(gathered[:, :, :s]), np.asarray(cache[name][:, :, :s]))


def test_chunked_prefill_matches_one_shot(key):
    """Feeding the prompt through decode_paged in chunks reproduces the
    one-shot prefill logits exactly (what admission relies on)."""
    cfg = _cfg()
    params = _params(key, cfg)
    toks = [5, 9, 17, 3, 40, 2, 8]
    ref, _, _ = lm.prefill(params, jnp.asarray([toks], jnp.int32), cfg,
                           max_len=32)
    for chunk in (2, 3, 7):
        pages = lm.init_paged_cache(cfg, 10, 4)
        bt = jnp.asarray([[1 + i for i in range(8)]], jnp.int32)
        lens = jnp.zeros((1,), jnp.int32)
        for c0 in range(0, len(toks), chunk):
            feed = toks[c0:c0 + chunk]
            nv = len(feed)
            feed = feed + [0] * (chunk - nv)
            logits, pages = lm.decode_paged(
                params, pages, bt, jnp.asarray([feed], jnp.int32), lens,
                jnp.asarray([nv], jnp.int32), cfg)
            lens = lens + nv
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_ssm_family_cache_plan_requirements():
    cfg = _cfg("mamba2-370m")
    # state-carrying families need slots= (fixed-size rows, not blocks)
    with pytest.raises(ValueError, match="slots"):
        lm.init_paged_cache(cfg, 8, 4)
    pages = lm.init_paged_cache(cfg, 8, 4, slots=2)
    assert set(pages) == {"ssm"}
    # features needing reconstructible context raise at construction
    with pytest.raises(ValueError, match="prefix_cache"):
        PagedServingEngine({}, cfg, PagedServeConfig(prefix_cache=True))
    with pytest.raises(ValueError, match="speculative"):
        PagedServingEngine({}, cfg, PagedServeConfig(speculative=True))


# ---------------------------------------------------------------------------
# Engine-level equivalence + batch-composition invariance
# ---------------------------------------------------------------------------


def test_paged_engine_greedy_matches_fixed_slot_and_reference(key):
    cfg = _cfg()
    params = _params(key, cfg)
    prompts = {0: [5, 9, 17, 3], 1: [40, 2, 8, 30, 7]}
    pe = _paged_engine(params, cfg)
    fe = ServingEngine(params, cfg, ServeConfig(slots=2, max_len=64))
    for rid, p in prompts.items():
        pe.submit(Request(rid=rid, prompt=list(p), max_new_tokens=5))
        fe.submit(Request(rid=rid, prompt=list(p), max_new_tokens=5))
    got_p = {r.rid: r.generated for r in pe.run_until_drained()}
    got_f = {r.rid: r.generated for r in fe.run_until_drained()}
    assert got_p == got_f
    # cacheless greedy reference for request 0
    toks = list(prompts[0])
    for expect in got_p[0]:
        logits = lm.forward(params, jnp.asarray([toks], jnp.int32), cfg)
        assert int(jnp.argmax(logits[0, -1])) == expect
        toks.append(expect)


def _run_paged(params, cfg, reqs, *, slots, seed=7, num_blocks=0,
               submit_after=None, **kw):
    eng = _paged_engine(params, cfg, slots=slots, seed=seed,
                        num_blocks=num_blocks, **kw)
    late = dict(submit_after or {})             # after-tick -> Request
    for r in reqs:
        eng.submit(r)
    ticks = 0
    while eng.scheduler.has_work() or late:
        for t in [t for t in sorted(late) if ticks >= t]:
            eng.submit(late.pop(t))
        eng.step()
        ticks += 1
        assert ticks < 500
    return eng, {r.rid: r.generated for r in eng.finished}


REQ0 = dict(rid=0, prompt=[5, 9, 17, 3], max_new_tokens=6, temperature=0.8)
REQ1 = dict(rid=1, prompt=[40, 2, 8, 30, 7, 11, 2, 4], max_new_tokens=6,
            temperature=0.3)
REQ2 = dict(rid=2, prompt=[12, 33, 7], max_new_tokens=4, temperature=0.0)


def test_batch_composition_invariance_stochastic(key):
    """Same request + same key => same tokens, served alone, in a full
    batch, or admitted mid-stream — on a STOCHASTIC substrate (the SC rng
    folds per (request, position), never per batch)."""
    cfg = _cfg(sc_backend="moment", sc_nbit=512)
    params = _params(key, cfg)
    _, solo = _run_paged(params, cfg, [Request(**REQ0)], slots=1)
    _, full = _run_paged(
        params, cfg, [Request(**REQ0), Request(**REQ1), Request(**REQ2)],
        slots=3)
    _, mid = _run_paged(
        params, cfg, [Request(**REQ1), Request(**REQ2)], slots=2,
        submit_after={3: Request(**REQ0)})      # admitted mid-stream
    assert solo[0] == full[0]
    assert full[0] == mid[0]
    _, solo1 = _run_paged(params, cfg, [Request(**REQ1)], slots=1)
    assert solo1[1] == full[1] == mid[1]


def test_mid_stream_admission_invariance_greedy(key):
    """A greedy request admitted after several ticks (mid-batch refill)
    decodes exactly as when admitted first."""
    cfg = _cfg()
    params = _params(key, cfg)
    _, first = _run_paged(params, cfg, [Request(**REQ2)], slots=1)
    _, late = _run_paged(params, cfg, [Request(**REQ1)], slots=2,
                         submit_after={2: Request(**REQ2)})
    assert late[2] == first[2]


def test_eviction_resume_reproduces_tokens(key):
    """A tight pool forces an eviction; the evicted request re-prefills
    its context and must produce the SAME tokens as with a roomy pool
    (per-position rng + recompute-mode eviction)."""
    cfg = _cfg(sc_backend="moment", sc_nbit=512)
    params = _params(key, cfg)
    # 10 + 16 = 26 tokens/seq = 7 blocks each; the 12-usable-block pool
    # cannot hold both, so one sequence must evict and resume.
    mk = lambda: [
        Request(rid=0, prompt=[5, 9, 17, 3, 8, 2, 30, 11, 7, 6],
                max_new_tokens=16, temperature=0.6),
        Request(rid=1, prompt=[40, 2, 8, 30, 7, 11, 2, 4, 9, 9],
                max_new_tokens=16, temperature=0.6)]
    roomy_e, roomy = _run_paged(params, cfg, mk(), slots=2, max_len=48,
                                prefill_chunk=4)
    tight_e, tight = _run_paged(params, cfg, mk(), slots=2, max_len=48,
                                prefill_chunk=4, num_blocks=13)
    assert tight_e.evictions > 0, "pool was meant to force an eviction"
    assert roomy_e.evictions == 0
    assert roomy == tight
    assert tight_e.kv.pool.free_blocks == 12    # everything released


def test_finished_blocks_recycle_mid_batch(key):
    """More requests than the pool could hold at once all complete: a
    finished request's blocks are reused by waiting requests without
    waiting for the batch to drain."""
    cfg = _cfg()
    params = _params(key, cfg)
    reqs = [Request(rid=i, prompt=[3 + i, 7, 11], max_new_tokens=4)
            for i in range(6)]
    eng, got = _run_paged(params, cfg, reqs, slots=2, max_len=32,
                          num_blocks=1 + 2 * 8)
    assert sorted(got) == list(range(6))
    assert all(1 <= len(v) <= 4 for v in got.values())
    assert eng.kv.live_blocks == 0


# ---------------------------------------------------------------------------
# Speculative decoding: draft/verify greedy == plain decode, token for token
# ---------------------------------------------------------------------------


def _spec_reqs():
    return [Request(rid=0, prompt=[5, 9, 17, 3], max_new_tokens=8),
            Request(rid=1, prompt=[40, 2, 8, 30, 7, 11], max_new_tokens=6)]


@pytest.mark.parametrize("k", [1, 2, 4])
def test_speculative_matches_plain_greedy(key, k):
    """Draft-k/verify emits EXACTLY the plain decode tokens for every k:
    the verifier replays the non-speculative per-(request, position) SC
    keys, so acceptance only changes how fast tokens appear, never which."""
    cfg = _cfg(sc_backend="moment", sc_nbit=512)
    params = _params(key, cfg)
    _, ref = _run_paged(params, cfg, _spec_reqs(), slots=2)
    eng, got = _run_paged(params, cfg, _spec_reqs(), slots=2,
                          speculative=True, spec_k=k)
    assert got == ref
    drafted = eng.metrics.value("serve_spec_drafted_tokens_total")
    accepted = eng.metrics.value("serve_spec_accepted_tokens_total")
    assert drafted and drafted % k == 0
    assert 0 <= accepted <= drafted


def test_speculative_fused_verify_matches_plain(key):
    """Verification through the fused paged-attention kernel (the serving
    config speculation targets) still reproduces plain greedy decode —
    the draft pass stays on the unfused path regardless."""
    cfg = _cfg(paged_attn="fused")
    params = _params(key, cfg)
    _, ref = _run_paged(params, cfg, _spec_reqs(), slots=2)
    eng, got = _run_paged(params, cfg, _spec_reqs(), slots=2,
                          speculative=True, spec_k=3)
    assert got == ref
    assert eng.metrics.value("serve_spec_drafted_tokens_total")


def test_speculative_disagreeing_draft_still_exact(key):
    """A deliberately mismatched draft backend (exact drafting for a
    noisy stochastic verifier) exercises the rejection path; the output
    contract is unchanged because rejected positions fall back to the
    verifier's own argmax."""
    cfg = _cfg(sc_backend="moment", sc_nbit=64)   # noisy verifier
    params = _params(key, cfg)
    _, ref = _run_paged(params, cfg, _spec_reqs(), slots=2)
    eng, got = _run_paged(params, cfg, _spec_reqs(), slots=2,
                          speculative=True, spec_k=4,
                          draft_backend="exact")
    assert got == ref
    drafted = eng.metrics.value("serve_spec_drafted_tokens_total")
    accepted = eng.metrics.value("serve_spec_accepted_tokens_total")
    assert accepted < drafted, "exact-vs-moment drafts should miss sometimes"


def test_speculative_mixed_batch_and_eviction(key):
    """Speculation composes with the rest of the engine: a sampled
    (non-greedy) neighbour shares verify ticks with the spec row, and a
    tight pool forces the usual evict/resume — tokens still match the
    roomy non-speculative run for every request."""
    cfg = _cfg(sc_backend="moment", sc_nbit=512)
    params = _params(key, cfg)
    mk = lambda: [
        Request(rid=0, prompt=[5, 9, 17, 3, 8, 2, 30, 11, 7, 6],
                max_new_tokens=16, temperature=0.0),
        Request(rid=1, prompt=[40, 2, 8, 30, 7, 11, 2, 4, 9, 9],
                max_new_tokens=16, temperature=0.6)]
    # 10 + 16 = 26 tokens/seq = 7 blocks each; 9 usable blocks cannot hold
    # both even with the spec row racing ahead, so one evicts and resumes.
    roomy_e, roomy = _run_paged(params, cfg, mk(), slots=2, max_len=28,
                                prefill_chunk=4)
    tight_e, tight = _run_paged(params, cfg, mk(), slots=2, max_len=28,
                                prefill_chunk=4, num_blocks=10,
                                speculative=True, spec_k=2)
    assert roomy_e.evictions == 0
    assert tight_e.evictions > 0, "pool was meant to force an eviction"
    assert tight == roomy
    assert tight_e.metrics.value("serve_spec_accepted_tokens_total")


def test_spec_counters_match_host_replay(key):
    """The acceptance telemetry is ARITHMETIC over the engine's own
    draft/verify log — histogram count/sum and both counters must equal a
    host-side replay of the acceptance rule on the logged tokens."""
    cfg = _cfg(sc_backend="moment", sc_nbit=64)
    params = _params(key, cfg)
    eng, got = _run_paged(params, cfg, _spec_reqs(), slots=2,
                          speculative=True, spec_k=3,
                          draft_backend="exact")
    log = eng.spec_log
    assert log, "greedy requests must take speculative ticks"
    replay = []
    for e in log:
        a = 0
        while a < len(e["drafted"]) and e["drafted"][a] == e["verified"][a]:
            a += 1
        replay.append(a)
        assert e["accepted"] == a
        assert len(e["verified"]) == e["k"] + 1
        # commit = accepted drafts + 1 verifier token, clipped by finish
        assert 1 <= e["committed"] <= a + 1
    assert eng.metrics.value("serve_spec_drafted_tokens_total") == \
        sum(e["k"] for e in log)
    assert eng.metrics.value("serve_spec_accepted_tokens_total") == \
        sum(replay)
    hist = eng.metrics.histogram("spec_accepted_tokens")
    assert hist.count() == len(log)
    assert hist.sum() == float(sum(replay))
    # every generated token of a greedy request is accounted for by some
    # tick's commit (speculative or plain)
    committed = sum(e["committed"] for e in log)
    assert committed <= sum(len(v) for v in got.values())


def test_speculative_config_validation(key):
    cfg = _cfg()
    params = _params(key, cfg)
    with pytest.raises(ValueError, match="spec_k"):
        _paged_engine(params, cfg, speculative=True, spec_k=0)
    with pytest.raises(ValueError, match="unknown SC backend"):
        _paged_engine(params, cfg, speculative=True,
                      draft_backend="no-such-backend")


# ---------------------------------------------------------------------------
# Arch-collector lifecycle (close idempotency + detach-on-raise)
# ---------------------------------------------------------------------------


def _listener_count():
    from repro.arch import trace
    return len(trace._LISTENERS)


def test_close_is_idempotent_fixed_slot(key):
    cfg = _cfg(sc_backend="array", sc_nbit=64)
    params = _params(key, cfg)
    n0 = _listener_count()
    eng = ServingEngine(params, cfg, ServeConfig(slots=1, max_len=32),
                        collect_arch_trace=True)
    assert _listener_count() == n0 + 1
    eng.close()
    assert _listener_count() == n0
    eng.close()                                 # double close: no-op
    eng.close()
    assert _listener_count() == n0
    eng.__del__()                               # close() then __del__
    assert _listener_count() == n0


def test_close_is_idempotent_paged(key):
    cfg = _cfg(sc_backend="array", sc_nbit=64)
    params = _params(key, cfg)
    n0 = _listener_count()
    eng = _paged_engine(params, cfg, slots=1, max_len=32)
    engt = PagedServingEngine(params, cfg, PagedServeConfig(
        slots=1, max_len=32, block_size=4), collect_arch_trace=True)
    assert _listener_count() == n0 + 1          # eng has no collector
    engt.close(); engt.close()
    assert _listener_count() == n0
    eng.close()                                 # collector-less close: no-op
    assert _listener_count() == n0


def test_step_raise_detaches_collector(key):
    """A step() that raises mid-tick must uninstall the collector before
    propagating — and the records must stay readable."""
    cfg = _cfg(sc_backend="array", sc_nbit=64)
    params = _params(key, cfg)
    n0 = _listener_count()
    eng = ServingEngine(params, cfg, ServeConfig(slots=1, max_len=32),
                        collect_arch_trace=True)
    eng.submit(Request(rid=0, prompt=[5, 9], max_new_tokens=2))
    eng.step()                                  # records prefill + decode
    records_before = len(eng.arch_collector.records)
    assert records_before > 0

    def boom(*a, **k):
        raise RuntimeError("mid-tick failure")
    eng._decode = boom
    eng.submit(Request(rid=1, prompt=[6, 7], max_new_tokens=2))
    with pytest.raises(RuntimeError, match="mid-tick"):
        eng.step()
    assert _listener_count() == n0              # detached despite the raise
    assert len(eng.arch_collector.records) == records_before
    eng.close()                                 # still a no-op
    assert _listener_count() == n0


def test_arch_report_prices_cost_per_request(key):
    """The collector's per-request token stamps prorate the aggregate
    trace cost under mixed traffic: shares sum to 1 and scale with each
    request's token count."""
    cfg = _cfg(sc_backend="array", sc_nbit=64)
    params = _params(key, cfg)
    eng = _paged_engine(params, cfg, slots=2, max_len=32, collect=True)
    eng.submit(Request(rid=0, prompt=[5, 9], max_new_tokens=2))
    eng.submit(Request(rid=1, prompt=[40, 2, 8, 30, 7, 3], max_new_tokens=5))
    eng.run_until_drained()
    try:
        report = eng.arch_report()
        assert report is not None and report.cycles > 0
        costs = eng.arch_request_costs()
        assert set(costs) == {0, 1}
        shares = sum(c["share"] for c in costs.values())
        assert abs(shares - 1.0) < 1e-6
        by_rid = {r.rid: r for r in eng.finished}
        for rid, c in costs.items():
            r = by_rid[rid]
            assert c["tokens"] == len(r.prompt) + len(r.generated)
        assert abs(sum(c["energy_pj"] for c in costs.values())
                   - report.energy_pj) < 1e-3 * max(report.energy_pj, 1)
    finally:
        eng.close()
