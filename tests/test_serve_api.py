"""The unified serve API: ServeOptions validation, CLI derivation,
build_engine routing, and the legacy-constructor deprecation contract."""

import argparse

import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import lm, params as P
from repro.serve import (PagedServingEngine, Request, ServeConfig,
                         ServeOptions, ServingEngine, add_cli_args,
                         build_engine, from_cli_args)
from repro.serve.engine import PagedServeConfig

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


@pytest.fixture(scope="module")
def setup(key):
    cfg = get_smoke_config("qwen2-0.5b").replace(**F32)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    return params, cfg


# ---------------------------------------------------------------------------
# ServeOptions.validate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad, match", [
    (dict(paged=True, mesh=True), "mutually exclusive"),
    (dict(fused_attention=True), "needs paged"),
    (dict(prefix_cache=True), "need paged"),
    (dict(speculative=True), "need paged"),
    (dict(chaos=True, mesh=True), "drop mesh"),
    (dict(rng_mode="dice"), "rng_mode"),
    (dict(fault_profile="broken-chip"), "unknown device profile"),
])
def test_validate_rejects_unservable_combos(bad, match):
    with pytest.raises(ValueError, match=match):
        ServeOptions(**bad).validate()


def test_validate_accepts_the_full_paged_stack():
    ServeOptions(paged=True, fused_attention=True, prefix_cache=True,
                 speculative=True, fault_profile="tiny").validate()


def test_resolve_profile_none_when_unset():
    assert ServeOptions().resolve_profile() is None
    assert ServeOptions(fault_profile="tiny").resolve_profile().sigma_ic \
        == 0.02


# ---------------------------------------------------------------------------
# CLI derivation: the launcher's flags come FROM the dataclass
# ---------------------------------------------------------------------------


def test_cli_round_trip_through_derived_flags():
    ap = argparse.ArgumentParser()
    add_cli_args(ap)
    args = ap.parse_args(["--paged", "--block-size", "8", "--max-blocks",
                          "32", "--fault-profile", "tiny", "--seed", "3"])
    opts = from_cli_args(args)
    assert opts.paged and opts.block_size == 8
    assert opts.num_blocks == 32          # --max-blocks maps onto the field
    assert opts.fault_profile == "tiny" and opts.seed == 3
    # defaults survive for untouched fields
    assert opts.slots == ServeOptions().slots


def test_cli_defaults_reproduce_default_options():
    ap = argparse.ArgumentParser()
    add_cli_args(ap)
    assert from_cli_args(ap.parse_args([])) == ServeOptions()


def test_non_cli_fields_stay_off_the_surface():
    ap = argparse.ArgumentParser()
    add_cli_args(ap)
    with pytest.raises(SystemExit):
        ap.parse_args(["--rng-mode", "content"])    # cli=False field


# ---------------------------------------------------------------------------
# build_engine routing
# ---------------------------------------------------------------------------


def test_build_engine_selects_engine_class(setup):
    params, cfg = setup
    assert isinstance(build_engine(params, cfg), ServingEngine)
    assert isinstance(
        build_engine(params, cfg, ServeOptions(paged=True, block_size=4)),
        PagedServingEngine)


def test_build_engine_applies_fused_attention_to_cfg(setup):
    params, cfg = setup
    eng = build_engine(params, cfg, ServeOptions(paged=True, block_size=4,
                                                 fused_attention=True))
    assert eng.cfg.paged_attn == "fused"


def test_build_engine_routes_fault_profile_onto_array_backend(setup):
    params, cfg = setup
    assert cfg.sc_backend in ("", "exact")   # the premise: exact math arch
    eng = build_engine(params, cfg,
                       ServeOptions(paged=True, block_size=4,
                                    fault_profile="tiny"))
    assert eng.cfg.sc_backend == "array"
    assert eng.device_profile.sigma_delta == 0.05
    # an ideal profile threads through but does NOT force the array
    # backend (bit-identity contract: ideal == paper math everywhere)
    eng2 = build_engine(params, cfg, ServeOptions(fault_profile="ideal"))
    assert eng2.cfg.sc_backend == cfg.sc_backend
    assert eng2.device_profile.is_ideal


def test_build_engine_validates(setup):
    params, cfg = setup
    with pytest.raises(ValueError, match="needs paged"):
        build_engine(params, cfg, ServeOptions(fused_attention=True))


def test_faulted_engine_serves(setup):
    """End-to-end: a tiny-profile engine generates tokens (the array
    backend realizes the faults without breaking the serve loop)."""
    params, cfg = setup
    eng = build_engine(params, cfg,
                       ServeOptions(paged=True, slots=1, max_len=32,
                                    block_size=4, prefill_chunk=4,
                                    fault_profile="tiny"))
    eng.submit(Request(rid=0, prompt=[5, 9, 17, 3], max_new_tokens=3))
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].generated) == 3


# ---------------------------------------------------------------------------
# Deprecation contract: direct construction warns, build_engine doesn't
# ---------------------------------------------------------------------------


def test_direct_constructors_warn(setup):
    params, cfg = setup
    with pytest.warns(DeprecationWarning, match="build_engine"):
        ServingEngine(params, cfg, ServeConfig(slots=1, max_len=16))
    with pytest.warns(DeprecationWarning, match="build_engine"):
        PagedServingEngine(params, cfg,
                           PagedServeConfig(slots=1, max_len=16,
                                            block_size=4))


def test_build_engine_is_warning_free(setup):
    params, cfg = setup
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        build_engine(params, cfg, ServeOptions(slots=1, max_len=16))
