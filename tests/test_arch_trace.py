"""TraceCollector request-stamping edge cases: zero recorded calls,
overlapping request ids, and the note_request/cost_per_request contract
the serving engines rely on for per-request cost attribution."""

import jax

from repro.arch import backend as arch_backend
from repro.arch import trace
from repro.sc.config import ScConfig
from repro.sc.registry import sc_dot


def test_cost_per_request_no_stamps_is_empty():
    c = trace.TraceCollector()
    assert c.cost_per_request() == {}


def test_cost_per_request_zero_sc_dot_calls():
    """Requests stamped but nothing recorded (e.g. an exact-substrate
    engine whose matmuls never hit the array backend): the prorated costs
    exist per stamped request, with zero cycles/energy — merge_reports
    over an empty record list is the all-zero report, not a crash."""
    c = trace.TraceCollector()
    c.note_request(0, 10)
    c.note_request(1, 30)
    agg = c.aggregate()
    assert agg.cycles == 0 and agg.energy_pj == 0.0
    costs = c.cost_per_request()
    assert set(costs) == {0, 1}
    assert costs[0]["share"] == 0.25 and costs[1]["share"] == 0.75
    assert costs[0]["cycles"] == 0.0 and costs[1]["energy_pj"] == 0.0


def test_cost_per_request_zero_total_tokens():
    """Stamps that sum to zero tokens cannot be prorated — empty dict,
    never a divide-by-zero."""
    c = trace.TraceCollector()
    c.note_request(0, 0)
    assert c.cost_per_request() == {}


def test_note_request_overlapping_ids_last_stamp_wins():
    """Re-stamping an id overwrites (an evicted-and-resumed request
    finishes once, but defensive callers may stamp twice): shares follow
    the LAST token count per id, and ids never double-count."""
    c = trace.TraceCollector()
    c.note_request(7, 5)
    c.note_request(7, 20)        # resume finished with more context
    c.note_request(8, 20)
    assert c.request_tokens == {7: 20, 8: 20}
    costs = c.cost_per_request()
    assert costs[7]["share"] == 0.5 == costs[8]["share"]


def test_cost_per_request_prorates_recorded_calls():
    """With real records, prorated cycles/energy sum back to the
    aggregate (up to the rounding in cost_per_request)."""
    cfg = ScConfig(backend="array", nbit=64)
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (4, 8))
    w = jax.random.uniform(key, (8, 4))
    c = trace.TraceCollector().install()
    try:
        sc_dot(key, x, w, cfg)
    finally:
        c.uninstall()
    assert len(c.records) == 1
    c.note_request(0, 30)
    c.note_request(1, 10)
    agg = c.aggregate()
    assert agg.cycles > 0
    costs = c.cost_per_request()
    assert abs(sum(v["cycles"] for v in costs.values()) - agg.cycles) < 0.5
    assert abs(sum(v["energy_pj"] for v in costs.values())
               - agg.energy_pj) < 0.01
    assert costs[0]["cycles"] > costs[1]["cycles"]


def test_schedule_call_matches_collected_record():
    """schedule_call standalone prices the same call the collector hears
    from a dispatch (same shape, same spec -> same report)."""
    cfg = ScConfig(backend="array", nbit=64)
    key = jax.random.PRNGKey(1)
    x = jax.random.uniform(key, (2, 8))
    w = jax.random.uniform(key, (8, 2))
    with trace.collect() as records:
        sc_dot(key, x, w, cfg)
    standalone = arch_backend.schedule_call(2, 8, 2, 64)
    assert records[0].report == standalone.report
