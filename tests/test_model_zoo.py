"""Model zoo on the SC substrate: every assigned arch runs forward AND
decode on a stochastic backend (no silent exact fallbacks — satellite of
the site-abstraction refactor), MoE capacity semantics match a dense
one-hot reference, and ragged expert shapes survive the per-expert
dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sc
from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import layers, lm, moe, params as P

B, S = 1, 8
F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _cfg(arch, **kw):
    return get_smoke_config(arch).replace(**F32, **kw)


def _inputs(key, cfg, s=S):
    if cfg.frontend == "embeddings":
        return jax.random.normal(key, (B, s, cfg.d_model), cfg.act_dtype)
    return jax.random.randint(key, (B, s), 0, cfg.vocab)


# ---------------------------------------------------------------------------
# Every family end-to-end on a stochastic backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_every_arch_forward_and_decode_on_moment(arch, key):
    """The acceptance bar of the zoo refactor: each config's forward pass
    AND its prefill+decode loop run with sc_backend='moment' — every
    matmul site (router, expert FFNs, SSM projections, frontend
    projection, unembed) must accept the threaded key."""
    cfg = _cfg(arch, sc_backend="moment", sc_nbit=64)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    inputs = _inputs(jax.random.fold_in(key, 1), cfg)
    rng = jax.random.fold_in(key, 2)
    logits = lm.forward(params, inputs, cfg, rng=rng)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits0, cache, lengths = lm.prefill(params, inputs, cfg, max_len=S + 4,
                                         rng=rng)
    assert bool(jnp.all(jnp.isfinite(logits0)))
    tok = jnp.argmax(logits0, -1).astype(jnp.int32)
    logits1, _ = lm.decode_step(params, cache, tok, lengths, cfg,
                                rng=jax.random.fold_in(rng, 1))
    assert logits1.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits1)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_stochastic_backend_without_rng_raises(arch, key):
    """satellite (a): a stochastic substrate with no key is an ERROR
    naming the site, never a silent exact fallback."""
    cfg = _cfg(arch, sc_backend="moment", sc_nbit=64)
    params = P.init_params(key, lm.lm_param_specs(cfg), cfg.param_dtype)
    inputs = _inputs(jax.random.fold_in(key, 1), cfg)
    with pytest.raises(ValueError, match="site"):
        lm.forward(params, inputs, cfg)


def test_dense_and_expert_dense_key_errors_name_site():
    cfg = _cfg("qwen2-0.5b", sc_backend="moment", sc_nbit=64)
    x = jnp.ones((2, 4), jnp.float32)
    w = jnp.ones((4, 3), jnp.float32)
    with pytest.raises(ValueError, match="'mlp_wi'"):
        layers.dense(x, w, cfg, site="mlp_wi")
    xe = jnp.ones((1, 2, 4, 4), jnp.float32)
    we = jnp.ones((2, 4, 3), jnp.float32)
    with pytest.raises(ValueError, match="'moe_wi'"):
        layers.expert_dense(xe, we, cfg, site="moe_wi")
    # exact stays keyless
    assert layers.dense(x, w, cfg.replace(sc_backend="exact")).shape == (2, 3)


# ---------------------------------------------------------------------------
# MoE capacity semantics vs a dense one-hot reference (satellite c)
# ---------------------------------------------------------------------------


def _moe_onehot_reference(x, p, cfg, cap):
    """GShard-style dense reference: renormalized top-k gates, tokens
    beyond an expert's capacity (in stable flat arrival order) DROP —
    their gate weight contributes nothing and is NOT re-renormalized."""
    b, s, d = x.shape
    k = cfg.top_k
    logits = np.asarray(x, np.float64) @ np.asarray(p["router"], np.float64)
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = np.asarray(gates / jnp.maximum(gates.sum(-1, keepdims=True),
                                           1e-9))
    eidx = np.asarray(eidx)
    wi, wo = np.asarray(p["wi"]), np.asarray(p["wo"])
    out = np.zeros((b, s, d), np.float64)
    dropped = 0
    for r in range(b):
        seen = {}
        for flat in range(s * k):
            t, j = divmod(flat, k)
            e = int(eidx[r, t, j])
            rank = seen.get(e, 0)
            seen[e] = rank + 1
            if rank >= cap:
                dropped += 1
                continue
            h = np.asarray(x[r, t], np.float64) @ wi[e]
            gate_h, up = np.split(h, 2)
            act = np.asarray(jax.nn.silu(jnp.asarray(gate_h))) * up
            out[r, t] += gates[r, t, j] * (act @ wo[e])
    return out, dropped


def test_moe_capacity_overflow_matches_onehot_reference(key):
    """Overflowing experts drop exactly the late arrivals the one-hot
    formulation drops, with renormalized gates — and drops DO occur."""
    cfg = _cfg("moonshot-v1-16b-a3b", n_experts=2, top_k=1,
               capacity_factor=0.25, shared_expert=False)
    s = 32
    cap = moe.capacity(cfg, s)
    assert s * cfg.top_k > cap * cfg.n_experts / 2  # overflow is possible
    p = P.init_params(key, moe.moe_specs(cfg), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, s, cfg.d_model),
                          jnp.float32)
    got = moe.moe_ffn(x, p, cfg)
    ref, dropped = _moe_onehot_reference(x, p, cfg, cap)
    assert dropped > 0, "test inputs never bound capacity — not a test"
    np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-4, atol=2e-4)


def test_moe_no_overflow_matches_dense_mixture(key):
    """With capacity slack the MoE output equals the unconstrained
    mixture (every token reaches every chosen expert)."""
    cfg = _cfg("moonshot-v1-16b-a3b", shared_expert=False)
    s = 4                                     # s*k=8 <= cap=8 per expert
    p = P.init_params(key, moe.moe_specs(cfg), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, s, cfg.d_model),
                          jnp.float32)
    got = moe.moe_ffn(x, p, cfg)
    ref, dropped = _moe_onehot_reference(x, p, cfg, moe.capacity(cfg, s))
    assert dropped == 0
    np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Ragged expert shapes through the per-expert dispatch (satellite c)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["moment", "pallas_fused"])
def test_expert_dense_ragged_shapes_match_per_expert_rows(backend, key):
    """expert_dense's scan must hand each (cap, d)x(d, f) expert problem
    to the registry exactly as a per-expert sc_dot_rows call would —
    including RAGGED shapes (non-power-of-two, non-multiple-of-8 f) that
    stress the kernel autotuner's shape handling."""
    b, e, cap, d, f = 1, 3, 4, 24, 40
    cfg = _cfg("qwen2-0.5b", sc_backend=backend, sc_nbit=64)
    x = jax.random.normal(key, (b, e, cap, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, d, f), jnp.float32)
    keys = jax.random.split(jax.random.fold_in(key, 2),
                            b * e * cap).reshape(b, e, cap, 2)
    got = layers.expert_dense(x, w, cfg, keys, site="moe_wi")
    assert got.shape == (b, e, cap, f)
    sc_cfg = sc.ScConfig(backend=sc.fast_backend(backend, cfg.sc_nbit),
                         nbit=cfg.sc_nbit)
    eidx = jnp.broadcast_to(jnp.arange(e)[None, :, None], (b, e, cap))
    folded = layers.site_key(keys, "moe_wi", eidx)
    for ei in range(e):
        ref = sc.sc_dot_rows(folded[0, ei], x[0, ei], w[ei], sc_cfg)
        # same keys => same draws; tolerance only covers XLA fusion-order
        # float drift between the scanned and direct dispatch
        np.testing.assert_allclose(np.asarray(got[0, ei]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)


def test_moe_per_token_keys_follow_dispatch(key):
    """A (b, s, 2) per-token key buffer rides the token->slot scatter:
    the same token's expert matmuls draw identical bits whatever its
    batch neighbours are (the paged engine's invariance contract)."""
    cfg = _cfg("moonshot-v1-16b-a3b", sc_backend="moment", sc_nbit=64,
               shared_expert=False)
    s = 3
    p = P.init_params(key, moe.moe_specs(cfg), jnp.float32)
    xa = jax.random.normal(jax.random.fold_in(key, 1), (1, s, cfg.d_model),
                           jnp.float32)
    xb = jax.random.normal(jax.random.fold_in(key, 2), (1, s, cfg.d_model),
                           jnp.float32)
    ka = jax.random.split(jax.random.fold_in(key, 3), s)[None]  # (1, s, 2)
    kb = jax.random.split(jax.random.fold_in(key, 4), s)[None]
    solo = moe.moe_ffn(xa, p, cfg, ka)
    both = moe.moe_ffn(jnp.concatenate([xa, xb]), p, cfg,
                       jnp.concatenate([ka, kb]))
    np.testing.assert_array_equal(np.asarray(solo[0]), np.asarray(both[0]))
