"""Pop-count strategies: functional equality + cycle-model properties."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import popcount


def _random_bits(seed, shape):
    return jax.random.bernoulli(
        jax.random.PRNGKey(seed), 0.5, shape).astype(jnp.uint8)


@given(seed=st.integers(0, 2**16), m=st.integers(1, 12),
       nbit=st.integers(1, 300))
@settings(max_examples=60, deadline=None)
def test_apc_equals_csa_fa(seed, m, nbit):
    """Both pop-count strategies return the exact same MAC sum."""
    states = _random_bits(seed, (m, nbit))
    apc_total = int(popcount.apc_popcount(states).sum())
    csa_total = int(popcount.csa_fa_popcount(states))
    assert apc_total == csa_total == int(np.asarray(states).sum())


@given(seed=st.integers(0, 2**16), rows=st.integers(3, 24))
@settings(max_examples=40, deadline=None)
def test_csa_compress_preserves_weighted_sum(seed, rows):
    """One 3:2 pass preserves sum + 2*carry-weight accounting: the paper's
    lock-step CSA is lossless. We verify on weight-1 rows: sum of inputs ==
    sum(s) + 2*sum(c) for each compressed group."""
    bits = _random_bits(seed, (rows, 64))
    out = popcount.csa_compress(bits)
    groups = rows // 3
    for g in range(groups):
        a, b, c = bits[3 * g], bits[3 * g + 1], bits[3 * g + 2]
        s, carry = out[2 * g], out[2 * g + 1]
        lhs = np.asarray(a, np.int32) + np.asarray(b) + np.asarray(c)
        rhs = np.asarray(s, np.int32) + 2 * np.asarray(carry, np.int32)
        np.testing.assert_array_equal(lhs, rhs)


def test_csa_passes_is_logarithmic():
    assert popcount.csa_passes(3) == 1
    assert popcount.csa_passes(2) == 0
    # ~log_{3/2}: 100 rows compress in ~10 passes, not ~100
    assert popcount.csa_passes(100) <= 12
    assert popcount.csa_passes(1000) <= 18


def test_apc_is_one_cycle_per_mul():
    assert popcount.apc_cycles(1) == 1
    assert popcount.apc_cycles(7) == 7


def test_fig6_amortization_converges():
    """Per-MUL CSA+FA cycles decrease with MAC length and CONVERGE to the
    constant CSA fold cost: the FA resolve is paid once per MAC (Fig. 6)."""
    nbit = 1024
    per = [popcount.csa_fa_cycles_per_mul(n, nbit) for n in (1, 10, 100, 1000)]
    assert per[0] > per[1] > per[2] > per[3]
    # converged regime: the asymptote is the per-MUL fold cost
    fold = popcount.csa_fold_cycles(popcount.rows_per_mul(nbit))
    assert abs(per[3] - fold) / fold < 0.05


def test_csa_fa_cycles_independent_of_row_width():
    """Lock-step bulk bitwise ops touch all columns at once: two nbit values
    with the SAME row count cost the same cycles (given equal result width)."""
    rb = int(np.ceil(np.log2(100 * 256)))
    assert popcount.rows_per_mul(200) == popcount.rows_per_mul(256) == 1
    assert popcount.csa_fa_cycles(100, 200, result_bits=rb) == \
        popcount.csa_fa_cycles(100, 256, result_bits=rb)
    # more rows (wider operands) cost more folds
    assert popcount.csa_fold_cycles(16) > popcount.csa_fold_cycles(1)
