"""Training-loop integration: loss decreases, microbatching equivalence,
optimizer state quantization, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import SyntheticLMData, make_batch
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import cosine_lr
from repro.train import TrainConfig, make_train_step
from repro.train.step import train_state_init

F32 = dict(param_dtype=jnp.float32, act_dtype=jnp.float32)


def _setup(arch="qwen2-0.5b", **tkw):
    cfg = get_smoke_config(arch).replace(**F32)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=100), **tkw)
    state = train_state_init(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=8)
    return cfg, tcfg, state, step, data


def test_loss_decreases_over_training():
    cfg, tcfg, state, step, data = _setup()
    losses = []
    for i in range(25):
        state, metrics = step(state, make_batch(data, i))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert all(np.isfinite(losses))


def test_microbatching_matches_full_batch():
    """grad accumulation over 4 microbatches == single big batch (same data,
    same rng fold pattern not required — compare against mean of losses)."""
    cfg, _, state1, step1, data = _setup(microbatches=1)
    _, _, state4, step4, _ = _setup(microbatches=4)
    batch = make_batch(data, 0)
    s1, m1 = step1(state1, batch)
    s4, m4 = step4(state4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    # parameter updates nearly identical (identical grads in exact mode)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     s1["params"], s4["params"])
    assert max(jax.tree.leaves(d)) < 1e-5


def test_cosine_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, 0)) == 0.0
    np.testing.assert_allclose(float(cosine_lr(cfg, 10)), 1e-3, rtol=1e-5)
    assert float(cosine_lr(cfg, 100)) < 1e-6
    assert float(cosine_lr(cfg, 5)) == pytest.approx(0.5e-3, rel=1e-4)


@pytest.mark.parametrize("state_dtype", ["f32", "bf16", "int8"])
def test_adamw_state_dtypes(state_dtype):
    cfg = AdamWConfig(state_dtype=state_dtype, weight_decay=0.0)
    params = {"w": jnp.ones((8, 8)) * 0.5}
    opt = adamw_init(params, cfg)
    grads = {"w": jnp.ones((8, 8)) * 0.1}
    new_p, new_opt, metrics = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(new_p["w"] - params["w"]).max()) > 0
    assert int(new_opt["step"]) == 1
    if state_dtype == "int8":
        assert new_opt["m"]["w"]["q"].dtype == jnp.int8
    elif state_dtype == "bf16":
        assert new_opt["m"]["w"].dtype == jnp.bfloat16
    # three more steps stay finite
    for _ in range(3):
        new_p, new_opt, _ = adamw_update(grads, new_opt, new_p, cfg)
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))


def test_quantized_state_tracks_f32_closely():
    """bf16/int8 optimizer states stay near the f32 trajectory over a few
    steps (decode-update-encode keeps math in f32)."""
    params = {"w": jnp.ones((16,)) * 0.3}
    grads = {"w": jnp.linspace(-0.1, 0.1, 16)}
    trajs = {}
    for kind in ("f32", "bf16"):
        cfg = AdamWConfig(state_dtype=kind)
        p, opt = dict(params), adamw_init(params, cfg)
        for _ in range(10):
            p, opt, _ = adamw_update(grads, opt, p, cfg)
        trajs[kind] = np.asarray(p["w"])
    np.testing.assert_allclose(trajs["bf16"], trajs["f32"], atol=5e-3)


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(clip_norm=1.0, lr=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(huge, opt, params, cfg)
    assert float(metrics["grad_norm"]) > 1e5   # reported norm is pre-clip


def test_train_with_sc_substrate_decreases_loss():
    """End-to-end: the paper's SC engine as the matmul substrate still
    trains (STE backward)."""
    cfg, tcfg, state, step, data = _setup("paper-sc")
    assert cfg.sc_mode == "moment"
    losses = []
    for i in range(15):
        state, metrics = step(state, make_batch(data, i))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-3:]) < losses[0]
    assert all(np.isfinite(losses))
