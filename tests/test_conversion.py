"""Tests for the §III-A data-conversion chain (LUT + DTC)."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import conversion, physics

CFG = conversion.ConversionConfig()


def test_lut_matches_minus_log():
    lut = conversion.build_lut(CFG)
    i = np.arange(1, CFG.levels)
    expect = -np.log(i / CFG.levels)
    got = np.asarray(lut)[1:]
    # fixed-point grid: max error is half an LSB of the table encoding
    lsb = CFG.max_tau_ns / (1 << CFG.lut_fixedpoint_bits)
    assert np.max(np.abs(got - expect)) <= lsb


def test_lut_zero_entry_is_full_scale():
    lut = conversion.build_lut(CFG)
    assert float(lut[0]) == CFG.max_tau_ns


def test_dtc_quantizes_to_grid():
    tau = jnp.array([0.0, 0.01, 0.033, 1.234, 100.0])
    q = conversion.dtc_quantize(tau, CFG)
    grid = np.asarray(q) / CFG.dtc_resolution_ns
    np.testing.assert_allclose(grid, np.round(grid), atol=1e-5)
    assert float(q[-1]) <= CFG.max_tau_ns  # saturates at full scale


@given(x=st.integers(0, 1023), y=st.integers(0, 1023))
@settings(max_examples=300, deadline=None)
def test_quantized_product_within_dtc_error_bound(x, y):
    """Deterministic (bias) error of P_usw(tau_X)·P_usw(tau_Y) obeys the
    physical DTC bound: dP = P·dtau with |dtau| <= res/2 per pulse, so
    |dP_prod| <= P_x·P_y·(res/2 + res/2) plus LUT fixed-point slack.
    (At p -> 1, tau -> 0 and the 22 ps grid costs up to ~1.1 % per operand —
    a real hardware effect, within the paper's sigma ~ 1.6 % noise floor.)"""
    ideal = float(conversion.ideal_product_probability(x, y, CFG))
    quant = float(conversion.quantized_product_probability(x, y, CFG))
    px, py = x / CFG.levels, y / CFG.levels
    bound = (px * py) * CFG.dtc_resolution_ns * 1.05 + 2 ** -12
    assert abs(quant - ideal) <= bound


@given(x=st.integers(128, 640), y=st.integers(128, 640))
@settings(max_examples=200, deadline=None)
def test_quantized_product_below_noise_floor_in_operating_range(x, y):
    """In the paper's normalized operating range (P around 0.5, §III-D) the
    deterministic conversion bias stays under the sigma ~ 1.6 % stochastic
    noise floor at nbit = 1000 — i.e. quantization never dominates the SC
    error budget the paper reports."""
    ideal = float(conversion.ideal_product_probability(x, y, CFG))
    quant = float(conversion.quantized_product_probability(x, y, CFG))
    assert abs(quant - ideal) < 0.016


@given(x=st.integers(1, 1023))
@settings(max_examples=300, deadline=None)
def test_operand_to_tau_roundtrip_within_dtc_resolution(x):
    """decode(P_usw(operand_to_tau(x))) recovers x to within the physical
    DTC resolution: |dP| = P·|dtau| with |dtau| <= res/2, i.e. at most
    ceil(P·res/2·2^n) + 1 operand LSBs (exactly 1 LSB for small operands)."""
    tau = conversion.operand_to_tau(x, CFG)
    p = conversion.tau_to_probability(tau)
    x_back = int(conversion.decode_probability(p, CFG))
    p_x = x / CFG.levels
    bound = int(np.ceil(p_x * CFG.dtc_resolution_ns / 2 * CFG.levels)) + 1
    assert abs(x_back - x) <= bound


def test_zero_operand_maps_to_near_zero_probability():
    tau = conversion.operand_to_tau(0, CFG)
    p = float(conversion.tau_to_probability(tau))
    assert p < 1e-6


def test_operand_to_tau_vectorized():
    xs = jnp.arange(0, 1024, 17)
    taus = conversion.operand_to_tau(xs, CFG)
    assert taus.shape == xs.shape
    # monotone: larger operand -> higher survival probability -> shorter pulse
    assert np.all(np.diff(np.asarray(taus)) <= 0)


def test_encode_decode_probability_roundtrip():
    xs = jnp.arange(CFG.levels)
    p = conversion.encode_probability(xs, CFG)
    back = conversion.decode_probability(p, CFG)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(xs))


def test_smaller_bitwidth_shrinks_lut():
    small = conversion.ConversionConfig(n_bits=8)
    assert conversion.build_lut(small).shape[0] == 256
    assert conversion.build_lut(CFG).shape[0] == 1024


def test_operating_current_drives_nondeterministic_region():
    """Mid-range operands land in the stochastic switching region
    (P not pinned at 0/1) — the §III-D normalization argument."""
    mid = conversion.operand_to_tau(512, CFG)
    p = float(physics.p_unswitched(mid, physics.I_C_UA))
    assert 0.05 < p < 0.95


def test_conversion_roundtrip_at_boundary_operands():
    """The three fixed-point boundary operands of the n-bit grid survive
    the full LUT → DTC → device → decode chain exactly: 0 (full-scale
    pulse, multiply-by-zero), 1 (longest finite pulse), and the max
    magnitude 2^n - 1 (shortest pulse, rounds to zero duration)."""
    for x in (0, 1, CFG.levels - 1):
        tau = conversion.operand_to_tau(x, CFG)
        p = conversion.tau_to_probability(tau)
        x_back = int(conversion.decode_probability(p, CFG))
        assert x_back == x, (x, float(tau), float(p))


def test_operand_grid_has_2n_levels_and_p1_clamps():
    """Regression (encode operand-grid off-by-one): round(p·2^n)/2^n yields
    2^n + 1 levels with p = 1.0 on the nonexistent LUT index 2^n.  The grid
    must have exactly 2^n levels — indices 0 .. 2^n - 1 (§III-A) — with the
    max-magnitude operand clamped to the top representable level."""
    from repro.sc import encoding
    from repro.sc.config import ScConfig
    for nbits in (4, 8, 10):
        cfg = ScConfig(operand_bits=nbits)
        levels = 1 << nbits
        # values spanning the full magnitude range incl. the max element
        v = jnp.linspace(-1.0, 1.0, 4 * levels + 1)
        _, p, scale = encoding.encode(v, cfg)
        idx = np.asarray(p) * levels
        np.testing.assert_allclose(idx, np.round(idx), atol=1e-4)
        assert float(scale) == 1.0
        # p = |v|/scale = 1.0 for the max element: must land on 2^n - 1
        assert int(idx.max()) == levels - 1, idx.max()
        assert idx.min() >= 0


def test_operand_grid_full_sweep_round_trips():
    """Every LUT index i survives encode()'s grid untouched: a value already
    ON the grid (p = i/2^n, i < 2^n) re-encodes to exactly index i."""
    from repro.sc import encoding
    from repro.sc.config import ScConfig
    cfg = ScConfig(operand_bits=10)
    levels = 1 << 10
    i = np.arange(levels)
    v = jnp.asarray(np.concatenate([[1.0], i / levels]))  # scale anchor = 1
    _, p, _ = encoding.encode(v, cfg)
    got = np.asarray(p[1:]) * levels
    np.testing.assert_array_equal(got.astype(np.int64), i)


def test_fx16_round_trip_exact_on_operand_grid():
    """Regression (fx16 downward bias): every level of the n-bit operand
    grid (n <= 16) must survive to_fx16 -> from_fx16 EXACTLY — including
    the top level, which previously collapsed against the 65535 clamp."""
    from repro.sc import encoding
    for nbits in (4, 10, 16):
        levels = 1 << nbits
        p = jnp.arange(levels, dtype=jnp.float32) / levels
        words = encoding.to_fx16(p)
        np.testing.assert_array_equal(
            np.asarray(words), np.arange(levels) * (65536 // levels))
        back = encoding.from_fx16(words)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(p))


def test_fx16_matches_packed_engine_bias_convention():
    """from_fx16 is the bias the Horner ladder realizes: P(bit=1) = w/2^16.
    Chain an encoded grid operand through to_fx16 and check the packed
    engine's expected pop-count E[count] = nbit·(w_x/2^16)·(w_y/2^16) is
    exactly p_x·p_y·nbit on the grid (no systematic truncation loss)."""
    from repro.sc import encoding
    from repro.sc.config import ScConfig
    cfg = ScConfig(operand_bits=10)
    v = jnp.asarray([1.0, 0.5, 0.25])          # max element -> top level
    _, p, _ = encoding.encode(v, cfg)
    w = encoding.to_fx16(p)
    realized = np.asarray(encoding.from_fx16(w), np.float64)
    expect = np.asarray(p, np.float64)
    np.testing.assert_array_equal(realized, expect)
    # top grid level: 1023/1024 exactly, NOT 65535/65536
    assert realized[0] == 1023.0 / 1024.0


def test_fx16_bias_words_at_boundaries():
    """encoding.to_fx16 at the fx16 boundaries: p=0 -> word 0, p=1 clamps
    to 65535 (not overflowing to 65536), and the represented bias is
    within one LSB of the request."""
    from repro.sc import encoding
    words = np.asarray(encoding.to_fx16(jnp.array([0.0, 0.5, 1.0])))
    np.testing.assert_array_equal(words, [0, 32768, 65535])
    back = words.astype(np.float64) / 65536.0
    assert np.all(np.abs(back - np.array([0.0, 0.5, 1.0])) <= 1.0 / 65536.0)
