"""Data pipeline determinism + sharding rules + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.data import SyntheticLMData, make_batch
from repro.data.pipeline import make_embedding_batch
from repro.distributed import compression
from repro.models.params import ParamSpec, partition_specs
from repro.sharding import act_spec
from repro.sharding.rules import logical_rules

# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

DATA = SyntheticLMData(vocab=1024, seq_len=64, global_batch=8, n_shards=2)


def test_batch_is_deterministic():
    b1 = make_batch(DATA, step=5, shard=0)
    b2 = make_batch(DATA, step=5, shard=0)
    np.testing.assert_array_equal(np.asarray(b1["inputs"]),
                                  np.asarray(b2["inputs"]))


def test_different_steps_and_shards_differ():
    b0 = make_batch(DATA, 0, 0)
    b1 = make_batch(DATA, 1, 0)
    s1 = make_batch(DATA, 0, 1)
    assert not np.array_equal(b0["inputs"], b1["inputs"])
    assert not np.array_equal(b0["inputs"], s1["inputs"])


def test_labels_are_shifted_inputs():
    b = make_batch(DATA, 3)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["inputs"][:, 1:]))


def test_tokens_in_vocab_range():
    b = make_batch(DATA, 2)
    toks = np.asarray(b["inputs"])
    assert toks.min() >= 0 and toks.max() < DATA.vocab


def test_shard_batch_size():
    assert DATA.shard_batch == 4
    assert make_batch(DATA, 0, 0)["inputs"].shape == (4, 64)


def test_embedding_batch_shapes():
    b = make_embedding_batch(DATA, d_model=32, step=0)
    assert b["inputs"].shape == (4, 64, 32)
    assert b["labels"].shape == (4, 64)


@given(step=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_zipf_structure_has_repeats(step):
    """The Markov structure means adjacent-token repeats are common —
    that is the learnable signal."""
    b = make_batch(DATA, step)
    toks = np.asarray(b["inputs"])
    rep_frac = (toks[:, 1:] == toks[:, :-1]).mean()
    assert rep_frac > 0.1


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_partition_specs_divisible_dims_shard():
    rules = {"embed": "data", "heads": "model",
             "__sizes__": {"data": 16, "model": 16}}
    specs = {"w": ParamSpec((4096, 1024), ("embed", "heads"))}
    ps = partition_specs(specs, rules)
    assert ps["w"] == P("data", "model")


def test_partition_specs_indivisible_dims_replicate():
    rules = {"embed": "data", "heads": "model",
             "__sizes__": {"data": 16, "model": 16}}
    specs = {"w": ParamSpec((100, 24), ("embed", "heads"))}  # 100%16, 24%16
    ps = partition_specs(specs, rules)
    assert ps["w"] == P(None, None)


def test_partition_specs_mixed():
    rules = {"embed": "data", "kv_heads": "model",
             "__sizes__": {"data": 16, "model": 16}}
    specs = {"wk": ParamSpec((4096, 256), ("embed", "kv_heads"))}
    ps = partition_specs(specs, rules)
    assert ps["wk"] == P("data", "model")


def test_act_spec_single_pod_mesh():
    mesh = _mesh11()
    spec = act_spec(mesh, "batch", "seq", "heads")
    assert spec == P("data", None, "model")


def test_logical_rules_pod_axis():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    rules = logical_rules(mesh, "act")
    assert rules["batch"] == ("pod", "data")
    assert rules["__sizes__"] == {"pod": 1, "data": 1, "model": 1}


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------


def test_quantize_dequantize_bounded_error(key):
    g = jax.random.normal(key, (128,)) * 5.0
    q, scale = compression._quantize(g)
    deq = q.astype(jnp.float32) * scale
    max_err = float(jnp.abs(deq - g).max())
    assert max_err <= float(scale) * 0.5 + 1e-6     # half-LSB rounding


def test_error_feedback_accumulates_residual(key):
    """Over repeated steps with a CONSTANT gradient, error feedback makes
    the running mean of transmitted gradients converge to the true value
    (the EF-SGD contract)."""
    g = jax.random.normal(key, (64,)) * 0.01 + 0.003
    r = jnp.zeros_like(g)
    sent = []
    for _ in range(50):
        corrected = g + r
        q, scale = compression._quantize(corrected)
        deq = q.astype(jnp.float32) * scale
        r = corrected - deq
        sent.append(deq)
    avg_sent = np.asarray(jnp.stack(sent).mean(0))
    np.testing.assert_allclose(avg_sent, np.asarray(g), atol=5e-4)


def test_compressed_grads_passthrough_without_pod_axis(key):
    mesh = _mesh11()

    def grad_fn(params, batch):
        return jnp.sum(params["w"] * batch), {"w": batch}

    fn = compression.compressed_grads(grad_fn, mesh)
    loss, grads, ef = fn({"w": jnp.ones(4)}, jnp.ones(4) * 2.0, None)
    assert ef is None
    np.testing.assert_allclose(np.asarray(grads["w"]), 2.0)


def test_init_error_feedback_shapes():
    ef = compression.init_error_feedback({"w": jnp.zeros((3, 4))}, n_pods=2)
    assert ef["w"].shape == (2, 3, 4)
