"""The benchmark-regression gate: injected regressions must exit nonzero,
matching artifacts must pass, and the tolerance classes must hold."""

import copy
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import bench_compare  # noqa: E402

BASE = {
    "tiny": True,
    "nbit": 1024,
    "backends": {
        "exact": {"shape": [32, 128, 32], "wall_us": 900.0,
                  "array_cycles": 512, "note": "plain XLA matmul"},
        "pallas_fused": {"shape": [4, 16, 4], "wall_us": 2400.0,
                         "array_cycles": 8, "note": "74x exact"},
    },
    "fused_vs_bitexact": {"shape": [4, 16, 4], "bit_exact": True,
                          "speedup": 73.7, "floor": 0.8},
    "workload": {"mean_interarrival_s": 0.02, "requests": 24},
    "paged": {"ticks": 17, "evictions": 0, "decode_p50_ms": 0.2,
              "decode_p95_ms": 0.4,
              "telemetry": {
                  "counters": {"serve_tokens_generated_total": 45,
                               "serve_evictions_total": 0,
                               "serve_ticks_total{kind=decode}": 12},
                  "gauges": {"serve_queue_depth": 0,
                             "serve_kv_blocks_free": 32}}},
    "paged_prefix": {"cache_hit_rate": 0.42, "tokens_per_s": 30.0},
    "paged_spec": {"accepted_per_step": 3.5, "acceptance_rate": 0.9},
    "zoo": {"moe": {"moment": {"n64": {"logits_cos_acc": 0.93}}}},
}


def _errors(current, **kw):
    return bench_compare.compare_payloads("BENCH_test.json", BASE, current,
                                          **kw)


def test_identical_payload_passes():
    assert _errors(copy.deepcopy(BASE)) == []


def test_wall_clock_noise_tolerated_but_blowup_fails():
    cur = copy.deepcopy(BASE)
    cur["backends"]["exact"]["wall_us"] = 900.0 * 5    # CI noise: fine
    assert _errors(cur) == []
    cur["backends"]["exact"]["wall_us"] = 900.0 * 50   # complexity blowup
    errs = _errors(cur)
    assert len(errs) == 1 and "wall_us" in errs[0]
    assert "wall-clock regression" in errs[0]


def test_deterministic_metric_change_fails():
    cur = copy.deepcopy(BASE)
    cur["backends"]["pallas_fused"]["array_cycles"] = 16
    errs = _errors(cur)
    assert len(errs) == 1
    assert "array_cycles" in errs[0] and "deterministic" in errs[0]


def test_bit_exact_flag_flip_fails():
    cur = copy.deepcopy(BASE)
    cur["fused_vs_bitexact"]["bit_exact"] = False
    errs = _errors(cur)
    assert len(errs) == 1 and "bit_exact" in errs[0]


def test_speedup_collapse_fails_but_drift_passes():
    cur = copy.deepcopy(BASE)
    cur["fused_vs_bitexact"]["speedup"] = 30.0         # drift: fine
    assert _errors(cur) == []
    cur["fused_vs_bitexact"]["speedup"] = 1.2          # collapse
    errs = _errors(cur)
    assert len(errs) == 1 and "speedup" in errs[0]


def test_missing_metric_is_a_regression():
    cur = copy.deepcopy(BASE)
    del cur["backends"]["pallas_fused"]                # backend vanished
    errs = _errors(cur)
    assert errs and all("missing from the fresh run" in e for e in errs)


def test_scheduler_counts_tolerate_runner_speed_but_not_blowups():
    """ticks/evictions are wall-clock-paced: runner-speed drift (both
    directions, including evictions appearing over a 0 baseline) passes;
    an order-of-magnitude blowup fails."""
    cur = copy.deepcopy(BASE)
    cur["paged"]["ticks"] = 9            # faster runner: fine
    cur["paged"]["evictions"] = 2        # a couple timing evictions: fine
    assert _errors(cur) == []
    cur["paged"]["ticks"] = 17 * 40      # scheduler thrash
    errs = _errors(cur)
    assert len(errs) == 1 and "ticks" in errs[0] and "blew up" in errs[0]


def test_latency_drift_tolerated_but_blowup_fails():
    """`*_ms` decode-latency percentiles get their own tolerance class:
    runner noise (a few x) passes, a past-tolerance blowup fails, and
    the knob is independent of --wall-tolerance."""
    cur = copy.deepcopy(BASE)
    cur["paged"]["decode_p50_ms"] = 0.2 * 5       # shared-runner noise
    cur["paged"]["decode_p95_ms"] = 0.4 * 15
    assert _errors(cur) == []
    cur["paged"]["decode_p95_ms"] = 0.4 * 50      # kernel got slow
    errs = _errors(cur)
    assert len(errs) == 1 and "decode_p95_ms" in errs[0]
    assert "decode-latency regression" in errs[0]
    # the latency knob moves independently of the wall knob
    assert _errors(cur, latency_tolerance=100.0) == []
    errs = _errors(cur, wall_tolerance=100.0)
    assert len(errs) == 1 and "decode_p95_ms" in errs[0]


def test_rate_metrics_gate_tightly_but_allow_jitter():
    """`*_rate` / `accepted_per_step` are serving-quality ratios: tiny
    jitter inside the 0.9x floor passes, a real collapse fails, higher is
    always fine, and the knob is independent of --ratio-floor."""
    assert bench_compare.classify("paged_prefix/cache_hit_rate") == "rate"
    assert bench_compare.classify("paged_spec/accepted_per_step") == "rate"
    assert bench_compare.classify("paged_spec/acceptance_rate") == "rate"
    cur = copy.deepcopy(BASE)
    cur["paged_prefix"]["cache_hit_rate"] = 0.40       # jitter: fine
    cur["paged_spec"]["accepted_per_step"] = 3.9       # higher: fine
    assert _errors(cur) == []
    cur["paged_prefix"]["cache_hit_rate"] = 0.1        # sharing collapsed
    errs = _errors(cur)
    assert len(errs) == 1 and "cache_hit_rate" in errs[0]
    assert "cache-sharing/acceptance regression" in errs[0]
    assert _errors(cur, rate_floor=0.2) == []          # its own knob
    assert len(_errors(cur, ratio_floor=0.01)) == 1
    cur = copy.deepcopy(BASE)
    cur["paged_spec"]["accepted_per_step"] = 0.5       # drafts stopped landing
    errs = _errors(cur)
    assert len(errs) == 1 and "accepted_per_step" in errs[0]


def test_acc_metrics_use_absolute_drop_band():
    """`*_acc` accuracy leaves (zoo bench fidelity vs the exact
    reference): sampling noise inside the absolute band passes, a real
    accuracy collapse fails, improvements always pass, and the band has
    its own --acc-tolerance knob."""
    assert bench_compare.classify(
        "zoo/moe/moment/n64/logits_cos_acc") == "acc"
    cur = copy.deepcopy(BASE)
    leaf = cur["zoo"]["moe"]["moment"]["n64"]
    leaf["logits_cos_acc"] = 0.85                      # noise: fine
    assert _errors(cur) == []
    leaf["logits_cos_acc"] = 0.99                      # better: fine
    assert _errors(cur) == []
    leaf["logits_cos_acc"] = 0.4                       # estimator broke
    errs = _errors(cur)
    assert len(errs) == 1 and "logits_cos_acc" in errs[0]
    assert "accuracy regression" in errs[0]
    assert _errors(cur, acc_tolerance=0.6) == []       # its own knob
    leaf["logits_cos_acc"] = 0.85
    assert len(_errors(cur, acc_tolerance=0.05)) == 1


def test_workload_config_is_compared_exactly():
    """Timing suffixes inside the workload/ subtree are CONFIG, not
    measurement: quietly densifying arrivals must fail the gate even
    though `_s`-suffixed wall metrics normally get a 20x band."""
    cur = copy.deepcopy(BASE)
    cur["workload"]["mean_interarrival_s"] = 0.005
    errs = _errors(cur)
    assert len(errs) == 1 and "mean_interarrival_s" in errs[0]
    assert "deterministic" in errs[0]


def test_registry_counters_compare_exactly():
    """`*_total`/`*_count` leaves are lifecycle counters exported from the
    obs registries: deterministic for a fixed workload, so ANY drift fails
    — even a drift that the count class would wave through."""
    cur = copy.deepcopy(BASE)
    t = cur["paged"]["telemetry"]["counters"]
    t["serve_tokens_generated_total"] = 46         # off by one
    errs = _errors(cur)
    assert len(errs) == 1 and "serve_tokens_generated_total" in errs[0]
    assert "lifecycle counter" in errs[0]


def test_labeled_counter_series_strip_labels_before_classifying():
    """A flattened series name like `serve_ticks_total{kind=decode}` still
    classifies as a counter (the label suffix is stripped first)."""
    assert bench_compare.classify(
        "paged/telemetry/counters/serve_ticks_total{kind=decode}") \
        == "counter"
    cur = copy.deepcopy(BASE)
    cur["paged"]["telemetry"]["counters"]["serve_ticks_total{kind=decode}"] \
        = 13
    errs = _errors(cur)
    assert len(errs) == 1 and "kind=decode" in errs[0]


def test_gauges_ignored_by_default_but_gated_on_opt_in():
    """gauges/... leaves are runtime state: drift AND disappearance pass
    by default; --check-gauges turns them into exact comparisons."""
    cur = copy.deepcopy(BASE)
    cur["paged"]["telemetry"]["gauges"]["serve_queue_depth"] = 3
    del cur["paged"]["telemetry"]["gauges"]["serve_kv_blocks_free"]
    assert _errors(cur) == []
    errs = _errors(cur, check_gauges=True)
    assert len(errs) == 2
    assert any("serve_queue_depth" in e and "registry gauge" in e
               for e in errs)
    assert any("serve_kv_blocks_free" in e and "missing" in e for e in errs)


def test_notes_are_ignored():
    cur = copy.deepcopy(BASE)
    cur["backends"]["exact"]["note"] = "different measured ratio text"
    assert _errors(cur) == []


def test_main_exits_nonzero_on_injected_regression(tmp_path):
    """End-to-end CLI: a regressed artifact makes main() return 1 and a
    clear message naming the metric; the clean artifact returns 0."""
    basedir = tmp_path / "baselines"
    curdir = tmp_path / "fresh"
    basedir.mkdir()
    curdir.mkdir()
    (basedir / "BENCH_x.json").write_text(json.dumps(BASE))
    (curdir / "BENCH_x.json").write_text(json.dumps(BASE))
    assert bench_compare.main(["--baseline-dir", str(basedir),
                               "--current-dir", str(curdir)]) == 0
    bad = copy.deepcopy(BASE)
    bad["backends"]["pallas_fused"]["array_cycles"] = 9999   # injected
    (curdir / "BENCH_x.json").write_text(json.dumps(bad))
    assert bench_compare.main(["--baseline-dir", str(basedir),
                               "--current-dir", str(curdir)]) == 1


def test_main_fails_when_fresh_artifact_missing(tmp_path):
    basedir = tmp_path / "baselines"
    basedir.mkdir()
    (basedir / "BENCH_x.json").write_text(json.dumps(BASE))
    assert bench_compare.main(["--baseline-dir", str(basedir),
                               "--current-dir", str(tmp_path)]) == 1


def test_repo_baselines_match_committed_schema():
    """The committed baselines parse and carry the mode flag the smoke job
    relies on: CI compares --tiny runs, so any baseline that records a
    mode must record tiny=True (a full-size refresh here would fail every
    smoke run on shape/nbit mismatches)."""
    bdir = bench_compare.DEFAULT_BASELINE_DIR
    names = [p for p in os.listdir(bdir) if p.startswith("BENCH_")]
    assert names, "benchmarks/baselines/ must ship refreshed baselines"
    for name in names:
        with open(os.path.join(bdir, name)) as f:
            payload = json.load(f)
        assert isinstance(payload, dict) and payload
        assert payload.get("tiny", True) is True, (
            f"{name}: baselines must come from --tiny runs"
        )
