"""Docs checker: markdown links resolve, and the code snippets embedded in
docs/backends.md / docs/scaling.md / docs/prefix_caching.md actually run
against the installed package.

    PYTHONPATH=src python tools/check_docs.py            # links + snippets
    PYTHONPATH=src python tools/check_docs.py --links-only

Snippets run in-process with a forced 8-device host platform (the scaling
guide shards over a (2, 4) mesh), so XLA_FLAGS is set before any snippet
gets a chance to import jax. Each file's ``python`` fenced blocks execute
in ONE shared namespace, top to bottom — the docs read as a session, and
they are checked as one.
"""

from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")

# Files whose links are checked.
LINK_FILES = ["README.md", "docs/paper_map.md", "docs/backends.md",
              "docs/scaling.md", "docs/serving.md", "docs/kernels.md",
              "docs/observability.md", "docs/prefix_caching.md",
              "docs/model_zoo.md", "docs/reliability.md"]
# Files whose ```python blocks are executed.
SNIPPET_FILES = ["docs/backends.md", "docs/scaling.md",
                 "docs/prefix_caching.md", "docs/model_zoo.md",
                 "docs/reliability.md"]


def check_links(relpath: str) -> list[str]:
    errors = []
    base = os.path.dirname(os.path.join(REPO, relpath))
    with open(os.path.join(REPO, relpath)) as f:
        for lineno, line in enumerate(f, 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path = target.split("#", 1)[0]
                if not path:          # pure in-page anchor
                    continue
                if not os.path.exists(os.path.normpath(
                        os.path.join(base, path))):
                    errors.append(f"{relpath}:{lineno}: broken link "
                                  f"-> {target}")
    return errors


def extract_snippets(relpath: str) -> list[tuple[int, str]]:
    """(first line number, source) of every ```python fenced block."""
    snippets = []
    lang, buf, start = None, [], 0
    with open(os.path.join(REPO, relpath)) as f:
        for lineno, line in enumerate(f, 1):
            m = FENCE_RE.match(line)
            if m and lang is None:
                lang, buf, start = m.group(1) or "text", [], lineno + 1
            elif m:
                if lang == "python":
                    snippets.append((start, "".join(buf)))
                lang = None
            elif lang is not None:
                buf.append(line)
    return snippets


def run_snippets(relpath: str) -> list[str]:
    namespace: dict = {"__name__": f"docs_snippet:{relpath}"}
    for start, src in extract_snippets(relpath):
        label = f"{relpath}:{start}"
        print(f"  running snippet {label} ({len(src.splitlines())} lines)")
        try:
            code = compile(src, label, "exec")
            exec(code, namespace)        # noqa: S102 — the point of the job
        except Exception as e:           # noqa: BLE001 — report, don't die
            return [f"{label}: snippet failed: {type(e).__name__}: {e}"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true",
                    help="skip executing the embedded code snippets")
    args = ap.parse_args(argv)

    if not args.links_only:
        # Must precede any jax import (snippets import jax themselves; the
        # scaling guide shards over a (2, 4) mesh). Set here — NOT at
        # module import — so importing this module (tests/test_docs.py)
        # leaks nothing into the importer's environment.
        os.environ.setdefault("XLA_FLAGS",
                              "--xla_force_host_platform_device_count=8")
        if "jax" in sys.modules:
            import jax
            if len(jax.devices()) < 8:
                print("ERROR: jax already initialized with "
                      f"{len(jax.devices())} devices; run check_docs in a "
                      "fresh process (snippets need 8)", file=sys.stderr)
                return 1

    errors: list[str] = []
    for relpath in LINK_FILES:
        if not os.path.exists(os.path.join(REPO, relpath)):
            errors.append(f"missing doc file: {relpath}")
            continue
        errors += check_links(relpath)
    print(f"checked links in {len(LINK_FILES)} files: "
          f"{'OK' if not errors else f'{len(errors)} broken'}")

    if not args.links_only and not errors:
        for relpath in SNIPPET_FILES:
            print(f"executing snippets from {relpath}")
            errors += run_snippets(relpath)

    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
