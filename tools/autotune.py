"""Refresh the fused SC engine's tile-size autotune cache.

Times every candidate (block_m, block_n, block_k, lane_words) tiling of
``kernels/sc_fused.py`` for the requested call shapes and writes the
winners to the versioned on-disk table the ``pallas_fused`` backend
consults (``src/repro/sc/autotune_cache.json`` by default — shipped with
the repo so everyone starts from measured tiles).

    PYTHONPATH=src python tools/autotune.py                  # bench shapes
    PYTHONPATH=src python tools/autotune.py --shapes 8x32x8 16x64x16 \
        --nbit 1024 --out /tmp/cache.json

Tile choice never changes results (the kernel draws from a global
counter-based stream), so the cache is safe to regenerate on any machine;
it only moves wall-clock.
"""

from __future__ import annotations

import argparse
import sys

from repro.sc import autotune

# default shape set: the sc_matmul_bench bit-exact-family shapes
# (full-size and --tiny)
DEFAULT_SHAPES = ["8x32x8", "4x16x4"]


def parse_shape(s: str) -> tuple:
    try:
        m, k, n = (int(v) for v in s.lower().split("x"))
    except ValueError:
        raise SystemExit(f"bad shape {s!r}; expected MxKxN, e.g. 8x32x8")
    return m, k, n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--shapes",
        nargs="+",
        default=DEFAULT_SHAPES,
        metavar="MxKxN",
        help="call shapes to tune",
    )
    ap.add_argument(
        "--nbit",
        type=int,
        nargs="+",
        default=[1024],
        help="stochastic bits per product (multiple of 32)",
    )
    ap.add_argument(
        "--out",
        default=None,
        help="cache file (default: the shipped table, or "
        "$REPRO_SC_AUTOTUNE_CACHE)",
    )
    ap.add_argument(
        "--iters",
        type=int,
        default=3,
        help="timing repetitions per candidate",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    entries = autotune.load_cache(args.out)
    for shape in args.shapes:
        m, k, n = parse_shape(shape)
        for nbit in args.nbit:
            if nbit % 32:
                raise SystemExit(f"--nbit {nbit} is not a multiple of 32")
            n_cands = len(autotune.candidate_tiles(m, k, n, nbit))
            print(f"tuning {m}x{k}x{n} nbit={nbit} ({n_cands} candidates)")
            best, best_us, table = autotune.tune_shape(
                m, k, n, nbit, iters=args.iters, verbose=not args.quiet
            )
            heur = autotune.heuristic_tile(m, k, n, nbit)
            heur_us = dict(table).get(heur, float("nan"))
            print(
                f"  best {best.kwargs()} at {best_us:.1f} us "
                f"(heuristic {heur.kwargs()} at {heur_us:.1f} us)"
            )
            entry = dict(best.kwargs())
            entry["wall_us"] = round(best_us, 1)
            entries[autotune.cache_key(m, k, n, nbit)] = entry
    path = autotune.save_cache(entries, args.out)
    autotune.reset_cache()
    print(
        f"[wrote {path}: {len(entries)} entries, "
        f"version {autotune.CACHE_VERSION}]"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
