"""Render, diff, and convert ``repro.obs`` telemetry artifacts.

One CLI for the three things an operator does with the files
``launch.serve --metrics-out/--trace-out`` (or any registry/tracer)
produce:

    python tools/obs_report.py metrics.prom              # snapshot table
    python tools/obs_report.py metrics.json before.json  # diff (cur, base)
    python tools/obs_report.py metrics.prom \
        --require serve_requests_finished_total ...      # CI assertion
    python tools/obs_report.py --chrome trace.jsonl -o trace.json

Metrics load from either format: a ``.json`` file is the registry's
:meth:`~repro.obs.MetricsRegistry.snapshot` verbatim, anything else
parses as Prometheus text exposition (``# TYPE`` lines give the kind;
histograms reassemble from their ``_bucket``/``_sum``/``_count``
series, keeping count and sum — the quantile estimates only live in the
JSON snapshot).  ``--require`` matches metric *names* (label sets
stripped), so it asserts "this series family was emitted" without
pinning label values.  ``--chrome`` converts a span JSONL into a Chrome
``trace_event`` file for chrome://tracing or https://ui.perfetto.dev.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs import trace as obs_trace  # noqa: E402

_TYPE_RE = re.compile(r"^# TYPE (\S+) (\S+)\s*$")
_SAMPLE_RE = re.compile(r"^([A-Za-z_:][A-Za-z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")


def _num(s: str):
    v = float(s)
    return int(v) if v.is_integer() else v


def parse_exposition(text: str) -> dict:
    """Prometheus text -> the registry snapshot shape (counters/gauges/
    histograms).  Histogram quantiles are not in the exposition, so the
    reassembled entries carry count/sum only."""
    kinds: dict = {}
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    hist: dict = {}                      # series name -> {count, sum}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        m = _TYPE_RE.match(line)
        if m:
            kinds[m.group(1)] = m.group(2)
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable exposition line: {line!r}")
        name, labels, value = m.group(1), m.group(2) or "", m.group(3)
        for base, suffix in ((name[:-7], "_bucket"), (name[:-4], "_sum"),
                             (name[:-6], "_count")):
            if name.endswith(suffix) and kinds.get(base) == "histogram":
                if suffix == "_bucket":
                    labels = re.sub(r",?le=\"[^\"]*\"", "", labels)
                    labels = "" if labels in ("{}", "{,}") else labels
                    break                # cumulative; count line has total
                series = base + _strip_quotes(labels)
                hist.setdefault(series, {})[suffix[1:]] = _num(value)
                break
        else:
            kind = kinds.get(name, "gauge")
            section = "counters" if kind == "counter" else "gauges"
            out[section][name + _strip_quotes(labels)] = _num(value)
    out["histograms"] = hist
    return out


def _strip_quotes(labels: str) -> str:
    """``{k="v",...}`` -> the snapshot's ``{k=v,...}`` form."""
    if not labels:
        return ""
    return labels.replace('"', "")


def load_snapshot(path: str) -> dict:
    with open(path) as f:
        text = f.read()
    if path.endswith(".json"):
        snap = json.loads(text)
        for section in ("counters", "gauges", "histograms"):
            snap.setdefault(section, {})
        return snap
    return parse_exposition(text)


def _base_name(series: str) -> str:
    return series.split("{", 1)[0]


def metric_names(snap: dict) -> set:
    names = set()
    for section in ("counters", "gauges", "histograms"):
        for series in snap.get(section, {}):
            names.add(_base_name(series))
    return names


def render_table(snap: dict) -> str:
    lines = []
    for section in ("counters", "gauges"):
        series = snap.get(section, {})
        if not series:
            continue
        lines.append(section.upper())
        width = max(len(s) for s in series)
        for name in sorted(series):
            lines.append(f"  {name:<{width}}  {series[name]:g}")
    hists = snap.get("histograms", {})
    if hists:
        lines.append("HISTOGRAMS")
        width = max(len(s) for s in hists)
        for name in sorted(hists):
            h = hists[name]
            parts = [f"count={h.get('count', 0):g}",
                     f"sum={h.get('sum', 0):g}"]
            for q in ("p50", "p95", "p99"):
                if q in h:
                    parts.append(f"{q}={h[q]:g}")
            lines.append(f"  {name:<{width}}  " + " ".join(parts))
    return "\n".join(lines) if lines else "(empty snapshot)"


def render_diff(cur: dict, base: dict) -> str:
    """Current vs. baseline: counter deltas, gauge moves, histogram
    count/sum deltas; series only one side has are flagged."""
    lines = []
    for section in ("counters", "gauges"):
        a, b = base.get(section, {}), cur.get(section, {})
        names = sorted(set(a) | set(b))
        if not names:
            continue
        lines.append(section.upper())
        width = max(len(n) for n in names)
        for name in names:
            if name not in b:
                lines.append(f"  {name:<{width}}  only in baseline "
                             f"({a[name]:g})")
            elif name not in a:
                lines.append(f"  {name:<{width}}  new ({b[name]:g})")
            elif section == "counters":
                lines.append(f"  {name:<{width}}  {a[name]:g} -> {b[name]:g}"
                             f"  ({b[name] - a[name]:+g})")
            else:
                lines.append(f"  {name:<{width}}  {a[name]:g} -> {b[name]:g}")
    a, b = base.get("histograms", {}), cur.get("histograms", {})
    names = sorted(set(a) | set(b))
    if names:
        lines.append("HISTOGRAMS")
        width = max(len(n) for n in names)
        for name in names:
            if name not in b:
                lines.append(f"  {name:<{width}}  only in baseline")
            elif name not in a:
                lines.append(f"  {name:<{width}}  new "
                             f"(count={b[name].get('count', 0):g})")
            else:
                dc = b[name].get("count", 0) - a[name].get("count", 0)
                ds = b[name].get("sum", 0) - a[name].get("sum", 0)
                lines.append(f"  {name:<{width}}  count {dc:+g} sum {ds:+g}")
    return "\n".join(lines) if lines else "(both snapshots empty)"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="metrics snapshot(s): .prom exposition or .json; "
                         "one renders a table, two diff (current, baseline)")
    ap.add_argument("--require", nargs="+", default=None, metavar="NAME",
                    help="exit nonzero unless every NAME appears as a "
                         "metric (label sets ignored)")
    ap.add_argument("--chrome", default=None, metavar="TRACE_JSONL",
                    help="convert a span JSONL to a Chrome trace_event "
                         "file instead of reading metrics")
    ap.add_argument("-o", "--out", default=None,
                    help="output path for --chrome (default: stdout)")
    args = ap.parse_args(argv)

    if args.chrome:
        if args.paths:
            ap.error("--chrome takes no metrics paths")
        payload = obs_trace.to_chrome(obs_trace.read_jsonl(args.chrome))
        text = json.dumps(payload, indent=1) + "\n"
        if args.out:
            with open(args.out, "w") as f:
                f.write(text)
            print(f"{args.chrome}: {len(payload['traceEvents']) - 1} spans "
                  f"-> {args.out}")
        else:
            sys.stdout.write(text)
        return 0

    if not args.paths or len(args.paths) > 2:
        ap.error("expected one snapshot (table) or two (diff)")
    try:
        snaps = [load_snapshot(p) for p in args.paths]
    except (OSError, ValueError) as e:
        print(f"ERROR: {e}", file=sys.stderr)
        return 1

    if len(snaps) == 2:
        print(render_diff(snaps[0], snaps[1]))
    else:
        print(render_table(snaps[0]))

    if args.require:
        names = metric_names(snaps[0])
        missing = [n for n in args.require if n not in names]
        for n in missing:
            print(f"ERROR: required metric missing: {n}", file=sys.stderr)
        if missing:
            return 1
        print(f"all {len(args.require)} required metrics present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
