"""Benchmark-regression gate: diff fresh BENCH_*.json against baselines.

The benches have always written machine-readable artifacts; this tool is
what finally *reads* them in CI.  It compares every metric in the
committed baselines (``benchmarks/baselines/``) against the freshly
produced files and exits nonzero naming each regressed metric, so a PR
that slows the hot path or silently drops a backend fails the smoke job
instead of shipping.

    PYTHONPATH=src python tools/bench_compare.py            # after benches
    python tools/bench_compare.py --baseline-dir benchmarks/baselines \
        --current-dir . --files BENCH_sc_matmul.json

Per-metric tolerance classes (suffix-matched on the leaf key):

* ``note``                — free-text, ignored (embeds measured ratios);
* ``*_us`` / ``*_s``      — wall-clock, lower is better: fail only past
                            ``--wall-tolerance``x the baseline (default
                            20x — catches accidental complexity blowups,
                            not shared-CI-runner noise);
* ``*_ms``                — per-token latency (serve decode p50/p95):
                            lower is better, fail only past
                            ``--latency-tolerance``x the baseline (its
                            own knob — latency percentiles over few
                            smoke-mode decode ticks are noisier than the
                            bulk wall metrics);
* ``*speedup*`` / ``*tokens_per_s`` — higher is better: fail below
                            ``--ratio-floor``x baseline (default 0.1x);
* ``*_rate`` / ``accepted_per_step`` — serving quality ratios (prefix-
                            cache hit rate, speculative acceptance):
                            higher is better, fail below
                            ``--rate-floor``x baseline (default 0.9x —
                            these are workload-determined, not
                            wall-clock-paced, so the floor is tight);
* ``*_acc``               — accuracy metrics on a [0, 1]-ish scale
                            (cosine / agreement vs the exact reference,
                            e.g. the zoo bench's stochastic-forward
                            fidelity): higher is better, fail when the
                            fresh value drops more than
                            ``--acc-tolerance`` *below* the baseline
                            (absolute, default 0.15 — covers sampling
                            noise between runs without letting a backend
                            quietly stop estimating the product);
* ``generated_tokens`` / ``ticks`` / ``evictions`` — scheduling counts
                            driven by real time (the serve bench paces
                            arrivals with the wall clock), so they get
                            the wall treatment: fail only on a blowup
                            past ``wall_tolerance x baseline + 5``
                            (additive slack covers zero baselines);
* ``workload/...``        — benchmark *configuration*: exact regardless
                            of suffix (a changed workload is a changed
                            benchmark, not a measurement);
* ``*_errors_total``      — modeled fault censuses (device bit errors,
                            engine error ticks): exact, checked before
                            the generic counter rule so a drift names
                            the fault model, not the workload;
* ``*_total`` / ``*_count`` — lifecycle counters exported from the
                            ``repro.obs`` registries (label suffixes like
                            ``{kind=decode}`` are stripped first): exact —
                            the benches only export counters whose totals
                            are deterministic for a fixed workload;
* ``gauges/...``          — registry gauges are point-in-time runtime
                            state (queue depth, pool occupancy at drain):
                            ignored unless ``--check-gauges``;
* everything else         — deterministic (modeled cycles/energy, shapes,
                            nbit, flags): exact, to float round-off.

A metric present in the baseline but MISSING from the fresh run is a
regression (a backend or section silently vanished); new metrics in the
fresh run are fine (baselines refresh when benches grow).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")

WALL_TOLERANCE = 20.0  # x baseline for *_us / *_s metrics
LATENCY_TOLERANCE = 20.0  # x baseline for *_ms latency metrics
RATIO_FLOOR = 0.1  # x baseline for speedup / throughput metrics
RATE_FLOOR = 0.9  # x baseline for hit-rate / acceptance-rate metrics
ACC_TOLERANCE = 0.15  # absolute allowed drop for *_acc accuracy metrics
COUNT_SLACK = 5.0  # additive slack for scheduler counts (0 baselines)
EXACT_RTOL = 1e-6  # float round-off for deterministic metrics

_COUNT_KEYS = {"generated_tokens", "ticks", "evictions"}
_RATE_KEYS = {"accepted_per_step"}


def classify(path: str) -> str:
    """Tolerance class of one leaf metric path (suffix conventions).

    ``workload/...`` subtrees are benchmark *configuration*, not
    measurement: they compare exactly whatever their suffix, so a PR
    cannot quietly move a headline metric by changing the workload
    underneath it (e.g. ``workload/mean_interarrival_s``).
    """
    key = path.rsplit("/", 1)[-1].split("{", 1)[0]   # strip label sets
    if key == "note":
        return "ignore"
    if "workload/" in path or path.startswith("workload"):
        return "exact"
    if "gauges/" in path or path.startswith("gauges"):
        return "gauge"
    if key.endswith("_rate") or key in _RATE_KEYS:
        return "rate"
    if key.endswith("_acc"):
        return "acc"
    if key.endswith("_errors_total"):
        # modeled fault censuses (arch_bit_errors_total, serve_errors_
        # total): exact like counters, but named separately so a drift
        # reads as "the device fault model changed", not runner noise
        return "errors"
    if key.endswith("_total") or key.endswith("_count"):
        return "counter"
    if "speedup" in key or key.endswith("tokens_per_s"):
        return "higher_better"
    if key.endswith("_ms"):
        return "latency"
    if key.endswith("_us") or key.endswith("_s"):
        return "wall"
    if key in _COUNT_KEYS:
        return "count"
    return "exact"


def _leaves(payload, prefix=""):
    """Flatten nested dicts to {path: leaf} (lists stay leaves)."""
    out = {}
    if isinstance(payload, dict):
        for k, v in payload.items():
            if isinstance(v, dict):
                out.update(_leaves(v, f"{prefix}{k}/"))
            else:
                out[f"{prefix}{k}"] = v
    else:
        out[prefix] = payload
    return out


def _check_leaf(path, base, cur, *, wall_tolerance, ratio_floor,
                latency_tolerance, rate_floor=RATE_FLOOR,
                acc_tolerance=ACC_TOLERANCE):
    rule = classify(path)
    if rule == "ignore":
        return None
    if rule == "errors":
        # fault censuses are frozen-map exact: the DeviceProfile pins the
        # per-cell draw, so ANY drift means the fault model moved
        if cur != base:
            return (
                f"{path}: {cur!r} != baseline {base!r} "
                "(modeled error census changed)"
            )
        return None
    if rule == "counter":
        # registry counters: exact (the benches only export ones that are
        # deterministic for a fixed workload — see serve_bench.telemetry)
        if cur != base:
            return (
                f"{path}: {cur!r} != baseline {base!r} "
                "(lifecycle counter changed)"
            )
        return None
    if rule == "gauge":
        # opted in via --check-gauges: exact, same as counters
        if cur != base:
            return (
                f"{path}: {cur!r} != baseline {base!r} "
                "(registry gauge changed)"
            )
        return None
    if isinstance(base, bool) or not isinstance(base, (int, float)):
        # flags, strings, shape lists: deterministic structure
        if cur != base:
            return (
                f"{path}: expected {base!r}, got {cur!r} "
                "(deterministic metric changed)"
            )
        return None
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        return f"{path}: expected a number like {base!r}, got {cur!r}"
    if rule == "wall":
        if cur > base * wall_tolerance:
            return (
                f"{path}: {cur:g} exceeds {wall_tolerance:g}x the "
                f"baseline {base:g} (wall-clock regression)"
            )
    elif rule == "latency":
        if cur > base * latency_tolerance:
            return (
                f"{path}: {cur:g} exceeds {latency_tolerance:g}x the "
                f"baseline {base:g} (decode-latency regression)"
            )
    elif rule == "higher_better":
        if cur < base * ratio_floor:
            return (
                f"{path}: {cur:g} fell below {ratio_floor:g}x the "
                f"baseline {base:g} (throughput/speedup regression)"
            )
    elif rule == "rate":
        # hit/acceptance rates are workload-determined, not wall-clock-
        # paced: a drop means sharing or speculation got worse, not that
        # the runner was slow — gate them tightly, higher is fine
        if cur < base * rate_floor:
            return (
                f"{path}: {cur:g} fell below {rate_floor:g}x the "
                f"baseline {base:g} (cache-sharing/acceptance regression)"
            )
    elif rule == "acc":
        # accuracy vs the exact reference: an absolute-drop gate (these
        # live near 1.0, so a multiplicative floor would be either
        # toothless or noise-triggered); improvements always pass
        if cur < base - acc_tolerance:
            return (
                f"{path}: {cur:g} dropped more than {acc_tolerance:g} "
                f"below the baseline {base:g} (accuracy regression)"
            )
    elif rule == "count":
        # wall-clock-paced counts: only an upward blowup is a regression
        # (runner speed legitimately moves these in both directions)
        if cur > base * wall_tolerance + COUNT_SLACK:
            return (
                f"{path}: {cur:g} exceeds {wall_tolerance:g}x the "
                f"baseline {base:g} + {COUNT_SLACK:g} "
                "(scheduling count blew up)"
            )
    else:
        tol = EXACT_RTOL * max(abs(base), 1.0)
        if abs(cur - base) > tol:
            return (
                f"{path}: {cur!r} != baseline {base!r} "
                "(deterministic metric changed)"
            )
    return None


def compare_payloads(
    name,
    baseline,
    current,
    *,
    wall_tolerance=WALL_TOLERANCE,
    ratio_floor=RATIO_FLOOR,
    latency_tolerance=LATENCY_TOLERANCE,
    rate_floor=RATE_FLOOR,
    acc_tolerance=ACC_TOLERANCE,
    check_gauges=False,
):
    """Every regression of ``current`` against ``baseline`` (else []).

    ``check_gauges`` opts the ``gauges/...`` leaves into the comparison;
    by default they are runtime state and skipped entirely (missing
    gauges are not regressions either)."""
    errors = []
    base_leaves = _leaves(baseline)
    cur_leaves = _leaves(current)
    for path in sorted(base_leaves):
        rule = classify(path)
        if rule == "ignore" or (rule == "gauge" and not check_gauges):
            continue
        if path not in cur_leaves:
            errors.append(
                f"{name}:{path}: metric missing from the fresh run "
                "(baseline has it)"
            )
            continue
        err = _check_leaf(
            path,
            base_leaves[path],
            cur_leaves[path],
            wall_tolerance=wall_tolerance,
            ratio_floor=ratio_floor,
            latency_tolerance=latency_tolerance,
            rate_floor=rate_floor,
            acc_tolerance=acc_tolerance,
        )
        if err:
            errors.append(f"{name}:{err}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default=DEFAULT_BASELINE_DIR)
    ap.add_argument("--current-dir", default=".")
    ap.add_argument(
        "--files",
        nargs="*",
        default=None,
        help="artifact names to compare (default: every BENCH_*.json "
        "in the baseline dir)",
    )
    ap.add_argument("--wall-tolerance", type=float, default=WALL_TOLERANCE)
    ap.add_argument("--ratio-floor", type=float, default=RATIO_FLOOR)
    ap.add_argument("--rate-floor", type=float, default=RATE_FLOOR)
    ap.add_argument(
        "--acc-tolerance", type=float, default=ACC_TOLERANCE,
        help="absolute drop below baseline tolerated for *_acc metrics"
    )
    ap.add_argument(
        "--latency-tolerance", type=float, default=LATENCY_TOLERANCE
    )
    ap.add_argument(
        "--check-gauges",
        action="store_true",
        help="compare gauges/... leaves exactly instead of skipping them",
    )
    args = ap.parse_args(argv)

    names = args.files
    if not names:
        pattern = os.path.join(args.baseline_dir, "BENCH_*.json")
        names = sorted(os.path.basename(p) for p in glob.glob(pattern))
    if not names:
        print(
            f"ERROR: no BENCH_*.json baselines in {args.baseline_dir}",
            file=sys.stderr,
        )
        return 1

    errors = []
    for name in names:
        base_path = os.path.join(args.baseline_dir, name)
        cur_path = os.path.join(args.current_dir, name)
        try:
            with open(base_path) as f:
                baseline = json.load(f)
        except (OSError, ValueError) as e:
            errors.append(f"{name}: unreadable baseline {base_path}: {e}")
            continue
        if not os.path.exists(cur_path):
            errors.append(
                f"{name}: fresh artifact missing at {cur_path} "
                "(did the bench run?)"
            )
            continue
        try:
            with open(cur_path) as f:
                current = json.load(f)
        except (OSError, ValueError) as e:
            errors.append(
                f"{name}: unreadable fresh artifact {cur_path}: {e} "
                "(bench killed mid-write?)"
            )
            continue
        file_errors = compare_payloads(
            name,
            baseline,
            current,
            wall_tolerance=args.wall_tolerance,
            ratio_floor=args.ratio_floor,
            latency_tolerance=args.latency_tolerance,
            rate_floor=args.rate_floor,
            acc_tolerance=args.acc_tolerance,
            check_gauges=args.check_gauges,
        )
        n_metrics = len(_leaves(baseline))
        status = "FAIL" if file_errors else "OK"
        print(
            f"{name}: {n_metrics} baseline metrics, "
            f"{len(file_errors)} regressed [{status}]"
        )
        errors += file_errors

    for e in errors:
        print(f"REGRESSION: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
