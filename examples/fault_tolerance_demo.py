"""Fault-tolerance demo: train with an injected worker crash, recover from
the latest checkpoint, and verify the run converges to the exact same state
as an uninterrupted run (deterministic data pipeline + checkpoint replay).

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""

from __future__ import annotations

import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import SyntheticLMData, make_batch
from repro.ft import FaultInjector, Supervisor
from repro.train import TrainConfig, make_train_step
from repro.train.step import train_state_init

STEPS, CKPT_EVERY, FAIL_AT = 20, 5, 13


def train(tag: str, ckpt_dir: str, injector=None):
    cfg = get_smoke_config("qwen2-0.5b").replace(
        param_dtype=jnp.float32, act_dtype=jnp.float32)
    tcfg = TrainConfig()
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=32, global_batch=4)
    state = train_state_init(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    sup = Supervisor(ckpt_dir=ckpt_dir, ckpt_every=CKPT_EVERY,
                     injector=injector)
    state, hist = sup.run(state, step, STEPS,
                          make_batch=lambda i: make_batch(data, i))
    print(f"[{tag}] final loss {hist['loss'][-1]:.4f}, "
          f"recoveries: {hist['recoveries']}")
    return state, hist


def main():
    for d in ("/tmp/ft_demo_clean", "/tmp/ft_demo_crash"):
        shutil.rmtree(d, ignore_errors=True)

    print(f"run A: {STEPS} uninterrupted steps")
    clean, _ = train("clean", "/tmp/ft_demo_clean")

    print(f"\nrun B: crash injected at step {FAIL_AT} "
          f"(checkpoint every {CKPT_EVERY})")
    crashed, hist = train("crash", "/tmp/ft_demo_crash",
                          FaultInjector(fail_at_steps=(FAIL_AT,)))
    assert len(hist["recoveries"]) == 1

    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         clean["params"], crashed["params"])
    worst = max(jax.tree.leaves(diffs))
    print(f"\nmax |param(clean) - param(crashed)| = {worst:.2e}")
    assert worst == 0.0, "recovery must replay to the identical state"
    print("recovered run is BIT-IDENTICAL to the uninterrupted run — "
          "checkpoint/restart + deterministic data = exact recovery")


if __name__ == "__main__":
    main()
