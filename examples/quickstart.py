"""Quickstart: the paper's SOT-MRAM stochastic-computing MUL engine in 60
seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks the full Eq. 4 pipeline on two operands, shows the error statistics
(paper Fig. 7), then lifts the engine to a matmul (the framework feature)
and shows the Pallas kernel path.
"""

import jax
import jax.numpy as jnp

from repro.core import conversion, engine, scmac
from repro.kernels import ops

key = jax.random.PRNGKey(0)

# --- 1. One stochastic MUL: X*Y via two write pulses --------------------
X_INT, Y_INT = 700, 300                     # 10-bit operands
cfg = engine.EngineConfig(nbit=1024)        # 2^10 MRAM cells per MUL

tau_x = conversion.operand_to_tau(X_INT, cfg.conv)
tau_y = conversion.operand_to_tau(Y_INT, cfg.conv)
print(f"operands {X_INT}, {Y_INT} -> pulse durations "
      f"{float(tau_x):.3f} ns, {float(tau_y):.3f} ns")

p_est, product = engine.sc_multiply(key, X_INT, Y_INT, cfg)
print(f"SC product:    {int(product)}  (true {X_INT * Y_INT}, "
      f"err {abs(int(product) - X_INT * Y_INT) / (X_INT * Y_INT) * 100:.2f}%)")

# --- 2. Error statistics (Fig. 7a) ---------------------------------------
keys = jax.random.split(key, 500)
p_true = (X_INT / 1024) * (Y_INT / 1024)
ests = jax.vmap(lambda k: engine.sc_multiply(k, X_INT, Y_INT, cfg)[0])(keys)
print(f"500 repeats:   mean={float(ests.mean()):.4f} (true {p_true:.4f}), "
      f"sigma={float(ests.std()) * 100:.2f}% — zero-centered Gaussian")

# --- 3. The engine as a framework matmul (NN MAC, paper SIII-C/D) --------
x = jax.random.normal(key, (8, 256))
w = jax.random.normal(jax.random.fold_in(key, 1), (256, 16))
sc_cfg = scmac.SCMacConfig(mode="moment", nbit=1024)
y_sc = scmac.sc_matmul(key, x, w, sc_cfg)
y_exact = x @ w
rel = float(jnp.abs(y_sc - y_exact).mean() / jnp.abs(y_exact).mean())
print(f"sc_matmul:     mean rel err {rel * 100:.1f}% at nbit=1024")

# --- 4. Pallas kernel path (bit-exact packed engine, interpret mode) -----
est = ops.sc_mul_bitexact(key, jnp.array([X_INT / 1024]),
                          jnp.array([Y_INT / 1024]), nbit=2048)
print(f"pallas kernel: p_est={float(est[0]):.4f} (true {p_true:.4f})")

# --- 5. Fused moment-matched SC matmul kernel -----------------------------
y_fused = ops.sc_matmul_fused(key, x, w, nbit=1024, block_m=8,
                              block_n=16, block_k=256)
rel_f = float(jnp.abs(y_fused - y_exact).mean() / jnp.abs(y_exact).mean())
print(f"fused kernel:  mean rel err {rel_f * 100:.1f}% — same statistics, "
      "one VMEM pass on TPU")
print("done.")
