"""Quickstart: the paper's SOT-MRAM stochastic-computing MUL engine in 60
seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks the full Eq. 4 pipeline on two operands, shows the error statistics
(paper Fig. 7), then lifts the engine to a matmul through the pluggable
``repro.sc`` backend registry — including an end-to-end LM forward whose
every dense() runs the fused Pallas kernel.
"""

import jax
import jax.numpy as jnp

from repro import arch, sc
from repro.configs import get_smoke_config
from repro.core import conversion, engine
from repro.kernels.sc_mul import sc_mul_bitexact
from repro.models import lm, params as params_lib

key = jax.random.PRNGKey(0)

# --- 1. One stochastic MUL: X*Y via two write pulses --------------------
X_INT, Y_INT = 700, 300                     # 10-bit operands
cfg = engine.EngineConfig(nbit=1024)        # 2^10 MRAM cells per MUL

tau_x = conversion.operand_to_tau(X_INT, cfg.conv)
tau_y = conversion.operand_to_tau(Y_INT, cfg.conv)
print(f"operands {X_INT}, {Y_INT} -> pulse durations "
      f"{float(tau_x):.3f} ns, {float(tau_y):.3f} ns")

p_est, product = engine.sc_multiply(key, X_INT, Y_INT, cfg)
print(f"SC product:    {int(product)}  (true {X_INT * Y_INT}, "
      f"err {abs(int(product) - X_INT * Y_INT) / (X_INT * Y_INT) * 100:.2f}%)")

# --- 2. Error statistics (Fig. 7a) ---------------------------------------
keys = jax.random.split(key, 500)
p_true = (X_INT / 1024) * (Y_INT / 1024)
ests = jax.vmap(lambda k: engine.sc_multiply(k, X_INT, Y_INT, cfg)[0])(keys)
print(f"500 repeats:   mean={float(ests.mean()):.4f} (true {p_true:.4f}), "
      f"sigma={float(ests.std()) * 100:.2f}% — zero-centered Gaussian")

# --- 3. The engine as a framework matmul: the sc_dot registry ------------
x = jax.random.normal(key, (8, 256))
w = jax.random.normal(jax.random.fold_in(key, 1), (256, 16))
y_exact = x @ w
print(f"registered backends: {', '.join(sc.available_backends())}")
for backend in ("moment", "pallas_moment"):
    sc_cfg = sc.ScConfig(backend=backend, nbit=1024,
                         block_m=8, block_n=16, block_k=256)
    y_sc = sc.sc_dot(key, x, w, sc_cfg)
    rel = float(jnp.abs(y_sc - y_exact).mean() / jnp.abs(y_exact).mean())
    print(f"sc_dot[{backend:>14s}]: mean rel err {rel * 100:.1f}% at "
          "nbit=1024")

# --- 4. Packed bit-exact Pallas engine on raw probabilities --------------
est = sc_mul_bitexact(key, jnp.array([X_INT / 1024]),
                      jnp.array([Y_INT / 1024]), nbit=2048)
print(f"pallas kernel: p_est={float(est[0]):.4f} (true {p_true:.4f})")

# --- 5. End-to-end: an LM whose every matmul is the fused Pallas kernel --
mcfg = get_smoke_config("paper-sc").replace(
    sc_backend="pallas_moment", param_dtype=jnp.float32,
    act_dtype=jnp.float32)
params = params_lib.init_params(key, lm.lm_param_specs(mcfg),
                                mcfg.param_dtype)
toks = jax.random.randint(key, (1, 16), 2, mcfg.vocab)
logits = lm.forward(params, toks, mcfg, rng=jax.random.PRNGKey(7))
logits_exact = lm.forward(params, toks, mcfg.replace(sc_backend="exact"))
drift = float(jnp.abs(logits - logits_exact).mean())
print(f"LM forward:    every dense() via sc_backend={mcfg.sc_backend!r}, "
      f"logits {tuple(logits.shape)}, mean |Δ| vs exact = {drift:.3f}")

# --- 6. The array-level architecture simulator (repro.arch) ---------------
# The same matmul "on hardware": tiled onto banks/subarrays, compiled to a
# pulse schedule, priced in cycles and picojoules — while the numerics run
# the bit-exact engine underneath.
xa = jax.random.normal(key, (4, 32))
wa = jax.random.normal(jax.random.fold_in(key, 2), (32, 8))
with arch.collect() as records:
    ya = sc.sc_dot(key, xa, wa, sc.ScConfig(backend="array", nbit=1024))
rec = records[0]
rep = rec.report
print(f"array backend: {rec.plan.products} MULs -> {rec.plan.waves} wave(s) "
      f"on {rec.plan.spec.banks} banks, {rep.cycles} cycles, "
      f"{rep.energy_nj:.1f} nJ, subarray util {rep.subarray_util:.2f}")
print(arch.format_trace(rec.trace))
print("done.")
