"""Serve a small model with batched requests through the continuous-batching
engine — the inference-side end-to-end driver (the paper's target workload
is NN inference MACs; --sc routes every prefill/decode matmul through the
SC substrate registry, any backend).

    PYTHONPATH=src python examples/serve_batch.py --requests 12 --slots 4
    PYTHONPATH=src python examples/serve_batch.py --sc            # SC decode
    PYTHONPATH=src python examples/serve_batch.py --sc \
        --sc-backend pallas_moment                    # fused Pallas kernel
    PYTHONPATH=src python examples/serve_batch.py --paged \
        --block-size 8 --max-blocks 48      # paged KV + chunked prefill
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm, params as params_lib
from repro.serve import Request, ServeOptions, build_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--sc", action="store_true",
                    help="route decode matmuls through the SC substrate")
    ap.add_argument("--sc-backend", default=None,
                    help="any backend registered in repro.sc (implies --sc; "
                         "default: moment)")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged continuous-batching "
                         "engine (block-pool KV + chunked prefill)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (--paged)")
    ap.add_argument("--max-blocks", type=int, default=0,
                    help="KV pool size in blocks (--paged; 0 = sized for "
                         "slots x max_len — shrink it to watch evictions)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens fed per row per tick (--paged)")
    args = ap.parse_args()
    if args.sc_backend:
        args.sc = True

    cfg = get_smoke_config(args.arch).replace(
        param_dtype=jnp.float32, act_dtype=jnp.float32,
        # a slightly larger smoke config so serving is non-trivial
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512)
    if args.sc:
        cfg = cfg.replace(sc_backend=args.sc_backend or "moment",
                          sc_nbit=1024)

    key = jax.random.PRNGKey(0)
    params = params_lib.init_params(key, lm.lm_param_specs(cfg),
                                    cfg.param_dtype)
    engine = build_engine(params, cfg, ServeOptions(
        paged=args.paged, slots=args.slots, max_len=args.max_len,
        block_size=args.block_size, num_blocks=args.max_blocks,
        prefill_chunk=args.prefill_chunk))

    rng = jax.random.PRNGKey(1)
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 4, 24))
        prompt = jax.random.randint(k, (plen,), 3, cfg.vocab).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=args.temperature))

    mode = "paged" if args.paged else "fixed-slot"
    print(f"serving {args.requests} requests on {args.slots} slots "
          f"({mode} continuous batching), sc={'on' if args.sc else 'off'}")
    t0 = time.time()
    ticks = 0
    while engine.queue or any(engine.active):
        engine.step()
        ticks += 1
        active = sum(r is not None for r in engine.active)
        if ticks % 10 == 0:
            print(f"  tick {ticks:4d}: active={active} "
                  f"queued={len(engine.queue)} done={len(engine.finished)}")
    dt = time.time() - t0
    total = sum(len(r.generated) for r in engine.finished)
    print(f"\nserved {len(engine.finished)} requests / {total} tokens in "
          f"{dt:.1f}s = {total / dt:.1f} tok/s "
          f"({ticks} engine ticks, batched decode)")
    if args.paged:
        print(f"  {engine.evictions} evictions; "
              f"{engine.kv.pool.free_blocks} blocks free at drain")
    for r in engine.finished[:3]:
        print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> "
              f"{r.generated[:10]}{'...' if len(r.generated) > 10 else ''}")


if __name__ == "__main__":
    main()
