"""End-to-end driver: train an LM whose every matmul runs through the
paper's SC engine (moment-matched substrate), under the fault-tolerance
supervisor with checkpointing.

Default is a CPU-friendly ~12M-param model for 200 steps (a few minutes).
The ~100M configuration used for the EXPERIMENTS.md run:

    PYTHONPATH=src python examples/train_sc_lm.py --d-model 512 \
        --layers 8 --d-ff 2048 --vocab 32768 --steps 300 --batch 8 --seq 256

Compares the SC substrate against the exact baseline over the same data
(the paper's claim: SC noise does not break the MAC consumer — here, the
strongest consumer test we can pose is "the LM still trains").
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import SyntheticLMData, make_batch
from repro.ft import Supervisor
from repro.optim import AdamWConfig
from repro.train import TrainConfig, make_train_step
from repro.train.step import train_state_init


def build_cfg(args, sc_backend: str) -> ModelConfig:
    return ModelConfig(
        name=f"sc-lm-{sc_backend}", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.d_model // 64 or 2,
        n_kv_heads=max((args.d_model // 64 or 2) // 2, 1),
        d_ff=args.d_ff, vocab=args.vocab, sc_backend=sc_backend,
        sc_nbit=args.nbit, attn_impl="full", remat="none",
        param_dtype=jnp.float32, act_dtype=jnp.float32)


def run(cfg: ModelConfig, args, tag: str):
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=args.lr, warmup_steps=args.steps // 10,
        total_steps=args.steps))
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, seed=0)
    state = train_state_init(jax.random.PRNGKey(0), cfg, tcfg)
    n_params = sum(v.size for v in jax.tree.leaves(state["params"]))
    print(f"[{tag}] {n_params / 1e6:.1f}M params, "
          f"sc_backend={cfg.sc_backend}")
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    sup = Supervisor(ckpt_dir=f"{args.ckpt_dir}/{tag}",
                     ckpt_every=args.steps // 4)
    t0 = time.time()
    losses = []

    def logged(state, batch):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        i = len(losses)
        if i % 20 == 0 or i == 1:
            print(f"[{tag}] step {i:4d} loss {losses[-1]:.4f} "
                  f"({(time.time() - t0) / i:.2f}s/step)", flush=True)
        return state, m

    state, hist = sup.run(state, logged, args.steps,
                          make_batch=lambda i: make_batch(data, i))
    first = sum(hist["loss"][:10]) / min(10, len(hist["loss"]))
    last = sum(hist["loss"][-10:]) / min(10, len(hist["loss"]))
    print(f"[{tag}] loss {first:.4f} -> {last:.4f} "
          f"({time.time() - t0:.0f}s total)")
    return first, last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=1024)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--nbit", type=int, default=1024)
    ap.add_argument("--ckpt-dir", default="/tmp/sc_lm_ckpt")
    ap.add_argument("--skip-baseline", action="store_true")
    ap.add_argument("--sc-backend", default="moment",
                    help="any backend registered in repro.sc")
    args = ap.parse_args()

    f_sc, l_sc = run(build_cfg(args, args.sc_backend), args, "sc")
    if not args.skip_baseline:
        f_ex, l_ex = run(build_cfg(args, "exact"), args, "exact")
        print(f"\nSC substrate:   {f_sc:.4f} -> {l_sc:.4f}")
        print(f"exact baseline: {f_ex:.4f} -> {l_ex:.4f}")
        print(f"SC loss penalty at end: {l_sc - l_ex:+.4f} "
              "(paper: SC error is zero-centered; training tolerates it)")


if __name__ == "__main__":
    main()
