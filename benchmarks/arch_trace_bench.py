"""Array-architecture trace benchmark: the paper's §V headlines from a
COMMAND TRACE instead of the closed-form model, then real workloads.

  1. One 10-bit MUL is tiled onto the array, compiled to its pulse
     schedule, and priced by the accountant — the ≈4× (vs conventional SC)
     and ≈18× (vs Boolean-PIM) cycle ratios must re-emerge from the trace
     makespan (they are asserted, not just printed).
  2. A real LM forward pass (paper-sc) replays with ``sc_backend="array"``:
     every dense() dispatch records its schedule; the per-call table shows
     where the cycles/energy go. Records are per COMPILED call (the layer
     scan body traces once), so the static workload pricing below carries
     the exact layer multiplicity.
  3. The same config's full dense() workload is priced statically
     (repro.arch.workload) — per-site cycles, energy, utilization — and,
     outside ``--tiny``, a production config (qwen3-14b at decode batch)
     shows the simulator holding up at scale.

Writes ``BENCH_arch_trace.json`` (headline ratios + workload totals) for
the CI artifact trail.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, section, write_json
from repro import arch, sc
from repro.configs import get_config, get_smoke_config
from repro.core import costmodel as cm
from repro.models import lm, params as params_lib

NBIT = 1024          # 2^10 stochastic bits = the paper's 10-bit operands
N_BITS = 10


def headline_from_trace() -> dict:
    """§V Fig. 9 ratios derived from the compiled command trace."""
    section("1. One 10-bit MUL: pulse schedule -> cycles -> §V ratios")
    rec = arch.schedule_call(1, 1, 1, NBIT)
    print(arch.format_trace(rec.trace))
    trace_cycles = rec.report.cycles
    sc_cycles = cm.cycles_sc(N_BITS)
    pim_anchor = cm.cycles_pim(8)          # the paper's published DRISA anchor
    vs_sc = sc_cycles / trace_cycles
    vs_pim = pim_anchor / trace_cycles
    emit("arch.trace.cycles_per_mul", trace_cycles,
         f"closed-form {cm.cycles_scpim_apc(N_BITS):.0f}")
    emit("arch.trace.energy_pj_per_mul", round(rec.report.energy_pj, 2),
         f"closed-form {cm.energy_scpim(N_BITS, 'apc')[0]:.2f}")
    emit("arch.trace.speedup_vs_sc", round(vs_sc, 2), "paper: ~4x")
    emit("arch.trace.speedup_vs_pim", round(vs_pim, 2), "paper: 18x")
    assert trace_cycles == cm.cycles_scpim_apc(N_BITS), \
        "trace makespan drifted from the closed-form §V model"
    assert 3.0 <= vs_sc <= 5.0, f"vs-SC ratio {vs_sc:.2f} outside Fig. 9a"
    assert 15.0 <= vs_pim <= 21.0, f"vs-PIM ratio {vs_pim:.2f} outside Fig. 9a"
    return {"cycles_per_mul": trace_cycles,
            "energy_pj_per_mul": round(rec.report.energy_pj, 3),
            "speedup_vs_sc": round(vs_sc, 3),
            "speedup_vs_pim": round(vs_pim, 3)}


def replay_forward(tokens: int = 8) -> dict:
    """Run a real LM forward on the array backend and read the trace."""
    section(f"2. LM forward replay on the array backend ({tokens} tokens)")
    key = jax.random.PRNGKey(0)
    cfg = get_smoke_config("paper-sc").replace(
        sc_backend="array", sc_nbit=NBIT,
        param_dtype=jnp.float32, act_dtype=jnp.float32)
    params = params_lib.init_params(key, lm.lm_param_specs(cfg),
                                    cfg.param_dtype)
    toks = jax.random.randint(key, (1, tokens), 2, cfg.vocab)
    with arch.collect() as records:
        logits = lm.forward(params, toks, cfg, rng=jax.random.PRNGKey(7))
    assert bool(jnp.all(jnp.isfinite(logits)))
    print(f"{'shape':<16s} {'products':>9s} {'waves':>6s} {'cycles':>7s} "
          f"{'energy_nJ':>10s} {'util':>5s}")
    for r in records:
        m, k, n = r.shape
        print(f"{f'{m}x{k}x{n}':<16s} {r.plan.products:>9,d} "
              f"{r.plan.waves:>6d} {r.report.cycles:>7,d} "
              f"{r.report.energy_nj:>10.1f} {r.report.subarray_util:>5.2f}")
    agg = arch.merge_reports(r.report for r in records)
    emit("arch.replay.calls", len(records),
         "per COMPILED dense() site (scan body traces once)")
    emit("arch.replay.cycles", agg.cycles, "sum over compiled sites")
    emit("arch.replay.energy_nj", round(agg.energy_nj, 1), "")
    return {"calls": len(records), "cycles": agg.cycles,
            "energy_pj": round(agg.energy_pj, 1)}


def price_model(arch_id: str, tokens: int, smoke: bool = False,
                top: int = 8) -> dict:
    """Static full-multiplicity pricing of one config's dense() workload."""
    cfg = (get_smoke_config if smoke else get_config)(arch_id)
    sites = arch.dense_workload(cfg, tokens)
    per_site, total = arch.price_workload(sites, NBIT)
    tag = f"{arch_id}{'(smoke)' if smoke else ''}"
    section(f"3. Full workload pricing: {tag}, {tokens} tokens, nbit={NBIT}")
    per_site.sort(key=lambda sr: -sr[1].cycles)
    for s, r in per_site[:top]:
        print(f"  {s.label:<12s} {s.m}x{s.k}x{s.n} x{s.count:<3d} "
              f"{r.cycles:>13,d} cyc  {r.energy_pj / 1e6:>9.2f} µJ  "
              f"util={r.subarray_util:.2f}")
    print(f"  {'TOTAL':<12s} {total.products:,} MULs  "
          f"{total.cycles:>13,d} cyc  {total.energy_pj / 1e6:>9.2f} µJ")
    emit(f"arch.workload.{tag}.cycles", total.cycles, f"{tokens} tokens")
    emit(f"arch.workload.{tag}.energy_uj", round(total.energy_pj / 1e6, 2), "")
    emit(f"arch.workload.{tag}.cycles_per_mul",
         round(total.cycles_per_product, 3),
         "amortized (waves pipeline MULs)")
    return {"tokens": tokens, "products": total.products,
            "cycles": total.cycles,
            "energy_pj": round(total.energy_pj, 1),
            "cycles_per_mul": round(total.cycles_per_product, 4)}


def main(tiny: bool = False):
    payload = {"nbit": NBIT, "tiny": tiny,
               "headline": headline_from_trace(),
               "replay": replay_forward(tokens=8),
               "workloads": {}}
    payload["workloads"]["paper-sc(smoke)"] = price_model(
        "paper-sc", tokens=8, smoke=True)
    if not tiny:
        payload["workloads"]["paper-sc"] = price_model("paper-sc", tokens=128)
        payload["workloads"]["qwen3-14b@decode128"] = price_model(
            "qwen3-14b", tokens=128)
    write_json("BENCH_arch_trace.json", payload)


if __name__ == "__main__":
    main(tiny="--tiny" in sys.argv)
