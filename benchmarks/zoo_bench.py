"""Model-zoo benchmark: every family x backend x nbit on the SC substrate.

The zoo refactor routes EVERY matmul site — dense MLPs, the MoE router
and per-expert FFNs, the SSM projections, the embeddings-frontend
projection, the unembed — through the ``repro.sc`` registry, and serves
every family on the paged engine via the per-family cache plan.  This
bench is the matrix that proves it stays true:

  1. Accuracy vs nbit (paper Fig. 7 lifted to whole-model forwards):
     cosine similarity between each stochastic backend's logits and the
     exact reference, per family, per bit budget — ``*_acc`` leaves that
     ``tools/bench_compare.py`` gates with an absolute-drop band.
  2. Variance sweep (Fig. 8 analogue): the sigma of repeated stochastic
     forwards must shrink ~1/sqrt(nbit); recorded as a
     ``variance_shrink_speedup`` ratio with a hard assert.
  3. Decode: each family drains a request through ``PagedServingEngine``
     on the moment substrate (SSM/hybrid ride the state slots beside the
     block table) and its greedy tokens must match the fixed-slot
     engine — ``paged_matches_fixed`` is an exact-gated flag.

Writes ``BENCH_zoo.json`` (CI archives it and diffs against
``benchmarks/baselines/BENCH_zoo.json``).  ``--tiny`` shrinks nbits,
repeats, and sequence lengths for the smoke job.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, section, timed, write_json
from repro.models import lm, params as params_lib
from repro.configs import get_smoke_config
from repro.serve import Request, ServeOptions, build_engine
from repro.serve.kv_cache import CachePlan

# one representative arch per cache-plan family; musicgen covers the
# embeddings frontend (frontend_proj site) on top of plain attention
FAMILIES = {
    "dense": "qwen2-0.5b",
    "moe": "moonshot-v1-16b-a3b",
    "ssm": "mamba2-370m",
    "hybrid": "zamba2-7b",
    "multimodal": "musicgen-large",
}
BACKENDS = ("exact", "moment", "pallas_fused")
NBITS = (64, 256, 1024)
VAR_REPEATS = 32

_TINY = dict(nbits=(32, 64), pallas_nbits=(32,), var_repeats=12, seq=6,
             var_families=("dense",), iters=1, warmup=0)
_FULL = dict(nbits=NBITS, pallas_nbits=NBITS, var_repeats=VAR_REPEATS,
             seq=12, var_families=("dense", "moe"), iters=3, warmup=1)

# variance must shrink with nbit: sigma ratio across a 2x (tiny) / 16x
# (full) bit-budget step, floored well under the ~sqrt ideal
VAR_SHRINK_FLOOR_TINY = 1.05
VAR_SHRINK_FLOOR = 2.0


def _cfg(arch, **kw):
    return get_smoke_config(arch).replace(
        param_dtype=jnp.float32, act_dtype=jnp.float32, **kw)


def _inputs(key, cfg, s):
    if cfg.frontend == "embeddings":
        return jax.random.normal(key, (1, s, cfg.d_model), cfg.act_dtype)
    return jax.random.randint(key, (1, s), 3, cfg.vocab)


def _cos(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-30))


def _forward_matrix(family, arch, knobs, key):
    """Accuracy-vs-nbit block of one family: logits cosine + wall time."""
    cfg0 = _cfg(arch)
    params = params_lib.init_params(key, lm.lm_param_specs(cfg0),
                                    cfg0.param_dtype)
    x = _inputs(jax.random.fold_in(key, 1), cfg0, knobs["seq"])
    rng = jax.random.fold_in(key, 2)
    exact = lm.forward(params, x, cfg0.replace(sc_backend="exact"), rng=rng)
    out = {}
    for backend in BACKENDS:
        per_nbit = {}
        # interpreted Pallas compiles dominate: --tiny trims the fused
        # leg to one bit budget and one timing call (log what's dropped)
        nbits = (knobs["pallas_nbits"] if backend.startswith("pallas")
                 else knobs["nbits"])
        if backend.startswith("pallas") and len(nbits) < len(knobs["nbits"]):
            print(f"  [{family}.{backend}: nbit sweep trimmed to "
                  f"{list(nbits)} under --tiny]")
        for nbit in nbits:
            cfg = cfg0.replace(sc_backend=backend, sc_nbit=nbit)
            fwd = lambda: lm.forward(params, x, cfg, rng=rng)
            wall = timed(fwd, iters=knobs["iters"], warmup=knobs["warmup"])
            acc = 1.0 if backend == "exact" else _cos(fwd(), exact)
            emit(f"zoo.{family}.{backend}.n{nbit}.logits_cos_acc",
                 round(acc, 4), f"cosine vs exact logits, seq={knobs['seq']}")
            per_nbit[f"n{nbit}"] = {"logits_cos_acc": round(acc, 4),
                                    "wall_us": round(wall, 1)}
            if backend == "exact":
                break                      # nbit is a no-op for exact
        out[backend] = per_nbit
    return out, params, cfg0


def _variance_sweep(family, params, cfg0, knobs, key):
    """Fig. 8 analogue: sigma of repeated moment forwards vs nbit."""
    x = _inputs(jax.random.fold_in(key, 1), cfg0, knobs["seq"])
    lo, hi = knobs["nbits"][0], knobs["nbits"][-1]
    sigma = {}
    for nbit in (lo, hi):
        cfg = cfg0.replace(sc_backend="moment", sc_nbit=nbit)
        outs = np.stack([
            np.asarray(lm.forward(params, x, cfg,
                                  rng=jax.random.fold_in(key, 100 + r)))
            for r in range(knobs["var_repeats"])])
        sigma[nbit] = float(outs.std(axis=0).mean())
    shrink = sigma[lo] / max(sigma[hi], 1e-30)
    ideal = float(np.sqrt(hi / lo))
    emit(f"zoo.{family}.variance_shrink_speedup", round(shrink, 2),
         f"sigma(n{lo})/sigma(n{hi}), ideal ~{ideal:.1f}x")
    floor = (VAR_SHRINK_FLOOR_TINY if knobs is _TINY else VAR_SHRINK_FLOOR)
    assert shrink >= floor, (
        f"{family}: variance shrank only {shrink:.2f}x from nbit={lo} to "
        f"nbit={hi} (floor {floor}x) — the substrate stopped averaging")
    return {f"sigma_n{lo}": round(sigma[lo], 5),
            f"sigma_n{hi}": round(sigma[hi], 5),
            "variance_shrink_speedup": round(shrink, 2)}


def _drain(engine, prompt):
    engine.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=4))
    return engine.run_until_drained()[0].generated


def _decode_check(family, params, cfg0, key):
    """Serve the family through the paged engine: greedy token identity
    vs the fixed-slot engine on the exact substrate (the cache-plan
    contract — chunked prefill reshapes stochastic draws, so identity
    across *engines* is an exact-backend property; the rng invariants on
    stochastic substrates are paged-vs-paged, pinned in
    tests/test_serve_zoo.py), plus a moment-substrate paged drain."""
    prompt = [5, 9, 17, 3, 8]
    popts = ServeOptions(paged=True, slots=1, max_len=32, block_size=4,
                         prefill_chunk=3)
    cfg = cfg0.replace(sc_backend="exact")
    want = _drain(build_engine(params, cfg,
                               ServeOptions(slots=1, max_len=32)), prompt)
    got = _drain(build_engine(params, cfg, popts), prompt)
    ok = got == want
    plan = CachePlan.for_config(cfg)
    emit(f"zoo.{family}.paged_matches_fixed", int(ok),
         f"plan: {plan.paged_layers} paged / {plan.state_layers} state "
         "layers")
    assert ok, (f"{family}: paged tokens {got} != fixed-slot {want} — "
                "the cache plan broke token identity")
    mcfg = cfg0.replace(sc_backend="moment", sc_nbit=64)
    stoch = _drain(build_engine(params, mcfg, popts), prompt)
    emit(f"zoo.{family}.stochastic_decode_ok", int(len(stoch) == 4),
         "moment-substrate paged drain")
    return {"paged_matches_fixed": ok,
            "stochastic_decode_ok": len(stoch) == 4,
            "paged_layers": plan.paged_layers,
            "state_layers": plan.state_layers,
            "generated": len(got)}


def main(key=None, tiny: bool = False):
    key = key if key is not None else jax.random.PRNGKey(11)
    knobs = _TINY if tiny else _FULL
    results: dict = {}
    for i, (family, arch) in enumerate(FAMILIES.items()):
        fkey = jax.random.fold_in(key, i)
        section(f"{family} ({arch}): backends x nbit, seq={knobs['seq']}")
        backends, params, cfg0 = _forward_matrix(family, arch, knobs, fkey)
        entry = {"arch": arch, "backends": backends}
        if family in knobs["var_families"]:
            entry["variance"] = _variance_sweep(family, params, cfg0,
                                                knobs, fkey)
        if cfg0.frontend == "tokens":      # serve path is token-frontend
            entry["decode"] = _decode_check(family, params, cfg0, fkey)
        results[family] = entry
    write_json("BENCH_zoo.json",
               {"tiny": tiny,
                "workload": {"seq": knobs["seq"],
                             "nbits": list(knobs["nbits"]),
                             "var_repeats": knobs["var_repeats"]},
                "families": results})


if __name__ == "__main__":
    main(tiny="--tiny" in sys.argv)
