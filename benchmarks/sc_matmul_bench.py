"""SC substrate benchmark: every registered backend through ONE entry point.

Three views:
  1. CPU-indicative wall-clock of the registered ``repro.sc`` backends,
     all dispatched through ``sc_dot`` (exact / moment / pallas_moment on
     the full shape; the O(M·K·N) bitexact family on a reduced shape) —
     relative cost of the interchangeable implementations.
  2. Modeled SOT-MRAM array cycles for each measured (backend, shape) from
     the repro.arch pulse-schedule compiler — what the same call costs on
     the paper's hardware, next to what it costs this host.
  3. Head-to-head gate: ``pallas_fused`` vs ``pallas_bitexact`` on the
     same operands — asserts bit-exact equivalence (same key ⇒ same
     bits) and a speedup floor (≥2x at full size; noise floor under
     ``--tiny``), recorded under ``fused_vs_bitexact``.
  4. Analytic TPU roofline of the fused kernel vs the unfused 3-matmul
     formulation — the fusion is the beyond-paper optimization, tripling
     arithmetic intensity at equal HBM traffic (§Perf iteration 3).

Writes ``BENCH_sc_matmul.json``: backend × shape → wall-time µs + modeled
array cycles (the machine-readable perf trajectory CI archives and
``tools/bench_compare.py`` gates against ``benchmarks/baselines/``).
``--tiny`` shrinks shapes for smoke/CI runs.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, section, timed, write_json
from repro import arch, sc
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS_BF16

M, K, N = 512, 2048, 512
NBIT = 1024

# backends that materialize every (i, k, j) product run on a reduced shape
# (pallas_fused shares pallas_bitexact's shape: the two are compared
# head-to-head below and must see identical operands)
_REDUCED = {"bitexact": (64, 256, 64), "pallas_bitexact": (8, 32, 8),
            "pallas_fused": (8, 32, 8), "array": (64, 256, 64)}

_TINY = dict(full=(32, 128, 32), reduced={"bitexact": (8, 32, 8),
                                          "pallas_bitexact": (4, 16, 4),
                                          "pallas_fused": (4, 16, 4),
                                          "array": (8, 32, 8)})

# full-size gate: the fused engine must beat the packed three-stage
# engine by at least this factor (bitstreams never leaving VMEM is the
# point); --tiny smoke runs keep a noise floor only, like serve_bench
FUSED_SPEEDUP_FLOOR = 2.0
FUSED_SPEEDUP_FLOOR_TINY = 0.8


def analytic_roofline():
    """SC-MAC kernel variants on one v5e chip (bf16 peak, f32 traffic) —
    the §Perf cell-3 iteration ladder."""
    flops = 3 * 2 * M * K * N                     # three dots
    variants = {
        # three separate dots re-read operands; mean/sum_p/sum_p2 round-trip
        "it0_unfused": 4 * (3 * (M * K + K * N) + 4 * M * N + 2 * M * N),
        # one pass, three VMEM accumulators; noise streamed from HBM
        "it1_fused": 4 * (M * K + K * N + 2 * M * N),
        # in-kernel PRNG epilogue: the (M, N) noise input disappears
        "it2_fused_prng": 4 * (M * K + K * N + M * N),
    }
    out = {}
    for name, b in variants.items():
        compute_s = flops / PEAK_FLOPS_BF16
        memory_s = b / HBM_BW
        ai = flops / b
        bound = "compute" if compute_s > memory_s else "memory"
        emit(f"scmac.roofline.{name}.arith_intensity", round(ai, 1),
             f"bound={bound} mem_s={memory_s:.2e} comp_s={compute_s:.2e}")
        out[name] = {"arith_intensity": round(ai, 1), "bound": bound}
    emit("scmac.roofline.fusion_traffic_saving",
         round(variants["it0_unfused"] / variants["it1_fused"], 2),
         "fused kernel HBM-traffic advantage")
    emit("scmac.roofline.prng_traffic_saving",
         round(variants["it1_fused"] / variants["it2_fused_prng"], 2),
         "in-kernel PRNG advantage on top of fusion")
    return out


def _array_cycles(m: int, k: int, n: int, nbit: int) -> int:
    """Modeled SOT-MRAM cycles for the call (repro.arch schedule makespan)."""
    return arch.schedule_call(m, k, n, nbit).report.cycles


def main(key=None, tiny: bool = False):
    key = key if key is not None else jax.random.PRNGKey(3)
    full = _TINY["full"] if tiny else (M, K, N)
    reduced = _TINY["reduced"] if tiny else _REDUCED
    m0, k0, n0 = full
    kx, kw, kk = jax.random.split(key, 3)
    x = jax.random.normal(kx, (m0, k0), jnp.float32)
    w = jax.random.normal(kw, (k0, n0), jnp.float32)

    results: dict = {}

    def put(backend, m, k, n, wall_us, note):
        results[backend] = {
            "shape": [m, k, n], "nbit": NBIT,
            "wall_us": round(wall_us, 1),
            "array_cycles": _array_cycles(m, k, n, NBIT),
            "note": note,
        }

    section(f"SC substrate backends via sc_dot, ({m0}x{k0}) @ ({k0}x{n0}), "
            f"nbit={NBIT}")
    t_exact = timed(
        lambda: sc.sc_dot(kk, x, w, sc.ScConfig(backend="exact")))
    emit("scmac.us.exact", round(t_exact, 1), "plain XLA matmul (CPU)")
    put("exact", m0, k0, n0, t_exact, "plain XLA matmul (CPU)")
    for backend in sc.available_backends():
        if backend == "exact":
            continue
        if backend in reduced:
            m, k, n = reduced[backend]
            xs, ws = x[:m, :k], w[:k, :n]
            t_ex = timed(lambda: jnp.dot(xs, ws).block_until_ready())
            cfg = sc.ScConfig(backend=backend, nbit=NBIT)
            t = timed(lambda: sc.sc_dot(kk, xs, ws, cfg))
            note = (f"{t / max(t_ex, 1e-9):.0f}x exact — the O(nbit) cost "
                    "the moment backends remove")
            emit(f"scmac.us.{backend}_{m}x{k}x{n}", round(t, 1), note)
            put(backend, m, k, n, t, note)
        else:
            cfg = sc.ScConfig(backend=backend, nbit=NBIT,
                              block_m=128, block_n=128, block_k=512)
            t = timed(lambda: sc.sc_dot(kk, x, w, cfg))
            note = ("Pallas interpret mode — correctness path, not perf"
                    if backend.startswith("pallas")
                    else f"{t / t_exact:.1f}x exact (3 dots + draw)")
            emit(f"scmac.us.{backend}", round(t, 1), note)
            put(backend, m0, k0, n0, t, note)

    section("Fused engine vs packed three-stage engine (pallas_fused "
            "vs pallas_bitexact)")
    m, k, n = reduced["pallas_bitexact"]
    xs, ws = x[:m, :k], w[:k, :n]
    yb = sc.sc_dot(kk, xs, ws,
                   sc.ScConfig(backend="pallas_bitexact", nbit=NBIT))
    yf = sc.sc_dot(kk, xs, ws,
                   sc.ScConfig(backend="pallas_fused", nbit=NBIT))
    bit_exact = bool(jnp.all(yb == yf))
    speedup = (results["pallas_bitexact"]["wall_us"]
               / max(results["pallas_fused"]["wall_us"], 1e-9))
    emit("scmac.fused.bit_exact", int(bit_exact),
         "same key => same bits as pallas_bitexact")
    emit("scmac.fused.speedup", round(speedup, 2),
         f"fused vs packed at {m}x{k}x{n}, nbit={NBIT}")
    assert bit_exact, (
        "pallas_fused diverged from pallas_bitexact under a shared key — "
        "the counter-based streams are out of sync")
    floor = FUSED_SPEEDUP_FLOOR_TINY if tiny else FUSED_SPEEDUP_FLOOR
    assert speedup >= floor, (
        f"pallas_fused speedup {speedup:.2f}x below the {floor}x floor "
        f"at {m}x{k}x{n} (tiny={tiny})")
    fused_cmp = {"shape": [m, k, n], "nbit": NBIT, "bit_exact": bit_exact,
                 "speedup": round(speedup, 2), "floor": floor}

    section("Analytic v5e roofline: fused vs unfused SC-MAC")
    roofline = analytic_roofline()

    write_json("BENCH_sc_matmul.json",
               {"tiny": tiny, "nbit": NBIT, "backends": results,
                "fused_vs_bitexact": fused_cmp, "roofline": roofline})


if __name__ == "__main__":
    main(tiny="--tiny" in sys.argv)
