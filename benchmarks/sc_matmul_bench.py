"""SC substrate benchmark: every registered backend through ONE entry point.

Two views:
  1. CPU-indicative wall-clock of the registered ``repro.sc`` backends,
     all dispatched through ``sc_dot`` (exact / moment / pallas_moment on
     the full shape; the O(M·K·N) bitexact pair on a reduced shape) —
     relative cost of the interchangeable implementations.
  2. Analytic TPU roofline of the fused kernel vs the unfused 3-matmul
     formulation — the fusion is the beyond-paper optimization, tripling
     arithmetic intensity at equal HBM traffic (§Perf iteration 3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, section, timed
from repro import sc
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS_BF16

M, K, N = 512, 2048, 512
NBIT = 1024

# backends that materialize every (i, k, j) product run on a reduced shape
_REDUCED = {"bitexact": (64, 256, 64), "pallas_bitexact": (8, 32, 8)}


def analytic_roofline():
    """SC-MAC kernel variants on one v5e chip (bf16 peak, f32 traffic) —
    the §Perf cell-3 iteration ladder."""
    flops = 3 * 2 * M * K * N                     # three dots
    variants = {
        # three separate dots re-read operands; mean/sum_p/sum_p2 round-trip
        "it0_unfused": 4 * (3 * (M * K + K * N) + 4 * M * N + 2 * M * N),
        # one pass, three VMEM accumulators; noise streamed from HBM
        "it1_fused": 4 * (M * K + K * N + 2 * M * N),
        # in-kernel PRNG epilogue: the (M, N) noise input disappears
        "it2_fused_prng": 4 * (M * K + K * N + M * N),
    }
    for name, b in variants.items():
        compute_s = flops / PEAK_FLOPS_BF16
        memory_s = b / HBM_BW
        ai = flops / b
        bound = "compute" if compute_s > memory_s else "memory"
        emit(f"scmac.roofline.{name}.arith_intensity", round(ai, 1),
             f"bound={bound} mem_s={memory_s:.2e} comp_s={compute_s:.2e}")
    emit("scmac.roofline.fusion_traffic_saving",
         round(variants["it0_unfused"] / variants["it1_fused"], 2),
         "fused kernel HBM-traffic advantage")
    emit("scmac.roofline.prng_traffic_saving",
         round(variants["it1_fused"] / variants["it2_fused_prng"], 2),
         "in-kernel PRNG advantage on top of fusion")


def main(key=None):
    key = key if key is not None else jax.random.PRNGKey(3)
    kx, kw, kk = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)

    section(f"SC substrate backends via sc_dot, ({M}x{K}) @ ({K}x{N}), "
            f"nbit={NBIT}")
    t_exact = timed(
        lambda: sc.sc_dot(kk, x, w, sc.ScConfig(backend="exact")))
    emit("scmac.us.exact", round(t_exact, 1), "plain XLA matmul (CPU)")
    for backend in sc.available_backends():
        if backend == "exact":
            continue
        if backend in _REDUCED:
            m, k, n = _REDUCED[backend]
            xs, ws = x[:m, :k], w[:k, :n]
            t_ex = timed(lambda: jnp.dot(xs, ws).block_until_ready())
            cfg = sc.ScConfig(backend=backend, nbit=NBIT)
            t = timed(lambda: sc.sc_dot(kk, xs, ws, cfg))
            emit(f"scmac.us.{backend}_{m}x{k}x{n}", round(t, 1),
                 f"{t / max(t_ex, 1e-9):.0f}x exact — the O(nbit) cost the "
                 "moment backends remove")
        else:
            cfg = sc.ScConfig(backend=backend, nbit=NBIT,
                              block_m=128, block_n=128, block_k=512)
            t = timed(lambda: sc.sc_dot(kk, x, w, cfg))
            note = ("Pallas interpret mode — correctness path, not perf"
                    if backend.startswith("pallas")
                    else f"{t / t_exact:.1f}x exact (3 dots + draw)")
            emit(f"scmac.us.{backend}", round(t, 1), note)

    section("Analytic v5e roofline: fused vs unfused SC-MAC")
    analytic_roofline()


if __name__ == "__main__":
    main()
