"""SC-MAC kernel benchmark: the paper's technique as a framework matmul.

Two views:
  1. CPU-indicative wall-clock of the three modes (exact / moment via the
     fused Pallas kernel in interpret mode / bitexact core) — relative cost.
  2. Analytic TPU roofline of the fused kernel vs the unfused 3-matmul
     formulation — the fusion is the beyond-paper optimization, tripling
     arithmetic intensity at equal HBM traffic (§Perf iteration 3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, section, timed
from repro.core import scmac
from repro.kernels import ops
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS_BF16

M, K, N = 512, 2048, 512
NBIT = 1024


def analytic_roofline():
    """SC-MAC kernel variants on one v5e chip (bf16 peak, f32 traffic) —
    the §Perf cell-3 iteration ladder."""
    flops = 3 * 2 * M * K * N                     # three dots
    variants = {
        # three separate dots re-read operands; mean/sum_p/sum_p2 round-trip
        "it0_unfused": 4 * (3 * (M * K + K * N) + 4 * M * N + 2 * M * N),
        # one pass, three VMEM accumulators; noise streamed from HBM
        "it1_fused": 4 * (M * K + K * N + 2 * M * N),
        # in-kernel PRNG epilogue: the (M, N) noise input disappears
        "it2_fused_prng": 4 * (M * K + K * N + M * N),
    }
    for name, b in variants.items():
        compute_s = flops / PEAK_FLOPS_BF16
        memory_s = b / HBM_BW
        ai = flops / b
        bound = "compute" if compute_s > memory_s else "memory"
        emit(f"scmac.roofline.{name}.arith_intensity", round(ai, 1),
             f"bound={bound} mem_s={memory_s:.2e} comp_s={compute_s:.2e}")
    emit("scmac.roofline.fusion_traffic_saving",
         round(variants["it0_unfused"] / variants["it1_fused"], 2),
         "fused kernel HBM-traffic advantage")
    emit("scmac.roofline.prng_traffic_saving",
         round(variants["it1_fused"] / variants["it2_fused_prng"], 2),
         "in-kernel PRNG advantage on top of fusion")


def main(key=None):
    key = key if key is not None else jax.random.PRNGKey(3)
    kx, kw, kk = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)

    section(f"SC matmul modes, ({M}x{K}) @ ({K}x{N}), nbit={NBIT}")
    t_exact = timed(lambda: jnp.dot(x, w).block_until_ready())
    emit("scmac.us.exact", round(t_exact, 1), "plain XLA matmul (CPU)")

    cfg = scmac.SCMacConfig(mode="moment", nbit=NBIT)
    t_moment = timed(lambda: scmac.sc_matmul(kk, x, w, cfg))
    emit("scmac.us.moment_core", round(t_moment, 1),
         f"{t_moment / t_exact:.1f}x exact (3 dots + draw)")

    t_fused = timed(lambda: ops.sc_matmul_fused(
        kk, x, w, nbit=NBIT, block_m=128, block_n=128, block_k=512))
    emit("scmac.us.moment_fused_interpret", round(t_fused, 1),
         "Pallas interpret mode — correctness path, not perf")

    # bitexact on a reduced shape (O(M*K*N) memory)
    xs, ws = x[:64, :256], w[:256, :64]
    cfgb = scmac.SCMacConfig(mode="bitexact", nbit=NBIT)
    t_bit = timed(lambda: scmac.sc_matmul(kk, xs, ws, cfgb))
    t_exact_s = timed(lambda: jnp.dot(xs, ws).block_until_ready())
    emit("scmac.us.bitexact_64x256x64", round(t_bit, 1),
         f"{t_bit / max(t_exact_s, 1e-9):.0f}x exact — the O(nbit) cost the "
         "moment mode removes")

    section("Analytic v5e roofline: fused vs unfused SC-MAC")
    analytic_roofline()


if __name__ == "__main__":
    main()
