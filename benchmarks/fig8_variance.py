"""Fig. 8 reproduction: robustness to hardware variance.

(a) MUL uncertainty vs sigma(I_c) 0-10 % — expect flat.
(b) MUL uncertainty vs sigma(Circuits) for SC+PIM vs logarithm multiplier —
    expect SC+PIM flat, log-mult degrading sharply.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, section
from repro.core import engine, physics, variance

X, Y = 400, 700
CFG = engine.EngineConfig(nbit=1024)
ITERS = 600


def _sweep(key, fn, sigmas):
    out = {}
    for i, s in enumerate(sigmas):
        keys = jax.random.split(jax.random.fold_in(key, i), ITERS)
        p = jax.vmap(lambda k: fn(k, s))(keys)
        out[s] = float(jnp.std(p))
    return out


def _profile_sweep(key, sigmas, base: physics.DeviceProfile):
    """sigma(I_c) sweep through the DeviceProfile path: each sigma is a
    frozen realized map, each iteration its own MUL cell bank."""
    out = {}
    x = jnp.full((ITERS,), X, jnp.int32)
    for i, s in enumerate(sigmas):
        p = variance.sc_mul_with_profile(
            jax.random.fold_in(key, i), x, Y, CFG,
            base.replace(sigma_ic=s))
        out[s] = float(jnp.std(p))
    return out


def main(key=None, profile=None):
    key = key if key is not None else jax.random.PRNGKey(7)
    base = physics.resolve_profile(profile) or physics.DeviceProfile()

    section("Fig 8a: sigma(MUL) vs sigma(I_c) — SC+PIM (realized maps)")
    ic = _profile_sweep(key, (0.0, 0.02, 0.04, 0.06, 0.08, 0.10), base)
    for s, v in ic.items():
        emit(f"fig8a.sigma_pct.ic={int(s * 100)}%", round(v * 100, 3),
             "paper: ~flat")

    section("Fig 8b: sigma(MUL) vs sigma(Circuits) — SC+PIM vs log-mult")
    sc = _sweep(jax.random.fold_in(key, 1),
                lambda k, s: variance.sc_mul_with_circuit_variance(
                    k, X, Y, CFG, s), (0.04, 0.06, 0.08, 0.10))
    lm = _sweep(jax.random.fold_in(key, 2),
                lambda k, s: variance.log_multiplier(k, X, Y, CFG.conv, s),
                (0.04, 0.06, 0.08, 0.10))
    for s in sc:
        emit(f"fig8b.scpim_sigma_pct.circ={int(s * 100)}%",
             round(sc[s] * 100, 3), "paper: ~flat")
    for s in lm:
        emit(f"fig8b.logmult_sigma_pct.circ={int(s * 100)}%",
             round(lm[s] * 100, 3), "paper: degrades sharply")
    emit("fig8b.logmult_over_scpim_at_10pct",
         round(lm[0.10] / sc[0.10], 2), "log-mult >> SC at high variance")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default=None,
                    help="base DeviceProfile name the sigma(I_c) sweep "
                         "perturbs (see core/physics.py)")
    main(profile=ap.parse_args().profile)
