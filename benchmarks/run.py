"""Benchmark driver: one module per paper table/figure, CSV rows
``name,value,derived`` plus ASCII summaries.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig9 fig10 # subset
"""

from __future__ import annotations

import sys
import time

from benchmarks import (arch_trace_bench, fig7_accuracy, fig8_variance,
                        fig9_cycles, fig10_energy, fig11_area, roofline,
                        sc_matmul_bench, zoo_bench)

SUITES = {
    "fig7": fig7_accuracy.main,     # accuracy statistics (paper Fig. 7)
    "fig8": fig8_variance.main,     # hardware variance (paper Fig. 8)
    "fig9": fig9_cycles.main,       # performance/cycles (paper Fig. 9)
    "fig10": fig10_energy.main,     # energy (paper Fig. 10)
    "fig11": fig11_area.main,       # area (paper Fig. 11)
    "scmac": sc_matmul_bench.main,  # the SC-MAC framework matmul + roofline
    "arch": arch_trace_bench.main,  # array simulator: §V ratios from traces
    "zoo": zoo_bench.main,          # model families x backends x nbit
    "roofline": roofline.main,      # 40-cell dry-run roofline table
}


def main() -> None:
    names = sys.argv[1:] or list(SUITES)
    t0 = time.time()
    for name in names:
        if name not in SUITES:
            print(f"unknown suite {name!r}; have {list(SUITES)}")
            raise SystemExit(2)
        SUITES[name]()
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
