"""Reliability envelope: accuracy as a function of device badness.

Sweeps the three device-realism axes a :class:`repro.core.physics.
DeviceProfile` exposes — manufacturing spread ``sigma``, bit-error rate
``ber``, and stochastic length ``nbit`` — and records how far each point
pushes the substrate off the paper's idealized math:

* **MUL envelope** (fig7/fig8-style): batched single MULs on the frozen
  variation maps, emitting error sigma and mean bias per (nbit, sigma).
* **Dot envelope**: small matmuls through the ``array`` backend with the
  full profile (variation + stuck-at + retention), emitting cosine
  accuracy vs the exact product per (sigma, ber) — plus the EXACT
  modeled fault census (``accounting.bit_error_census``) for that call,
  the ``*_errors_total`` leaves CI gates bit-for-bit.

All draws come from fixed PRNG keys and frozen Threefry maps, so every
leaf is deterministic; ``tools/bench_compare.py`` compares sigma/bias
exactly, ``cos_acc`` under the accuracy tolerance, and the censuses
under the dedicated ``errors`` class.  ``--tiny`` shrinks the grid for
CI; the committed baseline (``benchmarks/baselines/BENCH_envelope.json``)
is a ``--tiny`` artifact.

    PYTHONPATH=src:. python benchmarks/envelope_bench.py --tiny
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, section, write_json
from repro import sc
from repro.arch import accounting
from repro.core import engine, physics

TAU_X, TAU_Y = 0.3, 0.4
SEED = 7
MAP_CELLS = 1 << 14      # small frozen map -> fast census, full wraparound

# Full grid (local runs) vs --tiny (CI; the committed baseline).
GRID = dict(
    full=dict(sigmas=(0.0, 0.02, 0.05, 0.10), bers=(0.0, 1e-3, 5e-3),
              nbits=(256, 1024, 4096), iters=600, dot_nbit=1024),
    tiny=dict(sigmas=(0.0, 0.05), bers=(0.0, 2e-3),
              nbits=(256,), iters=200, dot_nbit=256),
)


def make_profile(sigma: float, ber: float) -> physics.DeviceProfile:
    """One envelope grid point: spread ``sigma`` lands on Delta (and half
    of it on I_c, matching the calibrated profile's ratio); ``ber``
    splits across the fault taxonomy (stuck-at symmetric, retention 5x
    rarer, matching the named profiles)."""
    return physics.DeviceProfile(
        sigma_delta=sigma, sigma_ic=0.5 * sigma,
        ber_stuck0=ber, ber_stuck1=ber, ber_retention=0.2 * ber,
        seed=SEED, map_cells=MAP_CELLS)


def mul_envelope(key, nbits, sigmas, iters: int) -> dict:
    """Fig7-style accuracy x fig8-style variance on the MUL engine:
    ``iters`` batched MULs per grid point, each on its own cell bank of
    the profile's frozen map."""
    out = {}
    p_true = float(np.exp(-(TAU_X + TAU_Y)))
    for i, nbit in enumerate(nbits):
        cfg = engine.EngineConfig(nbit=nbit)
        row = {}
        for j, s in enumerate(sigmas):
            prof = make_profile(s, 0.0)
            k = jax.random.fold_in(key, i * 97 + j)
            tau_x = jnp.full((iters,), TAU_X)
            p = engine.readout(engine.sc_multiply_states(
                k, tau_x, TAU_Y, cfg, profile=prof))
            err = np.asarray(p) - p_true
            cell = {"sigma_pct": round(float(err.std()) * 100, 3),
                    "bias_pct": round(float(err.mean()) * 100, 3)}
            emit(f"envelope.mul.nbit{nbit}.sigma{s}.sigma_pct",
                 cell["sigma_pct"],
                 "expect ~1/sqrt(nbit), ~flat in sigma (fig8)")
            emit(f"envelope.mul.nbit{nbit}.sigma{s}.bias_pct",
                 cell["bias_pct"], "variation-induced bias")
            row[f"sigma{s}"] = cell
        out[f"nbit{nbit}"] = row
    return out


def dot_envelope(key, sigmas, bers, nbit: int) -> dict:
    """Accuracy of a small matmul through the ``array`` backend under the
    full fault taxonomy, with the exact modeled error census per point."""
    m, kdim, n = 4, 16, 4
    kx, kw, kd = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (m, kdim), minval=-1.0, maxval=1.0)
    w = jax.random.uniform(kw, (kdim, n), minval=-1.0, maxval=1.0)
    y_ref = np.asarray(x @ w).ravel()
    cells = m * kdim * n * nbit
    out = {"workload": {"shape": [m, kdim, n], "nbit": nbit,
                        "cells": cells}}
    for i, s in enumerate(sigmas):
        for j, b in enumerate(bers):
            prof = make_profile(s, b)
            cfg = sc.ScConfig(backend="array", nbit=nbit, device=prof)
            y = np.asarray(sc.sc_dot(jax.random.fold_in(kd, i * 31 + j),
                                     x, w, cfg)).ravel()
            cos = float(np.dot(y, y_ref)
                        / max(np.linalg.norm(y) * np.linalg.norm(y_ref),
                              1e-12))
            census = accounting.bit_error_census(prof, cells)
            cell = {
                "cos_acc": round(cos, 3),
                "stuck0_errors_total": census["stuck0"],
                "stuck1_errors_total": census["stuck1"],
                "retention_errors_total": census["retention"],
            }
            emit(f"envelope.dot.sigma{s}.ber{b}.cos_acc", cell["cos_acc"],
                 "cosine vs exact product")
            emit(f"envelope.dot.sigma{s}.ber{b}.errors_total",
                 census["stuck0"] + census["stuck1"] + census["retention"],
                 "exact modeled fault census (bit_error_census)")
            out[f"sigma{s}_ber{b}"] = cell
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized grid (the committed baseline)")
    ap.add_argument("--json-out", default="BENCH_envelope.json",
                    metavar="PATH")
    args = ap.parse_args(argv)
    g = GRID["tiny" if args.tiny else "full"]
    key = jax.random.PRNGKey(SEED)

    section(f"MUL envelope: sigma x nbit ({'tiny' if args.tiny else 'full'}"
            f" grid, {g['iters']} MULs/point)")
    mul = mul_envelope(jax.random.fold_in(key, 0), g["nbits"], g["sigmas"],
                       g["iters"])

    section(f"Dot envelope: sigma x ber through the array backend "
            f"(nbit={g['dot_nbit']})")
    dot = dot_envelope(jax.random.fold_in(key, 1), g["sigmas"], g["bers"],
                       g["dot_nbit"])

    # Headline: how much the worst grid point degrades vs the ideal one.
    nb = f"nbit{g['nbits'][0]}"
    s_lo = mul[nb][f"sigma{g['sigmas'][0]}"]["sigma_pct"]
    s_hi = mul[nb][f"sigma{g['sigmas'][-1]}"]["sigma_pct"]
    worst_cos = min(v["cos_acc"] for kk, v in dot.items()
                    if kk != "workload")
    headline = {
        "sigma_inflation": round(s_hi / max(s_lo, 1e-9), 3),
        "worst_cos_acc": worst_cos,
    }
    section("Headline")
    emit("envelope.sigma_inflation", headline["sigma_inflation"],
         "sigma(worst spread)/sigma(ideal) at smallest nbit — paper: ~flat")
    emit("envelope.worst_cos_acc", headline["worst_cos_acc"],
         "accuracy floor across the swept envelope")

    write_json(args.json_out, {
        "tiny": bool(args.tiny),
        "headline": headline,
        "mul": mul,
        "dot": dot,
    })


if __name__ == "__main__":
    main()
