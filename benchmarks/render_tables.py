"""Render dry-run artifacts into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.render_tables dryrun_baseline.json
"""

from __future__ import annotations

import json
import sys


def render(path: str, mesh_filter: str | None = None) -> str:
    with open(path) as f:
        data = json.load(f)
    rows = []
    head = ("| arch | shape | mesh | bound | compute_s | memory_s | "
            "collective_s | useful | GB/dev |")
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for r in data["results"]:
        if "skipped" in r:
            if mesh_filter in (None, "16x16"):
                rows.append(f"| {r['arch']} | {r['shape']} | — | SKIP "
                            "(full attention, documented) | | | | | |")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rf = r["roofline"]
        mem = r.get("memory", {}).get("total_per_device_gb", float("nan"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rf['bound']} | "
            f"{rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | {rf['useful_fraction']:.2f} | "
            f"{mem:.2f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    mesh = sys.argv[2] if len(sys.argv) > 2 else None
    print(render(path, mesh))
