"""Fig. 7 reproduction: (a) MUL error distribution at nbit=1000 (expect
Gaussian, zero-centered, sigma ~ 1.6 %); (b) sigma vs nbit and vs tau_Y."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bar, emit, section
from repro.core import engine, physics

TAU_X, TAU_Y = 0.3, 0.4
ITERS = 1000


def _sigma(key, nbit: int, tau_y: float = TAU_Y, iters: int = ITERS,
           profile: physics.DeviceProfile | None = None):
    cfg = engine.EngineConfig(nbit=nbit)
    if profile is not None:
        # Batch the iterations so each one runs on its OWN cell bank of
        # the profile's frozen variation map (vmapped per-key MULs would
        # all read cells 0..nbit-1).
        tau_x = jnp.full((iters,), TAU_X)
        return engine.readout(engine.sc_multiply_states(
            key, tau_x, tau_y, cfg, profile=profile))
    keys = jax.random.split(key, iters)
    return jax.vmap(lambda k: engine.readout(
        engine.sc_multiply_states(k, TAU_X, tau_y, cfg)))(keys)


def main(key=None, profile=None):
    key = key if key is not None else jax.random.PRNGKey(42)
    profile = physics.resolve_profile(profile)

    section("Fig 7a: error distribution, nbit=1000, tau_X=0.3ns tau_Y=0.4ns")
    p = _sigma(key, 1000)
    p_true = float(np.exp(-(TAU_X + TAU_Y)))
    err = np.asarray(p) - p_true
    sigma = float(err.std())
    emit("fig7a.sigma_pct", round(sigma * 100, 3), "paper: ~1.6%")
    emit("fig7a.mean_bias_pct", round(float(err.mean()) * 100, 4),
         "paper: zero-centered")
    # ASCII histogram (the Gaussian shape check)
    hist, edges = np.histogram(err, bins=17, range=(-0.06, 0.06))
    for h, lo in zip(hist, edges[:-1]):
        bar(f"{lo * 100:+.1f}%", float(h), float(hist.max()))
    # Gaussian fit quality: compare to the binomial prediction
    pred = float(np.sqrt(p_true * (1 - p_true) / 1000))
    emit("fig7a.binomial_prediction_pct", round(pred * 100, 3),
         "sqrt(p(1-p)/n)")

    section("Fig 7b: sigma vs nbit (at tau_Y=0.4)")
    for i, nbit in enumerate((128, 256, 512, 1024, 2048, 4096)):
        s = float(np.asarray(_sigma(jax.random.fold_in(key, i), nbit,
                                    iters=600)).std())
        emit(f"fig7b.sigma_pct.nbit={nbit}", round(s * 100, 3),
             "expect ~1/sqrt(nbit)")

    section("Fig 7b: sigma vs tau_Y (nbit=1000) — expect ~flat")
    for j, tau_y in enumerate((0.1, 0.2, 0.3, 0.4, 0.6, 0.8)):
        s = float(np.asarray(_sigma(jax.random.fold_in(key, 100 + j), 1000,
                                    tau_y, iters=600)).std())
        emit(f"fig7b.sigma_pct.tau_y={tau_y}", round(s * 100, 3), "")

    if profile is not None:
        section("Fig 7a on a realized device (DeviceProfile)")
        pd = np.asarray(_sigma(jax.random.fold_in(key, 999), 1000,
                               profile=profile))
        errd = pd - p_true
        emit("fig7a.device_sigma_pct", round(float(errd.std()) * 100, 3),
             f"sigma_delta={profile.sigma_delta} sigma_ic={profile.sigma_ic}")
        emit("fig7a.device_mean_bias_pct",
             round(float(errd.mean()) * 100, 4), "variation-induced bias")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default=None,
                    help="named DeviceProfile (see core/physics.py)")
    main(profile=ap.parse_args().profile)
