"""Fig. 10 reproduction: energy per MUL with breakdown (expect 58 % saving
vs conventional SC; init step dominates the SC+PIM breakdown)."""

from __future__ import annotations

from benchmarks.common import bar, emit, section
from repro.core import costmodel as cm


def main():
    section("Fig 10: energy per 10-bit MUL (pJ)")
    e_apc, bd_apc = cm.energy_scpim(10, "apc")
    e_csa, bd_csa = cm.energy_scpim(10, "csa", 100)
    e_sc, bd_sc = cm.energy_sc(10)
    e_pim, bd_pim = cm.energy_pim(10)
    rows = {"SC+PIM (APC)": e_apc, "SC+PIM (CSA)": e_csa,
            "SC": e_sc, "PIM": e_pim}
    vmax = max(rows.values())
    for name, e in rows.items():
        bar(name, e, vmax, suffix=" pJ")
        emit(f"fig10.energy_pj.{name}", round(e, 3), "")
    emit("fig10.saving_vs_sc_pct",
         round((1 - e_apc / e_sc) * 100, 1), "paper: 58%")

    section("Fig 10: SC+PIM (APC) breakdown")
    for k, v in bd_apc.items():
        bar(k, v, max(bd_apc.values()), suffix=" pJ")
        emit(f"fig10.breakdown.scpim.{k}", round(v, 3),
             "init dominates (strong+long preset pulse)")

    section("Fig 10: conventional-SC breakdown")
    for k, v in bd_sc.items():
        bar(k, v, max(bd_sc.values()), suffix=" pJ")
        emit(f"fig10.breakdown.sc.{k}", round(v, 3),
             "buffering ~88% (paper)")


if __name__ == "__main__":
    main()
