"""Roofline table (§Roofline deliverable): reads the dry-run artifact
(dryrun_results.json at the repo root, produced by repro.launch.dryrun) and
prints the three-term roofline per (arch x shape x mesh) with the dominant
bottleneck and the MODEL_FLOPS/HLO_FLOPs useful fraction.

Run the dry-run first if the artifact is missing:
    PYTHONPATH=src python -m repro.launch.dryrun --out dryrun_results.json
"""

from __future__ import annotations

import json
import os

from benchmarks.common import emit, section

ARTIFACT_CANDIDATES = ("dryrun_results.json",
                       os.path.join(os.path.dirname(__file__), "..",
                                    "dryrun_results.json"))


def load():
    for path in ARTIFACT_CANDIDATES:
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
    return None


def main():
    data = load()
    section("Roofline per (arch x shape x mesh) — from the dry-run artifact")
    if data is None:
        emit("roofline.status", "SKIPPED",
             "run repro.launch.dryrun first (artifact not found)")
        return
    results = data["results"]
    live = [r for r in results if "roofline" in r]
    skips = [r for r in results if "skipped" in r]
    print(f"{'arch':<28}{'shape':<13}{'mesh':<9}{'bound':<11}"
          f"{'compute_s':>10}{'memory_s':>10}{'coll_s':>10}{'useful':>8}"
          f"{'GB/dev':>8}")
    for r in live:
        rf = r["roofline"]
        mem = r.get("memory", {}).get("total_per_device_gb", float("nan"))
        print(f"{r['arch']:<28}{r['shape']:<13}{r['mesh']:<9}"
              f"{rf['bound']:<11}{rf['compute_s']:>10.3f}"
              f"{rf['memory_s']:>10.3f}{rf['collective_s']:>10.3f}"
              f"{rf['useful_fraction']:>8.2f}{mem:>8.2f}")
    for r in skips:
        print(f"{r['arch']:<28}{r['shape']:<13}{'-':<9}SKIP: {r['skipped'][:40]}")
    emit("roofline.live_cells", len(live), "")
    emit("roofline.skipped_cells", len(skips),
         "full-attention archs x long_500k")
    emit("roofline.failures", len(data.get("failures", [])), "must be 0")

    bounds = {}
    for r in live:
        b = r["roofline"]["bound"]
        bounds[b] = bounds.get(b, 0) + 1
    for b, n in sorted(bounds.items()):
        emit(f"roofline.bound.{b}", n, "cells dominated by this term")


if __name__ == "__main__":
    main()
