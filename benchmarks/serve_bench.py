"""Serving benchmark: paged continuous batching vs the fixed-slot engine.

Replays one Poisson-arrival workload with mixed prompt/output lengths
through both engines and writes ``BENCH_serve.json`` (tokens/s, p50/p99
request latency, ticks, evictions).  The workload is built to look like
real traffic: inter-arrival times are exponential and every request draws
its own prompt length and output budget, so the fixed-slot engine pays
its structural costs — one prefill compilation per distinct prompt
length, batch=1 admission stalls, and full-length KV rows stranded by
short requests — while the paged engine serves everything through two
compiled shapes (chunk-width and width-1 steps) over a shared block pool.

The paged engine runs TWICE — reference (``paged_attn="unfused"``) and
fused Pallas attention (``"fused"``) — and both report per-decode-tick
wall times as ``decode_p50_ms`` / ``decode_p95_ms`` (ms per live token),
the metric the fused kernel targets; ``tools/bench_compare.py`` gates
them under its latency tolerance class.  On accelerators the fused run
must not be slower than the reference; host runs execute Pallas in
interpret mode (a correctness harness, not a fast path), so there the
assertion only backstops a catastrophic blowup and the honest measured
ratio is recorded in ``paged_fused.note``.

    PYTHONPATH=src python -m benchmarks.serve_bench            # full
    PYTHONPATH=src python -m benchmarks.serve_bench --tiny     # CI smoke

A second, fully deterministic shared-prefix workload (every request
behind one 24-token system prompt, all greedy, open loop) runs the paged
engine three more times — content-rng baseline, ``prefix_cache=True``,
and prefix cache + speculative decoding — and records ``cache_hit_rate``
and ``accepted_per_step``, the rate metrics ``tools/bench_compare.py``
gates under its rate-floor class.  The hit accounting is asserted as
arithmetic (late admissions adopt the whole prompt), and both features
must reproduce the baseline's tokens bit-for-bit.

The run asserts the paged engine's tokens/s beats fixed-slot on this
workload — the acceptance bar for the continuous-batching refactor —
and that greedy requests decode identical tokens on every engine.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, section, write_json
from repro.configs import get_smoke_config
from repro.models import lm, params as params_lib
from repro.serve import Request, ServeOptions, build_engine


def build_workload(n_requests: int, vocab: int, *, seed: int,
                   mean_interarrival_s: float, prompt_range, newtok_range):
    """One shared request schedule both engines replay."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_interarrival_s, n_requests))
    specs = []
    for rid in range(n_requests):
        plen = int(rng.integers(prompt_range[0], prompt_range[1] + 1))
        prompt = rng.integers(3, vocab, plen).tolist()
        max_new = int(rng.integers(newtok_range[0], newtok_range[1] + 1))
        temp = float(rng.choice([0.0, 0.7]))
        specs.append(dict(rid=rid, prompt=prompt, max_new_tokens=max_new,
                          temperature=temp))
    return arrivals.tolist(), specs


def drive(engine, specs, arrivals):
    """Feed requests at their arrival times; measure per-request latency.

    Token and request totals come from the engine's ``repro.obs`` metrics
    registry — the same counters operators scrape — so the bench numbers
    and the telemetry can never disagree."""
    reqs = [Request(**dict(s)) for s in specs]      # fresh per engine
    n = len(reqs)
    t0 = time.perf_counter()
    submitted = 0
    finish_at: dict = {}
    while len(finish_at) < n:
        now = time.perf_counter() - t0
        while submitted < n and arrivals[submitted] <= now:
            engine.submit(reqs[submitted])
            submitted += 1
        seen = len(engine.finished)
        progressed = engine.step()
        for r in engine.finished[seen:]:
            finish_at[r.rid] = time.perf_counter() - t0
        if not progressed and submitted < n:
            time.sleep(max(0.0,
                           arrivals[submitted] - (time.perf_counter() - t0)))
    makespan = time.perf_counter() - t0
    lat = np.asarray([finish_at[s["rid"]] - arrivals[i]
                      for i, s in enumerate(specs)])
    tokens = int(engine.metrics.value("serve_tokens_generated_total") or 0)
    return {
        "requests": int(
            engine.metrics.value("serve_requests_finished_total") or 0),
        "generated_tokens": tokens,
        "makespan_s": round(makespan, 3),
        "tokens_per_s": round(tokens / makespan, 2),
        "latency_p50_s": round(float(np.percentile(lat, 50)), 3),
        "latency_p99_s": round(float(np.percentile(lat, 99)), 3),
    }


# Paged-engine lifecycle counters that are DETERMINISTIC for a fixed
# workload: tokens are a function of each request alone (per-request
# rng), and with the default full-size pool there are no evictions, so
# admissions/blocks/prefill totals don't depend on arrival timing.
# These land under ``telemetry/counters`` in the artifact, where
# tools/bench_compare.py matches them exactly.  (The fixed-slot engine's
# token counts are per-tick-rng and timing-dependent — no exact section.)
_EXACT_COUNTERS = (
    "serve_requests_submitted_total", "serve_requests_admitted_total",
    "serve_requests_finished_total", "serve_tokens_generated_total",
    "serve_evictions_total", "serve_prefill_tokens_total",
    "serve_kv_blocks_allocated_total", "serve_kv_blocks_freed_total",
    "serve_prefix_cache_hit_tokens_total",
    "serve_prefix_cache_lookups_total", "serve_prefix_cache_cow_total",
    "serve_spec_drafted_tokens_total", "serve_spec_accepted_tokens_total",
)


def telemetry(engine):
    """Registry-backed subsection of one paged engine's stats: exact
    lifecycle counters plus drain-time gauges (runtime state, ignored by
    the regression gate unless ``--check-gauges``)."""
    counters = {n: int(engine.metrics.value(n) or 0)
                for n in _EXACT_COUNTERS}
    return {"counters": counters,
            "gauges": engine.metrics.snapshot()["gauges"]}


def _registry_ticks(engine):
    m = engine.metrics
    ticks = int((m.value("serve_ticks_total", kind="prefill") or 0)
                + (m.value("serve_ticks_total", kind="decode") or 0))
    return ticks, int(m.value("serve_evictions_total") or 0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized workload (small model, few requests)")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--sc-backend", default="exact",
                    help="substrate for both engines (exact isolates the "
                         "serving-layer comparison)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.tiny:
        n_requests = args.requests or 8
        max_len, prompt_range, newtok_range = 64, (4, 20), (3, 8)
        layers_, d_model, chunk = 2, 64, 6
    else:
        n_requests = args.requests or 24
        max_len, prompt_range, newtok_range = 128, (4, 40), (4, 24)
        layers_, d_model, chunk = 4, 128, 8

    cfg = get_smoke_config("qwen2-0.5b").replace(
        param_dtype=jnp.float32, act_dtype=jnp.float32,
        n_layers=layers_, d_model=d_model, n_heads=4, n_kv_heads=2,
        d_ff=4 * d_model, sc_backend=args.sc_backend)
    params = params_lib.init_params(
        jax.random.PRNGKey(args.seed), lm.lm_param_specs(cfg),
        cfg.param_dtype)
    arrivals, specs = build_workload(
        n_requests, cfg.vocab, seed=args.seed + 1,
        mean_interarrival_s=0.02,
        prompt_range=prompt_range, newtok_range=newtok_range)

    section(f"serve bench: {n_requests} Poisson requests, prompts "
            f"{prompt_range}, outputs {newtok_range}, slots={args.slots}, "
            f"sc={args.sc_backend}")

    base_opts = ServeOptions(slots=args.slots, max_len=max_len,
                             seed=args.seed)
    paged_opts = base_opts.replace(paged=True, block_size=8,
                                   prefill_chunk=chunk)

    fixed = build_engine(params, cfg, base_opts)
    fixed_stats = drive(fixed, specs, arrivals)
    fixed.close()
    emit("fixed_slot.tokens_per_s", fixed_stats["tokens_per_s"])

    paged = build_engine(params, cfg, paged_opts)
    paged_stats = drive(paged, specs, arrivals)
    paged_stats["ticks"], paged_stats["evictions"] = _registry_ticks(paged)
    paged_stats.update(paged.decode_latency_ms() or {})
    paged_stats["telemetry"] = telemetry(paged)
    paged.close()
    emit("paged.tokens_per_s", paged_stats["tokens_per_s"])

    fused = build_engine(params, cfg,
                         paged_opts.replace(fused_attention=True))
    fused_stats = drive(fused, specs, arrivals)
    fused_stats["ticks"], fused_stats["evictions"] = _registry_ticks(fused)
    fused_stats.update(fused.decode_latency_ms() or {})
    fused_stats["telemetry"] = telemetry(fused)
    fused.close()
    emit("paged_fused.decode_p50_ms", fused_stats.get("decode_p50_ms"))

    speedup = paged_stats["tokens_per_s"] / max(
        fixed_stats["tokens_per_s"], 1e-9)
    emit("paged_vs_fixed.speedup", round(speedup, 2))

    # Same schedule, same requests => greedy requests must decode the same
    # tokens on both engines (temperature>0 requests differ: the engines'
    # rng contracts differ by design — per-request vs per-tick).  The
    # fused-attention engine replays the paged run exactly: same math to
    # float tolerance must mean same greedy tokens.
    fixed_by_rid = {r.rid: r.generated for r in fixed.finished}
    paged_by_rid = {r.rid: r.generated for r in paged.finished}
    fused_by_rid = {r.rid: r.generated for r in fused.finished}
    for s in specs:
        if s["temperature"] == 0.0:
            assert fixed_by_rid[s["rid"]] == paged_by_rid[s["rid"]], (
                f"greedy request {s['rid']} diverged between engines")
            assert paged_by_rid[s["rid"]] == fused_by_rid[s["rid"]], (
                f"greedy request {s['rid']} diverged between unfused and "
                "fused paged attention")

    lat_ratio = (fused_stats.get("decode_p50_ms", 0.0)
                 / max(paged_stats.get("decode_p50_ms", 1e-9), 1e-9))
    fused_stats["note"] = (
        f"fused/unfused decode p50 ratio {lat_ratio:.2f}x on "
        f"{jax.default_backend()} "
        "(host runs execute Pallas in interpret mode)")

    # --- Shared-prefix workload: prefix caching + speculative decoding ---
    # Open loop (everyone submitted at t=0) and all-greedy, so admission
    # order, adopted blocks, and every generated token are DETERMINISTIC:
    # exactly the first `slots` requests prefill the shared system prompt,
    # every later admission adopts it whole, and the hit-rate assertion
    # below is arithmetic, not a tolerance.
    shared_len = 3 * 8                       # 3 full blocks at block_size=8
    n_pre = max(n_requests, args.slots * 2)
    rng = np.random.default_rng(args.seed + 2)
    sys_prompt = rng.integers(3, cfg.vocab, shared_len).tolist()
    pre_specs = [dict(rid=rid,
                      prompt=sys_prompt + rng.integers(
                          3, cfg.vocab, int(rng.integers(2, 7))).tolist(),
                      max_new_tokens=int(rng.integers(4, 9)),
                      temperature=0.0)
                 for rid in range(n_pre)]
    zeros = [0.0] * n_pre
    section(f"shared-prefix workload: {n_pre} greedy requests behind a "
            f"{shared_len}-token system prompt")

    def _prefix_engine(**kw):
        return build_engine(params, cfg, paged_opts.replace(**kw))

    base = _prefix_engine(rng_mode="content")
    base_stats = drive(base, pre_specs, zeros)
    base_by_rid = {r.rid: r.generated for r in base.finished}
    base.close()

    cached = _prefix_engine(prefix_cache=True)
    cached_stats = drive(cached, pre_specs, zeros)
    hit = int(cached.metrics.value("serve_prefix_cache_hit_tokens_total"))
    pre = int(cached.metrics.value("serve_prefill_tokens_total"))
    cached_stats["cache_hit_rate"] = round(hit / max(hit + pre, 1), 4)
    cached_stats["telemetry"] = telemetry(cached)
    assert hit == (n_pre - args.slots) * shared_len, (
        f"deterministic hit accounting broke: {hit} adopted tokens, "
        f"expected {(n_pre - args.slots) * shared_len}")
    assert cached_stats["cache_hit_rate"] > 0
    cached_by_rid = {r.rid: r.generated for r in cached.finished}
    assert cached_by_rid == base_by_rid, (
        "prefix caching changed generated tokens")
    cached.close()
    emit("paged_prefix.cache_hit_rate", cached_stats["cache_hit_rate"])
    emit("paged_prefix.tokens_per_s", cached_stats["tokens_per_s"])

    spec = _prefix_engine(prefix_cache=True, speculative=True, spec_k=4)
    spec_stats = drive(spec, pre_specs, zeros)
    s_hit = int(spec.metrics.value("serve_prefix_cache_hit_tokens_total"))
    s_pre = int(spec.metrics.value("serve_prefill_tokens_total"))
    spec_stats["cache_hit_rate"] = round(s_hit / max(s_hit + s_pre, 1), 4)
    steps = spec.metrics.histogram("spec_accepted_tokens").count()
    acc = int(spec.metrics.value("serve_spec_accepted_tokens_total") or 0)
    drafted = int(spec.metrics.value("serve_spec_drafted_tokens_total") or 0)
    spec_stats["accepted_per_step"] = round(acc / max(steps, 1), 4)
    spec_stats["acceptance_rate"] = round(acc / max(drafted, 1), 4)
    spec_stats["telemetry"] = telemetry(spec)
    assert steps > 0 and acc > 0, "greedy traffic must take spec ticks"
    spec_by_rid = {r.rid: r.generated for r in spec.finished}
    assert spec_by_rid == base_by_rid, (
        "speculative decoding changed generated tokens")
    spec.close()
    emit("paged_spec.accepted_per_step", spec_stats["accepted_per_step"])
    emit("paged_spec.tokens_per_s", spec_stats["tokens_per_s"])

    prefix_speedup = cached_stats["tokens_per_s"] / max(
        base_stats["tokens_per_s"], 1e-9)
    emit("prefix_vs_paged.speedup", round(prefix_speedup, 2))

    payload = {
        "tiny": bool(args.tiny),
        "workload": {
            "requests": n_requests, "slots": args.slots,
            "max_len": max_len, "prompt_range": list(prompt_range),
            "new_token_range": list(newtok_range),
            "mean_interarrival_s": 0.02, "sc_backend": args.sc_backend,
            "distinct_prompt_lengths": len(
                {len(s["prompt"]) for s in specs}),
        },
        "fixed_slot": fixed_stats,
        "paged": paged_stats,
        "paged_fused": fused_stats,
        "paged_prefix_base": base_stats,
        "paged_prefix": cached_stats,
        "paged_spec": spec_stats,
        "speedup_tokens_per_s": round(speedup, 3),
        "prefix_speedup_tokens_per_s": round(prefix_speedup, 3),
    }
    write_json("BENCH_serve.json", payload)

    # Decode-latency bar for the fused kernel.  On an accelerator the
    # compiled kernel must not lose to the unfused path; in interpret
    # mode (any host run, tiny or full) the kernel is a Python-level
    # correctness harness, so only a catastrophic blowup fails here and
    # the measured ratio ships in the note above for honest reading.
    lat_tol = 1.05 if jax.default_backend() == "tpu" else 50.0
    assert lat_ratio <= lat_tol, (
        f"fused decode p50 is {lat_ratio:.2f}x the unfused path "
        f"(tolerance {lat_tol}x on {jax.default_backend()})")

    # Full-size runs gate hard on the acceptance bar (paged must win).
    # --tiny is the CI smoke pass on shared wall-clock-noisy runners, so
    # it only backstops against catastrophic regression; the committed
    # full-size BENCH_serve.json is the performance evidence.
    floor = 0.8 if args.tiny else 1.0
    assert speedup > floor, (
        f"paged engine must beat fixed-slot on tokens/s under mixed-length "
        f"Poisson traffic (floor {floor}x for "
        f"{'tiny smoke' if args.tiny else 'full'} runs), got {speedup:.2f}x")
    print(f"paged continuous batching: {speedup:.2f}x fixed-slot tokens/s "
          f"({paged_stats['tokens_per_s']} vs "
          f"{fixed_stats['tokens_per_s']} tok/s; paged p99 "
          f"{paged_stats['latency_p99_s']}s vs fixed "
          f"{fixed_stats['latency_p99_s']}s)")

    # Prefix caching skips (n - slots) * shared_len prefill tokens on this
    # workload, so it must not LOSE throughput to the plain paged engine;
    # tiny runs only backstop wall-clock noise on shared runners.
    assert prefix_speedup > floor, (
        f"prefix caching must not regress paged tokens/s on a shared-"
        f"prefix workload (floor {floor}x), got {prefix_speedup:.2f}x")
    print(f"prefix cache: hit rate {cached_stats['cache_hit_rate']}, "
          f"{prefix_speedup:.2f}x paged tokens/s; speculative "
          f"accepted/step {spec_stats['accepted_per_step']} "
          f"(acceptance {spec_stats['acceptance_rate']})")
    return payload


if __name__ == "__main__":
    main()
