"""Shared benchmark utilities: timing, CSV emit, JSON artifacts, ASCII plots."""

from __future__ import annotations

import json
import time

import jax


def timed(fn, *args, iters: int = 5, warmup: int = 2, **kw):
    """Median wall-clock microseconds per call (CPU-indicative only)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, value, derived: str = ""):
    """One CSV row: name,value,derived."""
    print(f"{name},{value},{derived}")


def bar(label: str, value: float, vmax: float, width: int = 40,
        suffix: str = ""):
    n = int(width * value / max(vmax, 1e-30))
    print(f"  {label:<22s} {'#' * n}{' ' * (width - n)} {value:10.3f}{suffix}")


def section(title: str):
    print(f"\n=== {title} " + "=" * max(8, 68 - len(title)))


def write_json(path: str, payload: dict):
    """Machine-readable benchmark artifact (BENCH_*.json at the CWD; CI
    uploads these so the perf trajectory is diffable across commits)."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[wrote {path}]")
