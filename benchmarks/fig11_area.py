"""Fig. 11 reproduction: area overhead with breakdown (expect ~10x saving
vs conventional SC; SNG is 95 % of SC area; LUT shrinks at 8-bit)."""

from __future__ import annotations

from benchmarks.common import bar, emit, section
from repro.core import costmodel as cm


def main():
    section("Fig 11: area overhead (um^2)")
    a_apc, bd_apc = cm.area_scpim(10, "apc")
    a_csa, bd_csa = cm.area_scpim(10, "csa")
    a_sc, bd_sc = cm.area_sc(10)
    a_pim, bd_pim = cm.area_pim(10)
    rows = {"SC+PIM (APC)": a_apc, "SC+PIM (CSA)": a_csa,
            "SC": a_sc, "PIM": a_pim}
    vmax = max(rows.values())
    for name, a in rows.items():
        bar(name, a, vmax, suffix=" um2")
        emit(f"fig11.area_um2.{name}", round(a, 1), "")
    emit("fig11.sc_over_scpim", round(a_sc / a_apc, 2),
         "paper: ~one order of magnitude")

    section("Fig 11: breakdowns")
    for k, v in bd_apc.items():
        emit(f"fig11.breakdown.scpim.{k}", round(v, 1),
             "LUT comparable to DTC+APC at 10-bit")
    for k, v in bd_sc.items():
        emit(f"fig11.breakdown.sc.{k}", round(v, 1), "SNG = 95%")

    # LUT scaling with operand width (the 8-bit remark in §V-D)
    for bits in (8, 10, 12):
        _, bd = cm.area_scpim(bits, "apc")
        emit(f"fig11.lut_um2.bits={bits}", round(bd["lut"], 1),
             "exponential in bit length")


if __name__ == "__main__":
    main()
