"""Fig. 9 reproduction: (a) cycles per MUL across designs (expect ~4x vs SC,
~18x vs PIM at 10-bit); (b) cycles vs operand bit length."""

from __future__ import annotations

from benchmarks.common import bar, emit, section
from repro.core import costmodel as cm


def main():
    section("Fig 9a: cycle count per 10-bit MUL")
    rows = {
        "SC+PIM (APC)": cm.cycles_scpim_apc(10),
        "SC+PIM (CSA)": cm.cycles_scpim_csa(10, 100),
        "SC": cm.cycles_sc(10),
        "PIM": cm.cycles_pim(10),
    }
    vmax = max(rows.values())
    for name, c in rows.items():
        bar(name, c, vmax, suffix=" cycles")
        emit(f"fig9a.cycles.{name}", round(c, 2), "")
    r = cm.headline_ratios(10)
    emit("fig9a.speedup_vs_sc", round(r["speedup_vs_sc"], 2), "paper: ~4x")
    emit("fig9a.speedup_vs_pim", round(r["speedup_vs_pim"], 2), "paper: 18x")

    section("Fig 9b: MUL cycles vs operand bit length")
    for bits in (4, 6, 8, 10, 12, 14, 16):
        ours = cm.cycles_scpim_apc(bits)
        pim = cm.cycles_pim(bits)
        emit(f"fig9b.scpim.bits={bits}", round(ours, 1),
             "flat-ish (parallel stochastic bits)")
        emit(f"fig9b.pim.bits={bits}", pim, "grows super-linearly")


if __name__ == "__main__":
    main()
