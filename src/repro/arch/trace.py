"""Trace collection: record what the ``array`` backend compiled, per call.

The backend's tiler/scheduler/accountant run in ordinary Python while JAX
traces the surrounding computation — the schedule depends only on operand
SHAPES, never values — so recording happens at *trace time*: under ``jit``
each compiled shape contributes exactly ONE record however many times the
executable later runs (a ``jax.lax.scan`` over layers likewise records its
body once). Callers that replay a record R times scale with
``scaled(record, R)``.

Two ways to listen:

    with arch.collect() as records:          # scoped (benchmarks, tests)
        y = sc.sc_dot(key, x, w, cfg)

    collector = arch.TraceCollector()        # long-lived (serve engine)
    collector.install()
    ...                                      # jit compilations record here
    collector.uninstall()

Multiple listeners may be active; every record goes to all of them.
"""

from __future__ import annotations

import contextlib
import dataclasses

from repro.arch import accounting
from repro.arch.schedule import Command
from repro.arch.spec import ArraySpec
from repro.arch.tiler import TilePlan, plan_summary


@dataclasses.dataclass(frozen=True)
class CallRecord:
    """One compiled ``sc_dot`` call on the array: plan + trace + price.

    ``shards`` is the mesh-shard multiplicity the call was traced under
    (``repro.sc.shard_scope``): ``shard_map`` traces its body once for
    every shard, so ``plan``/``trace``/``report`` describe ONE shard's
    slice and ``shards`` says how many such slices run concurrently on
    disjoint mesh devices.  ``effective_report`` merges them as
    concurrent banks (makespan = slowest shard; energy/products add).
    """

    plan: TilePlan
    trace: tuple[Command, ...]
    report: accounting.TraceReport
    shards: int = 1

    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.plan.m, self.plan.k, self.plan.n)

    @property
    def effective_report(self) -> accounting.TraceReport:
        if self.shards == 1:
            return self.report
        return accounting.merge_concurrent_reports(
            [self.report] * self.shards)

    def as_dict(self) -> dict:
        return {"plan": plan_summary(self.plan),
                "shards": self.shards,
                "report": accounting.report_dict(self.report)}


class TraceCollector:
    """Accumulates CallRecords from every array-backend dispatch in scope.

    Serving engines additionally stamp per-request token counts
    (:meth:`note_request`) so :meth:`cost_per_request` can prorate the
    aggregate trace cost across a mixed-traffic batch — the records
    themselves are per compiled SHAPE (jit caching), so tokens are the
    only per-request signal available at this layer.
    """

    def __init__(self):
        self.records: list[CallRecord] = []
        self.request_tokens: dict = {}      # request id -> context tokens

    def note_request(self, rid, tokens: int) -> None:
        """Stamp a finished request's total token count (prompt +
        generated).  Re-stamping the same id overwrites."""
        self.request_tokens[rid] = int(tokens)

    def cost_per_request(self) -> dict:
        """Prorate the aggregate trace cost over the stamped requests.

        Returns ``{rid: {"tokens", "share", "cycles", "energy_pj"}}`` —
        each request charged the aggregate cycles/energy in proportion to
        its token count.  Proportional attribution is the honest choice
        here: records are per compiled shape, not per executed tick, so
        token counts are the per-request quantity the engine actually
        knows."""
        total = sum(self.request_tokens.values())
        if not total:
            return {}
        agg = self.aggregate()
        out = {}
        for rid, tokens in sorted(self.request_tokens.items()):
            share = tokens / total
            out[rid] = {
                "tokens": tokens,
                "share": round(share, 6),
                "cycles": round(agg.cycles * share, 1),
                "energy_pj": round(agg.energy_pj * share, 3),
            }
        return out

    def install(self) -> "TraceCollector":
        if self not in _LISTENERS:
            _LISTENERS.append(self)
        return self

    def uninstall(self) -> None:
        if self in _LISTENERS:
            _LISTENERS.remove(self)

    def clear(self) -> None:
        self.records.clear()
        self.request_tokens.clear()

    def aggregate(self) -> accounting.TraceReport:
        """Serial merge over recorded calls, each first merged across its
        concurrent mesh shards (so a sharded matmul's makespan is its
        slowest shard, not the sum of all shards)."""
        return accounting.merge_reports(
            r.effective_report for r in self.records)


_LISTENERS: list[TraceCollector] = []


def record(rec: CallRecord) -> None:
    for listener in _LISTENERS:
        listener.records.append(rec)


def active() -> bool:
    """True when at least one collector is listening (lets the backend skip
    schedule compilation entirely on hot paths nobody is watching)."""
    return bool(_LISTENERS)


@contextlib.contextmanager
def collect():
    """Scoped collection: yields the live list of CallRecords."""
    c = TraceCollector().install()
    try:
        yield c.records
    finally:
        c.uninstall()


def scaled(report: accounting.TraceReport,
           repeats: int) -> accounting.TraceReport:
    """Price a record replayed ``repeats`` times (e.g. a scanned layer body
    compiled once but executed n_layers times)."""
    if repeats < 0:
        raise ValueError(f"repeats must be >= 0, got {repeats}")
    return accounting.merge_reports([report] * repeats)


def summarize(records, spec: ArraySpec | None = None) -> dict:
    """JSON-ready roll-up of a record list (benchmarks / serve dumps)."""
    records = list(records)
    agg = accounting.merge_reports(r.effective_report for r in records)
    out = {"calls": len(records),
           "aggregate": accounting.report_dict(agg)}
    if spec is not None:
        out["spec"] = dataclasses.asdict(spec)
    return out
