"""The ``array`` SC backend: run sc_dot "on the hardware".

Registered in the :mod:`repro.sc` registry (lazily — importing this module
is what registers it), so ``ScConfig(backend="array")`` turns every
``dense()`` in the model stack and every serve-engine prefill/decode matmul
into an array-level execution: the call is tiled onto the active
:class:`~repro.arch.spec.ArraySpec`, compiled to a pulse schedule, priced
by the accountant, and recorded to any active trace collector — all at
JAX trace time (the schedule depends only on shapes).

Numerics reuse the registered bit-exact engines per size class, so the
returned values ARE the stochastic estimates the cell array would produce:

* tiny calls (≤ ``_PALLAS_CELL_CAP`` cells, nbit % 32 == 0) run the packed
  Pallas engine — real two-pulse AND + SWAR pop-count per cell word;
* validation-scale calls (≤ ``_BITEXACT_PRODUCT_CAP`` products) run the
  binomial ``bitexact`` backend — one Binomial(nbit, P_x·P_y) pop-count
  per product, the paper's Monte-Carlo;
* larger calls fall back to the CLT ``moment`` backend, whose first two
  moments equal the bitexact ensemble's — the only tractable stand-in at
  model scale (the trace still prices the full array execution).

The active ArraySpec / CostParams are ambient (``use_spec`` /
``use_params``) rather than ScConfig fields, so model code selecting the
backend by name needs no plumbing changes to re-target hardware geometry.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.arch import accounting, trace
from repro.arch.schedule import compile_schedule
from repro.arch.spec import ArraySpec, DEFAULT_SPEC
from repro.arch.tiler import tile_matmul
from repro.core import physics
from repro.core.costmodel import CostParams, DEFAULT_PARAMS
from repro.sc import backends as sc_backends
from repro.sc import encoding
from repro.sc.config import ScConfig
from repro.sc.registry import register_backend

# Numerics size classes (cells = products × nbit).
_PALLAS_CELL_CAP = 1 << 16          # packed Pallas engine (O(cells/8) bytes)
_BITEXACT_PRODUCT_CAP = 1 << 21     # jnp binomial engine (O(products) floats)

# Device-realism size classes (non-ideal cfg.device only): calls up to this
# many cells run the REALIZED per-cell maps (each virtual cell reads its
# own frozen rate/fault entry); larger calls model the cell population
# statistically through the map's rate quantiles.
_DEVICE_CELL_CAP = 1 << 20
_RATE_QUANTILES = 16

_SPEC_STACK: list[ArraySpec] = [DEFAULT_SPEC]
_PARAMS_STACK: list[CostParams] = [DEFAULT_PARAMS]


def current_spec() -> ArraySpec:
    return _SPEC_STACK[-1]


def current_params() -> CostParams:
    return _PARAMS_STACK[-1]


@contextlib.contextmanager
def use_spec(spec: ArraySpec):
    """Scope the array geometry the ``array`` backend schedules onto."""
    _SPEC_STACK.append(spec)
    try:
        yield spec
    finally:
        _SPEC_STACK.pop()


@contextlib.contextmanager
def use_params(params: CostParams):
    """Scope the cost knobs the accountant prices traces with."""
    _PARAMS_STACK.append(params)
    try:
        yield params
    finally:
        _PARAMS_STACK.pop()


def schedule_call(m: int, k: int, n: int, nbit: int,
                  spec: ArraySpec | None = None,
                  params: CostParams | None = None) -> trace.CallRecord:
    """Tile + compile + price one (m, k) @ (k, n) call — the pure-Python
    core the backend runs at trace time, also usable standalone (static
    workload analyses, benchmarks)."""
    spec = spec if spec is not None else current_spec()
    params = params if params is not None else current_params()
    plan = tile_matmul(m, k, n, nbit, spec)
    cmds = compile_schedule(plan, params)
    report = accounting.account(cmds, spec, params)
    return trace.CallRecord(plan=plan, trace=cmds, report=report)


def _numerics(key, x, w, cfg: ScConfig):
    if cfg.device is not None and not cfg.device.is_ideal:
        return _device_numerics(key, x, w, cfg)
    products = x.shape[0] * x.shape[1] * w.shape[1]
    cells = products * cfg.nbit
    if cfg.nbit % 32 == 0 and cells <= _PALLAS_CELL_CAP:
        return sc_backends.pallas_bitexact(key, x, w, cfg)
    if products <= _BITEXACT_PRODUCT_CAP:
        return sc_backends.bitexact(key, x, w, cfg)
    return sc_backends.moment(key, x, w, cfg)


@functools.lru_cache(maxsize=8)
def _rate_quantiles(profile: physics.DeviceProfile) -> np.ndarray:
    """Fixed 16-point quantile summary of the profile's realized
    survival-rate map — the population statistics the large-call device
    path models cells with."""
    maps = physics.cell_maps(profile)
    qs = (np.arange(_RATE_QUANTILES) + 0.5) / _RATE_QUANTILES
    return np.quantile(maps.rate.astype(np.float64), qs).astype(np.float32)


def _device_numerics(key, x, w, cfg: ScConfig):
    """Stochastic estimate under a NON-ideal device profile.

    A cell whose realized rate exponent is ``r`` survives a pulse
    programmed for probability ``p`` with probability ``p**r``
    (P' = exp(-tau*r) = P**r — core/physics.py).  Small calls
    (≤ ``_DEVICE_CELL_CAP`` cells) read their literal wrapped span of the
    frozen per-cell maps: Bernoulli(p**r_c) per cell, retention flips,
    then stuck-at overrides, then pop-count — the realized array.  Larger
    calls collapse the cell population to its rate quantiles and draw the
    CLT count with the same closed-form stuck/retention densities, so the
    bias and variance match the realized path's ensemble.
    """
    prof = cfg.device
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    p_prod = jnp.clip(px[:, :, None] * pw[None, :, :], 0.0, 1.0)  # (M, K, N)
    sign = sx[:, :, None] * sw[None, :, :]
    m, k = x.shape
    n = w.shape[1]
    cells = m * k * n * cfg.nbit
    f = prof.ber_retention
    if cells <= _DEVICE_CELL_CAP:
        maps = physics.cell_maps(prof)
        idx = physics.cell_span(prof, cells).reshape(m, k, n, cfg.nbit)
        rate = jnp.asarray(maps.rate[idx])
        pc = p_prod[..., None] ** rate
        key_b, key_f = jax.random.split(key)
        bits = jax.random.uniform(key_b, pc.shape) < pc
        if f > 0.0:
            bits ^= jax.random.uniform(key_f, pc.shape) < f
        if prof.ber_stuck0 > 0.0:
            bits &= ~jnp.asarray(maps.stuck0[idx])
        if prof.ber_stuck1 > 0.0:
            bits |= jnp.asarray(maps.stuck1[idx])
        est = jnp.mean(bits.astype(jnp.float32), axis=-1)
    else:
        maps = physics.cell_maps(prof)
        rq = jnp.asarray(_rate_quantiles(prof))
        pv = jnp.mean(p_prod[..., None] ** rq, axis=-1)
        s0 = float(maps.cum0[-1]) / prof.map_cells
        s1 = float(maps.cum1[-1]) / prof.map_cells
        p_read = (1.0 - s0 - s1) * (pv * (1.0 - f) + (1.0 - pv) * f) + s1
        noise = jax.random.normal(key, p_read.shape, dtype=jnp.float32)
        var = p_read * (1.0 - p_read) / cfg.nbit
        est = p_read + noise * jnp.sqrt(var)
    return jnp.sum(sign * est, axis=1) * (scx * scw)


def _note_bit_errors(profile: physics.DeviceProfile, cells: int,
                     shards: int) -> None:
    """Export one priced call's fault census (``accounting.py``) to the
    global registry as ``arch_bit_errors_total{kind,shard}``.  Trace-time
    and census-exact, so CI can gate the series bit-for-bit."""
    reg = obs.default_registry()
    if not reg.enabled:
        return
    census = accounting.bit_error_census(profile, cells)
    c = reg.counter(
        "arch_bit_errors_total",
        "modeled bit errors injected at the array backend, by fault kind")
    for kind in ("stuck0", "stuck1", "retention"):
        c.inc(census[kind] * shards, kind=kind, shard=str(shards))


def _note_pricing(rec: trace.CallRecord) -> None:
    """Fold one priced call into the observability hooks: cycle/energy
    counters in the global registry (disabled by default) and the
    effective report's headline numbers onto the innermost open trace
    span — the ``sc.dispatch`` span of the call being priced, when a
    tracer is installed."""
    rep = rec.effective_report
    reg = obs.default_registry()
    if reg.enabled:
        reg.counter(
            "arch_sc_dot_calls_total",
            "array-backend calls priced at trace time").inc()
        reg.counter(
            "arch_cycles_total",
            "modeled array cycles across priced calls").inc(rep.cycles)
        reg.counter(
            "arch_energy_pj_total",
            "modeled array energy (pJ) across priced calls").inc(
                rep.energy_pj)
    tr = obs.current_tracer()
    if tr is not None and tr.enabled:
        tr.attr(arch_cycles=rep.cycles,
                arch_energy_pj=round(rep.energy_pj, 3),
                arch_shards=rec.shards)


@register_backend("array")
def array(key, x, w, cfg: ScConfig):
    """Array-level execution: schedule + account (trace time), then the
    size-matched bit-exact numerics."""
    if trace.active():
        from repro.sc import sharded as sc_sharded
        rec = schedule_call(x.shape[0], x.shape[1], w.shape[1], cfg.nbit)
        shards = sc_sharded.current_shard_count()
        if shards != 1:
            # Inside a sharded dispatch the shard_map body traces ONCE for
            # all shards; x/w here are already one shard's slice, so the
            # record carries the concurrency multiplicity instead of being
            # re-recorded per shard.
            rec = trace.CallRecord(plan=rec.plan, trace=rec.trace,
                                   report=rec.report, shards=shards)
        trace.record(rec)
        _note_pricing(rec)
    else:
        # Still validate the mapping (a call that cannot be scheduled on the
        # active spec should fail loudly even when nobody is tracing).
        tile_matmul(x.shape[0], x.shape[1], w.shape[1], cfg.nbit,
                    current_spec())
    if cfg.device is not None and not cfg.device.is_ideal:
        # Device-realism telemetry is independent of arch trace
        # collection: any traced-or-not call on a faulty device exports
        # its census when the global registry is enabled.
        from repro.sc import sharded as sc_sharded
        _note_bit_errors(cfg.device,
                         x.shape[0] * x.shape[1] * w.shape[1] * cfg.nbit,
                         sc_sharded.current_shard_count())
    return _numerics(key, x, w, cfg)
