"""The ``array`` SC backend: run sc_dot "on the hardware".

Registered in the :mod:`repro.sc` registry (lazily — importing this module
is what registers it), so ``ScConfig(backend="array")`` turns every
``dense()`` in the model stack and every serve-engine prefill/decode matmul
into an array-level execution: the call is tiled onto the active
:class:`~repro.arch.spec.ArraySpec`, compiled to a pulse schedule, priced
by the accountant, and recorded to any active trace collector — all at
JAX trace time (the schedule depends only on shapes).

Numerics reuse the registered bit-exact engines per size class, so the
returned values ARE the stochastic estimates the cell array would produce:

* tiny calls (≤ ``_PALLAS_CELL_CAP`` cells, nbit % 32 == 0) run the packed
  Pallas engine — real two-pulse AND + SWAR pop-count per cell word;
* validation-scale calls (≤ ``_BITEXACT_PRODUCT_CAP`` products) run the
  binomial ``bitexact`` backend — one Binomial(nbit, P_x·P_y) pop-count
  per product, the paper's Monte-Carlo;
* larger calls fall back to the CLT ``moment`` backend, whose first two
  moments equal the bitexact ensemble's — the only tractable stand-in at
  model scale (the trace still prices the full array execution).

The active ArraySpec / CostParams are ambient (``use_spec`` /
``use_params``) rather than ScConfig fields, so model code selecting the
backend by name needs no plumbing changes to re-target hardware geometry.
"""

from __future__ import annotations

import contextlib

from repro import obs
from repro.arch import accounting, trace
from repro.arch.schedule import compile_schedule
from repro.arch.spec import ArraySpec, DEFAULT_SPEC
from repro.arch.tiler import tile_matmul
from repro.core.costmodel import CostParams, DEFAULT_PARAMS
from repro.sc import backends as sc_backends
from repro.sc.config import ScConfig
from repro.sc.registry import register_backend

# Numerics size classes (cells = products × nbit).
_PALLAS_CELL_CAP = 1 << 16          # packed Pallas engine (O(cells/8) bytes)
_BITEXACT_PRODUCT_CAP = 1 << 21     # jnp binomial engine (O(products) floats)

_SPEC_STACK: list[ArraySpec] = [DEFAULT_SPEC]
_PARAMS_STACK: list[CostParams] = [DEFAULT_PARAMS]


def current_spec() -> ArraySpec:
    return _SPEC_STACK[-1]


def current_params() -> CostParams:
    return _PARAMS_STACK[-1]


@contextlib.contextmanager
def use_spec(spec: ArraySpec):
    """Scope the array geometry the ``array`` backend schedules onto."""
    _SPEC_STACK.append(spec)
    try:
        yield spec
    finally:
        _SPEC_STACK.pop()


@contextlib.contextmanager
def use_params(params: CostParams):
    """Scope the cost knobs the accountant prices traces with."""
    _PARAMS_STACK.append(params)
    try:
        yield params
    finally:
        _PARAMS_STACK.pop()


def schedule_call(m: int, k: int, n: int, nbit: int,
                  spec: ArraySpec | None = None,
                  params: CostParams | None = None) -> trace.CallRecord:
    """Tile + compile + price one (m, k) @ (k, n) call — the pure-Python
    core the backend runs at trace time, also usable standalone (static
    workload analyses, benchmarks)."""
    spec = spec if spec is not None else current_spec()
    params = params if params is not None else current_params()
    plan = tile_matmul(m, k, n, nbit, spec)
    cmds = compile_schedule(plan, params)
    report = accounting.account(cmds, spec, params)
    return trace.CallRecord(plan=plan, trace=cmds, report=report)


def _numerics(key, x, w, cfg: ScConfig):
    products = x.shape[0] * x.shape[1] * w.shape[1]
    cells = products * cfg.nbit
    if cfg.nbit % 32 == 0 and cells <= _PALLAS_CELL_CAP:
        return sc_backends.pallas_bitexact(key, x, w, cfg)
    if products <= _BITEXACT_PRODUCT_CAP:
        return sc_backends.bitexact(key, x, w, cfg)
    return sc_backends.moment(key, x, w, cfg)


def _note_pricing(rec: trace.CallRecord) -> None:
    """Fold one priced call into the observability hooks: cycle/energy
    counters in the global registry (disabled by default) and the
    effective report's headline numbers onto the innermost open trace
    span — the ``sc.dispatch`` span of the call being priced, when a
    tracer is installed."""
    rep = rec.effective_report
    reg = obs.default_registry()
    if reg.enabled:
        reg.counter(
            "arch_sc_dot_calls_total",
            "array-backend calls priced at trace time").inc()
        reg.counter(
            "arch_cycles_total",
            "modeled array cycles across priced calls").inc(rep.cycles)
        reg.counter(
            "arch_energy_pj_total",
            "modeled array energy (pJ) across priced calls").inc(
                rep.energy_pj)
    tr = obs.current_tracer()
    if tr is not None and tr.enabled:
        tr.attr(arch_cycles=rep.cycles,
                arch_energy_pj=round(rep.energy_pj, 3),
                arch_shards=rec.shards)


@register_backend("array")
def array(key, x, w, cfg: ScConfig):
    """Array-level execution: schedule + account (trace time), then the
    size-matched bit-exact numerics."""
    if trace.active():
        from repro.sc import sharded as sc_sharded
        rec = schedule_call(x.shape[0], x.shape[1], w.shape[1], cfg.nbit)
        shards = sc_sharded.current_shard_count()
        if shards != 1:
            # Inside a sharded dispatch the shard_map body traces ONCE for
            # all shards; x/w here are already one shard's slice, so the
            # record carries the concurrency multiplicity instead of being
            # re-recorded per shard.
            rec = trace.CallRecord(plan=rec.plan, trace=rec.trace,
                                   report=rec.report, shards=shards)
        trace.record(rec)
        _note_pricing(rec)
    else:
        # Still validate the mapping (a call that cannot be scheduled on the
        # active spec should fail loudly even when nobody is tracing).
        tile_matmul(x.shape[0], x.shape[1], w.shape[1], cfg.nbit,
                    current_spec())
    return _numerics(key, x, w, cfg)
