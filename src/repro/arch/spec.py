"""The physical array hierarchy: chip → bank → subarray → 256-cell rows.

The paper's engine is not one MUL cell but an *architecture* (§III-D, §V):
cross-point rows capped at 256 cells by IR drop, grouped into subarrays
that share a row decoder and a bank of sense amplifiers + one APC, grouped
into banks that operate fully in parallel and merge their pop-counts
through a log-depth adder tree. ``ArraySpec`` is the frozen description of
that hierarchy; the tiler (:mod:`repro.arch.tiler`) maps ``sc_dot`` calls
onto it and the scheduler (:mod:`repro.arch.schedule`) serializes whatever
doesn't fit.

The same row-parallelism rules as the closed-form model
(:mod:`repro.core.costmodel`) apply: every row of a subarray can be preset
/ pulsed / sensed in ONE command (multi-row activation), different
subarrays never conflict, and a single product's rows always land in one
subarray so its merge tree stays local.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Chip geometry. Frozen + hashable (usable as a jit static / dict key).

    Defaults give a modest 8-bank chip: 8 × 16 subarrays × 64 rows × 256
    cells = 2 M cells — 2048 concurrent 10-bit MULs per wave.
    """

    banks: int = 8
    subarrays_per_bank: int = 16
    rows_per_subarray: int = 64
    row_length: int = 256            # IR-drop row limit (§III-D)

    def __post_init__(self):
        for field in ("banks", "subarrays_per_bank", "rows_per_subarray",
                      "row_length"):
            v = getattr(self, field)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(f"ArraySpec.{field} must be a positive int, "
                                 f"got {v!r}")

    # ------------------------------ totals ---------------------------------
    @property
    def subarrays(self) -> int:
        return self.banks * self.subarrays_per_bank

    @property
    def rows(self) -> int:
        return self.subarrays * self.rows_per_subarray

    @property
    def cells(self) -> int:
        return self.rows * self.row_length

    @property
    def cells_per_subarray(self) -> int:
        return self.rows_per_subarray * self.row_length

    # --------------------------- per-MUL mapping ----------------------------
    def rows_per_product(self, nbit: int) -> int:
        """Rows one nbit-cell MUL occupies (its private cell bank)."""
        if nbit <= 0:
            raise ValueError(f"nbit must be positive, got {nbit}")
        return -(-nbit // self.row_length)

    def products_per_subarray(self, nbit: int) -> int:
        """Concurrent MULs one subarray hosts in a single wave."""
        rpp = self.rows_per_product(nbit)
        if rpp > self.rows_per_subarray:
            raise ValueError(
                f"one {nbit}-bit product needs {rpp} rows but a subarray has "
                f"only {self.rows_per_subarray}; enlarge rows_per_subarray or "
                "lower nbit (cross-subarray products are not modeled)")
        return self.rows_per_subarray // rpp

    def products_per_wave(self, nbit: int) -> int:
        """Concurrent MULs across the whole chip in one wave."""
        return self.products_per_subarray(nbit) * self.subarrays

    def replace(self, **kw) -> "ArraySpec":
        return dataclasses.replace(self, **kw)


DEFAULT_SPEC = ArraySpec()
