"""Pulse-schedule compiler: lower a TilePlan to a command trace.

One wave of the engine executes the §III-D sequence on every active
subarray simultaneously (multi-row activation; banks fully parallel):

    PRESET    strong reverse pulse, all occupied rows at once
    PULSE_X   stochastic write pulse for the X operands (one DTC launch
              per product, durations differ per row, one cycle budget)
    PULSE_Y   second pulse — in-place AND with the surviving X bits
    READ      sense + latch every occupied row (per-bank SAs)
    POPCOUNT  per-row APC counts, one cycle, parallel
    MERGE     log-depth adder tree folding one product's per-row counts
              (absent when a product fits a single row)

Waves serialize — that is the bank/subarray conflict accounting: a call
bigger than one wave reuses the same cells and pays the full sequence
again. Identical full waves are folded into a single command row with a
``repeat`` count, so a trace is O(1) in matmul size while still being an
exact record of what the hardware would issue.
"""

from __future__ import annotations

import dataclasses

from repro.arch.tiler import TilePlan
from repro.core.costmodel import CostParams, DEFAULT_PARAMS

#: Command opcodes in issue order within a wave.
OPS = ("PRESET", "PULSE_X", "PULSE_Y", "READ", "POPCOUNT", "MERGE")


@dataclasses.dataclass(frozen=True)
class Command:
    """One (possibly folded) trace row.

    ``cycles`` is the duration of a single issue; ``repeat`` folds identical
    issues from consecutive steady-state waves. ``subarrays``/``rows`` count
    the parallel footprint of one issue; ``cells``/``products`` are the live
    stochastic bits / scalar MULs one issue covers (energy accounting).
    """

    op: str
    cycles: int
    repeat: int
    subarrays: int
    rows: int            # occupied rows per active subarray
    cells: int           # live cells across the chip for one issue
    products: int        # scalar MULs covered by one issue

    @property
    def total_cycles(self) -> int:
        return self.cycles * self.repeat


def _wave_commands(plan: TilePlan, params: CostParams, subarrays: int,
                   products: int, repeat: int) -> list[Command]:
    """The §III-D sequence for one wave shape, folded ``repeat`` times."""
    if products == 0 or repeat == 0:
        return []
    rows = -(-products // subarrays) * plan.rows_per_product
    cells = products * plan.nbit
    mk = lambda op, cyc: Command(op=op, cycles=cyc, repeat=repeat,
                                 subarrays=subarrays, rows=rows, cells=cells,
                                 products=products)
    cmds = [
        mk("PRESET", params.preset_cycles),
        mk("PULSE_X", params.pulse_cycles),
        mk("PULSE_Y", params.pulse_cycles),
        mk("READ", params.sa_read_cycles),
        mk("POPCOUNT", 1),           # per-row APCs fire together, one cycle
    ]
    merge = params.merge_cycles(plan.rows_per_product)
    if merge:
        cmds.append(mk("MERGE", merge))
    return cmds


def compile_schedule(plan: TilePlan,
                     params: CostParams = DEFAULT_PARAMS) -> tuple[Command, ...]:
    """Lower ``plan`` to its command trace (full waves folded, then tail)."""
    if plan.spec.row_length != params.row_length:
        raise ValueError(
            f"ArraySpec.row_length={plan.spec.row_length} disagrees with "
            f"CostParams.row_length={params.row_length}; the trace would "
            "price rows the tiler never allocated")
    trace = _wave_commands(plan, params, plan.spec.subarrays,
                           plan.products_per_wave, plan.full_waves)
    trace += _wave_commands(plan, params, max(plan.tail_subarrays, 1),
                            plan.tail_products, 1 if plan.tail_products else 0)
    return tuple(trace)


def makespan(trace: tuple[Command, ...]) -> int:
    """Total cycles of the trace (commands within a call serialize; all
    spatial parallelism is already inside each command)."""
    return sum(c.total_cycles for c in trace)


def format_trace(trace: tuple[Command, ...], limit: int = 16) -> str:
    """Human-readable trace table (the format README documents)."""
    head = (f"{'op':<9s} {'cyc':>4s} {'rep':>6s} {'subarr':>6s} "
            f"{'rows':>5s} {'cells':>10s} {'products':>9s}")
    lines = [head, "-" * len(head)]
    for c in trace[:limit]:
        lines.append(f"{c.op:<9s} {c.cycles:>4d} {c.repeat:>6d} "
                     f"{c.subarrays:>6d} {c.rows:>5d} {c.cells:>10d} "
                     f"{c.products:>9d}")
    if len(trace) > limit:
        lines.append(f"... ({len(trace) - limit} more commands)")
    lines.append(f"makespan = {makespan(trace)} cycles")
    return "\n".join(lines)
