"""Accounting engine: walk a command trace and price it with CostParams.

Energy rules mirror :func:`repro.core.costmodel.energy_scpim` exactly, so a
single-product trace prices to the same picojoules as the closed-form
model (tests pin this):

    PRESET    cells × I²R·τ_preset (over-driven)
    PULSE_X   cells × I²R·τ_pulse  +  one LUT+DTC conversion per product
    PULSE_Y   same as PULSE_X (second operand)
    READ      free (folded into the APC charge, as in the closed form)
    POPCOUNT  one APC charge per product
    MERGE     free (adder tree folded into the APC charge)

Cycles are the trace makespan. Utilization metrics report how well the
workload kept the chip busy: ``subarray_util`` is occupied subarray-cycles
over offered subarray-cycles; ``cell_occupancy`` is live cells over offered
cells in the rows the commands actually touched.
"""

from __future__ import annotations

import dataclasses

from repro.arch.schedule import Command, makespan
from repro.arch.spec import ArraySpec
from repro.core.costmodel import CostParams, DEFAULT_PARAMS


@dataclasses.dataclass(frozen=True)
class TraceReport:
    """What one call (or an aggregate of calls) cost on the array."""

    cycles: int
    energy_pj: float
    products: int
    subarray_util: float        # occupied subarray-cycles / offered
    cell_occupancy: float       # live cells / cells in touched rows
    cycles_by_op: dict
    energy_by_op: dict

    @property
    def energy_nj(self) -> float:
        return self.energy_pj * 1e-3

    @property
    def cycles_per_product(self) -> float:
        return self.cycles / self.products if self.products else 0.0

    @property
    def energy_pj_per_product(self) -> float:
        return self.energy_pj / self.products if self.products else 0.0


def _command_energy_pj(c: Command, params: CostParams) -> float:
    if c.op == "PRESET":
        return c.cells * params.preset_energy_pj_per_cell()
    if c.op in ("PULSE_X", "PULSE_Y"):
        return (c.cells * params.pulse_energy_pj_per_cell()
                + c.products * params.conversion_energy_pj_per_operand())
    if c.op == "POPCOUNT":
        return c.products * params.apc_energy_pj
    return 0.0      # READ / MERGE folded into the APC charge (closed form)


def account(trace: tuple[Command, ...], spec: ArraySpec,
            params: CostParams = DEFAULT_PARAMS) -> TraceReport:
    """Price a compiled trace on ``spec`` hardware with ``params`` knobs."""
    total_cycles = makespan(trace)
    cycles_by_op: dict = {}
    energy_by_op: dict = {}
    energy = 0.0
    products = 0
    busy_subarray_cycles = 0
    live_cells = 0
    row_cells = 0
    for c in trace:
        cycles_by_op[c.op] = cycles_by_op.get(c.op, 0) + c.total_cycles
        e = _command_energy_pj(c, params) * c.repeat
        energy_by_op[c.op] = energy_by_op.get(c.op, 0.0) + e
        energy += e
        if c.op == "POPCOUNT":      # count each product once per wave issue
            products += c.products * c.repeat
        busy_subarray_cycles += c.subarrays * c.total_cycles
        live_cells += c.cells * c.repeat
        row_cells += c.subarrays * c.rows * spec.row_length * c.repeat
    offered = spec.subarrays * total_cycles
    return TraceReport(
        cycles=total_cycles, energy_pj=energy, products=products,
        subarray_util=busy_subarray_cycles / offered if offered else 0.0,
        cell_occupancy=live_cells / row_cells if row_cells else 0.0,
        cycles_by_op=cycles_by_op, energy_by_op=energy_by_op)


def merge_concurrent_reports(reports) -> TraceReport:
    """Aggregate reports of calls running AT THE SAME TIME on disjoint
    mesh slices (one report per shard of a sharded ``sc_dot``).

    Shards are concurrent banks, not queued calls: the makespan is the
    slowest shard (max, not sum), energy and products add, and the per-op
    cycle breakdown adds (it counts op-cycles *executed* across the
    combined hardware, like busy-cycles — so ``cycles_by_op`` may exceed
    ``cycles``, exactly as it does for parallel banks inside one trace).
    ``subarray_util`` re-normalizes busy subarray-cycles against the
    combined offer (n_shards × makespan worth of chips), so idle tails on
    fast shards count against utilization; ``cell_occupancy`` stays a
    cycle-weighted mean (it is defined over touched rows only).
    """
    reports = list(reports)
    if not reports:
        return TraceReport(0, 0.0, 0, 0.0, 0.0, {}, {})
    cycles = max(r.cycles for r in reports)
    n = len(reports)
    cbo: dict = {}
    ebo: dict = {}
    for r in reports:
        for op, c in r.cycles_by_op.items():
            cbo[op] = cbo.get(op, 0) + c
        for op, e in r.energy_by_op.items():
            ebo[op] = ebo.get(op, 0.0) + e
    busy = sum(r.subarray_util * r.cycles for r in reports)
    occ_cycles = sum(r.cycles for r in reports)
    occ = (sum(r.cell_occupancy * r.cycles for r in reports) / occ_cycles
           if occ_cycles else 0.0)
    return TraceReport(
        cycles=cycles,
        energy_pj=sum(r.energy_pj for r in reports),
        products=sum(r.products for r in reports),
        subarray_util=busy / (n * cycles) if cycles else 0.0,
        cell_occupancy=occ,
        cycles_by_op=cbo, energy_by_op=ebo)


def merge_reports(reports) -> TraceReport:
    """Aggregate per-call reports into one (calls serialize on the chip:
    cycles add; utilizations combine cycle-weighted)."""
    reports = list(reports)
    if not reports:
        return TraceReport(0, 0.0, 0, 0.0, 0.0, {}, {})
    cycles = sum(r.cycles for r in reports)
    cbo: dict = {}
    ebo: dict = {}
    for r in reports:
        for op, c in r.cycles_by_op.items():
            cbo[op] = cbo.get(op, 0) + c
        for op, e in r.energy_by_op.items():
            ebo[op] = ebo.get(op, 0.0) + e
    wsum = lambda attr: (sum(getattr(r, attr) * r.cycles for r in reports)
                         / cycles if cycles else 0.0)
    return TraceReport(
        cycles=cycles,
        energy_pj=sum(r.energy_pj for r in reports),
        products=sum(r.products for r in reports),
        subarray_util=wsum("subarray_util"),
        cell_occupancy=wsum("cell_occupancy"),
        cycles_by_op=cbo, energy_by_op=ebo)


def report_dict(r: TraceReport) -> dict:
    """JSON-ready view (benchmark artifacts, serve trace dumps)."""
    return {
        "cycles": r.cycles,
        "energy_pj": round(r.energy_pj, 3),
        "products": r.products,
        "cycles_per_product": round(r.cycles_per_product, 4),
        "energy_pj_per_product": round(r.energy_pj_per_product, 4),
        "subarray_util": round(r.subarray_util, 4),
        "cell_occupancy": round(r.cell_occupancy, 4),
        "cycles_by_op": dict(r.cycles_by_op),
        "energy_by_op": {k: round(v, 3) for k, v in r.energy_by_op.items()},
    }


# ---------------------------------------------------------------------------
# Device-fault census (ROADMAP item 4): price a call's injected bit errors
# ---------------------------------------------------------------------------

def bit_error_census(profile, cells: int, start: int = 0) -> dict:
    """Error budget of ``cells`` cell reads under a device profile.

    Stuck-at counts are EXACT — the profile's fault map is frozen, so the
    census is a prefix-sum lookup over the wrapped cell span, not a
    sample (``core/physics.py:stuck_counts``).  Retention flips redraw
    per read, so their entry is the rounded expectation — deterministic
    given (profile, cells), which is what lets CI gate
    ``arch_bit_errors_total`` exactly.
    """
    from repro.core import physics
    s0, s1 = physics.stuck_counts(profile, cells, start)
    return {
        "cells": cells,
        "stuck0": s0,
        "stuck1": s1,
        "retention": int(round(profile.ber_retention * cells)),
    }


def subarray_error_masks(profile, spec: ArraySpec) -> list[dict]:
    """Per-subarray stuck-fault masks for one wave over ``spec``.

    Subarray ``s`` owns physical cells ``[s*cps, (s+1)*cps)`` of the
    profile's map (wrapping when the chip is larger than ``map_cells``);
    each entry reports that subarray's stuck-cell population — the mask
    the scheduler would program around on a mapped part, and the
    per-shard breakdown behind ``arch_bit_errors_total``.
    """
    cps = spec.cells_per_subarray
    return [
        {"subarray": s, **{k: v for k, v in
                           bit_error_census(profile, cps, s * cps).items()
                           if k != "retention"}}
        for s in range(spec.subarrays)
    ]
