"""Static workload extraction: a ModelConfig's SC-routed matmuls.

``dense_workload(cfg, tokens)`` enumerates every matmul a forward pass
routes through ``layers.dense`` (and therefore through ``sc_dot`` when
``cfg.sc_backend`` is stochastic and an rng is plumbed), with explicit
per-layer multiplicity —
the scanned layer body compiles once but the hardware executes it
``n_layers`` times, so a compile-time trace alone under-counts. This is
what lets the trace benchmark and ``profile_cell --sc-trace`` price a
PRODUCTION-shape forward pass without materializing any O(M·K·N) numerics.

Attention score/value einsums and the SSM state scan are not SC-routed
(they are not ``dense`` calls) and are deliberately absent.
"""

from __future__ import annotations

import dataclasses

from repro.arch.accounting import (
    TraceReport, merge_concurrent_reports, merge_reports)
from repro.arch.backend import schedule_call
from repro.arch.spec import ArraySpec
from repro.core.costmodel import CostParams


@dataclasses.dataclass(frozen=True)
class MatmulSite:
    """One dense() site: (tokens, k) @ (k, n), executed ``count`` times."""

    label: str
    m: int
    k: int
    n: int
    count: int

    @property
    def products(self) -> int:
        return self.m * self.k * self.n * self.count


def dense_workload(cfg, tokens: int) -> list[MatmulSite]:
    """All dense() matmuls of one forward pass over ``tokens`` tokens."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    sites: list[MatmulSite] = []
    add = lambda label, k, n, count=1: sites.append(
        MatmulSite(label, tokens, k, n, count))

    # Layer multiplicities come from the lm assembly itself so the static
    # pricing can never drift from what the scan actually executes.
    from repro.models import lm
    n_layers = cfg.n_layers
    if cfg.family in ("ssm", "hybrid"):
        # Mamba2 block projections (ssm.py): z, x, B, C, dt in; out proj.
        di, st = cfg.d_inner, cfg.ssm_state
        n_ssm = lm.n_backbone_layers(cfg)
        add("ssm.wz", d, di, n_ssm)
        add("ssm.wx", d, di, n_ssm)
        add("ssm.wB", d, st, n_ssm)
        add("ssm.wC", d, st, n_ssm)
        add("ssm.wdt", d, cfg.ssm_heads, n_ssm)
        add("ssm.out", di, d, n_ssm)
        if cfg.family == "hybrid":
            n_shared = lm.n_shared_invocations(cfg)
            _attn_sites(add, d, h, kvh, hd, n_shared, prefix="shared.")
            _mlp_sites(add, cfg, n_shared, prefix="shared.")
    else:
        _attn_sites(add, d, h, kvh, hd, n_layers)
        if cfg.family == "moe":
            # Router + top_k expert FFN visits per token (dense equivalents).
            add("moe.router", d, cfg.n_experts, n_layers)
            visits = cfg.top_k + (1 if cfg.shared_expert else 0)
            wi_cols = 2 * cfg.d_ff if cfg.mlp_variant == "swiglu" else cfg.d_ff
            add("moe.wi", d, wi_cols, n_layers * visits)
            add("moe.wo", cfg.d_ff, d, n_layers * visits)
        else:
            _mlp_sites(add, cfg, n_layers)
    # Zoo sites outside the scanned blocks: the embeddings-frontend
    # projection and the unembed head both dispatch through dense() with
    # the threaded rng (sites "frontend_proj" / "unembed"), so they are
    # part of the SC-routed workload too (keep in sync with lm.forward).
    if cfg.frontend == "embeddings":
        add("frontend.proj", d, d)
    add("unembed", d, cfg.vocab)
    return sites


def _attn_sites(add, d, h, kvh, hd, count, prefix=""):
    add(prefix + "attn.wq", d, h * hd, count)
    add(prefix + "attn.wk", d, kvh * hd, count)
    add(prefix + "attn.wv", d, kvh * hd, count)
    add(prefix + "attn.wo", h * hd, d, count)


def _mlp_sites(add, cfg, count, prefix=""):
    wi_cols = 2 * cfg.d_ff if cfg.mlp_variant == "swiglu" else cfg.d_ff
    add(prefix + "mlp.wi", cfg.d_model, wi_cols, count)
    add(prefix + "mlp.wo", cfg.d_ff, cfg.d_model, count)


def price_workload(sites, nbit: int, spec: ArraySpec | None = None,
                   params: CostParams | None = None):
    """Schedule every site on the array and price the whole pass.

    Returns ``(per_site, total)`` where ``per_site`` is a list of
    ``(site, TraceReport)`` — the site's report already includes its
    ``count`` multiplicity — and ``total`` merges them all.
    """
    per_site: list[tuple[MatmulSite, TraceReport]] = []
    for s in sites:
        one = schedule_call(s.m, s.k, s.n, nbit, spec, params).report
        per_site.append((s, merge_reports([one] * s.count)))
    total = merge_reports(r for _, r in per_site)
    return per_site, total


def shard_site(site: MatmulSite, data: int = 1, model: int = 1) -> MatmulSite:
    """One mesh slice's share of ``site`` under the SC sharding rules:
    rows (m) split over the ``data`` span, contraction (k) over ``model``
    (ceil-division — indivisible dims cost the padded shard)."""
    ceil = lambda a, b: -(-a // b)
    return dataclasses.replace(site, m=ceil(site.m, max(data, 1)),
                               k=ceil(site.k, max(model, 1)))


def price_workload_sharded(sites, nbit: int, *, data: int = 1,
                           model: int = 1, spec: ArraySpec | None = None,
                           params: CostParams | None = None):
    """Price a workload executed mesh-sharded: ``data × model`` chips,
    each running one shard of every matmul concurrently.

    Each site is priced as its per-shard slice (rows ÷ ``data``,
    contraction ÷ ``model``; see :func:`shard_site`), the shard reports
    merge as CONCURRENT banks (makespan = slowest shard, energy and
    products add — the psum/adder-tree merge itself is free, like MERGE
    in the single-chip trace), and sites serialize as usual.  With
    ``data == model == 1`` this is exactly :func:`price_workload`.

    Returns ``(per_site, total)`` shaped like :func:`price_workload`.
    """
    n_shards = max(data, 1) * max(model, 1)
    per_site: list[tuple[MatmulSite, TraceReport]] = []
    for s in sites:
        piece = shard_site(s, data, model)
        one = schedule_call(piece.m, piece.k, piece.n, nbit,
                           spec, params).report
        sharded = merge_concurrent_reports([one] * n_shards)
        per_site.append((s, merge_reports([sharded] * s.count)))
    total = merge_reports(r for _, r in per_site)
    return per_site, total
