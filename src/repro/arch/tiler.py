"""Tiler: decompose an ``sc_dot(x, w)`` call onto the array hierarchy.

An (M, K) @ (K, N) SC matmul is M·K·N independent scalar MULs, each
claiming its own bank of ``nbit`` cells (= ``rows_per_product`` rows in ONE
subarray, so the product's APC merge tree stays subarray-local). The tiler
packs those products into **waves**: one wave fills every subarray of the
chip with as many products as fit; successive waves reuse the same cells
(that reuse is the bank/subarray conflict the scheduler charges for).

Because every full wave is identical (same command sequence, same active
cell count), the plan stores {geometry, full-wave count, tail wave} rather
than a per-product list — O(1) memory however large the matmul, which is
what lets the serve engine trace production shapes. ``iter_tiles`` expands
the plan into per-(wave, subarray) tiles for tests and small-shape
inspection.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

from repro.arch.spec import ArraySpec, DEFAULT_SPEC


@dataclasses.dataclass(frozen=True)
class Tile:
    """One subarray's share of one wave: ``products`` MULs side by side."""

    wave: int
    bank: int
    subarray: int          # index within the bank
    products: int
    rows: int              # rows occupied (products × rows_per_product)
    cells: int             # active cells (products × nbit; rows may be partial)


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """The full mapping of one matmul call onto an ArraySpec."""

    m: int
    k: int
    n: int
    nbit: int
    spec: ArraySpec
    products: int                # m·k·n scalar MULs
    rows_per_product: int
    products_per_subarray: int   # wave capacity of one subarray
    waves: int                   # total waves (ceil)
    full_waves: int              # waves with every subarray at capacity
    tail_products: int           # products in the final partial wave (0 if none)

    @property
    def products_per_wave(self) -> int:
        return self.products_per_subarray * self.spec.subarrays

    @property
    def tail_subarrays(self) -> int:
        """Subarrays active in the tail wave."""
        if self.tail_products == 0:
            return 0
        return -(-self.tail_products // self.products_per_subarray)

    @property
    def cells_touched(self) -> int:
        """Total cell-writes of the call (products × nbit, preset excluded)."""
        return self.products * self.nbit


def tile_matmul(m: int, k: int, n: int, nbit: int,
                spec: ArraySpec = DEFAULT_SPEC) -> TilePlan:
    """Plan the wave decomposition of an (m, k) @ (k, n) call at ``nbit``."""
    for name, v in (("m", m), ("k", k), ("n", n)):
        if v <= 0:
            raise ValueError(f"matmul dim {name} must be positive, got {v}")
    products = m * k * n
    pps = spec.products_per_subarray(nbit)   # validates nbit vs subarray size
    per_wave = pps * spec.subarrays
    waves = -(-products // per_wave)
    full_waves = products // per_wave
    tail = products - full_waves * per_wave
    return TilePlan(m=m, k=k, n=n, nbit=nbit, spec=spec, products=products,
                    rows_per_product=spec.rows_per_product(nbit),
                    products_per_subarray=pps, waves=waves,
                    full_waves=full_waves, tail_products=tail)


def iter_tiles(plan: TilePlan, max_tiles: int = 100_000) -> Iterator[Tile]:
    """Expand the plan into explicit per-(wave, subarray) tiles.

    Intended for tests / small shapes — raises rather than silently
    truncating if the expansion would exceed ``max_tiles``.
    """
    total = (plan.full_waves * plan.spec.subarrays) + plan.tail_subarrays
    if total > max_tiles:
        raise ValueError(f"plan expands to {total} tiles > max_tiles="
                         f"{max_tiles}; use the aggregate plan fields instead")
    spb = plan.spec.subarrays_per_bank
    for wave in range(plan.waves):
        if wave < plan.full_waves:
            remaining = plan.products_per_wave
        else:
            remaining = plan.tail_products
        for s in range(plan.spec.subarrays):
            take = min(plan.products_per_subarray, remaining)
            if take <= 0:
                break
            remaining -= take
            yield Tile(wave=wave, bank=s // spb, subarray=s % spb,
                       products=take, rows=take * plan.rows_per_product,
                       cells=take * plan.nbit)


def plan_summary(plan: TilePlan) -> dict:
    """Machine-readable one-liner for traces / JSON benchmarks."""
    return {
        "shape": [plan.m, plan.k, plan.n],
        "nbit": plan.nbit,
        "products": plan.products,
        "rows_per_product": plan.rows_per_product,
        "products_per_subarray": plan.products_per_subarray,
        "waves": plan.waves,
        "tail_products": plan.tail_products,
        "spec": dataclasses.asdict(plan.spec),
    }


def occupancy(plan: TilePlan) -> float:
    """Mean fraction of chip cells doing useful work across the call's waves
    (1.0 = every wave fills every subarray row cell with live stochastic
    bits; < 1 from tail waves and from nbit not filling whole rows)."""
    used = plan.products * plan.nbit
    offered = plan.waves * plan.spec.cells
    return used / offered if offered else 0.0
