"""repro.arch — array-level simulator of the SOT-MRAM SC engine.

The paper's headline numbers come from an *architecture* (§III-D, §V):
256-cell cross-point rows grouped into subarrays and banks with
row-parallel preset/pulse/read sequencing. This package makes that
architecture executable:

    spec.py        ArraySpec — chip → bank → subarray → 256-cell rows
    tiler.py       decompose sc_dot(x, w) into row-sized tiles / waves
    schedule.py    compile tiles to a PRESET/PULSE/READ/POPCOUNT/MERGE trace
    accounting.py  walk the trace with core.costmodel.CostParams →
                   cycles / energy / utilization
    trace.py       collectors recording every array-backend dispatch
    backend.py     the registered ``array`` SC backend + ambient spec/params
    workload.py    static per-layer matmul extraction for production shapes

Usage — run any model "on hardware" and read the bill:

    from repro import arch, sc
    with arch.collect() as records:
        y = sc.sc_dot(key, x, w, sc.ScConfig(backend="array", nbit=1024))
    print(arch.format_trace(records[0].trace))
    print(arch.report_dict(records[0].report))
"""

from repro.arch.spec import ArraySpec, DEFAULT_SPEC                # noqa: F401
from repro.arch.tiler import (                                     # noqa: F401
    Tile, TilePlan, iter_tiles, occupancy, plan_summary, tile_matmul)
from repro.arch.schedule import (                                  # noqa: F401
    OPS, Command, compile_schedule, format_trace, makespan)
from repro.arch.accounting import (                                # noqa: F401
    TraceReport, account, merge_concurrent_reports, merge_reports,
    report_dict)
from repro.arch.trace import (                                     # noqa: F401
    CallRecord, TraceCollector, collect, scaled, summarize)
from repro.arch.backend import (                                   # noqa: F401
    current_params, current_spec, schedule_call, use_params, use_spec)
from repro.arch.workload import (                                  # noqa: F401
    MatmulSite, dense_workload, price_workload, price_workload_sharded,
    shard_site)
