"""Version-compat helpers for jax API moves (this container pins 0.4.x).

Mesh- and shard_map-shaped shims live next to their single consumers
(``launch/specs.abstract_mesh``, ``distributed/compression._shard_map``);
helpers with more than one call site go here.
"""

from __future__ import annotations

import jax


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` landed after 0.4.x; fall back to
    ``jax.tree_util.tree_flatten_with_path``."""
    fn = getattr(jax.tree, "flatten_with_path", None) or \
        jax.tree_util.tree_flatten_with_path
    return fn(tree, is_leaf=is_leaf)
