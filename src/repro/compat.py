"""Version-compat helpers for jax API moves (this container pins 0.4.x).

Mesh-shaped shims live next to their single consumers
(``launch/specs.abstract_mesh``); helpers with more than one call site go
here — ``shard_map_compat`` serves both the gradient-compression pod
reduction (``distributed/compression``) and the mesh-sharded SC substrate
(``sc/sharded``).
"""

from __future__ import annotations

import jax


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` landed after 0.4.x; fall back to
    ``jax.tree_util.tree_flatten_with_path``."""
    fn = getattr(jax.tree, "flatten_with_path", None) or \
        jax.tree_util.tree_flatten_with_path
    return fn(tree, is_leaf=is_leaf)


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes=None,
                     check_rep=True):
    """Version-compat shard_map, manual over ``manual_axes``.

    ``manual_axes=None`` means fully manual (every mesh axis).  jax >= 0.5
    spells partial-manual ``jax.shard_map(..., axis_names=...)``; 0.4.x
    spells it ``jax.experimental.shard_map.shard_map(..., auto=<the
    rest>)`` and its partial-auto form has no eager path, so that branch
    is wrapped in ``jax.jit``.
    """
    import inspect

    if manual_axes is None:
        manual_axes = frozenset(mesh.axis_names)

    def rep_kwarg(fn):
        # The replication-check flag was renamed check_rep -> check_vma;
        # forward it under whichever name this jax spells (callers like
        # sc_dot_sharded disable it deliberately, so dropping it silently
        # would resurface rep-check failures on upgrade).
        params = inspect.signature(fn).parameters
        for name in ("check_vma", "check_rep"):
            if name in params:
                return {name: check_rep}
        return {}

    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(manual_axes),
                             **rep_kwarg(jax.shard_map))
    from jax.experimental.shard_map import shard_map
    auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    if not auto:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, **rep_kwarg(shard_map))
    mapped = shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       auto=auto, **rep_kwarg(shard_map))
    # 0.4.x partial-auto shard_map has no eager path — trace it always
    return jax.jit(mapped)
