"""Canonical operand encoding shared by every SC backend.

This is the ONE place float tensors become stochastic-computing operands:

* sign/magnitude split — the paper's engine multiplies unsigned
  probabilities; signs are carried beside the magnitudes and multiply
  through the accumulation (standard SC practice).
* per-tensor max-abs scale — magnitudes map onto [0, 1] so every value is
  a valid Bernoulli bias; the product of the two scales is re-applied to
  the decoded output.
* operand-grid quantization — the paper drives pulse durations from an
  n-bit LUT/DTC (§III-A), so encoded probabilities snap to a 2^n grid.
* fx16 bias words — the packed Pallas engine consumes biases as 16-bit
  fixed point (the Horner-ladder resolution in kernels/sc_mul.py).

The deleted PR-1 shims (``core/scmac.py``, ``kernels/ops.py``) used to
each carry a copy of this logic; this module is the single home now.
"""

from __future__ import annotations

import jax.numpy as jnp


FX16_ONE = 1 << 16      # fixed-point unit of the packed-engine bias words


def encode(v, cfg):
    """float tensor -> (sign, probability, scale). p ∈ [0,1), v ≈ sign·p·scale.

    ``cfg`` needs ``quantize`` and ``operand_bits`` (any ScConfig-shaped
    object qualifies).

    The operand grid is the paper's n-bit LUT index space (§III-A): an
    operand X ∈ [0, 2^n - 1] encodes probability X / 2^n, so the top
    representable level is (2^n - 1)/2^n — index 2^n does not exist in the
    table.  Probabilities therefore snap to ``round(p·2^n)`` *clamped* to
    2^n - 1; the previous un-clamped round produced 2^n + 1 levels with
    p = 1.0 landing on the nonexistent index 2^n.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    p = jnp.abs(v) / scale
    if cfg.quantize:
        p = quantize_grid(p, 1 << cfg.operand_bits)
    return jnp.sign(v), p, scale


def quantize_grid(p, levels: int):
    """Snap probabilities onto the paper's n-bit LUT/DTC operand grid.

    The clamped round described in :func:`encode` — THE single source of
    the grid formula: the host encoding above and the fused Pallas
    kernel's in-kernel encoding (``kernels/sc_fused.py``) both call this,
    which is what keeps their fx16 bias words bit-identical.
    """
    return jnp.clip(jnp.round(p * levels), 0, levels - 1) / levels


def decode(sign, p, scale):
    """Inverse of :func:`encode` (up to quantization)."""
    return sign * p * scale


def to_fx16(p):
    """Probability in [0, 1] -> 16-bit bias word w, Bernoulli bias w / 2^16.

    Round-to-nearest, so the round-trip through :func:`from_fx16` is EXACT
    on every operand grid of ``operand_bits <= 16``: a grid level
    p = i / 2^n maps to w = i·2^(16-n) and back losslessly.  p = 1.0 itself
    has no 16-bit word (w = 2^16 needs a 17th bit) and clamps to 65535;
    :func:`encode`'s clamped grid keeps quantized probabilities at
    (2^n - 1)/2^n or below, so the packed Pallas path never hits the clamp
    and max-magnitude operands are no longer biased downward.
    """
    return jnp.clip(jnp.round(p * FX16_ONE), 0, FX16_ONE - 1).astype(
        jnp.uint32)


def from_fx16(w):
    """Bias word -> the probability the packed engine realizes (w / 2^16).

    This is exactly the per-bit probability of the Horner-ladder Bernoulli
    synthesis in ``kernels/sc_mul.py``, so ``from_fx16(to_fx16(p))`` is the
    bias the hardware path actually draws with.
    """
    return w.astype(jnp.float32) / FX16_ONE


def pad_to(x, multiple, axis):
    """Zero-pad ``axis`` of ``x`` up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)
