"""Canonical operand encoding shared by every SC backend.

This is the ONE place float tensors become stochastic-computing operands:

* sign/magnitude split — the paper's engine multiplies unsigned
  probabilities; signs are carried beside the magnitudes and multiply
  through the accumulation (standard SC practice).
* per-tensor max-abs scale — magnitudes map onto [0, 1] so every value is
  a valid Bernoulli bias; the product of the two scales is re-applied to
  the decoded output.
* operand-grid quantization — the paper drives pulse durations from an
  n-bit LUT/DTC (§III-A), so encoded probabilities snap to a 2^n grid.
* fx16 bias words — the packed Pallas engine consumes biases as 16-bit
  fixed point (the Horner-ladder resolution in kernels/sc_mul.py).

``core/scmac.py`` and ``kernels/ops.py`` used to each carry a copy of this
logic; both now delegate here.
"""

from __future__ import annotations

import jax.numpy as jnp


def encode(v, cfg):
    """float tensor -> (sign, probability, scale). p ∈ [0,1], v ≈ sign·p·scale.

    ``cfg`` needs ``quantize`` and ``operand_bits`` (ScConfig or the legacy
    SCMacConfig both qualify).
    """
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    p = jnp.abs(v) / scale
    if cfg.quantize:
        levels = 1 << cfg.operand_bits
        p = jnp.round(p * levels) / levels   # n-bit operand grid (LUT input)
    return jnp.sign(v), p, scale


def decode(sign, p, scale):
    """Inverse of :func:`encode` (up to quantization)."""
    return sign * p * scale


def to_fx16(p):
    """Probability in [0, 1] -> 16-bit fixed-point bias word (clamped)."""
    return jnp.minimum(jnp.round(p * 65536.0), 65535.0).astype(jnp.uint32)


def pad_to(x, multiple, axis):
    """Zero-pad ``axis`` of ``x`` up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)
