"""Tile-size autotuner for the fused SC engine (``pallas_fused``).

The fused kernel's wall-clock is dominated by how its (block_m, block_n,
block_k, lane_words) tiling trades grid-step overhead against per-tile
working-set size — and the best point depends on the call shape.  This
module owns that choice:

* a **versioned on-disk cache** (``autotune_cache.json``, shipped with
  the repo) maps ``(M, K, N, nbit, dtype)`` to a measured-best
  :class:`FusedTile`; ``tools/autotune.py`` refreshes it;
* a **deterministic heuristic** (:func:`heuristic_tile`) answers cache
  misses, so cold shapes still run with a sane tiling and the lookup is
  a pure function of the call signature;
* the tuner itself (:func:`tune_shape`) times candidate tiles through
  the real kernel entry point.

Crucially the tile choice can NEVER change results: the kernel draws
every stochastic word from the global counter-based stream
(``sc/ctr_rng.py``), so outputs are bitwise invariant to the tiling —
the cache is a pure performance table, safe to regenerate on any
machine (asserted in ``tests/test_sc_fused.py``).

Cache format (``CACHE_VERSION`` bumps invalidate the whole file)::

    {"version": 1,
     "entries": {"8x32x8|nbit=1024|dtype=float32":
                 {"block_m": 8, "block_n": 8, "block_k": 32,
                  "lane_words": 16, "wall_us": 1234.5}}}
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

from repro import obs

CACHE_VERSION = 1
DEFAULT_CACHE_PATH = os.path.join(os.path.dirname(__file__),
                                  "autotune_cache.json")
_CACHE_ENV = "REPRO_SC_AUTOTUNE_CACHE"

# per-tile uint32 working set cap (words): two Bernoulli word buffers of
# bm*bk*bn*lane_words words each must stay VMEM-resident on a real TPU.
_MAX_TILE_WORDS = 1 << 16


@dataclasses.dataclass(frozen=True)
class FusedTile:
    """One fused-kernel tiling: matmul blocks + RNG words per inner pass.

    ``lane_words`` packed 32-bit words (= 32·lane_words stochastic cells
    per lane pass) are drawn per Horner-ladder sweep; smaller values
    shrink the VMEM working set, larger values amortize sweep overhead.
    """

    block_m: int = 8
    block_n: int = 8
    block_k: int = 32
    lane_words: int = 16

    def kwargs(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class AttnTile:
    """One fused paged-attention tiling (``kernels/paged_attention.py``).

    ``block_q`` query rows per grid step; ``lane_words`` packed 32-bit
    RNG words per Horner sweep of the SC-sampled QK^T (deterministic
    entries carry ``lane_words = 1`` as a placeholder — no rng drawn).
    Like the matmul tiles, the choice can never change bits: every
    logit's pop-count total is computed whole within one grid step from
    globally-addressed counters.
    """

    block_q: int = 8
    lane_words: int = 16

    def kwargs(self) -> dict:
        return dataclasses.asdict(self)


def cache_key(m: int, k: int, n: int, nbit: int,
              dtype: str = "float32") -> str:
    return f"{m}x{k}x{n}|nbit={nbit}|dtype={dtype}"


def attn_cache_key(rows: int, block_size: int, head_dim: int, nbit: int,
                   dtype: str = "float32") -> str:
    """``attn`` kernel-kind key: (query rows, kv block, head dim, nbit).

    ``rows = group * chunk_width`` is the kernel's flattened query-row
    axis per (batch, kv-head) slice; ``nbit = 0`` marks the
    deterministic (non-SC) QK^T variant.  The kind prefix keeps the
    attention entries disjoint from the matmul keys in the same
    versioned file.
    """
    return (f"attn|{rows}x{block_size}x{head_dim}|nbit={nbit}"
            f"|dtype={dtype}")


def load_cache(path: str | None = None) -> dict:
    """Entries of the on-disk cache; {} when absent, invalid, or stale.

    A ``version`` mismatch (``CACHE_VERSION`` bump) invalidates the whole
    file — stale tables from older kernel generations are never applied.
    """
    path = path or os.environ.get(_CACHE_ENV) or DEFAULT_CACHE_PATH
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return {}
    if payload.get("version") != CACHE_VERSION:
        return {}
    entries = payload.get("entries")
    return entries if isinstance(entries, dict) else {}


def save_cache(entries: dict, path: str | None = None) -> str:
    path = path or os.environ.get(_CACHE_ENV) or DEFAULT_CACHE_PATH
    with open(path, "w") as f:
        json.dump({"version": CACHE_VERSION, "entries": entries}, f,
                  indent=2, sort_keys=True)
        f.write("\n")
    return path


_CACHE: dict | None = None


def _cached_entries() -> dict:
    global _CACHE
    if _CACHE is None:
        _CACHE = load_cache()
    return _CACHE


def reset_cache() -> None:
    """Drop the in-process cache (tests / after tools/autotune.py runs)."""
    global _CACHE
    _CACHE = None


def _note_lookup(kind: str, result: str, tile) -> None:
    """Telemetry for one tile lookup: a hit/miss counter in the global
    registry (disabled by default) plus the chosen tile folded into the
    innermost open trace span — under an instrumented run that is the
    ``sc.dispatch`` span of the fused backend that asked."""
    reg = obs.default_registry()
    if reg.enabled:
        reg.counter(
            "sc_autotune_lookups_total",
            "tile-cache lookups by the fused backends (hit = stored "
            "measured tile, miss = deterministic heuristic)").inc(
                kind=kind, result=result)
    tr = obs.current_tracer()
    if tr is not None and tr.enabled:
        tr.attr(sc_autotune=result,
                sc_tile=str(dataclasses.astuple(tile)))


def _pow2_cover(dim: int, cap: int) -> int:
    """Smallest power of two >= dim, clamped to cap (operands pad up)."""
    p = 1
    while p < dim and p < cap:
        p *= 2
    return p


def heuristic_tile(m: int, k: int, n: int, nbit: int) -> FusedTile:
    """Deterministic cache-miss fallback: modest power-of-two blocks.

    Small M/N tiles keep the cubic (bm, bk, bn, lane_words) Bernoulli
    working set bounded; K gets the largest block the VMEM cap allows so
    the integer accumulator loops as few grid steps as possible.
    """
    nwords = max(1, nbit // 32)
    bm = _pow2_cover(m, 8)
    bn = _pow2_cover(n, 8)
    bk = _pow2_cover(k, 32)
    lane = min(nwords, 16)
    while bm * bn * bk * lane > _MAX_TILE_WORDS and lane > 1:
        lane //= 2
    while bm * bn * bk * lane > _MAX_TILE_WORDS and bk > 1:
        bk //= 2
    return FusedTile(block_m=bm, block_n=bn, block_k=bk, lane_words=lane)


def get_tile(m: int, k: int, n: int, nbit: int, dtype: str = "float32",
             cache: dict | None = None) -> FusedTile:
    """Cache-then-heuristic lookup — THE tile the fused backend runs with.

    Pure function of (shape, nbit, dtype, cache contents): a cache hit
    returns the stored tile verbatim; a miss falls back to
    :func:`heuristic_tile`.  Either way the kernel's outputs are
    identical (tiling never changes the counter-based draw).
    """
    entries = cache if cache is not None else _cached_entries()
    entry = entries.get(cache_key(m, k, n, nbit, dtype))
    if entry is not None:
        try:
            tile = FusedTile(
                block_m=int(entry["block_m"]), block_n=int(entry["block_n"]),
                block_k=int(entry["block_k"]),
                lane_words=int(entry["lane_words"]))
            if min(dataclasses.astuple(tile)) >= 1:
                _note_lookup("matmul", "hit", tile)
                return tile
        except (KeyError, TypeError, ValueError):
            pass                     # malformed entry -> heuristic
    tile = heuristic_tile(m, k, n, nbit)
    _note_lookup("matmul", "miss", tile)
    return tile


def heuristic_attn_tile(rows: int, block_size: int, head_dim: int,
                        nbit: int) -> AttnTile:
    """Deterministic cache-miss fallback for the paged-attention kernel.

    Deterministic QK^T (``nbit <= 0``) draws no stochastic words, so the
    only knob is ``block_q``; the SC variant bounds its per-step
    (block_q, block_size, head_dim, lane_words) Bernoulli working set by
    the same VMEM cap as the matmul tiles.
    """
    bq = _pow2_cover(rows, 8)
    if nbit <= 0:
        return AttnTile(block_q=bq, lane_words=1)
    nwords = max(1, nbit // 32)
    lane = min(nwords, 16)
    while bq * block_size * head_dim * lane > _MAX_TILE_WORDS and lane > 1:
        lane //= 2
    while bq * block_size * head_dim * lane > _MAX_TILE_WORDS and bq > 1:
        bq //= 2
    return AttnTile(block_q=bq, lane_words=lane)


def get_attn_tile(rows: int, block_size: int, head_dim: int, nbit: int,
                  dtype: str = "float32",
                  cache: dict | None = None) -> AttnTile:
    """Cache-then-heuristic lookup for the fused paged-attention kernel.

    Same contract as :func:`get_tile`: pure function of the call
    signature and cache contents, and the returned tiling can never
    change the kernel's bits — only its wall-clock.
    """
    entries = cache if cache is not None else _cached_entries()
    entry = entries.get(attn_cache_key(rows, block_size, head_dim, nbit,
                                       dtype))
    if entry is not None:
        try:
            tile = AttnTile(block_q=int(entry["block_q"]),
                            lane_words=int(entry["lane_words"]))
            if min(dataclasses.astuple(tile)) >= 1:
                _note_lookup("attn", "hit", tile)
                return tile
        except (KeyError, TypeError, ValueError):
            pass                     # malformed entry -> heuristic
    tile = heuristic_attn_tile(rows, block_size, head_dim, nbit)
    _note_lookup("attn", "miss", tile)
    return tile


def candidate_tiles(m: int, k: int, n: int, nbit: int) -> list:
    """The tuner's search space for one call shape (heuristic included).

    Deliberately small: each candidate pays a fresh kernel compile, and
    tiny ``lane_words`` values are excluded outright — the Horner sweep
    unrolls ``nwords / lane_words`` chunks, so small lanes inflate both
    trace size (compile time) and per-step overhead.
    """
    nwords = max(1, nbit // 32)
    cands = []
    for bm in {_pow2_cover(m, c) for c in (4, 8, 16)}:
        for bn in {_pow2_cover(n, c) for c in (4, 8, 16)}:
            for bk in {_pow2_cover(k, c) for c in (16, 32, 64)}:
                for lane in {min(nwords, c) for c in (16, 32)}:
                    if bm * bn * bk * lane <= _MAX_TILE_WORDS:
                        cands.append(FusedTile(bm, bn, bk, lane))
    cands.append(heuristic_tile(m, k, n, nbit))
    return sorted(set(cands), key=lambda t: dataclasses.astuple(t))


def measure_tile(m: int, k: int, n: int, nbit: int, tile: FusedTile, *,
                 operand_bits: int = 10, iters: int = 3,
                 warmup: int = 1, seed: int = 0) -> float:
    """Median wall-clock µs of the fused kernel under ``tile``."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import sc_fused
    from repro.sc import ctr_rng, encoding

    key = jax.random.PRNGKey(seed)
    kx, kw, kk = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (m, k), jnp.float32, -1.0, 1.0)
    w = jax.random.uniform(kw, (k, n), jnp.float32, -1.0, 1.0)
    kx2, ky2 = jax.random.split(kk)
    xp = encoding.pad_to(encoding.pad_to(x, tile.block_m, 0), tile.block_k, 1)
    wp = encoding.pad_to(encoding.pad_to(w, tile.block_k, 0), tile.block_n, 1)
    keys = jnp.broadcast_to(
        jnp.concatenate([ctr_rng.raw_key(kx2), ctr_rng.raw_key(ky2)])[None],
        (xp.shape[0], 4))

    def run():
        return sc_fused.sc_fused_popcount(
            keys, xp, wp, k_orig=k, n_orig=n, nbit=nbit,
            levels=1 << operand_bits, quantize=True,
            **tile.kwargs()).block_until_ready()

    for _ in range(warmup):
        run()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def tune_shape(m: int, k: int, n: int, nbit: int, *,
               candidates: list | None = None, iters: int = 3,
               verbose: bool = False) -> tuple:
    """Time every candidate tile; returns ``(best_tile, best_us, table)``."""
    cands = candidates if candidates is not None else candidate_tiles(
        m, k, n, nbit)
    table = []
    for tile in cands:
        us = measure_tile(m, k, n, nbit, tile, iters=iters)
        table.append((tile, us))
        if verbose:
            print(f"  {dataclasses.astuple(tile)!s:<22} {us:10.1f} us")
    best_tile, best_us = min(table, key=lambda tu: tu[1])
    return best_tile, best_us, table


def candidate_attn_tiles(rows: int, block_size: int, head_dim: int,
                         nbit: int) -> list:
    """Search space for one paged-attention call shape (small on purpose)."""
    cands = []
    if nbit <= 0:
        for bq in {_pow2_cover(rows, c) for c in (4, 8, 16, 32)}:
            cands.append(AttnTile(block_q=bq, lane_words=1))
    else:
        nwords = max(1, nbit // 32)
        for bq in {_pow2_cover(rows, c) for c in (4, 8, 16)}:
            for lane in {min(nwords, c) for c in (8, 16, 32)}:
                if bq * block_size * head_dim * lane <= _MAX_TILE_WORDS:
                    cands.append(AttnTile(block_q=bq, lane_words=lane))
    cands.append(heuristic_attn_tile(rows, block_size, head_dim, nbit))
    return sorted(set(cands), key=lambda t: dataclasses.astuple(t))


def measure_attn_tile(rows: int, block_size: int, head_dim: int, nbit: int,
                      tile: AttnTile, *, num_pages: int = 8,
                      operand_bits: int = 10, iters: int = 3,
                      warmup: int = 1, seed: int = 0) -> float:
    """Median wall-clock µs of the fused paged-attention kernel.

    ``rows`` is treated as a single-request, single-kv-head row axis
    (chunk width ``rows``, group 1) — the per-step work the kernel does
    is identical for any (group, chunk) split of the same row count.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import paged_attention
    from repro.sc import ctr_rng

    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.uniform(kq, (1, rows, 1, head_dim), jnp.float32,
                           -1.0, 1.0)
    k_pages = jax.random.uniform(
        kk, (num_pages, block_size, 1, head_dim), jnp.float32, -1.0, 1.0)
    v_pages = jax.random.uniform(
        kv, (num_pages, block_size, 1, head_dim), jnp.float32, -1.0, 1.0)
    table = jnp.arange(num_pages, dtype=jnp.int32)[None]
    lengths = jnp.array([num_pages * block_size - rows], jnp.int32)
    keys = jnp.broadcast_to(ctr_rng.raw_key(key)[None, None],
                            (1, rows, 2))

    if nbit <= 0:
        def run():
            return paged_attention.paged_attention_fused(
                q, k_pages, v_pages, table, lengths,
                block_q=tile.block_q).block_until_ready()
    else:
        def run():
            return paged_attention.paged_attention_fused_sc(
                keys, q, k_pages, v_pages, table, lengths, nbit=nbit,
                operand_bits=operand_bits, block_q=tile.block_q,
                lane_words=tile.lane_words).block_until_ready()

    for _ in range(warmup):
        run()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def tune_attn_shape(rows: int, block_size: int, head_dim: int, nbit: int, *,
                    candidates: list | None = None, iters: int = 3,
                    verbose: bool = False) -> tuple:
    """Time every candidate attention tile; ``(best, best_us, table)``."""
    cands = candidates if candidates is not None else candidate_attn_tiles(
        rows, block_size, head_dim, nbit)
    table = []
    for tile in cands:
        us = measure_attn_tile(rows, block_size, head_dim, nbit, tile,
                               iters=iters)
        table.append((tile, us))
        if verbose:
            print(f"  {dataclasses.astuple(tile)!s:<22} {us:10.1f} us")
    best_tile, best_us = min(table, key=lambda tu: tu[1])
    return best_tile, best_us, table
