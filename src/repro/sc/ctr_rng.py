"""Counter-based RNG shared by the bit-exact SC engines.

The packed Pallas engine (``pallas_bitexact``) and the fused engine
(``pallas_fused``) must draw the SAME stochastic bits from the same key —
that is what makes the fused kernel a drop-in fast path (same key ⇒ same
bits ⇒ bit-identical outputs).  ``jax.random.bits`` cannot provide that
stream: its counter layout is an implementation detail of the host-side
threefry lowering and is unavailable inside a Pallas kernel.  This module
pins the stream explicitly instead:

    word(key, c0, c1) = Threefry-2x32(key, (c0, c1))[0]

with a documented counter layout (see :func:`product_counters`):

    c0 = flat product index  (i·K + k)·N + j       — one MUL per (i, k, j)
    c1 = s·nwords + w                              — Horner slice s, word w

and the x/y operand streams separated by ``jax.random.split`` of the
caller's key (exactly as ``pallas_bitexact`` always did).  Everything here
is plain ``uint32`` jnp arithmetic, so the SAME function body runs on the
host (building the packed engine's input stream) and inside a Pallas
kernel (regenerating tiles of the stream in VMEM without ever
materializing it) — bit equality holds by construction, not by testing
two implementations against each other.

Counter widths: ``c0`` is one 32-bit word, so the bit-exact family
addresses at most 2^32 scalar products per call — far beyond the
validation scales the O(M·K·N·nbit) engines can run at anyway.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Threefry-2x32 constants (Salmon et al., SC'11): 20 rounds = 5 groups of
# 4, rotation schedule alternating between the two quartets, key words
# re-injected after every group with the round-group counter.
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = 0x1BD11BDA


def _rotl(x, d: int):
    return (x << jnp.uint32(d)) | (x >> jnp.uint32(32 - d))


def threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32 (20 rounds) on uint32 arrays; returns ``(x0, x1)``.

    All four arguments broadcast against each other, so a scalar key pair
    against an array of counters evaluates the whole counter block in one
    vectorized pass — on the host or inside a Pallas kernel alike.
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    ks2 = k0 ^ k1 ^ jnp.uint32(_PARITY)
    ks = (k0, k1, ks2)
    x0 = jnp.asarray(c0, jnp.uint32) + k0
    x1 = jnp.asarray(c1, jnp.uint32) + k1
    for group in range(5):
        for rot in _ROTATIONS[group % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, rot)
            x1 = x1 ^ x0
        x0 = x0 + ks[(group + 1) % 3]
        x1 = x1 + ks[(group + 2) % 3] + jnp.uint32(group + 1)
    return x0, x1


def uniform_words(key2, c0, c1):
    """One iid-uniform uint32 word per counter pair (first threefry lane).

    ``key2`` is a raw ``(2,)`` uint32 key (``raw_key`` normalizes typed
    keys); ``c0`` / ``c1`` are broadcastable uint32 counter arrays.
    """
    return threefry2x32(key2[0], key2[1], c0, c1)[0]


def raw_key(key):
    """Normalize a PRNG key to its raw ``(..., 2)`` uint32 key data."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return key.astype(jnp.uint32)


def product_counters(n_products: int, nwords: int):
    """The pinned (c0, c1) layout of one operand's per-product stream.

    Returns ``c0`` of shape ``(n_products, 1, 1)`` (flat product index)
    and ``c1`` of shape ``(1, NSLICES, nwords)`` (``s·nwords + w``), ready
    to broadcast into :func:`uniform_words` to produce the
    ``(n_products, NSLICES, nwords)`` uniform block the packed engine
    consumes.  The fused kernel computes the same two counters from its
    grid coordinates and draws only its own tile.
    """
    from repro.kernels.sc_mul import NSLICES

    c0 = jnp.arange(n_products, dtype=jnp.uint32)[:, None, None]
    c1 = (jnp.arange(NSLICES, dtype=jnp.uint32)[:, None] * jnp.uint32(nwords)
          + jnp.arange(nwords, dtype=jnp.uint32)[None, :])[None]
    return c0, c1


def operand_stream(key2, n_products: int, nwords: int):
    """Host-side materialization: ``(n_products, NSLICES, nwords)`` words.

    This is exactly the stream ``pallas_bitexact`` feeds its packed
    kernel; ``pallas_fused`` regenerates the same words tile-locally.
    """
    c0, c1 = product_counters(n_products, nwords)
    return uniform_words(key2, c0, c1)
