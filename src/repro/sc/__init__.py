"""repro.sc — the unified SC multiplication substrate.

The paper's thesis is that every memory bit is an SC MUL engine; this
package is the software analogue: ONE operation interface

    sc_dot(key, x, w, cfg)            # x @ w through the SC engine

with interchangeable array-level implementations behind a registry
(``exact``, ``moment``, ``bitexact``, ``pallas_moment``,
``pallas_bitexact``, plus the lazily-registered ``array`` architecture
simulator from :mod:`repro.arch`), one canonical operand encoding, and the
straight-through gradient applied once at the dispatch boundary so every
backend is trainable. The model stack (models/layers.py:dense), the
serving engine, the trainer, and the benchmarks all route here.
"""

from repro.sc.config import ScConfig                      # noqa: F401
from repro.sc.registry import (                           # noqa: F401
    available_backends, get_backend, register_backend, sc_dot)
from repro.sc import backends as _backends                # noqa: F401  (registers)
from repro.sc import encoding                             # noqa: F401
