"""repro.sc — the unified SC multiplication substrate.

The paper's thesis is that every memory bit is an SC MUL engine; this
package is the software analogue: ONE operation interface

    sc_dot(key, x, w, cfg)            # x @ w through the SC engine

with interchangeable array-level implementations behind a registry
(``exact``, ``moment``, ``bitexact``, ``pallas_moment``,
``pallas_bitexact``, ``pallas_fused``, plus the lazily-registered
``array`` architecture simulator from :mod:`repro.arch`), one canonical
operand encoding, and the straight-through gradient applied once at the
dispatch boundary so every backend is trainable. The model stack
(models/layers.py:dense), the serving engine, the trainer, and the
benchmarks all route here.  ``sc_dot_rows`` is the per-row-key variant
(one key per output row — the serve engine's batch-invariance path), and
``fast_backend`` resolves a backend name to its bit-identical fast path
(``pallas_bitexact`` -> ``pallas_fused``, same counter-based stream from
:mod:`repro.sc.ctr_rng`, tiles from :mod:`repro.sc.autotune`).

Scale-out lives in :mod:`repro.sc.sharded`: ``sc_dot_sharded`` splits one
contraction across a JAX device mesh (batch rows over the data axes,
contraction over the model axis with a psum merge), and ``use_mesh``
makes the model stack route every stochastic matmul through it
automatically.  See ``docs/scaling.md``.

Public API (see ``docs/backends.md`` for the selection guide):

* :class:`~repro.sc.config.ScConfig` — one frozen config per substrate.
* :func:`~repro.sc.registry.sc_dot` — the dispatch entry point.
* :func:`~repro.sc.registry.sc_dot_rows` — per-row-key dispatch.
* :func:`~repro.sc.registry.register_backend` /
  :func:`~repro.sc.registry.register_rows_backend` /
  :func:`~repro.sc.registry.get_backend` /
  :func:`~repro.sc.registry.available_backends` /
  :func:`~repro.sc.registry.fast_backend` — the registry hooks.
* :func:`~repro.sc.registry.draft_backend` /
  :func:`~repro.sc.registry.register_draft_pair` — the speculative
  draft/verify pairing (cheap guesser per verify-grade backend).
* :func:`~repro.sc.sharded.sc_dot_sharded` /
  :func:`~repro.sc.sharded.use_mesh` /
  :class:`~repro.sc.sharded.ScShardRules` — the mesh-sharded path.
"""

from repro.core.physics import DeviceProfile              # noqa: F401  (re-export)
from repro.sc.config import (                             # noqa: F401
    ScConfig, current_device_profile, use_device_profile)
from repro.sc.registry import (                           # noqa: F401
    available_backends, draft_backend, fast_backend, get_backend,
    register_backend, register_draft_pair, register_rows_backend, sc_dot,
    sc_dot_rows)
from repro.sc import autotune                             # noqa: F401
from repro.sc import backends as _backends                # noqa: F401  (registers)
from repro.sc import ctr_rng                              # noqa: F401
from repro.sc import encoding                             # noqa: F401
from repro.sc.sharded import (                            # noqa: F401
    DEFAULT_RULES, ScShardRules, active_mesh, current_shard_count,
    resolve_rules, sc_dot_sharded, shard_counts, shard_scope, use_mesh)
