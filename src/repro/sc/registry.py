"""Backend registry + the single dispatch entry point ``sc_dot``.

Every software realization of the paper's SC MUL engine registers here
under a name; ``sc_dot(key, x, w, cfg)`` looks the backend up from
``cfg.backend`` and runs it. The straight-through ``custom_vjp`` lives at
THIS boundary — not inside any backend — so every registered backend
(including the Pallas kernels, which have no differentiation rules) is
trainable for free: the backward pass is the exact-product jacobian, which
is the unbiased pathwise choice because E[SC output] equals the exact
product (paper Fig. 7a, zero-centered error).

Adding a backend is a one-file change:

    from repro.sc import register_backend

    @register_backend("my_backend")
    def my_backend(key, x, w, cfg):   # x: (M, K), w: (K, N) float32
        ...
        return y                      # (M, N) float32
"""

from __future__ import annotations

import contextlib
from functools import partial

import jax
import jax.numpy as jnp

from repro import obs
from repro.sc.config import ScConfig

_BACKENDS: dict = {}

# Backends living outside repro.sc register on first use: name -> module
# whose import performs the @register_backend call. Keeps repro.sc free of
# upward dependencies (repro.arch imports repro.sc, not vice versa) while
# ScConfig(backend="array") still works with no explicit import.
_LAZY_BACKENDS: dict = {"array": "repro.arch.backend"}

# Optional batched per-row-key implementations: name -> fn(keys, x2d, w,
# cfg) with keys (M, 2).  Backends without one fall back to a vmap of the
# single-key path in ``sc_dot_rows``.
_ROW_BACKENDS: dict = {}

# name -> bit-identical faster backend.  ``fast_backend`` (consulted by
# models/layers.py:dense) upgrades through this map; entries are only
# valid when the two backends provably produce the same bits per key.
_FAST_ALIASES: dict = {"pallas_bitexact": "pallas_fused"}

# verify backend -> cheap DRAFT backend for speculative decoding.  The
# draft only has to GUESS tokens (the verifier re-derives every emitted
# token under its own backend, so draft quality moves throughput, never
# outputs); the registry pairs each verify-grade backend with the
# cheapest stand-in that tracks it: stochastic backends draft with
# ``moment`` (the closed-form mean of the SC estimator — no bitstreams,
# one dense matmul of work) and ``exact`` drafts as itself (nothing is
# cheaper, and its guesses are then always right).
_DRAFT_PAIRS: dict = {"exact": "exact"}
_DEFAULT_DRAFT = "moment"


def register_backend(name: str):
    """Decorator: register an SC matmul backend under ``name``.

    The decorated function must have signature
    ``fn(key, x2d, w, cfg) -> y2d`` with ``x2d: (M, K)``, ``w: (K, N)``
    float32 and return ``(M, N)`` float32; ``sc_dot`` handles leading-dim
    flattening, dispatch, and the straight-through gradient, so the
    backend itself needs no differentiation rules.  Registration makes
    the name selectable everywhere a backend is named — ``ScConfig``,
    ``ModelConfig.sc_backend``, the launchers' ``--sc-backend`` flags.
    """
    def deco(fn):
        _BACKENDS[name] = fn
        return fn
    return deco


def get_backend(name: str):
    """Resolve a backend name to its function (importing lazy entries).

    Raises ``ValueError`` naming the registered backends when ``name`` is
    unknown.
    """
    if name not in _BACKENDS and name in _LAZY_BACKENDS:
        import importlib
        importlib.import_module(_LAZY_BACKENDS[name])
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown SC backend {name!r}; registered: "
            f"{sorted(set(_BACKENDS) | set(_LAZY_BACKENDS))}") from None


def available_backends() -> tuple:
    """Sorted names of every selectable backend (lazy ones included)."""
    return tuple(sorted(set(_BACKENDS) | set(_LAZY_BACKENDS)))


def register_rows_backend(name: str):
    """Decorator: register a batched per-row-key path for backend ``name``.

    The decorated function must have signature
    ``fn(keys, x2d, w, cfg) -> y2d`` with ``keys: (M, 2)`` raw uint32
    keys and ``x2d: (M, K)``; row i must depend on ``keys[i]`` / ``x[i]``
    only and match the single-key backend called on that row alone —
    ``sc_dot_rows`` uses it in place of a per-row vmap.
    """
    def deco(fn):
        _ROW_BACKENDS[name] = fn
        return fn
    return deco


def fast_backend(name: str, nbit: int | None = None) -> str:
    """Resolve ``name`` to its bit-identical fast path, if one exists.

    ``pallas_bitexact`` upgrades to ``pallas_fused`` (same counter-based
    stream, same bits per key — asserted in tests/test_sc_fused.py);
    every other name returns unchanged.  ``nbit`` guards upgrades whose
    target needs a packed word multiple.
    """
    fast = _FAST_ALIASES.get(name)
    if fast is None:
        return name
    if nbit is not None and nbit % 32 != 0:
        return name
    return fast


def register_draft_pair(verify: str, draft: str) -> None:
    """Pair ``verify`` with the draft backend speculative decoding should
    guess with.  Both names must already be registered/resolvable; the
    pairing itself carries no bit-identity obligation (accepted tokens
    are always the VERIFIER's greedy tokens)."""
    get_backend(draft)          # fail fast on unknown names
    _DRAFT_PAIRS[verify] = draft


def draft_backend(name: str) -> str:
    """Draft backend paired with verify backend ``name``.

    Upgrades applied by ``fast_backend`` don't change the pairing
    (``pallas_bitexact`` and ``pallas_fused`` draft identically);
    unpaired stochastic backends fall back to ``moment`` — the
    closed-form expectation of the SC estimator, one dense matmul per
    dispatch and deterministically close to every unbiased backend's
    mean, which is what makes its greedy guesses land.
    """
    return _DRAFT_PAIRS.get(name, _DEFAULT_DRAFT)


def _dispatch_scope(entry: str, backend: str, m: int, k: int, n: int):
    """Telemetry for one dispatch, recorded at TRACE time — under ``jit``
    that is once per compiled shape, not once per device call, so the
    counters measure compilation traffic and the spans measure trace
    wall-clock.  Both hooks are default-off: the counter goes to the
    disabled-by-default global registry and the span to the global tracer
    slot (usually empty), so an uninstrumented run pays two cheap reads.
    """
    reg = obs.default_registry()
    if reg.enabled:
        reg.counter(
            "sc_dispatch_total",
            "sc_dot/sc_dot_rows dispatches at trace time (once per "
            "compiled shape under jit)").inc(backend=backend, entry=entry)
    tr = obs.current_tracer()
    if tr is None or not tr.enabled:
        return contextlib.nullcontext()
    return tr.span("sc.dispatch", entry=entry, backend=backend,
                   m=m, k=k, n=n)


def _dispatch(key, x, w, cfg: ScConfig):
    fn = get_backend(cfg.backend)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    with _dispatch_scope("sc_dot", cfg.backend, x2.shape[0], x2.shape[1],
                         w.shape[-1]):
        y = fn(key, x2, w, cfg)
    return y.reshape(*lead, w.shape[-1])


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def sc_dot(key, x, w, cfg: ScConfig = ScConfig()):
    """``x @ w`` through the configured SC backend.

    Args:
        key: PRNG key driving the stochastic bits (``exact`` ignores it;
            same key + same cfg ⇒ same bits on every stochastic backend).
        x: (..., K) float operand; leading dims flatten to the row dim.
        w: (K, N) float operand.
        cfg: :class:`~repro.sc.config.ScConfig` naming the backend and
            its knobs (static under ``jit``).

    Returns:
        (..., N) float32 — the SC estimate of the product.  The gradient
        is straight-through (exact-product jacobian) regardless of
        backend, so any registered backend is trainable.  For the
        mesh-sharded variant see :func:`repro.sc.sharded.sc_dot_sharded`.
    """
    return _dispatch(key, x, w, cfg)


def _sc_dot_fwd(key, x, w, cfg):
    return _dispatch(key, x, w, cfg), (x, w)


def _sc_dot_bwd(cfg, res, g):
    x, w = res
    gx = jnp.dot(g, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    gw = jnp.dot(
        x.reshape(-1, x.shape[-1]).T, g.reshape(-1, g.shape[-1]),
        preferred_element_type=jnp.float32).astype(w.dtype)
    return None, gx, gw


sc_dot.defvjp(_sc_dot_fwd, _sc_dot_bwd)


def _dispatch_rows(keys, x, w, cfg: ScConfig):
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    k2 = keys.reshape(-1, keys.shape[-1])
    with _dispatch_scope("sc_dot_rows", cfg.backend, x2.shape[0],
                         x2.shape[1], w.shape[-1]):
        fn = _ROW_BACKENDS.get(cfg.backend)
        if fn is not None:
            y = fn(k2, x2, w, cfg)
        else:
            base = get_backend(cfg.backend)
            y = jax.vmap(
                lambda kk, xr: base(kk, xr[None, :], w, cfg)[0])(k2, x2)
    return y.reshape(*lead, w.shape[-1])


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def sc_dot_rows(keys, x, w, cfg: ScConfig = ScConfig()):
    """``x @ w`` with PER-ROW keys: row i draws from ``keys[i]`` alone.

    Args:
        keys: (..., 2) raw PRNG keys, leading dims matching ``x``'s — one
            key per row of the flattened row dimension.
        x: (..., K) float operand; leading dims flatten to the row dim.
        w: (K, N) float operand.
        cfg: the substrate config (static under ``jit``).

    Row i's output (stochastic bits AND encoding scale) is a function of
    ``(keys[i], x[i], w)`` only and equals
    ``sc_dot(keys[i], x[i:i+1], w, cfg)`` — the batch-composition
    invariance the continuous-batching serve engine relies on.  Backends
    registered via :func:`register_rows_backend` (``pallas_fused``) run
    the whole batch in one kernel launch; the rest fall back to a vmap of
    the single-key path.  The gradient is the same straight-through
    exact-product jacobian as :func:`sc_dot`.
    """
    return _dispatch_rows(keys, x, w, cfg)


def _sc_dot_rows_fwd(keys, x, w, cfg):
    return _dispatch_rows(keys, x, w, cfg), (x, w)


def _sc_dot_rows_bwd(cfg, res, g):
    x, w = res
    gx = jnp.dot(g, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    gw = jnp.dot(
        x.reshape(-1, x.shape[-1]).T, g.reshape(-1, g.shape[-1]),
        preferred_element_type=jnp.float32).astype(w.dtype)
    return None, gx, gw


sc_dot_rows.defvjp(_sc_dot_rows_fwd, _sc_dot_rows_bwd)
