"""Backend registry + the single dispatch entry point ``sc_dot``.

Every software realization of the paper's SC MUL engine registers here
under a name; ``sc_dot(key, x, w, cfg)`` looks the backend up from
``cfg.backend`` and runs it. The straight-through ``custom_vjp`` lives at
THIS boundary — not inside any backend — so every registered backend
(including the Pallas kernels, which have no differentiation rules) is
trainable for free: the backward pass is the exact-product jacobian, which
is the unbiased pathwise choice because E[SC output] equals the exact
product (paper Fig. 7a, zero-centered error).

Adding a backend is a one-file change:

    from repro.sc import register_backend

    @register_backend("my_backend")
    def my_backend(key, x, w, cfg):   # x: (M, K), w: (K, N) float32
        ...
        return y                      # (M, N) float32
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.sc.config import ScConfig

_BACKENDS: dict = {}

# Backends living outside repro.sc register on first use: name -> module
# whose import performs the @register_backend call. Keeps repro.sc free of
# upward dependencies (repro.arch imports repro.sc, not vice versa) while
# ScConfig(backend="array") still works with no explicit import.
_LAZY_BACKENDS: dict = {"array": "repro.arch.backend"}


def register_backend(name: str):
    """Decorator: register an SC matmul backend under ``name``.

    The decorated function must have signature
    ``fn(key, x2d, w, cfg) -> y2d`` with ``x2d: (M, K)``, ``w: (K, N)``
    float32 and return ``(M, N)`` float32; ``sc_dot`` handles leading-dim
    flattening, dispatch, and the straight-through gradient, so the
    backend itself needs no differentiation rules.  Registration makes
    the name selectable everywhere a backend is named — ``ScConfig``,
    ``ModelConfig.sc_backend``, the launchers' ``--sc-backend`` flags.
    """
    def deco(fn):
        _BACKENDS[name] = fn
        return fn
    return deco


def get_backend(name: str):
    """Resolve a backend name to its function (importing lazy entries).

    Raises ``ValueError`` naming the registered backends when ``name`` is
    unknown.
    """
    if name not in _BACKENDS and name in _LAZY_BACKENDS:
        import importlib
        importlib.import_module(_LAZY_BACKENDS[name])
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown SC backend {name!r}; registered: "
            f"{sorted(set(_BACKENDS) | set(_LAZY_BACKENDS))}") from None


def available_backends() -> tuple:
    """Sorted names of every selectable backend (lazy ones included)."""
    return tuple(sorted(set(_BACKENDS) | set(_LAZY_BACKENDS)))


def _dispatch(key, x, w, cfg: ScConfig):
    fn = get_backend(cfg.backend)
    lead = x.shape[:-1]
    y = fn(key, x.reshape(-1, x.shape[-1]), w, cfg)
    return y.reshape(*lead, w.shape[-1])


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def sc_dot(key, x, w, cfg: ScConfig = ScConfig()):
    """``x @ w`` through the configured SC backend.

    Args:
        key: PRNG key driving the stochastic bits (``exact`` ignores it;
            same key + same cfg ⇒ same bits on every stochastic backend).
        x: (..., K) float operand; leading dims flatten to the row dim.
        w: (K, N) float operand.
        cfg: :class:`~repro.sc.config.ScConfig` naming the backend and
            its knobs (static under ``jit``).

    Returns:
        (..., N) float32 — the SC estimate of the product.  The gradient
        is straight-through (exact-product jacobian) regardless of
        backend, so any registered backend is trainable.  For the
        mesh-sharded variant see :func:`repro.sc.sharded.sc_dot_sharded`.
    """
    return _dispatch(key, x, w, cfg)


def _sc_dot_fwd(key, x, w, cfg):
    return _dispatch(key, x, w, cfg), (x, w)


def _sc_dot_bwd(cfg, res, g):
    x, w = res
    gx = jnp.dot(g, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    gw = jnp.dot(
        x.reshape(-1, x.shape[-1]).T, g.reshape(-1, g.shape[-1]),
        preferred_element_type=jnp.float32).astype(w.dtype)
    return None, gx, gw


sc_dot.defvjp(_sc_dot_fwd, _sc_dot_bwd)
