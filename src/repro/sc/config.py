"""Frozen configuration for the SC multiplication substrate.

One ``ScConfig`` fully determines how ``repro.sc.sc_dot`` computes a
matmul: which registered backend runs it, how many stochastic bits back
each scalar product, how operands quantize onto the paper's DTC grid, and
(for the Pallas backends) the kernel tile shape. The dataclass is frozen
and hashable so it can ride through ``jax.jit`` / ``custom_vjp`` as a
static argument.
"""

from __future__ import annotations

import contextlib
import dataclasses

from repro.core.physics import DeviceProfile


@dataclasses.dataclass(frozen=True)
class ScConfig:
    """Configuration of one SC matmul substrate (frozen, hashable).

    Attributes:
        backend: name of a backend in the ``repro.sc`` registry —
            one of ``exact | moment | bitexact | pallas_moment |
            pallas_bitexact | pallas_fused | array`` out of the box (see
            ``docs/backends.md`` for the trade-offs), or anything
            registered via :func:`repro.sc.register_backend`.
            ``pallas_fused`` ignores the ``block_*`` tiles below and
            takes its tiling from the autotune cache
            (``repro.sc.autotune``; bitwise identical either way).
        nbit: stochastic bits per scalar product — the number of MRAM
            cells each MUL occupies (paper: 2**operand_bits).  Error
            std scales as 1/sqrt(nbit).
        operand_bits: resolution of the LUT/DTC operand grid encoded
            probabilities snap to (paper §III-A: 10).
        quantize: apply that operand-grid quantization (disable for
            backend-numerics studies on un-quantized operands).
        block_m / block_n / block_k: Pallas moment-kernel tile shape
            (clamped per-call to the operand shape).
        interpret: run Pallas kernels in interpreter mode (CPU-safe; this
            container).  Real TPUs flip it off to compile through Mosaic.
    """

    backend: str = "exact"      # name in the repro.sc registry
    nbit: int = 1024            # stochastic bits per scalar product
    operand_bits: int = 10      # quantization of encoded probabilities (paper: 10)
    quantize: bool = True       # apply the LUT/DTC-grid operand quantization
    # Pallas kernel tiling (moment kernel; clamped to the operand shape)
    block_m: int = 128
    block_n: int = 128
    block_k: int = 512
    # interpret=True runs the kernels on CPU (this container); real TPUs
    # flip it off to compile through Mosaic.
    interpret: bool = True
    # Device-realism profile (core/physics.py:DeviceProfile): frozen
    # per-cell variation + bit-error rates.  None or an ideal profile is
    # bit-identical to the paper's idealized math on every backend; a
    # non-ideal profile is realized by the ``array`` backend only (the
    # functional backends model the ideal device by construction).
    device: DeviceProfile | None = None

    def replace(self, **kw) -> "ScConfig":
        """Functional update, e.g. ``cfg.replace(backend="moment")``."""
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Ambient device profile: one knob for call sites that build their own
# ScConfig internally (models/layers.py:dense, and through it the serve
# engines).  ``build_engine(options=ServeOptions(fault_profile=...))``
# enters this scope around each tick so every stochastic matmul the model
# traces picks the profile up without threading it through ModelConfig.
# ---------------------------------------------------------------------------

_PROFILE_STACK: list[DeviceProfile] = []


@contextlib.contextmanager
def use_device_profile(profile: DeviceProfile | None):
    """Scope under which internally-constructed ``ScConfig``s carry
    ``device=profile``.  ``None`` is allowed and means no-op (callers can
    pass an unconditional context)."""
    if profile is None:
        yield
        return
    _PROFILE_STACK.append(profile)
    try:
        yield
    finally:
        _PROFILE_STACK.pop()


def current_device_profile() -> DeviceProfile | None:
    """Innermost :func:`use_device_profile` scope, or None."""
    return _PROFILE_STACK[-1] if _PROFILE_STACK else None
