"""Frozen configuration for the SC multiplication substrate.

One ``ScConfig`` fully determines how ``repro.sc.sc_dot`` computes a
matmul: which registered backend runs it, how many stochastic bits back
each scalar product, how operands quantize onto the paper's DTC grid, and
(for the Pallas backends) the kernel tile shape. The dataclass is frozen
and hashable so it can ride through ``jax.jit`` / ``custom_vjp`` as a
static argument.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ScConfig:
    backend: str = "exact"      # name in the repro.sc registry
    nbit: int = 1024            # stochastic bits per scalar product
    operand_bits: int = 10      # quantization of encoded probabilities (paper: 10)
    quantize: bool = True       # apply the LUT/DTC-grid operand quantization
    # Pallas kernel tiling (moment kernel; clamped to the operand shape)
    block_m: int = 128
    block_n: int = 128
    block_k: int = 512
    # interpret=True runs the kernels on CPU (this container); real TPUs
    # flip it off to compile through Mosaic.
    interpret: bool = True

    def replace(self, **kw) -> "ScConfig":
        return dataclasses.replace(self, **kw)
