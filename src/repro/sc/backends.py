"""The registered SC matmul backends.

Five realizations of the paper's in-memory MUL engine lifted to matmul
shape, all sharing the canonical encoding in :mod:`repro.sc.encoding` and
all reached exclusively through :func:`repro.sc.sc_dot` (a sixth,
``array`` — the array-level architecture simulator — lives in
:mod:`repro.arch.backend` and registers lazily on first use):

* ``exact``           — plain MXU matmul (deterministic reference).
* ``moment``          — CLT moment-matched jnp path: 3 dots + 1 Gaussian
                        draw reproduce the engine's error statistics at
                        O(1) cost per product (see the derivation below).
* ``bitexact``        — paper-faithful Monte-Carlo: every scalar product
                        samples a Binomial(nbit, P_x·P_w) pop-count.
* ``pallas_moment``   — the fused Pallas kernel (kernels/sc_mac.py): the
                        three moment dots ride one pass over the operand
                        tiles with VMEM-resident accumulators.
* ``pallas_bitexact`` — the packed Pallas engine (kernels/sc_mul.py)
                        lifted to matmul shape: one bank of 32-cell words
                        per (i, k, j) scalar product, two-pulse AND +
                        SWAR pop-count, then the signed reduction over K.

Moment derivation (shared by ``moment`` / ``pallas_moment``): by CLT the
signed MAC output is Normal(mean, var) with

    mean = x @ w                          (signed, scaled)
    var  = scale²·[(p_x @ p_w) − (p_x² @ p_w²)] / nbit

First/second moments match ``bitexact`` exactly; the binomial→normal
deviation is < 1 % KS distance at nbit ≥ 256.

Memory classes: ``exact``/``moment``/``pallas_moment`` are O(MN) and run
at model scale; ``bitexact`` is O(M·K·N) and ``pallas_bitexact`` is
O(M·K·N·nbit/8) entropy bytes — validation-scale only, exactly like
running the real cell arrays would be.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sc import encoding
from repro.sc.config import ScConfig
from repro.sc.registry import register_backend


@register_backend("exact")
def exact(key, x, w, cfg: ScConfig):
    del key
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


@register_backend("moment")
def moment(key, x, w, cfg: ScConfig):
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    mean = jnp.dot(sx * px, sw * pw, preferred_element_type=jnp.float32)
    # Var of each product estimate = p(1-p)/nbit with p = p_x·p_w;
    # Σ_k p_k = px@pw, Σ_k p_k² = px²@pw² (p_x,p_w independent across k).
    sum_p = jnp.dot(px, pw, preferred_element_type=jnp.float32)
    sum_p2 = jnp.dot(px * px, pw * pw, preferred_element_type=jnp.float32)
    var = jnp.maximum(sum_p - sum_p2, 0.0) / cfg.nbit
    noise = jax.random.normal(key, mean.shape, dtype=mean.dtype)
    return (mean + noise * jnp.sqrt(var)) * (scx * scw)


@register_backend("bitexact")
def bitexact(key, x, w, cfg: ScConfig):
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    p_prod = px[..., :, None] * pw[None, ...]        # (M, K, N) = P_x·P_w
    sign = sx[..., :, None] * sw[None, ...]
    counts = jax.random.binomial(key, n=float(cfg.nbit), p=p_prod)
    est = counts.astype(jnp.float32) / cfg.nbit      # ≈ P_x·P_w per product
    return jnp.sum(sign * est, axis=-2) * (scx * scw)


@register_backend("pallas_moment")
def pallas_moment(key, x, w, cfg: ScConfig):
    from repro.kernels import sc_mac as sc_mac_kernel
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    xs = encoding.pad_to(sx * px, max(1, min(cfg.block_m, x.shape[0])), 0)
    xs = encoding.pad_to(xs, min(cfg.block_k, x.shape[1]), 1)
    ws = encoding.pad_to(sw * pw, min(cfg.block_k, x.shape[1]), 0)
    ws = encoding.pad_to(ws, max(1, min(cfg.block_n, w.shape[1])), 1)
    noise = jax.random.normal(key, (xs.shape[0], ws.shape[1]), jnp.float32)
    out = sc_mac_kernel.sc_mac_fused(
        xs, ws, noise, nbit=cfg.nbit, block_m=cfg.block_m,
        block_n=cfg.block_n, block_k=cfg.block_k, interpret=cfg.interpret)
    return out[: x.shape[0], : w.shape[1]] * (scx * scw)


# rows-per-tile of the packed MUL kernel; small because each row already
# carries NSLICES·(nbit/32) uniform words
_MUL_BLOCK_M = 8


@register_backend("pallas_bitexact")
def pallas_bitexact(key, x, w, cfg: ScConfig):
    from repro.kernels import sc_mul as sc_mul_kernel
    assert cfg.nbit % sc_mul_kernel.LANE_BITS == 0, \
        "pallas_bitexact needs nbit to be a multiple of 32 (packed words)"
    nwords = cfg.nbit // sc_mul_kernel.LANE_BITS
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    m, k = x.shape
    n = w.shape[1]
    # one packed MUL (its own bank of nbit cells) per (i, k, j) product
    px_flat = jnp.broadcast_to(px[:, :, None], (m, k, n)).reshape(-1)
    pw_flat = jnp.broadcast_to(pw[None, :, :], (m, k, n)).reshape(-1)
    pxf = encoding.pad_to(encoding.to_fx16(px_flat), _MUL_BLOCK_M, 0)
    pwf = encoding.pad_to(encoding.to_fx16(pw_flat), _MUL_BLOCK_M, 0)
    kx, ky = jax.random.split(key)
    shape = (pxf.shape[0], sc_mul_kernel.NSLICES, nwords)
    rx = jax.random.bits(kx, shape, jnp.uint32)
    ry = jax.random.bits(ky, shape, jnp.uint32)
    counts = sc_mul_kernel.sc_mul_popcount(
        pxf, pwf, rx, ry, block_m=_MUL_BLOCK_M, interpret=cfg.interpret)
    est = counts[: m * k * n].astype(jnp.float32).reshape(m, k, n) / cfg.nbit
    sign = sx[:, :, None] * sw[None, :, :]
    return jnp.sum(sign * est, axis=1) * (scx * scw)
