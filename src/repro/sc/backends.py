"""The registered SC matmul backends.

Six realizations of the paper's in-memory MUL engine lifted to matmul
shape, all sharing the canonical encoding in :mod:`repro.sc.encoding` and
all reached exclusively through :func:`repro.sc.sc_dot` (a seventh,
``array`` — the array-level architecture simulator — lives in
:mod:`repro.arch.backend` and registers lazily on first use):

* ``exact``           — plain MXU matmul (deterministic reference).
* ``moment``          — CLT moment-matched jnp path: 3 dots + 1 Gaussian
                        draw reproduce the engine's error statistics at
                        O(1) cost per product (see the derivation below).
* ``bitexact``        — paper-faithful Monte-Carlo: every scalar product
                        samples a Binomial(nbit, P_x·P_w) pop-count.
* ``pallas_moment``   — the fused moment Pallas kernel (kernels/sc_mac.py):
                        the three moment dots ride one pass over the
                        operand tiles with VMEM-resident accumulators.
* ``pallas_bitexact`` — the packed Pallas engine (kernels/sc_mul.py)
                        lifted to matmul shape: one bank of 32-cell words
                        per (i, k, j) scalar product, two-pulse AND +
                        SWAR pop-count, then the signed reduction over K.
* ``pallas_fused``    — the fully fused engine (kernels/sc_fused.py):
                        encoding, counter-based RNG, thresholding and
                        pop-count accumulation in ONE autotuned kernel.
                        Draws the SAME counter-based stream as
                        ``pallas_bitexact`` (``sc/ctr_rng.py``), so the
                        two are bit-identical per key — this is the
                        default fast path ``models/layers.py:dense``
                        upgrades ``pallas_bitexact`` to.

Moment derivation (shared by ``moment`` / ``pallas_moment``): by CLT the
signed MAC output is Normal(mean, var) with

    mean = x @ w                          (signed, scaled)
    var  = scale²·[(p_x @ p_w) − (p_x² @ p_w²)] / nbit

First/second moments match ``bitexact`` exactly; the binomial→normal
deviation is < 1 % KS distance at nbit ≥ 256.

Memory classes: ``exact``/``moment``/``pallas_moment`` are O(MN) and run
at model scale; ``bitexact`` is O(M·K·N) and ``pallas_bitexact`` is
O(M·K·N·nbit/8) entropy bytes — validation-scale only, exactly like
running the real cell arrays would be.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sc import autotune, ctr_rng, encoding
from repro.sc.config import ScConfig
from repro.sc.registry import register_backend, register_rows_backend


@register_backend("exact")
def exact(key, x, w, cfg: ScConfig):
    del key
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


@register_backend("moment")
def moment(key, x, w, cfg: ScConfig):
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    mean = jnp.dot(sx * px, sw * pw, preferred_element_type=jnp.float32)
    # Var of each product estimate = p(1-p)/nbit with p = p_x·p_w;
    # Σ_k p_k = px@pw, Σ_k p_k² = px²@pw² (p_x,p_w independent across k).
    sum_p = jnp.dot(px, pw, preferred_element_type=jnp.float32)
    sum_p2 = jnp.dot(px * px, pw * pw, preferred_element_type=jnp.float32)
    var = jnp.maximum(sum_p - sum_p2, 0.0) / cfg.nbit
    noise = jax.random.normal(key, mean.shape, dtype=mean.dtype)
    return (mean + noise * jnp.sqrt(var)) * (scx * scw)


@register_backend("bitexact")
def bitexact(key, x, w, cfg: ScConfig):
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    p_prod = px[..., :, None] * pw[None, ...]        # (M, K, N) = P_x·P_w
    sign = sx[..., :, None] * sw[None, ...]
    counts = jax.random.binomial(key, n=float(cfg.nbit), p=p_prod)
    est = counts.astype(jnp.float32) / cfg.nbit      # ≈ P_x·P_w per product
    return jnp.sum(sign * est, axis=-2) * (scx * scw)


@register_backend("pallas_moment")
def pallas_moment(key, x, w, cfg: ScConfig):
    from repro.kernels import sc_mac as sc_mac_kernel
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    xs = encoding.pad_to(sx * px, max(1, min(cfg.block_m, x.shape[0])), 0)
    xs = encoding.pad_to(xs, min(cfg.block_k, x.shape[1]), 1)
    ws = encoding.pad_to(sw * pw, min(cfg.block_k, x.shape[1]), 0)
    ws = encoding.pad_to(ws, max(1, min(cfg.block_n, w.shape[1])), 1)
    noise = jax.random.normal(key, (xs.shape[0], ws.shape[1]), jnp.float32)
    out = sc_mac_kernel.sc_mac_fused(
        xs, ws, noise, nbit=cfg.nbit, block_m=cfg.block_m,
        block_n=cfg.block_n, block_k=cfg.block_k, interpret=cfg.interpret)
    return out[: x.shape[0], : w.shape[1]] * (scx * scw)


# rows-per-tile of the packed MUL kernel; small because each row already
# carries NSLICES·(nbit/32) uniform words
_MUL_BLOCK_M = 8


@register_backend("pallas_bitexact")
def pallas_bitexact(key, x, w, cfg: ScConfig):
    from repro.kernels import sc_mul as sc_mul_kernel
    assert cfg.nbit % sc_mul_kernel.LANE_BITS == 0, \
        "pallas_bitexact needs nbit to be a multiple of 32 (packed words)"
    nwords = cfg.nbit // sc_mul_kernel.LANE_BITS
    sx, px, scx = encoding.encode(x, cfg)
    sw, pw, scw = encoding.encode(w, cfg)
    m, k = x.shape
    n = w.shape[1]
    # one packed MUL (its own bank of nbit cells) per (i, k, j) product
    px_flat = jnp.broadcast_to(px[:, :, None], (m, k, n)).reshape(-1)
    pw_flat = jnp.broadcast_to(pw[None, :, :], (m, k, n)).reshape(-1)
    pxf = encoding.pad_to(encoding.to_fx16(px_flat), _MUL_BLOCK_M, 0)
    pwf = encoding.pad_to(encoding.to_fx16(pw_flat), _MUL_BLOCK_M, 0)
    # entropy from the PINNED counter-based stream (sc/ctr_rng.py): the
    # fused engine regenerates exactly these words in-kernel, which is
    # what makes pallas_fused a bit-identical drop-in for this backend.
    kx, ky = jax.random.split(key)
    rx = ctr_rng.operand_stream(ctr_rng.raw_key(kx), pxf.shape[0], nwords)
    ry = ctr_rng.operand_stream(ctr_rng.raw_key(ky), pxf.shape[0], nwords)
    counts = sc_mul_kernel.sc_mul_popcount(
        pxf, pwf, rx, ry, block_m=_MUL_BLOCK_M, interpret=cfg.interpret)
    counts3 = counts[: m * k * n].reshape(m, k, n)
    # exact signed integer reduction over K: associative, so it matches
    # the fused kernel's per-tile accumulation bit-for-bit
    sign_i = sx.astype(jnp.int32)[:, :, None] * sw.astype(jnp.int32)[None]
    total = jnp.sum(sign_i * counts3, axis=1)
    return total.astype(jnp.float32) / cfg.nbit * (scx * scw)


def _fused_engine(keys4, x, w, cfg: ScConfig, scx, scw, *, row_keys):
    """The ONE scale/pad/launch/rescale recipe behind both fused entry
    points.  Sharing it is what keeps the documented bit-identity
    contracts (fused == packed; rows mode == per-row single calls)
    honest: per-call and per-row modes differ ONLY in the key rows, the
    encoding scale shape, and the kernel's ``row_keys`` flag.

    keys4: (M, 4) raw per-row key words [kx0, kx1, ky0, ky1];
    scx: () in per-call mode, (M, 1) in rows mode (``encode``'s max-abs
    formula either way).
    """
    from repro.kernels import sc_fused as sc_fused_kernel
    assert cfg.nbit % sc_fused_kernel.LANE_BITS == 0, \
        "pallas_fused needs nbit to be a multiple of 32 (packed words)"
    m, k = x.shape
    n = w.shape[1]
    tile = autotune.get_tile(m, k, n, cfg.nbit)
    keys4 = encoding.pad_to(keys4, tile.block_m, 0)
    spx = encoding.pad_to(
        encoding.pad_to(x / scx, tile.block_m, 0), tile.block_k, 1)
    spw = encoding.pad_to(
        encoding.pad_to(w / scw, tile.block_k, 0), tile.block_n, 1)
    total = sc_fused_kernel.sc_fused_popcount(
        keys4, spx, spw, k_orig=k, n_orig=n, nbit=cfg.nbit,
        levels=1 << cfg.operand_bits, quantize=cfg.quantize,
        row_keys=row_keys, interpret=cfg.interpret, **tile.kwargs())
    return total[:m, :n].astype(jnp.float32) / cfg.nbit * (scx * scw)


@register_backend("pallas_fused")
def pallas_fused(key, x, w, cfg: ScConfig):
    """One-kernel fast path: encode + draw + threshold + pop-count fused.

    Bit-identical to ``pallas_bitexact`` under the same key (shared
    counter-based stream, exact integer accumulation) while never
    materializing a bitstream outside VMEM.  Tile sizes come from the
    autotuner cache (heuristic on miss) and cannot affect the bits.
    """
    scx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)   # encoding.encode scale
    scw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    kx, ky = jax.random.split(key)
    keys4 = jnp.broadcast_to(
        jnp.concatenate([ctr_rng.raw_key(kx), ctr_rng.raw_key(ky)])[None],
        (x.shape[0], 4))
    return _fused_engine(keys4, x, w, cfg, scx, scw, row_keys=False)


@register_rows_backend("pallas_fused")
def pallas_fused_rows(keys, x, w, cfg: ScConfig):
    """Per-row-key fused path (the serve engine's vmap replacement).

    keys: (M, 2) raw keys — row i's bits AND encoding scale depend on
    ``keys[i]`` and ``x[i]`` alone, and equal the single-row call
    ``pallas_fused(keys[i], x[i:i+1], w, cfg)`` bit-for-bit (the kernel
    drops the row term from the product index in ``row_keys`` mode).
    """
    scx = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True), 1e-30)
    scw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    split = jax.vmap(jax.random.split)(ctr_rng.raw_key(keys))   # (M, 2, 2)
    keys4 = jnp.concatenate([split[:, 0], split[:, 1]], axis=-1).astype(
        jnp.uint32)
    return _fused_engine(keys4, x, w, cfg, scx, scw, row_keys=True)
