"""Mesh-sharded SC execution: ``sc_dot`` split across device-mesh axes.

The paper's throughput comes from memory-level parallelism — every MRAM
row is an independent SC engine, and arrays scale by running many engines
at once.  This module is the software analogue one level up: a single
``sc_dot`` contraction is split across the axes of a JAX device mesh with
``shard_map``, so every mesh slice runs its own SC engines on its own
operand shard:

* the flattened row dimension M of ``x`` shards over the *batch* axes
  (``("pod", "data")`` by default — pure data parallelism, no collective
  needed on the forward pass);
* the contraction dimension K shards over the *contract* axes
  (``("model",)`` by default) — each shard pop-counts its own slice of the
  K products and the partial signed accumulations merge with a
  ``psum``, exactly as per-subarray POPCOUNTs merge through the adder
  tree inside one chip (§IV).

RNG semantics: every shard folds the caller's key with its index along
each axis that actually splits the operands (``fold_in`` per axis), so
shards draw independent stochastic bits while the whole computation stays
a deterministic function of (key, mesh, rules).  Axes of size one — and
axes that do not divide their dimension — are dropped by
:func:`resolve_rules` and do NOT perturb the key, so a degenerate 1×1
mesh (or rules naming no live axis) reproduces single-device ``sc_dot``
bit-for-bit with the same key.

Gradients: the straight-through VJP lives at the ``sc_dot`` dispatch
boundary and ``shard_map`` differentiates through it — the ``psum``
transposes to a broadcast, each shard computes the exact-product jacobian
for its operand block, and the assembled gradient equals the unsharded
exact-matmul gradient.

The model stack routes here automatically: ``models/layers.py:dense``
consults :func:`active_mesh` and calls :func:`sc_dot_sharded` whenever a
mesh scope (:func:`use_mesh`) is active, so training and serving scale
across devices with no caller changes.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math

import jax

from repro.sc.config import ScConfig
from repro.sc.registry import sc_dot


@dataclasses.dataclass(frozen=True)
class ScShardRules:
    """Which mesh axes shard an ``sc_dot``.

    ``batch`` axes split the flattened row dimension M of ``x`` (pure data
    parallelism); ``contract`` axes split the contraction dimension K (the
    partial accumulations merge with a ``psum``).  Axis names that are
    absent from the mesh, have size one, or do not divide their dimension
    are dropped per-call by :func:`resolve_rules`.
    """

    batch: tuple = ("pod", "data")
    contract: tuple = ("model",)


DEFAULT_RULES = ScShardRules()


def resolve_rules(mesh, m: int, k: int,
                  rules: ScShardRules | None = None) -> ScShardRules:
    """Concretize ``rules`` against ``mesh`` and the call shape.

    Keeps only axes that exist in the mesh with size > 1 and whose product
    divides the dimension they shard (M for batch axes, K for contract
    axes).  Indivisible dims therefore fall back to replication — the same
    per-tensor degradation the parameter sharding rules use.
    """
    rules = rules if rules is not None else DEFAULT_RULES
    sizes = dict(mesh.shape)

    def live(axes, dim):
        kept = []
        span = 1
        for ax in axes:
            sz = sizes.get(ax, 1)
            if sz > 1 and dim % (span * sz) == 0:
                kept.append(ax)
                span *= sz
        return tuple(kept)

    return ScShardRules(batch=live(tuple(rules.batch), m),
                        contract=live(tuple(rules.contract), k))


def _axis_span(mesh, axes) -> int:
    sizes = dict(mesh.shape)
    return math.prod(sizes[a] for a in axes) if axes else 1


def shard_counts(mesh, m: int, k: int,
                 rules: ScShardRules | None = None) -> tuple:
    """(batch shards, contract shards) a call would actually split into."""
    r = resolve_rules(mesh, m, k, rules)
    return _axis_span(mesh, r.batch), _axis_span(mesh, r.contract)


def sc_dot_sharded(key, x, w, cfg: ScConfig = ScConfig(), *, mesh,
                   rules: ScShardRules | None = None):
    """``x @ w`` through the SC substrate, sharded over ``mesh``.

    x: (..., K); w: (K, N); returns (..., N) exactly like ``sc_dot``.
    Leading dims of ``x`` flatten to M, which shards over ``rules.batch``;
    K shards over ``rules.contract`` with the partial signed pop-count
    accumulations merged by a ``psum`` (the straight-through VJP rides
    through it).  Every shard folds ``key`` with its mesh indices, so
    shards draw independent bits; when no axis survives
    :func:`resolve_rules` this is exactly ``sc_dot(key, x, w, cfg)`` —
    same key, same bits.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map_compat

    lead = x.shape[:-1]
    k_dim = x.shape[-1]
    m = math.prod(lead) if lead else 1
    r = resolve_rules(mesh, m, k_dim, rules)
    if not r.batch and not r.contract:
        return sc_dot(key, x, w, cfg)

    n_shards = _axis_span(mesh, r.batch) * _axis_span(mesh, r.contract)
    x2 = x.reshape(m, k_dim)
    batch_spec = r.batch if r.batch else None
    contract_spec = r.contract if r.contract else None

    def local(key, xs, ws):
        for ax in r.batch + r.contract:
            key = jax.random.fold_in(key, jax.lax.axis_index(ax))
        y = sc_dot(key, xs, ws, cfg)
        if r.contract:
            y = jax.lax.psum(y, r.contract)
        return y

    mapped = shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(), P(batch_spec, contract_spec), P(contract_spec, None)),
        out_specs=P(batch_spec, None),
        check_rep=False)
    with shard_scope(n_shards):
        y = mapped(key, x2, w)
    return y.reshape(*lead, w.shape[-1])


# ---------------------------------------------------------------------------
# Ambient mesh scope — what makes dense() route here with no caller changes
# ---------------------------------------------------------------------------

_MESH_STACK: list = []


@contextlib.contextmanager
def use_mesh(mesh, rules: ScShardRules | None = None):
    """Scope within which the model stack shards every SC matmul.

    While active, ``models.layers.dense`` routes stochastic matmuls
    through :func:`sc_dot_sharded` on this mesh.  The scope must surround
    the *tracing* of the jitted computation (the first call), because
    that is when ``dense`` consults it.
    """
    _MESH_STACK.append((mesh, rules))
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def active_mesh():
    """(mesh, rules) of the innermost :func:`use_mesh`, or ``None``."""
    return _MESH_STACK[-1] if _MESH_STACK else None


# ---------------------------------------------------------------------------
# Shard multiplicity scope — read by the `array` backend's trace records
# ---------------------------------------------------------------------------

_SHARD_COUNT: list[int] = [1]


@contextlib.contextmanager
def shard_scope(n: int):
    """Mark that sc_dot dispatches traced inside run on ``n`` concurrent
    mesh shards.  ``shard_map`` traces its body once for all shards, so
    the ``array`` backend stamps each CallRecord with this multiplicity
    and the accountant merges shard reports as *concurrent* banks
    (makespan = max, energy/products add) rather than serial calls."""
    _SHARD_COUNT.append(n)
    try:
        yield
    finally:
        _SHARD_COUNT.pop()


def current_shard_count() -> int:
    return _SHARD_COUNT[-1]
