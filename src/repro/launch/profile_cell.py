import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-cell HLO profile: where the bytes / FLOPs / collective traffic live.

The §Perf hypothesis loop's "profiler" on a CPU-only container: lowers one
(arch x shape) cell on the production mesh and prints the per-device byte
breakdown by opcode, the collective breakdown by (kind, operand size), and
the while-loop trip counts the analyzer resolved.

    PYTHONPATH=src python -m repro.launch.profile_cell \
        --arch llama4-maverick-400b-a17b --shape train_4k [--multipod]

``--sc-trace`` additionally prices the cell's dense() workload on the
SOT-MRAM array simulator (repro.arch): per-site pulse-schedule cycles and
energy at the production shape, independent of the XLA lowering.
"""

import argparse           # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.dryrun import lower_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def profile(arch: str, shape: str, multi_pod: bool = False, top: int = 18):
    mesh = make_production_mesh(multi_pod=multi_pod)
    result, hc = lower_cell(arch, shape, mesh, compile_=True,
                            return_cost=True)
    rf = result["roofline"]
    print(f"\n== {arch} x {shape} x {result['mesh']} ==")
    print(f"bound={rf['bound']}  compute_s={rf['compute_s']:.3f}  "
          f"memory_s={rf['memory_s']:.3f}  "
          f"collective_s={rf['collective_s']:.3f}  "
          f"useful={rf['useful_fraction']:.3f}")
    print(f"mem/dev={result.get('memory', {}).get('total_per_device_gb')}GB  "
          f"unresolved_loops={hc.unresolved_loops}")

    total_b = sum(hc.bytes_by_opcode.values()) or 1
    print(f"\n-- bytes by opcode (per device, total "
          f"{total_b / 1e12:.2f} TB) --")
    for op, b in sorted(hc.bytes_by_opcode.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {op:<24s} {b / 1e12:9.3f} TB  {b / total_b * 100:5.1f}%")

    total_c = sum(hc.coll_by_shape.values()) or 1
    print(f"\n-- collectives by (kind, operand bytes) (per device, total "
          f"{total_c / 1e9:.2f} GB) --")
    for sk, b in sorted(hc.coll_by_shape.items(), key=lambda kv: -kv[1])[:top]:
        kind, sz = sk.rsplit(":", 1)
        print(f"  {kind:<20s} op={int(sz) / 1e6:10.1f} MB   total "
              f"{b / 1e9:9.2f} GB  {b / total_c * 100:5.1f}%")
    return result, hc


def sc_trace(arch: str, shape: str, nbit: int = 1024, top: int = 12):
    """Price the cell's dense() workload on the SOT-MRAM array simulator.

    Static analysis (repro.arch.workload): no lowering, no numerics — the
    pulse-schedule compiler runs per matmul site with explicit layer
    multiplicity, so production shapes price in milliseconds.
    """
    from repro import arch as arch_sim
    cfg = get_config(arch)
    sh = SHAPES[shape]
    tokens = sh.global_batch * (1 if sh.kind == "decode" else sh.seq_len)
    sites = arch_sim.dense_workload(cfg, tokens)
    per_site, total = arch_sim.price_workload(sites, nbit)
    print(f"\n-- SOT-MRAM array trace: {arch} x {shape} "
          f"({tokens} tokens, nbit={nbit}, spec={arch_sim.DEFAULT_SPEC}) --")
    per_site.sort(key=lambda sr: -sr[1].cycles)
    for s, r in per_site[:top]:
        print(f"  {s.label:<14s} {s.m}x{s.k}x{s.n} x{s.count:<3d} "
              f"{r.cycles:>14,d} cyc  {r.energy_pj / 1e6:10.2f} µJ  "
              f"util={r.subarray_util:.2f}")
    print(f"  {'TOTAL':<14s} {total.products:,} MULs  "
          f"{total.cycles:>14,d} cyc  {total.energy_pj / 1e6:10.2f} µJ  "
          f"util={total.subarray_util:.2f}")
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--top", type=int, default=18)
    ap.add_argument("--sc-trace", action="store_true",
                    help="also price the dense() workload on the SOT-MRAM "
                         "array simulator (repro.arch)")
    ap.add_argument("--sc-nbit", type=int, default=1024)
    args = ap.parse_args()
    profile(args.arch, args.shape, args.multipod, args.top)
    if args.sc_trace:
        sc_trace(args.arch, args.shape, args.sc_nbit, args.top)


if __name__ == "__main__":
    main()
