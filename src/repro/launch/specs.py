"""ShapeDtypeStruct stand-ins + NamedSharding trees for every lowered step.

``input_specs(cfg, shape)`` produces the exact abstract inputs each
(architecture × input-shape) cell lowers with — weak-type-correct,
shardable, zero allocation. The companion ``*_shardings`` helpers derive
NamedSharding trees from the same logical rules the model uses, so the
dry-run, trainer, and server can never disagree on layout.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm, params as params_lib
from repro.sharding import rules as sharding_rules


def abstract_mesh(axis_sizes, axis_names):
    """Version-compat AbstractMesh constructor.

    jax <= 0.4.x spells it ``AbstractMesh((("data", 16), ("model", 16)))``
    (a tuple of (name, size) pairs); jax >= 0.5 spells it
    ``AbstractMesh((16, 16), ("data", "model"))``. Tests and tooling build
    production-scale sharding trees through this shim so either jax works.
    """
    import inspect

    from jax.sharding import AbstractMesh

    params = inspect.signature(AbstractMesh.__init__).parameters
    if "shape_tuple" in params:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def _sizes(mesh):
    # mesh.shape works for both concrete Mesh and AbstractMesh (tests build
    # the production sharding trees without 512 devices).
    return dict(mesh.shape)


def _dp_axes(mesh, n: int):
    """Data-parallel mesh axes usable for a batch of size n (or None)."""
    sizes = _sizes(mesh)
    axes = tuple(a for a in ("pod", "data") if a in sizes)
    if not axes:
        return None
    if n > 0 and n % math.prod(sizes[a] for a in axes) == 0:
        return axes
    if "data" in sizes and n > 0 and n % sizes["data"] == 0:
        return ("data",)
    return None


def _div(n: int, mesh, axis: str):
    sizes = _sizes(mesh)
    return axis if axis in sizes and n % sizes[axis] == 0 else None


# ---------------------------------------------------------------------------
# Abstract inputs per shape kind
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract step inputs for one cell. Returns a dict:

    train   -> {batch: {inputs, labels}}
    prefill -> {inputs}
    decode  -> {cache, tokens, lengths}
    """
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        if cfg.frontend == "embeddings":
            inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.act_dtype)
        else:
            inputs = tok
        return {"batch": {"inputs": inputs, "labels": tok}}
    if shape.kind == "prefill":
        if cfg.frontend == "embeddings":
            return {"inputs": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   cfg.act_dtype)}
        return {"inputs": tok}
    # decode: one new token against a cache of seq_len
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def param_shardings(cfg: ModelConfig, mesh):
    specs = lm.lm_param_specs(cfg)
    return params_lib.tree_map_specs(
        lambda ps: NamedSharding(mesh, ps),
        params_lib.partition_specs(specs,
                                   sharding_rules.logical_rules(mesh)))


def opt_shardings(cfg: ModelConfig, mesh, param_sh):
    """AdamW m/v mirror the parameter shardings (f32/bf16 state)."""
    return {"m": param_sh, "v": param_sh,
            "step": NamedSharding(mesh, P())}


def batch_shardings(cfg: ModelConfig, mesh, batch: int):
    dp = _dp_axes(mesh, batch)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))  # noqa: E731
    if cfg.frontend == "embeddings":
        return {"inputs": ns(dp, None, None), "labels": ns(dp, None)}
    return {"inputs": ns(dp, None), "labels": ns(dp, None)}


def cache_shardings(cfg: ModelConfig, mesh, batch: int, max_len: int):
    """Mirror lm.init_cache structure with layout-adaptive specs:

    * batch shards over (pod, data) when divisible;
    * the KV-cache SEQUENCE shards over `model` — decode reads the whole
      cache every step, so sharding its seq axis divides both the HBM
      footprint and the cache-read bandwidth by the TP degree (kv-head TP
      cannot: kv_heads < model axis on most assigned archs). When batch
      leaves (pod, data) unused (long_500k, batch=1) the sequence shards
      over EVERY available axis — full 256/512-way cache distribution;
    * ssm heads / d_inner shard over model when divisible.
    """
    sizes = _sizes(mesh)
    dp = _dp_axes(mesh, batch)
    seq_axes = []
    if dp is None:
        seq_axes += [a for a in ("pod", "data") if a in sizes]
    if "model" in sizes:
        seq_axes.append("model")
    total = math.prod(sizes[a] for a in seq_axes) if seq_axes else 1
    seq_ax = tuple(seq_axes) if seq_axes and max_len % total == 0 else None
    if seq_ax is not None and len(seq_ax) == 1:
        seq_ax = seq_ax[0]
    kv_ax = None if seq_ax else _div(cfg.n_kv_heads, mesh, "model")
    ns = lambda *spec: NamedSharding(mesh, P(*spec))  # noqa: E731

    if cfg.family in ("ssm", "hybrid"):
        inner_ax = _div(cfg.d_inner, mesh, "model")
        state_ax = _div(cfg.ssm_heads, mesh, "model")
        out = {"ssm": {
            "conv_x": ns(None, dp, None, inner_ax),
            "conv_B": ns(None, dp, None, None),
            "conv_C": ns(None, dp, None, None),
            "state": ns(None, dp, state_ax, None, None),
        }}
        if cfg.family == "hybrid":
            out["shared_k"] = ns(None, dp, seq_ax, kv_ax, None)
            out["shared_v"] = ns(None, dp, seq_ax, kv_ax, None)
        return out
    return {"k": ns(None, dp, seq_ax, kv_ax, None),
            "v": ns(None, dp, seq_ax, kv_ax, None)}


def logits_sharding(cfg: ModelConfig, mesh, batch: int, with_seq: bool):
    dp = _dp_axes(mesh, batch)
    v_ax = _div(cfg.vocab, mesh, "model")
    if with_seq:
        return NamedSharding(mesh, P(dp, None, v_ax))
    return NamedSharding(mesh, P(dp, v_ax))


def replicated(mesh):
    return NamedSharding(mesh, P())
