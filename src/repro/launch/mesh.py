"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its first
jax import, and everything else must see the real single CPU device.
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: (data=16, model=16) = 256 chips; multi-pod adds a
    leading pure-DP pod axis (2 pods = 512 chips over DCI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(mesh.shape)   # works for Mesh and AbstractMesh


def data_parallel_size(mesh) -> int:
    sizes = mesh_axis_sizes(mesh)
    return math.prod(sizes.get(a, 1) for a in ("pod", "data"))
