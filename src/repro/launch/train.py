"""Training launcher.

Runs real training (CPU-scale smoke configs, or the paper-sc config whose
matmuls route through the SC engine) under the fault-tolerance supervisor,
with checkpointing and deterministic data. On a TPU cluster the same
entrypoint runs the full configs — the mesh builder and sharding trees are
identical; only device count changes.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 30 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, sc
from repro.sharding import sc_shard_rules
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLMData, make_batch
from repro.data.pipeline import make_embedding_batch
from repro.ft import FaultInjector, Supervisor
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.train import TrainConfig, make_train_step, train_state_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--sc-backend", default=None,
                    help="SC substrate backend (any name registered in "
                         "repro.sc: exact | moment | bitexact | "
                         "pallas_moment | pallas_bitexact)")
    ap.add_argument("--sc-mode", default=None,
                    choices=[None, "exact", "moment", "bitexact"],
                    help="DEPRECATED alias for --sc-backend")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    cfg = cfg.replace(param_dtype=jnp.float32, act_dtype=jnp.float32)
    if args.sc_backend or args.sc_mode:
        cfg = cfg.replace(sc_backend=args.sc_backend or args.sc_mode)

    mesh = make_local_mesh()
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 10, 1)),
        microbatches=args.microbatches, seed=args.seed)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed)

    key = jax.random.PRNGKey(args.seed)
    state = train_state_init(key, cfg, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh), donate_argnums=(0,))

    # Mesh-sharded SC substrate: while this scope is active, every dense()
    # in the traced step shards its stochastic matmul over the mesh
    # (sc_dot_sharded; no-op on a single device — size-1 axes drop out).
    if cfg.sc_backend != "exact" and len(jax.devices()) > 1:
        substrate_scope = lambda: sc.use_mesh(mesh, sc_shard_rules(mesh))
    else:
        substrate_scope = contextlib.nullcontext

    def batch_fn(step):
        if cfg.frontend == "embeddings":
            return make_embedding_batch(data, cfg.d_model, step)
        return make_batch(data, step)

    start_step = 0
    if args.resume and checkpoint.latest_step(args.ckpt_dir) is not None:
        state, extra, _ = checkpoint.restore(args.ckpt_dir, state)
        start_step = extra["data_step"]
        print(f"resumed from step {start_step}")

    injector = (FaultInjector(fail_at_steps=(args.inject_failure_at,))
                if args.inject_failure_at is not None else None)
    sup = Supervisor(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     injector=injector)

    t0 = time.time()
    losses = []

    def logged_step(state, batch):
        with substrate_scope():
            state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        step = len(losses) + start_step
        if step % 5 == 0 or step == 1:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['grad_norm']:.3f} lr {metrics['lr']:.2e} "
                  f"({(time.time()-t0)/max(len(losses),1):.2f}s/step)",
                  flush=True)
        return state, metrics

    state, history = sup.run(state, logged_step, args.steps,
                             make_batch=batch_fn, start_step=start_step)
    print(f"done: first loss {history['loss'][0]:.4f} -> "
          f"last {history['loss'][-1]:.4f}; "
          f"recoveries={len(history['recoveries'])}")
    return state, history


if __name__ == "__main__":
    main()
