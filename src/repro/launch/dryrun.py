import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes, and record memory / cost / collective
analyses for EXPERIMENTS.md §Dry-run and §Roofline.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first backend init, and the dry-run needs 512 virtual
host devices for the (2, 16, 16) multi-pod mesh. Nothing else in the repo
sets this flag — smoke tests and benchmarks see the real single CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from functools import partial  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, supported_shapes  # noqa: E402
from repro.launch import hlo_analysis, specs as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm, params as params_lib  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.optim.adamw import adamw_init  # noqa: E402
from repro.train import TrainConfig, make_train_step  # noqa: E402
from repro.train.step import make_constrain, make_param_constrain  # noqa: E402

DRYRUN_ARCHS = [a for a in ARCH_IDS if a != "paper-sc"]


def _abstract_state(cfg, tcfg, mesh):
    """Abstract train state + matching shardings."""
    p_specs = lm.lm_param_specs(cfg)
    params = params_lib.abstract_params(p_specs, cfg.param_dtype)
    opt = jax.eval_shape(lambda p: adamw_init(p, tcfg.optimizer), params)
    p_sh = S.param_shardings(cfg, mesh)
    state_sh = {"params": p_sh, "opt": S.opt_shardings(cfg, mesh, p_sh)}
    return {"params": params, "opt": opt}, state_sh


def lower_cell(arch: str, shape_name: str, mesh, *, compile_: bool = True,
               return_cost: bool = False):
    """Lower (and optionally compile) one cell. Returns a result dict
    (and the HloCost profile when ``return_cost``)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = mesh.devices.size
    t0 = time.time()

    # Optimizer state dtype: bf16 for the 400B config (f32 Adam does not fit
    # 16 GB/chip at 256 chips — see EXPERIMENTS §Dry-run), f32 elsewhere.
    state_dtype = "bf16" if "400b" in arch else "f32"
    tcfg = TrainConfig(optimizer=AdamWConfig(state_dtype=state_dtype))

    with mesh:
        if shape.kind == "train":
            state, state_sh = _abstract_state(cfg, tcfg, mesh)
            batch = S.input_specs(cfg, shape)["batch"]
            batch_sh = S.batch_shardings(cfg, mesh, shape.global_batch)
            step = make_train_step(cfg, tcfg, mesh)
            metrics_sh = {"loss": S.replicated(mesh),
                          "grad_norm": S.replicated(mesh),
                          "lr": S.replicated(mesh)}
            jitted = jax.jit(step,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, metrics_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            params = params_lib.abstract_params(lm.lm_param_specs(cfg),
                                                cfg.param_dtype)
            p_sh = S.param_shardings(cfg, mesh)
            inp = S.input_specs(cfg, shape)["inputs"]
            inp_sh = S.batch_shardings(cfg, mesh, shape.global_batch)["inputs"]
            cache_sh = S.cache_shardings(cfg, mesh, shape.global_batch,
                                         shape.seq_len)
            out_sh = (S.logits_sharding(cfg, mesh, shape.global_batch, False),
                      cache_sh, S.replicated(mesh))
            fn = partial(lm.prefill, cfg=cfg, max_len=shape.seq_len,
                         constrain=make_constrain(mesh),
                         constrain_params=make_param_constrain(mesh, cfg))
            jitted = jax.jit(lambda p, x: fn(p, x),
                             in_shardings=(p_sh, inp_sh),
                             out_shardings=out_sh)
            lowered = jitted.lower(params, inp)
        else:  # decode
            params = params_lib.abstract_params(lm.lm_param_specs(cfg),
                                                cfg.param_dtype)
            p_sh = S.param_shardings(cfg, mesh)
            ins = S.input_specs(cfg, shape)
            cache_sh = S.cache_shardings(cfg, mesh, shape.global_batch,
                                         shape.seq_len)
            dp = S._dp_axes(mesh, shape.global_batch)
            vec_sh = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(dp))
            out_sh = (S.logits_sharding(cfg, mesh, shape.global_batch, False),
                      cache_sh)
            fn = partial(lm.decode_step, cfg=cfg,
                         constrain=make_constrain(mesh),
                         constrain_params=make_param_constrain(mesh, cfg))
            jitted = jax.jit(lambda p, c, t, l: fn(p, c, t, l),
                             in_shardings=(p_sh, cache_sh, vec_sh, vec_sh),
                             out_shardings=out_sh,
                             donate_argnums=(1,))
            lowered = jitted.lower(params, ins["cache"], ins["tokens"],
                                   ins["lengths"])

        result = {"arch": arch, "shape": shape_name, "chips": chips,
                  "mesh": "x".join(map(str, mesh.devices.shape)),
                  "lower_s": round(time.time() - t0, 1)}
        if not compile_:
            return (result, None) if return_cost else result

        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        args_b = result["memory"].get("argument_size_in_bytes", 0)
        temp_b = result["memory"].get("temp_size_in_bytes", 0)
        result["memory"]["total_per_device_gb"] = round(
            (args_b + temp_b) / 2**30, 3)
    except Exception as e:                       # CPU backend may not support
        result["memory_error"] = f"{type(e).__name__}: {e}"

    # XLA's own cost analysis (recorded as a cross-check; it counts while
    # bodies once, so the roofline uses our trip-count-aware HLO walk).
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    result["xla_cost"] = {k: float(cost[k])
                          for k in ("flops", "bytes accessed") if k in cost}

    hc = hlo_analysis.analyze_hlo(compiled.as_text())
    result["hlo_cost"] = {
        "flops_per_device": hc.flops, "bytes_per_device": hc.bytes,
        "collectives_by_kind": hc.coll_by_kind,
        "unresolved_loops": hc.unresolved_loops}

    mf = hlo_analysis.model_flops_estimate(cfg, shape)
    rf = hlo_analysis.roofline_from_cost(hc, chips, model_flops=mf)
    result["roofline"] = rf.row()
    return (result, hc) if return_cost else result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=DRYRUN_ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else DRYRUN_ARCHS
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], []
    for arch in archs:
        cfg = get_config(arch)
        shapes = supported_shapes(cfg)
        skipped = [s for s in SHAPES if s not in shapes]
        for sk in skipped:
            if args.shape in (None, sk):
                results.append({"arch": arch, "shape": sk, "skipped":
                                "full-attention arch: long_500k needs "
                                "sub-quadratic attention (DESIGN.md)"})
        for shape_name in shapes:
            if args.shape and shape_name != args.shape:
                continue
            for multi_pod in meshes:
                mesh = make_production_mesh(multi_pod=multi_pod)
                label = f"{arch} × {shape_name} × {'x'.join(map(str, mesh.devices.shape))}"
                try:
                    r = lower_cell(arch, shape_name, mesh,
                                   compile_=not args.no_compile)
                    results.append(r)
                    rf = r.get("roofline", {})
                    print(f"[ok] {label}: compile={r.get('compile_s', '-')}s "
                          f"mem/dev={r.get('memory', {}).get('total_per_device_gb', '?')}GB "
                          f"bound={rf.get('bound', '?')}", flush=True)
                except Exception as e:
                    failures.append({"cell": label, "error": str(e)})
                    print(f"[FAIL] {label}: {type(e).__name__}: {e}",
                          flush=True)
                    traceback.print_exc()
                with open(args.out, "w") as f:
                    json.dump({"results": results, "failures": failures},
                              f, indent=1)
    print(f"\n{len(results)} cells recorded, {len(failures)} failures "
          f"-> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
