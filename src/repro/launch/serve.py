"""Serving launcher: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 8 --slots 4
    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --block-size 8 --max-blocks 64          # paged KV + chunked prefill
    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --fault-profile tiny                    # serve on a non-ideal device
    PYTHONPATH=src python -m repro.launch.serve --smoke --chaos \
        --fault-profile tiny                    # 2-shard fleet, drain/resume
    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --metrics-out metrics.prom --trace-out trace.jsonl   # telemetry

Engine flags are DERIVED from ``serve.ServeOptions`` field metadata
(``serve.add_cli_args``) — the launcher only hand-rolls its workload
knobs (--arch/--smoke/--requests/--max-new/--temperature/
--shared-prefix) and output paths.  Construction goes through
``serve.build_engine``; ``--chaos`` serves a 2-shard paged fleet under
``ft.FleetSupervisor`` with a deterministic mid-run shard degradation
and prints the drain/resume ledger.

``--metrics-out`` / ``--trace-out`` turn observability on: the global
``repro.obs`` registry is enabled (so substrate counters — sc dispatch,
autotune hits, arch pricing, device bit errors — record too), a tracer
is installed for the run, and after the drain the Prometheus exposition
and span JSONL land at the given paths (``.json`` metrics suffix writes
the JSON snapshot instead).  Render either with ``tools/obs_report.py``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import obs, serve
from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import lm, params as params_lib
from repro.serve import Request
from repro.sharding import sc_shard_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token system prompt to every "
                         "request (exercises the prefix cache; 0 = fully "
                         "random prompts)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the run's metrics after drain: Prometheus "
                         "text exposition, or the JSON snapshot when PATH "
                         "ends in .json (enables observability)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write per-request trace spans as JSONL after "
                         "drain (enables observability; convert with "
                         "tools/obs_report.py --chrome)")
    serve.add_cli_args(ap)          # every ServeOptions field as a flag
    args = ap.parse_args(argv)
    options = serve.from_cli_args(args)
    if options.chaos and not options.paged:
        options = options.replace(paged=True)   # chaos implies --paged
    try:
        options.validate()
    except ValueError as e:
        raise SystemExit(f"bad flag combination: {e}") from None

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    cfg = cfg.replace(param_dtype=jnp.float32, act_dtype=jnp.float32)
    if cfg.frontend == "embeddings":
        raise SystemExit("serve demo uses token-frontend archs")

    key = jax.random.PRNGKey(options.seed)
    params = params_lib.init_params(key, lm.lm_param_specs(cfg),
                                    cfg.param_dtype)
    mesh = rules = None
    if options.mesh:
        mesh = make_local_mesh(options.model_parallel)
        rules = sc_shard_rules(mesh)
        print(f"serving on mesh {dict(mesh.shape)}")
    # Observability: one registry holds the serve-layer AND substrate
    # series (the engine records into the global default registry, which
    # the sc/autotune/arch hooks also target), and the installed tracer
    # collects spans process-wide for the duration of the run.
    metrics = tracer = None
    if args.metrics_out or args.trace_out:
        metrics = obs.enable()
        tracer = obs.install_tracer(obs.Tracer())
    if options.fault_profile:
        p = options.resolve_profile()
        print(f"device profile '{options.fault_profile}': "
              f"sigma_delta={p.sigma_delta} sigma_ic={p.sigma_ic} "
              f"ber={p.ber_stuck0}/{p.ber_stuck1}/{p.ber_retention}")

    if options.chaos:
        fleet = _build_fleet(params, cfg, options, metrics, tracer)
        engine = None
    else:
        fleet = None
        engine = serve.build_engine(params, cfg, options, mesh=mesh,
                                    shard_rules=rules, metrics=metrics,
                                    tracer=tracer)
        if options.paged:
            print(f"paged engine: block_size={options.block_size} "
                  f"pool={engine.kv.cfg.num_blocks} blocks "
                  f"(chunked prefill {options.prefill_chunk}"
                  + (", prefix cache" if options.prefix_cache else "")
                  + (f", speculative k={options.spec_k}"
                     if options.speculative else "") + ")")

    rng = jax.random.PRNGKey(options.seed + 1)
    shared = []
    if args.shared_prefix:
        rng, k = jax.random.split(rng)
        shared = jax.random.randint(
            k, (args.shared_prefix,), 3, cfg.vocab).tolist()
    target = fleet if fleet is not None else engine
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 4, 17))
        prompt = shared + jax.random.randint(
            k, (plen,), 3, cfg.vocab).tolist()
        target.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=args.temperature))

    t0 = time.time()
    finished = target.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/max(dt,1e-9):.1f} tok/s)")
    if fleet is not None:
        print(f"  fleet: {fleet.shards} shards, "
              f"{fleet.drains} drained, {fleet.resumed} requests resumed, "
              f"{fleet.readmissions} readmitted")
    elif options.paged:
        print(f"  {engine.ticks} ticks, {engine.evictions} evictions, "
              f"{engine.kv.pool.free_blocks} blocks free at drain")
        lat = engine.decode_latency_ms()
        if lat:
            print(f"  decode p50={lat['decode_p50_ms']:.2f} "
                  f"p95={lat['decode_p95_ms']:.2f} ms/token")
        if options.prefix_cache:
            hit = engine.metrics.value(
                "serve_prefix_cache_hit_tokens_total") or 0
            pre = engine.metrics.value("serve_prefill_tokens_total") or 0
            rate = hit / max(hit + pre, 1)
            print(f"  prefix cache: {int(hit)} tokens adopted "
                  f"(hit rate {rate:.2f})")
        if options.speculative:
            drafted = engine.metrics.value(
                "serve_spec_drafted_tokens_total") or 0
            acc = engine.metrics.value(
                "serve_spec_accepted_tokens_total") or 0
            print(f"  speculative: {int(acc)}/{int(drafted)} drafted "
                  "tokens accepted")
    for r in sorted(finished, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt[:6]={r.prompt[:6]} "
              f"generated={r.generated}")
    if args.metrics_out:
        if args.metrics_out.endswith(".json"):
            with open(args.metrics_out, "w") as f:
                f.write(metrics.snapshot_json())
        else:
            with open(args.metrics_out, "w") as f:
                f.write(metrics.exposition())
        print(f"  metrics -> {args.metrics_out}")
    if args.trace_out:
        tracer.write_jsonl(args.trace_out)
        print(f"  trace   -> {args.trace_out} ({len(tracer.spans)} spans)")
    if tracer is not None:
        obs.uninstall_tracer(tracer)
        obs.disable()
    return finished


def _build_fleet(params, cfg, options, metrics, tracer):
    """2-shard paged fleet under the FT supervisor with a deterministic
    chaos schedule: shard 1 degrades mid-run, its in-flight requests
    drain onto shard 0 and finish there."""
    from repro.ft import supervisor as ftsup
    shard_opts = options.replace(chaos=False, mesh=False)
    fleet = ftsup.FleetSupervisor(
        lambda shard: serve.build_engine(params, cfg, shard_opts,
                                         tracer=tracer),
        shards=2, metrics=metrics,
        chaos=ftsup.ChaosMonkey(at_tick=2, shard=1))
    print("chaos fleet: 2 shards, degradation scheduled at tick 2 "
          "on shard 1")
    return fleet


if __name__ == "__main__":
    main()
