"""Serving launcher: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 8 --slots 4
    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --block-size 8 --max-blocks 64          # paged KV + chunked prefill
    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --metrics-out metrics.prom --trace-out trace.jsonl   # telemetry

``--metrics-out`` / ``--trace-out`` turn observability on: the global
``repro.obs`` registry is enabled (so substrate counters — sc dispatch,
autotune hits, arch pricing — record too), a tracer is installed for the
run, and after the drain the Prometheus exposition and span JSONL land
at the given paths (``.json`` metrics suffix writes the JSON snapshot
instead).  Render either with ``tools/obs_report.py``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import lm, params as params_lib
from repro.serve import (PagedServeConfig, PagedServingEngine, Request,
                         ServeConfig, ServingEngine)
from repro.sharding import sc_shard_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", action="store_true",
                    help="shard the SC substrate over a local device mesh "
                         "(slots map to data shards; needs a stochastic "
                         "--arch sc_backend; fixed-slot engine only)")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="model axis size of the local mesh (--mesh)")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged continuous-batching "
                         "engine (block-pool KV cache + chunked prefill + "
                         "eviction-on-OOM; every family — ssm/hybrid archs "
                         "carry state slots beside the block table)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (--paged)")
    ap.add_argument("--max-blocks", type=int, default=0,
                    help="pool size in blocks incl. the null block "
                         "(--paged; 0 = size for slots x max_len)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens fed per row per tick (--paged)")
    ap.add_argument("--fused-attention", action="store_true",
                    help="run the fused paged-attention Pallas kernel "
                         "instead of gather+chunk_decode_attention "
                         "(--paged; see docs/kernels.md)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="block-level prefix caching: requests sharing a "
                         "prompt prefix adopt cached KV blocks instead of "
                         "re-prefilling (--paged; forces content-chain "
                         "rng — see docs/prefix_caching.md)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft/verify speculative decoding on greedy "
                         "rows: draft with the paired cheap backend, "
                         "verify in one multi-token pass (--paged)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculative step "
                         "(--speculative)")
    ap.add_argument("--draft-backend", default="",
                    help="draft backend name (--speculative; default: "
                         "the registry pairing for the arch's sc_backend)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token system prompt to every "
                         "request (exercises the prefix cache; 0 = fully "
                         "random prompts)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the run's metrics after drain: Prometheus "
                         "text exposition, or the JSON snapshot when PATH "
                         "ends in .json (enables observability)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write per-request trace spans as JSONL after "
                         "drain (enables observability; convert with "
                         "tools/obs_report.py --chrome)")
    args = ap.parse_args(argv)
    if args.paged and args.mesh:
        raise SystemExit("--paged and --mesh are mutually exclusive (the "
                         "paged engine is single-mesh-slice; see "
                         "docs/serving.md)")
    if args.fused_attention and not args.paged:
        raise SystemExit("--fused-attention needs --paged (it is the "
                         "paged decode path's kernel)")
    if (args.prefix_cache or args.speculative) and not args.paged:
        raise SystemExit("--prefix-cache/--speculative need --paged (they "
                         "are paged-engine features; see "
                         "docs/prefix_caching.md)")

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    cfg = cfg.replace(param_dtype=jnp.float32, act_dtype=jnp.float32)
    if args.fused_attention:
        cfg = cfg.replace(paged_attn="fused")
    if cfg.frontend == "embeddings":
        raise SystemExit("serve demo uses token-frontend archs")

    key = jax.random.PRNGKey(args.seed)
    params = params_lib.init_params(key, lm.lm_param_specs(cfg),
                                    cfg.param_dtype)
    mesh = rules = None
    if args.mesh:
        mesh = make_local_mesh(args.model_parallel)
        rules = sc_shard_rules(mesh)
        print(f"serving on mesh {dict(mesh.shape)}")
    # Observability: one registry holds the serve-layer AND substrate
    # series (the engine records into the global default registry, which
    # the sc/autotune/arch hooks also target), and the installed tracer
    # collects spans process-wide for the duration of the run.
    metrics = tracer = None
    if args.metrics_out or args.trace_out:
        metrics = obs.enable()
        tracer = obs.install_tracer(obs.Tracer())
    if args.paged:
        engine = PagedServingEngine(params, cfg, PagedServeConfig(
            slots=args.slots, max_len=args.max_len, seed=args.seed,
            block_size=args.block_size, num_blocks=args.max_blocks,
            prefill_chunk=args.prefill_chunk,
            prefix_cache=args.prefix_cache, speculative=args.speculative,
            spec_k=args.spec_k, draft_backend=args.draft_backend),
            metrics=metrics, tracer=tracer)
        print(f"paged engine: block_size={args.block_size} "
              f"pool={engine.kv.cfg.num_blocks} blocks "
              f"(chunked prefill {args.prefill_chunk}"
              + (", prefix cache" if args.prefix_cache else "")
              + (f", speculative k={args.spec_k}" if args.speculative
                 else "") + ")")
    else:
        engine = ServingEngine(params, cfg, ServeConfig(
            slots=args.slots, max_len=args.max_len, seed=args.seed),
            mesh=mesh, shard_rules=rules, metrics=metrics, tracer=tracer)

    rng = jax.random.PRNGKey(args.seed + 1)
    shared = []
    if args.shared_prefix:
        rng, k = jax.random.split(rng)
        shared = jax.random.randint(
            k, (args.shared_prefix,), 3, cfg.vocab).tolist()
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 4, 17))
        prompt = shared + jax.random.randint(
            k, (plen,), 3, cfg.vocab).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=args.temperature))

    t0 = time.time()
    finished = engine.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/max(dt,1e-9):.1f} tok/s)")
    if args.paged:
        print(f"  {engine.ticks} ticks, {engine.evictions} evictions, "
              f"{engine.kv.pool.free_blocks} blocks free at drain")
        lat = engine.decode_latency_ms()
        if lat:
            print(f"  decode p50={lat['decode_p50_ms']:.2f} "
                  f"p95={lat['decode_p95_ms']:.2f} ms/token")
        if args.prefix_cache:
            hit = engine.metrics.value(
                "serve_prefix_cache_hit_tokens_total") or 0
            pre = engine.metrics.value("serve_prefill_tokens_total") or 0
            rate = hit / max(hit + pre, 1)
            print(f"  prefix cache: {int(hit)} tokens adopted "
                  f"(hit rate {rate:.2f})")
        if args.speculative:
            drafted = engine.metrics.value(
                "serve_spec_drafted_tokens_total") or 0
            acc = engine.metrics.value(
                "serve_spec_accepted_tokens_total") or 0
            print(f"  speculative: {int(acc)}/{int(drafted)} drafted "
                  "tokens accepted")
    for r in finished[:4]:
        print(f"  req {r.rid}: prompt[:6]={r.prompt[:6]} "
              f"generated={r.generated}")
    if args.metrics_out:
        if args.metrics_out.endswith(".json"):
            with open(args.metrics_out, "w") as f:
                f.write(metrics.snapshot_json())
        else:
            with open(args.metrics_out, "w") as f:
                f.write(metrics.exposition())
        print(f"  metrics -> {args.metrics_out}")
    if args.trace_out:
        tracer.write_jsonl(args.trace_out)
        print(f"  trace   -> {args.trace_out} ({len(tracer.spans)} spans)")
    if tracer is not None:
        obs.uninstall_tracer(tracer)
        obs.disable()
    return finished


if __name__ == "__main__":
    main()
