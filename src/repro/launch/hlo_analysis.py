"""HLO analysis: trip-count-aware FLOP / byte / collective accounting.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
48-layer scan (``while`` loop) body is counted a single time, understating
FLOPs and bytes by ~n_layers, and collective operand sizes are not reported
at all. This module walks the post-SPMD per-device HLO text itself:

  * builds a per-computation symbol table (instruction -> output shape),
  * counts dot FLOPs (2 · |output| · contracted dims) wherever they appear
    (including inside fusions),
  * accounts bytes at fusion granularity (operands + outputs of top-level
    instructions — XLA's own bytes-accessed convention),
  * sums operand bytes of every collective (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, sync or async),
  * multiplies everything inside a ``while`` body by the loop trip count
    (recovered from the loop condition's comparison constant),

yielding the three roofline terms. All quantities are per-device (the HLO is
the per-device module); totals scale by chip count.
"""

from __future__ import annotations

import dataclasses
import re

# TPU v5e per-chip hardware constants (assignment-specified).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")

# HBM-byte accounting follows a TPU fusion model: only ops that would
# materialize a buffer on a well-fused TPU pipeline count; elementwise
# chains are assumed fused into their producers/consumers. CPU HLO (this
# container) barely fuses, so summing every op's operands would overstate
# HBM traffic by an order of magnitude — see DESIGN.md §Roofline-method.
_BYTES_OPS = {
    "dot": "io",                     # operands + output (weights stream HBM)
    "convolution": "io",
    "fusion": "io",
    "gather": "o",
    "scatter": "io",
    "dynamic-slice": "o",
    "dynamic-update-slice": "u",     # update operand (in-place on TPU)
    "copy": "io",
    "sort": "io",
    "reduce": "o",
    "reduce-window": "o",
    "cholesky": "io", "triangular-solve": "io",
    "rng-bit-generator": "o",
}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    out_bytes: int
    out_dims: list          # dims of (first) output shape
    opcode: str
    operands: list          # operand instruction names
    attrs: str
    operand_txt: str = ""   # raw operand text (constant values live here)


def _split_top_level(s: str) -> list:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [x.strip() for x in out if x.strip()]


def _parse_instr(line: str) -> Instr | None:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    # --- output shape ---
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        shape_txt, rest = rhs[:i + 1], rhs[i + 1:]
    else:
        sm = re.match(r"^[a-z][a-z0-9]*\[[\d,]*\](?:\{[^}]*\})?", rhs)
        if not sm:
            return None
        shape_txt, rest = sm.group(0), rhs[sm.end():]
    om = _SHAPE_RE.search(shape_txt)
    out_dims = [int(d) for d in om.group(2).split(",") if d] if om else []
    # --- opcode + operand list ---
    rest = rest.strip()
    opm = re.match(r"^([\w\-]+)\(", rest)
    if not opm:
        return None
    opcode = opm.group(1)
    depth, j = 0, opm.end() - 1
    for j in range(opm.end() - 1, len(rest)):
        depth += rest[j] == "("
        depth -= rest[j] == ")"
        if depth == 0:
            break
    operand_txt = rest[opm.end():j]
    attrs = rest[j + 1:]
    operands = []
    for tok in _split_top_level(operand_txt):
        nm = re.search(r"%([\w.\-]+)\s*$", tok)
        if nm:
            operands.append(nm.group(1))
    return Instr(name, _shape_bytes(shape_txt), out_dims, opcode, operands,
                 attrs, operand_txt)


def _parse_computations(hlo: str) -> dict:
    comps: dict = {}
    name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hm = _HEADER_RE.match(line.strip())
        if hm and line.strip().endswith("{"):
            name = hm.group(2)
            comps[name] = {"instrs": {}, "entry": bool(hm.group(1))}
            continue
        if line.strip() == "}":
            name = None
            continue
        if name is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            comps[name]["instrs"][ins.name] = ins
    return comps


def _dims_attr(attrs: str, key: str) -> list:
    m = re.search(key + r"=\{([\d,]*)\}", attrs)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    unresolved_loops: int = 0
    # profile breakdowns (per-device): where the bytes/flops/collectives live
    bytes_by_opcode: dict = dataclasses.field(default_factory=dict)
    coll_by_shape: dict = dataclasses.field(default_factory=dict)

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll_by_kind.values()))

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes * k,
                       {kk: v * k for kk, v in self.coll_by_kind.items()},
                       self.unresolved_loops,
                       {kk: v * k for kk, v in self.bytes_by_opcode.items()},
                       {kk: v * k for kk, v in self.coll_by_shape.items()})

    def __iadd__(self, o: "HloCost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v
        for k, v in o.bytes_by_opcode.items():
            self.bytes_by_opcode[k] = self.bytes_by_opcode.get(k, 0) + v
        for k, v in o.coll_by_shape.items():
            self.coll_by_shape[k] = self.coll_by_shape.get(k, 0) + v
        self.unresolved_loops += o.unresolved_loops
        return self


def _collective_kind(opcode: str) -> str | None:
    base = opcode[:-6] if opcode.endswith("-start") else opcode
    base = base[:-5] if base.endswith("-done") else base
    return base if base in _COLLECTIVES else None


def analyze_hlo(hlo: str) -> HloCost:
    comps = _parse_computations(hlo)

    def operand_bytes(comp, ins: Instr) -> int:
        table = comps[comp]["instrs"]
        return sum(table[o].out_bytes for o in ins.operands if o in table)

    # Loop trip counts: lax.scan lowers to `while` whose condition computation
    # ends in `compare(iter, K)` with K a scalar constant. Resolve K through
    # the condition computation's symbol table (constant -> name -> compare
    # operand); fall back to the max scalar constant in the computation.
    cond_consts: dict = {}
    for cname, comp in comps.items():
        consts: dict = {}
        compare_consts: list = []
        for ins in comp["instrs"].values():
            if ins.opcode == "constant":
                mc = re.fullmatch(r"\s*(\d+)\s*", ins.operand_txt or "")
                if mc:
                    consts[ins.name] = int(mc.group(1))
        for ins in comp["instrs"].values():
            if ins.opcode == "compare":
                for op in ins.operands:
                    if op in consts:
                        compare_consts.append(consts[op])
        if compare_consts:
            cond_consts[cname] = compare_consts
        elif consts:
            cond_consts[cname] = list(consts.values())
    # Raw-text fallback (constants inlined into the compare line).
    cur = None
    for raw in hlo.splitlines():
        hm = _HEADER_RE.match(raw.strip())
        if hm and raw.strip().endswith("{"):
            cur = hm.group(2)
            continue
        if raw.strip() == "}":
            cur = None
            continue
        if cur is not None and cur not in cond_consts:
            for m in re.finditer(r"constant\((\d+)\)", raw):
                cond_consts.setdefault(cur, []).append(int(m.group(1)))

    def fusion_operand_bytes(comp_name: str, ins: Instr) -> int:
        """Bytes a fusion actually READS per operand.

        A scan-over-layers body receives the full stacked (n_layers, ...)
        parameter arrays but reads only the current layer's slice: when a
        fusion operand's corresponding parameter inside the called
        computation feeds ONLY dynamic-slice/slice/gather ops, charge the
        sliced size instead of the full array — that is what TPU HBM
        streams. Everything else is charged at full operand size."""
        table = comps[comp_name]["instrs"]
        m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
        sub = comps.get(m.group(1)) if m else None
        if sub is None:
            return sum(table[o].out_bytes for o in ins.operands
                       if o in table)
        # parameter index -> instruction, and a consumer map
        params = {}
        for si in sub["instrs"].values():
            if si.opcode == "parameter":
                pm = re.fullmatch(r"\s*(\d+)\s*", si.operand_txt or "")
                if pm:
                    params[int(pm.group(1))] = si.name
        consumers: dict = {}
        for si in sub["instrs"].values():
            for op in si.operands:
                consumers.setdefault(op, []).append(si)
        total = 0
        for idx, oname in enumerate(ins.operands):
            full = table[oname].out_bytes if oname in table else 0
            pname = params.get(idx)
            cons = consumers.get(pname, []) if pname else []
            if cons and all(c.opcode in ("dynamic-slice", "slice", "gather")
                            for c in cons):
                total += min(full, sum(c.out_bytes for c in cons))
            else:
                total += full
        return total

    def visit(comp_name: str, depth: int = 0,
              flops_only: bool = False) -> HloCost:
        cost = HloCost()
        if comp_name not in comps or depth > 24:
            return cost
        for ins in comps[comp_name]["instrs"].values():
            kind = _collective_kind(ins.opcode)
            if kind and not ins.opcode.endswith("-done") \
                    and not flops_only:
                b = operand_bytes(comp_name, ins)
                cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0) + b
                skey = f"{kind}:{int(b)}"
                cost.coll_by_shape[skey] = cost.coll_by_shape.get(skey, 0) + b
            if ins.opcode == "dot":
                table = comps[comp_name]["instrs"]
                lhs = table.get(ins.operands[0]) if ins.operands else None
                contracted = 1
                if lhs is not None:
                    for d in _dims_attr(ins.attrs, "lhs_contracting_dims"):
                        if d < len(lhs.out_dims):
                            contracted *= lhs.out_dims[d]
                out_elems = 1
                for d in ins.out_dims:
                    out_elems *= d
                cost.flops += 2.0 * out_elems * contracted
            if not flops_only and ins.opcode in _BYTES_OPS:
                mode = _BYTES_OPS[ins.opcode]
                if ins.opcode == "fusion":
                    nb = ins.out_bytes + fusion_operand_bytes(comp_name, ins)
                elif mode == "io":
                    nb = ins.out_bytes + operand_bytes(comp_name, ins)
                elif mode == "o":
                    nb = ins.out_bytes
                else:               # "u" — DUS: update operand only
                    table = comps[comp_name]["instrs"]
                    if len(ins.operands) >= 2 and ins.operands[1] in table:
                        nb = table[ins.operands[1]].out_bytes
                    else:
                        nb = ins.out_bytes
                cost.bytes += nb
                cost.bytes_by_opcode[ins.opcode] = \
                    cost.bytes_by_opcode.get(ins.opcode, 0) + nb
            # --- descend ---
            if ins.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if m:
                    sub = visit(m.group(1), depth + 1, flops_only=True)
                    cost.flops += sub.flops
            elif ins.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                if mb:
                    trips = None
                    if mc:
                        vals = cond_consts.get(mc.group(1), [])
                        trips = max(vals) if vals else None
                    if trips is None:
                        trips = 1
                        cost.unresolved_loops += 1
                    sub = visit(mb.group(1), depth + 1, flops_only)
                    cost += sub.scaled(trips)
            elif ins.opcode in ("call", "async-start"):
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
                if m:
                    cost += visit(m.group(1), depth + 1, flops_only)
            elif ins.opcode == "conditional":
                for m in re.finditer(r"%([\w.\-]+)", ins.attrs):
                    if m.group(1) in comps:
                        cost += visit(m.group(1), depth + 1, flops_only)
        return cost

    entry = next((n for n, c in comps.items() if c["entry"]), None)
    return visit(entry) if entry else HloCost()


# Backwards-compatible collective summary --------------------------------


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: int
    unresolved_loops: int


def collective_bytes(hlo: str) -> CollectiveStats:
    c = analyze_hlo(hlo)
    return CollectiveStats(c.coll_by_kind, int(c.coll_bytes),
                           c.unresolved_loops)


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    flops: float                  # total HLO dot-FLOPs (global, all devices)
    hbm_bytes: float              # total bytes accessed (global)
    coll_bytes: float             # total collective bytes (global)
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops: float = 0.0

    @property
    def dominant_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def row(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
        }


def roofline_from_cost(cost: HloCost, chips: int, *,
                       model_flops: float = 0.0) -> Roofline:
    """Per-device HloCost -> global three-term roofline."""
    flops = cost.flops * chips
    hbm = cost.bytes * chips
    coll = cost.coll_bytes * chips
    compute_s = flops / (chips * PEAK_FLOPS_BF16)
    memory_s = hbm / (chips * HBM_BW)
    collective_s = coll / (chips * ICI_BW)
    bound = max((("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return Roofline(flops, hbm, coll, chips, compute_s, memory_s,
                    collective_s, bound, model_flops)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train (N = active params), 2·N·tokens inference,
    PLUS the causal-attention score/value FLOPs (2·2·b·s²·h·hd·½ forward) —
    at 32k context the attention term dominates the weight term, so leaving
    it out would make the useful-fraction metric meaningless for the
    prefill/long-context cells."""
    n_active = active_param_count(cfg)
    tokens = shape.global_batch * shape.seq_len
    b, s = shape.global_batch, shape.seq_len
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    attn_fwd = 2.0 * 2.0 * b * s * s * h * hd * 0.5   # QK^T + PV, causal
    if cfg.family == "ssm":
        attn_fwd = 0.0
    elif cfg.family == "hybrid":
        # only the shared block invocations attend
        from repro.models import lm as lm_mod
        attn_fwd *= lm_mod.n_shared_invocations(cfg)
    else:
        attn_fwd *= cfg.n_layers
    if shape.kind == "train":
        return 6.0 * n_active * tokens + 3.0 * attn_fwd
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens + attn_fwd
    # decode: 1 new token attends to the full cache
    attn_dec = 2.0 * 2.0 * b * s * h * hd
    if cfg.family == "ssm":
        attn_dec = 0.0
    elif cfg.family == "hybrid":
        from repro.models import lm as lm_mod
        attn_dec *= lm_mod.n_shared_invocations(cfg)
    else:
        attn_dec *= cfg.n_layers
    return 2.0 * n_active * shape.global_batch + attn_dec


def _spec_leaves_with_paths(cfg):
    from repro.models import lm as lm_mod
    from repro.models.params import ParamSpec
    specs = lm_mod.lm_param_specs(cfg)
    from repro.compat import tree_flatten_with_path
    flat, _ = tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return [([str(getattr(p, "key", "")) for p in path], s)
            for path, s in flat]


def param_count(cfg) -> int:
    import math
    return sum(math.prod(s.shape) for _, s in _spec_leaves_with_paths(cfg))


def active_param_count(cfg) -> int:
    """Active params per token (MoE: top_k of the expert stack + the rest)."""
    import math
    total = param_count(cfg)
    if cfg.family != "moe" or cfg.n_experts == 0:
        return total
    expert = sum(
        math.prod(s.shape) for keys, s in _spec_leaves_with_paths(cfg)
        if "ffn" in keys and ("wi" in keys or "wo" in keys))
    active_expert = expert * cfg.top_k / cfg.n_experts
    return int(total - expert + active_expert)
