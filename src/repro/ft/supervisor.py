"""Fault-tolerance supervisor: checkpoint/restart, heartbeats, stragglers.

On a real cluster each worker process runs the train loop and a sidecar
heartbeat; the supervisor (one per job) watches heartbeats, and on a missed
deadline kills the step, re-forms the mesh from the survivors (elastic), and
restores from the last complete checkpoint. This container is
single-process, so the same control flow runs in-process: failures are
raised as :class:`WorkerFailure` (tests inject them at chosen steps), and
recovery = restore + replay. Determinism makes recovery exact: the data
pipeline is a pure function of the step counter, so a restored run produces
bit-identical batches.

Straggler mitigation: per-step wall-times feed an EMA; a step exceeding
``threshold × EMA`` marks its (simulated) worker as a straggler. The
production response — re-dispatch the slice to a hot spare and demote the
straggler — is modeled by the ``on_straggler`` callback; the default logs
and continues (the step still completes: synchronous SPMD has no partial
progress to lose).

Serve-fleet health (ROADMAP item 5 groundwork): :func:`engine_health`
reads one serving engine's ``repro.obs`` metrics registry into an
:class:`EngineHealth` snapshot (error rate, queue depth, active rows,
eviction pressure), and :class:`HealthMonitor` turns a stream of those
snapshots into degraded/healthy verdicts — real telemetry instead of the
stub inputs the drain logic will eventually act on.  No drain logic
lives here yet; a degraded verdict is just the signal a future
supervisor uses to drain the shard and resume its requests elsewhere.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro import checkpoint


class WorkerFailure(RuntimeError):
    """Injected/observed worker crash (lost node, preemption, OOM-kill)."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0
    ema_decay: float = 0.8
    ema: float | None = None
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, duration_s: float) -> bool:
        is_straggler = (self.ema is not None
                        and duration_s > self.threshold * self.ema)
        if is_straggler:
            self.events.append((step, duration_s, self.ema))
        self.ema = (duration_s if self.ema is None
                    else self.ema_decay * self.ema
                    + (1 - self.ema_decay) * duration_s)
        return is_straggler


@dataclasses.dataclass(frozen=True)
class EngineHealth:
    """One serving engine's health, read from its metrics registry."""

    ticks: int = 0
    errors: int = 0
    error_rate: float = 0.0          # errors per tick (0 when no ticks)
    queue_depth: int = 0
    active_requests: int = 0
    finished: int = 0
    evictions: int = 0


def engine_health(registry) -> EngineHealth:
    """Snapshot a serving engine's ``repro.obs`` registry.

    Reads the error-rate and queue-depth series the engines maintain
    (``serve_errors_total``, ``serve_ticks_total``,
    ``serve_queue_depth``, ``serve_active_requests``, ...); series the
    engine never touched read as zero, so a fresh engine is trivially
    healthy.
    """
    def num(name, **labels):
        v = registry.value(name, **labels)
        return 0 if v is None else v

    # serve_ticks_total is labeled by kind (prefill/decode)
    ticks = int(num("serve_ticks_total", kind="prefill")
                + num("serve_ticks_total", kind="decode"))
    errors = int(num("serve_errors_total"))
    return EngineHealth(
        ticks=ticks,
        errors=errors,
        error_rate=errors / ticks if ticks else float(errors > 0),
        queue_depth=int(num("serve_queue_depth")),
        active_requests=int(num("serve_active_requests")),
        finished=int(num("serve_requests_finished_total")),
        evictions=int(num("serve_evictions_total")),
    )


@dataclasses.dataclass
class HealthMonitor:
    """Degraded-shard detector over :class:`EngineHealth` snapshots.

    A shard is DEGRADED when its error rate exceeds ``max_error_rate``
    or its queue depth exceeds ``max_queue_depth`` for
    ``patience`` consecutive observations (one hot tick is load, a
    sustained backlog is a stall).  ``observe`` returns the verdict and
    appends degraded events to ``events``; acting on the verdict
    (drain + resume) is deliberately out of scope here.
    """

    max_error_rate: float = 0.0
    max_queue_depth: int = 64
    patience: int = 2
    events: list = dataclasses.field(default_factory=list)
    _backlog_streak: int = 0

    def observe(self, health: EngineHealth) -> bool:
        degraded = False
        if health.error_rate > self.max_error_rate:
            degraded = True
            self.events.append(("error_rate", health))
        if health.queue_depth > self.max_queue_depth:
            self._backlog_streak += 1
            if self._backlog_streak >= self.patience:
                degraded = True
                self.events.append(("queue_backlog", health))
        else:
            self._backlog_streak = 0
        return degraded

    def observe_registry(self, registry) -> bool:
        """Convenience: snapshot + observe in one call (what a fleet
        supervisor polls per engine per heartbeat)."""
        return self.observe(engine_health(registry))


@dataclasses.dataclass
class Supervisor:
    """Drives a step function with checkpoint/restart fault recovery."""

    ckpt_dir: str
    ckpt_every: int = 10
    max_restarts: int = 5
    heartbeat_timeout_s: float = 600.0
    injector: FaultInjector | None = None
    stragglers: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor)
    on_straggler: Callable | None = None
    restarts: int = 0
    last_heartbeat: float = dataclasses.field(default_factory=time.monotonic)

    def heartbeat(self):
        self.last_heartbeat = time.monotonic()

    def heartbeat_stale(self) -> bool:
        return time.monotonic() - self.last_heartbeat \
            > self.heartbeat_timeout_s

    def run(self, state, step_fn, n_steps: int, *, make_batch,
            start_step: int = 0, state_shardings=None):
        """Run ``n_steps`` of ``step_fn(state, batch)`` with recovery.

        make_batch(step) supplies the (deterministic) batch. Returns
        (state, history) where history records losses and recovery events.
        """
        history = {"loss": [], "recoveries": [], "straggler_steps": []}
        step = start_step
        while step < n_steps:
            try:
                t0 = time.monotonic()
                if self.injector is not None:
                    self.injector.check(step)
                state, metrics = step_fn(state, make_batch(step))
                dt = time.monotonic() - t0
                self.heartbeat()
                if self.stragglers.observe(step, dt):
                    history["straggler_steps"].append(step)
                    if self.on_straggler is not None:
                        self.on_straggler(step, dt)
                history["loss"].append(float(metrics["loss"]))
                step += 1
                if step % self.ckpt_every == 0:
                    checkpoint.save(self.ckpt_dir, step, state,
                                    extra={"data_step": step})
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}") from e
                restored = checkpoint.latest_step(self.ckpt_dir)
                if restored is None:
                    # no checkpoint yet -> restart from scratch
                    history["recoveries"].append((step, 0))
                    step = start_step
                    continue
                if state_shardings is not None:
                    state, extra, _ = checkpoint.restore_resharded(
                        self.ckpt_dir, state, state_shardings)
                else:
                    state, extra, _ = checkpoint.restore(self.ckpt_dir, state)
                step = extra["data_step"]
                history["recoveries"].append((step, restored))
        return state, history
