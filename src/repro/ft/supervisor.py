"""Fault-tolerance supervisor: checkpoint/restart, heartbeats, stragglers.

On a real cluster each worker process runs the train loop and a sidecar
heartbeat; the supervisor (one per job) watches heartbeats, and on a missed
deadline kills the step, re-forms the mesh from the survivors (elastic), and
restores from the last complete checkpoint. This container is
single-process, so the same control flow runs in-process: failures are
raised as :class:`WorkerFailure` (tests inject them at chosen steps), and
recovery = restore + replay. Determinism makes recovery exact: the data
pipeline is a pure function of the step counter, so a restored run produces
bit-identical batches.

Straggler mitigation: per-step wall-times feed an EMA; a step exceeding
``threshold × EMA`` marks its (simulated) worker as a straggler. The
production response — re-dispatch the slice to a hot spare and demote the
straggler — is modeled by the ``on_straggler`` callback; the default logs
and continues (the step still completes: synchronous SPMD has no partial
progress to lose).

Serve-fleet health (ROADMAP item 5): :func:`engine_health` reads one
serving engine's ``repro.obs`` metrics registry into an
:class:`EngineHealth` snapshot (error rate, queue depth, active rows,
eviction pressure), and :class:`HealthMonitor` turns a stream of those
snapshots into degraded/healthy verdicts.  :class:`FleetSupervisor`
ACTS on the verdicts: it serves a fleet of paged engines, polls each
shard's registry (windowed, so readmitted shards can prove themselves
healthy) plus its heartbeat, and on degradation DRAINS the shard —
``PagedServingEngine.drain()`` checkpoints every in-flight request —
and resumes the checkpoints on healthy shards (warm KV-payload resume
for attention families, cold recompute resume otherwise).  The
per-(request key, position) rng contract makes either resume
token-identical to an unfaulted run; :class:`ChaosMonkey` injects
deterministic degradations so tests and the ``--chaos`` launcher can
assert exactly that.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro import checkpoint


class WorkerFailure(RuntimeError):
    """Injected/observed worker crash (lost node, preemption, OOM-kill)."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0
    ema_decay: float = 0.8
    ema: float | None = None
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, duration_s: float) -> bool:
        is_straggler = (self.ema is not None
                        and duration_s > self.threshold * self.ema)
        if is_straggler:
            self.events.append((step, duration_s, self.ema))
        self.ema = (duration_s if self.ema is None
                    else self.ema_decay * self.ema
                    + (1 - self.ema_decay) * duration_s)
        return is_straggler


@dataclasses.dataclass(frozen=True)
class EngineHealth:
    """One serving engine's health, read from its metrics registry."""

    ticks: int = 0
    errors: int = 0
    error_rate: float = 0.0          # errors per tick (0 when no ticks)
    queue_depth: int = 0
    active_requests: int = 0
    finished: int = 0
    evictions: int = 0


def engine_health(registry) -> EngineHealth:
    """Snapshot a serving engine's ``repro.obs`` registry.

    Reads the error-rate and queue-depth series the engines maintain
    (``serve_errors_total``, ``serve_ticks_total``,
    ``serve_queue_depth``, ``serve_active_requests``, ...); series the
    engine never touched read as zero, so a fresh engine is trivially
    healthy.
    """
    def num(name, **labels):
        v = registry.value(name, **labels)
        return 0 if v is None else v

    # serve_ticks_total is labeled by kind (prefill/decode/spec)
    ticks = int(num("serve_ticks_total", kind="prefill")
                + num("serve_ticks_total", kind="decode")
                + num("serve_ticks_total", kind="spec"))
    errors = int(num("serve_errors_total"))
    return EngineHealth(
        ticks=ticks,
        errors=errors,
        error_rate=errors / ticks if ticks else float(errors > 0),
        queue_depth=int(num("serve_queue_depth")),
        active_requests=int(num("serve_active_requests")),
        finished=int(num("serve_requests_finished_total")),
        evictions=int(num("serve_evictions_total")),
    )


@dataclasses.dataclass
class HealthMonitor:
    """Degraded-shard detector over :class:`EngineHealth` snapshots.

    A shard is DEGRADED when its error rate exceeds ``max_error_rate``
    or its queue depth exceeds ``max_queue_depth`` for
    ``patience`` consecutive observations (one hot tick is load, a
    sustained backlog is a stall).  ``observe`` returns the verdict and
    appends degraded events to ``events``; acting on the verdict
    (drain + resume) is :class:`FleetSupervisor`'s job.

    ``window=True`` judges each observation on the DELTA since the
    previous one instead of lifetime totals — the mode a fleet needs for
    READMISSION: counters are monotonic, so a shard that errored once
    would otherwise read degraded forever, and a readmitted shard could
    never prove itself healthy again.
    """

    max_error_rate: float = 0.0
    max_queue_depth: int = 64
    patience: int = 2
    window: bool = False
    events: list = dataclasses.field(default_factory=list)
    _backlog_streak: int = 0
    _prev: EngineHealth | None = None

    def observe(self, health: EngineHealth) -> bool:
        if self.window:
            prev = self._prev if self._prev is not None else EngineHealth()
            self._prev = health
            dt = health.ticks - prev.ticks
            de = health.errors - prev.errors
            health = dataclasses.replace(
                health, ticks=dt, errors=de,
                error_rate=de / dt if dt else float(de > 0))
        degraded = False
        if health.error_rate > self.max_error_rate:
            degraded = True
            self.events.append(("error_rate", health))
        if health.queue_depth > self.max_queue_depth:
            self._backlog_streak += 1
            if self._backlog_streak >= self.patience:
                degraded = True
                self.events.append(("queue_backlog", health))
        else:
            self._backlog_streak = 0
        return degraded

    def observe_registry(self, registry) -> bool:
        """Convenience: snapshot + observe in one call (what a fleet
        supervisor polls per engine per heartbeat)."""
        return self.observe(engine_health(registry))


@dataclasses.dataclass
class Supervisor:
    """Drives a step function with checkpoint/restart fault recovery."""

    ckpt_dir: str
    ckpt_every: int = 10
    max_restarts: int = 5
    heartbeat_timeout_s: float = 600.0
    injector: FaultInjector | None = None
    stragglers: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor)
    on_straggler: Callable | None = None
    restarts: int = 0
    last_heartbeat: float = dataclasses.field(default_factory=time.monotonic)

    def heartbeat(self):
        self.last_heartbeat = time.monotonic()

    def heartbeat_stale(self) -> bool:
        return time.monotonic() - self.last_heartbeat \
            > self.heartbeat_timeout_s

    def run(self, state, step_fn, n_steps: int, *, make_batch,
            start_step: int = 0, state_shardings=None):
        """Run ``n_steps`` of ``step_fn(state, batch)`` with recovery.

        make_batch(step) supplies the (deterministic) batch. Returns
        (state, history) where history records losses and recovery events.
        """
        history = {"loss": [], "recoveries": [], "straggler_steps": []}
        step = start_step
        while step < n_steps:
            try:
                t0 = time.monotonic()
                if self.injector is not None:
                    self.injector.check(step)
                state, metrics = step_fn(state, make_batch(step))
                dt = time.monotonic() - t0
                self.heartbeat()
                if self.stragglers.observe(step, dt):
                    history["straggler_steps"].append(step)
                    if self.on_straggler is not None:
                        self.on_straggler(step, dt)
                history["loss"].append(float(metrics["loss"]))
                step += 1
                if step % self.ckpt_every == 0:
                    checkpoint.save(self.ckpt_dir, step, state,
                                    extra={"data_step": step})
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}") from e
                restored = checkpoint.latest_step(self.ckpt_dir)
                if restored is None:
                    # no checkpoint yet -> restart from scratch
                    history["recoveries"].append((step, 0))
                    step = start_step
                    continue
                if state_shardings is not None:
                    state, extra, _ = checkpoint.restore_resharded(
                        self.ckpt_dir, state, state_shardings)
                else:
                    state, extra, _ = checkpoint.restore(self.ckpt_dir, state)
                step = extra["data_step"]
                history["recoveries"].append((step, restored))
        return state, history


# ---------------------------------------------------------------------------
# Serve-fleet supervision: drain a degraded shard, resume elsewhere
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ChaosMonkey:
    """Deterministic chaos schedule for serve fleets: at fleet tick
    ``at_tick``, degrade shard ``shard`` by bumping its engine's
    ``serve_errors_total`` — exactly the telemetry a real crash loop
    would emit, so the drain path under test is the production path."""

    at_tick: int = 4
    shard: int = 1
    errors: int = 3
    fired: bool = False

    def maybe_fire(self, tick: int, engines: list) -> bool:
        if self.fired or tick < self.at_tick or self.shard >= len(engines):
            return False
        self.fired = True
        engines[self.shard]._m_errors.inc(self.errors)
        return True


class FleetSupervisor:
    """Serves one request stream across a fleet of paged engines with
    health-driven shard failover.

    ``engine_factory(shard) -> PagedServingEngine`` builds each shard
    (all shards MUST share the engine seed so per-request keys — and
    therefore tokens — are shard-independent).  ``submit`` round-robins
    over healthy shards; ``step`` ticks every healthy shard, fires the
    optional :class:`ChaosMonkey`, then polls health.

    A shard is degraded when its windowed :class:`HealthMonitor` trips
    on the engine's own registry (``HealthMonitor.observe_registry``) or
    its heartbeat goes stale (a shard that stopped ticking).  Degrading
    drains the shard's every request (``PagedServingEngine.drain()``)
    and resumes the checkpoints round-robin on the remaining healthy
    shards — clients just see their requests finish.  The drained shard
    sits out ``cooldown`` polls, then READMITS; the windowed monitor
    judges it on post-readmission deltas, so one historical incident
    does not blacklist it forever.  A shard never double-drains: only
    healthy shards are polled for degradation.

    Fleet-level telemetry (``ft_*`` series, labeled by shard) lands in
    ``metrics`` — pass the global ``repro.obs`` registry to export it
    beside the substrate counters.
    """

    def __init__(self, engine_factory, shards: int = 2, metrics=None,
                 monitor_factory=None, heartbeat_timeout_s: float = 600.0,
                 cooldown: int = 4, chaos: ChaosMonkey | None = None):
        from repro import obs
        if shards < 2:
            raise ValueError("a failover fleet needs >= 2 shards")
        self.shards = shards
        self.engines = [engine_factory(s) for s in range(shards)]
        mk = monitor_factory or (lambda s: HealthMonitor(window=True))
        self.monitors = [mk(s) for s in range(shards)]
        self.healthy = [True] * shards
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.last_heartbeat = [time.monotonic()] * shards
        self.cooldown = cooldown
        self._cooldowns = [0] * shards
        self.chaos = chaos
        self.ticks = 0
        self._rr = 0
        self.drains = 0
        self.resumed = 0
        self.readmissions = 0
        m = metrics if metrics is not None else obs.MetricsRegistry()
        self.metrics = m
        self._m_degraded = m.counter(
            "ft_shard_degraded_total",
            "degraded verdicts acted on, labeled shard")
        self._m_drains = m.counter(
            "ft_shard_drains_total", "shards drained, labeled shard")
        self._m_resumed = m.counter(
            "ft_requests_resumed_total",
            "drained requests resumed, labeled by TARGET shard")
        self._m_readmit = m.counter(
            "ft_shard_readmissions_total",
            "drained shards readmitted after cooldown, labeled shard")
        for s in range(shards):
            # materialize every shard's series at 0 so exporters (and
            # obs_report --require gates) see the family even on
            # incident-free runs
            for c in (self._m_degraded, self._m_drains, self._m_resumed,
                      self._m_readmit):
                c.inc(0, shard=str(s))

    # ------------------------------------------------------------------
    def submit(self, req) -> int:
        """Round-robin the request onto a healthy shard; returns it."""
        order = [s for s in range(self.shards) if self.healthy[s]]
        if not order:
            raise RuntimeError("no healthy shards to submit to")
        shard = order[self._rr % len(order)]
        self._rr += 1
        self.engines[shard].submit(req)
        return shard

    def heartbeat(self, shard: int) -> None:
        self.last_heartbeat[shard] = time.monotonic()

    def heartbeat_stale(self, shard: int) -> bool:
        return (time.monotonic() - self.last_heartbeat[shard]
                > self.heartbeat_timeout_s)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One fleet tick: tick healthy shards, fire chaos, poll health."""
        progressed = False
        for s in range(self.shards):
            if not self.healthy[s]:
                continue
            try:
                progressed = bool(self.engines[s].step()) or progressed
                self.heartbeat(s)
            except Exception:
                # the engine already counted serve_errors_total; the poll
                # below turns the telemetry into a drain
                pass
        self.ticks += 1
        if self.chaos is not None:
            self.chaos.maybe_fire(self.ticks, self.engines)
        self.poll()
        return progressed

    def poll(self) -> None:
        """Health pass: degrade-and-drain tripped shards, readmit cooled
        ones.  Only healthy shards are judged — no double drains."""
        for s in range(self.shards):
            if not self.healthy[s]:
                self._cooldowns[s] -= 1
                if self._cooldowns[s] <= 0:
                    self.healthy[s] = True
                    self.readmissions += 1
                    self._m_readmit.inc(shard=str(s))
                continue
            tripped = self.monitors[s].observe_registry(
                self.engines[s].metrics)
            if tripped or self.heartbeat_stale(s):
                self.degrade(s)

    def degrade(self, shard: int) -> list:
        """Drain ``shard`` and resume its requests on healthy shards.
        Idempotent per incident: an already-degraded shard is skipped."""
        if not self.healthy[shard]:
            return []
        self._m_degraded.inc(shard=str(shard))
        self.healthy[shard] = False
        self._cooldowns[shard] = self.cooldown
        ckpts = self.engines[shard].drain()
        self.drains += 1
        self._m_drains.inc(shard=str(shard))
        targets = [s for s in range(self.shards) if self.healthy[s]]
        if not targets:
            raise RuntimeError(
                f"shard {shard} degraded with no healthy shard left to "
                "resume its requests on")
        for i, ckpt in enumerate(ckpts):
            t = targets[i % len(targets)]
            self.engines[t].restore(ckpt)
            self.resumed += 1
            self._m_resumed.inc(shard=str(t))
        return ckpts

    # ------------------------------------------------------------------
    @property
    def finished(self) -> list:
        out = []
        for e in self.engines:
            out.extend(e.finished)
        return out

    def has_work(self) -> bool:
        return any(e.scheduler.has_work() for e in self.engines)

    def run_until_drained(self, max_ticks: int = 10_000) -> list:
        ticks = 0
        while self.has_work() and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished

    def close(self) -> None:
        for e in self.engines:
            e.close()
