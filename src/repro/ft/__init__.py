from repro.ft.supervisor import (  # noqa: F401
    ChaosMonkey, EngineHealth, FaultInjector, FleetSupervisor,
    HealthMonitor, StragglerMonitor, Supervisor, WorkerFailure,
    engine_health)
