from repro.ft.supervisor import (  # noqa: F401
    EngineHealth, FaultInjector, HealthMonitor, StragglerMonitor,
    Supervisor, WorkerFailure, engine_health)
