from repro.ft.supervisor import (  # noqa: F401
    FaultInjector, StragglerMonitor, Supervisor, WorkerFailure)
