"""Deterministic, host-sharded synthetic LM data pipeline.

Every batch is a pure function of ``(seed, step, shard)`` via counter-based
threefry — no state to checkpoint beyond the step counter, and any host can
regenerate any shard (this is what makes restart/elastic-reshard trivial:
the restored step number IS the data-pipeline state).

The stream is structured (not uniform noise) so losses move during the
example runs: documents are Zipf-distributed token runs with document
boundaries, packed back-to-back into fixed-length rows (the standard packed
pretraining layout). Labels are inputs shifted left; the last target wraps
to BOS.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

BOS = 1


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1          # data-parallel hosts
    zipf_a: float = 1.2        # token frequency skew

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch(self, step: int, shard: int = 0):
        return make_batch(self, step, shard)


def _zipf_tokens(key, shape, vocab: int, a: float):
    """Zipf-ish token draw: inverse-CDF on u^a, avoiding specials 0/1."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    ranks = jnp.floor((vocab - 2) * u ** a).astype(jnp.int32)
    return jnp.clip(ranks + 2, 2, vocab - 1)


def make_batch(cfg: SyntheticLMData, step: int, shard: int = 0):
    """Returns {"inputs": (b, s) int32, "labels": (b, s) int32} for one shard."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard)
    kt, kd, kr = jax.random.split(key, 3)
    b, s = cfg.shard_batch, cfg.seq_len
    toks = _zipf_tokens(kt, (b, s), cfg.vocab, cfg.zipf_a)
    # Markov-ish structure: token t depends on t-1 half the time, so there
    # is signal for the model to learn (loss decreases in the examples).
    repeat = jax.random.bernoulli(kr, 0.5, (b, s))
    toks = jnp.where(repeat, jnp.roll(toks, 1, axis=1), toks)
    # Document boundaries every ~doc_len tokens: insert BOS.
    doc_len = max(s // 4, 8)
    offsets = jax.random.randint(kd, (b, 1), 0, doc_len)
    pos = jnp.arange(s)[None, :]
    is_bos = (pos + offsets) % doc_len == 0
    inputs = jnp.where(is_bos, BOS, toks).astype(jnp.int32)
    labels = jnp.roll(inputs, -1, axis=1).at[:, -1].set(BOS)
    return {"inputs": inputs, "labels": labels}


def make_embedding_batch(cfg: SyntheticLMData, d_model: int, step: int,
                         shard: int = 0, dtype=jnp.float32):
    """Stub-frontend variant: precomputed frame/patch embeddings + labels."""
    tok_batch = make_batch(cfg, step, shard)
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ 0x5EED), step), shard)
    emb = (jax.random.normal(key, (cfg.shard_batch, cfg.seq_len, d_model),
                             jnp.float32) * 0.02).astype(dtype)
    return {"inputs": emb, "labels": tok_batch["labels"]}
