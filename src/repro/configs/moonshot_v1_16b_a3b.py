"""moonshot-v1-16b-a3b [moe]: Moonlight (kimi) 16B-A3B MoE.

48L, d_model=2048, 16 heads (kv=16), expert d_ff=1408, vocab=163840,
64 experts top-6 (deepseek-v3-style fine-grained experts).
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, tie_embeddings=False)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=256,
    n_experts=8, top_k=2, attn_impl="full", remat="none")
