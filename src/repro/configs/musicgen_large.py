"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens.

48L, d_model=2048, 32 heads (MHA — kv=32), d_ff=8192 (classic GELU MLP),
vocab=2048 (one EnCodec codebook; interleaving pattern is frontend-side).
The EnCodec frontend is a STUB: inputs arrive as precomputed frame
embeddings. [arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=2048,
    mlp_variant="gelu", frontend="embeddings", tie_embeddings=False)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    attn_impl="full", remat="none")
