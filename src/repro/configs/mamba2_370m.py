"""mamba2-370m [ssm]: pure SSD (state-space duality), attention-free.

48L, d_model=1024, ssm_state=128, vocab=50280 (d_inner=2048, headdim=64 ->
32 ssm heads). d_ff=0 — the Mamba2 block IS the layer. The paper's
attention-sharding aspects are N/A (attention-free); the SC MUL substrate
still applies to all projections. [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, tie_embeddings=True)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, vocab=256, ssm_state=16, ssm_headdim=16,
    ssm_chunk=8, remat="none")
