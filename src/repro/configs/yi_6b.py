"""yi-6b [dense]: llama-architecture GQA.

32L, d_model=4096, 32 heads (GQA kv=4), d_ff=11008 (SwiGLU), vocab=64000.
[arXiv:2403.04652; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=4, d_ff=11008, vocab=64000, tie_embeddings=False)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    attn_impl="full", remat="none")
