"""llama4-maverick-400b-a17b [moe]: 400B total / 17B active, early fusion.

48L, d_model=5120, 40 heads (GQA kv=8), expert d_ff=8192, vocab=202048,
128 experts top-1 + one always-on shared expert. Text backbone only; the
early-fusion vision tokens arrive pre-embedded (stub frontend). iRoPE
attention chunking is not modeled (treated as full attention — DESIGN.md).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    n_experts=128, top_k=1, shared_expert=True, tie_embeddings=False)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=256,
    n_experts=8, top_k=1, attn_impl="full", remat="none")
