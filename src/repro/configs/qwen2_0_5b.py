"""qwen2-0.5b [dense]: GQA with QKV bias; tied embeddings.

24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864 (SwiGLU), vocab=151936.
Primary SC-engine demo arch (small enough to train with sc_mode="moment"
end-to-end on CPU). [arXiv:2407.10671; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151936, qkv_bias=True,
    tie_embeddings=True,
    # 14 heads do not divide the 16-way TP axis -> context-parallel
    # attention; 2048-token chunks keep the PER-DEVICE q-tile at 128 rows
    # (MXU-aligned) instead of 64 (EXPERIMENTS &Perf cell-2 iteration 1).
    attn_chunk=2048)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    attn_impl="full", remat="none")
