"""chameleon-34b [vlm]: early-fusion mixed-modal transformer.

48L, d_model=8192, 64 heads (GQA kv=8), d_ff=22016 (SwiGLU), vocab=65536
(text + VQ-GAN image codes in one shared vocabulary — image tokens are
ordinary ids, so the frontend stub only marks modality spans). qk-norm per
the paper's training-stability fix. [arXiv:2405.09818; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536, qk_norm=True,
    tie_embeddings=False)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    attn_impl="full", remat="none")
