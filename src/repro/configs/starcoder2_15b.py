"""starcoder2-15b [dense]: GQA + RoPE code model.

40L, d_model=6144, 48 heads (GQA kv=4), d_ff=24576 (classic GELU MLP),
vocab=49152. [arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=4, d_ff=24576, vocab=49152,
    mlp_variant="gelu", qkv_bias=True, tie_embeddings=False)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    attn_impl="full", remat="none")
