"""Model + shape configuration schema, and the architecture registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # attention features
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mlp_variant: str = "swiglu"    # swiglu | gelu (classic 4x MLP)
    tie_embeddings: bool = True    # False -> separate unembedding matrix
    attn_impl: str = "blockwise"   # blockwise | full
    attn_chunk: int = 1024         # kv/q chunk for blockwise attention
    # paged decode attention path (kernels/paged_attention.py):
    # unfused (reference gather + chunk_decode_attention) | fused (one
    # Pallas kernel, same math) | fused_sc (fused, SC-sampled QK^T —
    # needs per-token rng keys, see models/attention.py)
    paged_attn: str = "unfused"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # hybrid (zamba2): one SHARED attn+MLP block every `attn_every` ssm layers
    attn_every: int = 0
    # SC multiplication substrate (the paper's engine as a framework feature):
    # any backend registered in repro.sc — exact | moment | bitexact |
    # pallas_moment | pallas_bitexact. ``sc_mode`` is the deprecated alias;
    # the two fields are kept in sync (see __post_init__ / replace).
    sc_backend: str = ""
    sc_mode: str = ""              # DEPRECATED: use sc_backend
    sc_nbit: int = 1024
    # dtypes
    param_dtype: Any = jnp.bfloat16
    act_dtype: Any = jnp.bfloat16
    # input frontend: "tokens" (ids) or "embeddings" (stubbed modality frontend)
    frontend: str = "tokens"
    # remat policy inside the layer scan: none | full
    remat: str = "full"

    def __post_init__(self):
        # sc_mode -> sc_backend migration: either spelling may be passed at
        # construction; afterwards both fields hold the resolved backend so
        # legacy readers of cfg.sc_mode keep working. Two different non-empty
        # values is a conflict (e.g. raw dataclasses.replace updating only
        # sc_mode against a mirrored sc_backend) — refuse rather than let
        # one spelling silently win.
        if self.sc_backend and self.sc_mode and self.sc_mode != self.sc_backend:
            raise ValueError(
                f"conflicting sc_backend={self.sc_backend!r} / "
                f"sc_mode={self.sc_mode!r}; set one (or use "
                "ModelConfig.replace, which keeps the alias pair in sync)")
        if not self.sc_backend:
            object.__setattr__(self, "sc_backend", self.sc_mode or "exact")
        if self.sc_mode != self.sc_backend:
            object.__setattr__(self, "sc_mode", self.sc_backend)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:           # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        # keep the sc_backend/sc_mode alias pair in sync: whichever spelling
        # the caller passes wins over the mirrored stale value of the other
        if "sc_backend" in kw and "sc_mode" not in kw:
            kw["sc_mode"] = kw["sc_backend"]
        elif "sc_mode" in kw and "sc_backend" not in kw:
            kw["sc_backend"] = kw["sc_mode"]
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "musicgen-large", "moonshot-v1-16b-a3b", "llama4-maverick-400b-a17b",
    "chameleon-34b", "starcoder2-15b", "qwen2-0.5b", "qwen3-14b", "yi-6b",
    "zamba2-7b", "mamba2-370m", "paper-sc",
]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.SMOKE


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """Which of the four assigned shapes an architecture runs (§Arch-applicability).

    ``long_500k`` needs sub-quadratic attention: only the SSM/hybrid archs
    run it; pure full-attention archs skip (documented in DESIGN.md).
    """
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        out.append("long_500k")
    return out
