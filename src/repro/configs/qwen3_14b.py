"""qwen3-14b [dense]: GQA + qk-norm, explicit head_dim=128.

40L, d_model=5120, 40 heads (GQA kv=8), d_ff=17408 (SwiGLU), vocab=151936.
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=17408, vocab=151936, qk_norm=True,
    head_dim=128, tie_embeddings=False)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, attn_impl="full", remat="none")
