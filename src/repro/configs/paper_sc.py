"""paper-sc: the paper's own evaluation config lifted to an LM.

A compact dense LM whose every matmul runs through the SOT-MRAM SC engine
(moment-matched mode, nbit=1024 = 2^10 stochastic bits for 10-bit operands
— exactly the paper's §V setup). Used by the end-to-end training example
and the accuracy benchmarks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-sc", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=2, d_ff=1024, vocab=2048,
    sc_backend="moment", sc_nbit=1024, attn_impl="full", remat="none",
    tie_embeddings=True)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, d_ff=128, vocab=256)
