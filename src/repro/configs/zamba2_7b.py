"""zamba2-7b [hybrid]: Mamba2 backbone + ONE weight-shared attention block.

81 "layers" = 54 Mamba2 layers + 27 invocations of the shared (MHA + MLP)
block (attn_every=2). d_model=3584, 32 heads (kv=32 — MHA), d_ff=14336,
vocab=32000, ssm_state=64 (d_inner=7168, headdim=64 -> 112 ssm heads).
Per-invocation LoRA deltas of the published model are omitted (DESIGN.md
§Simplifications). [arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_headdim=64, attn_every=2, tie_embeddings=False)

SMOKE = CONFIG.replace(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    ssm_state=16, ssm_headdim=16, ssm_chunk=8, attn_impl="full",
    remat="none")
