"""Pallas TPU kernel: fused moment-matched SC matmul (the beyond-paper MAC).

Computes, in ONE pass over the operand tiles (classic (i, j, k) matmul grid
with three f32 VMEM accumulators):

    mean  += sx_tile @ sw_tile          (signed probabilities — the MXU dot)
    sum_p += |sx|   @ |sw|              (Σ_k p_x·p_w)
    sum_p2+= sx²    @ sw²               (Σ_k p_x²·p_w², signs square away)

and at the final k-step emits

    out = (mean + noise · sqrt(max(sum_p − sum_p2, 0) / nbit)) · scale

which is the CLT-exact distribution of the SOT-MRAM MAC pop-count
(mean = exact product, variance = Σ_k p(1−p)/nbit — see the moment
backend in sc/backends.py for the derivation). All three dots ride the same operand tiles, so arithmetic
intensity is 3× a plain matmul at identical HBM traffic; the Gaussian noise
is a (bm, bn) input tile consumed once at the epilogue.

MXU alignment: block sizes default to 128×128×512 (f32); the K reduction is
the innermost ("arbitrary") grid axis so accumulators live across k-steps in
VMEM scratch — the standard Pallas TPU matmul pipeline shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sc_mac_kernel(x_ref, w_ref, noise_ref, out_ref,
                   acc_mean, acc_p, acc_p2, *, inv_nbit: float, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_mean[...] = jnp.zeros_like(acc_mean)
        acc_p[...] = jnp.zeros_like(acc_p)
        acc_p2[...] = jnp.zeros_like(acc_p2)

    x = x_ref[...]          # (bm, bk) signed probabilities sx·px
    w = w_ref[...]          # (bk, bn) signed probabilities sw·pw
    acc_mean[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc_p[...] += jnp.dot(jnp.abs(x), jnp.abs(w),
                          preferred_element_type=jnp.float32)
    acc_p2[...] += jnp.dot(x * x, w * w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        var = jnp.maximum(acc_p[...] - acc_p2[...], 0.0) * inv_nbit
        out_ref[...] = acc_mean[...] + noise_ref[...] * jnp.sqrt(var)


def _box_muller(bits_a, bits_b):
    """Standard normals from two uint32 words (Box-Muller on the VPU)."""
    u1 = (bits_a >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    u2 = (bits_b >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    u1 = jnp.maximum(u1, 1e-12)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(2.0 * jnp.pi * u2)


def _sc_mac_kernel_prng(seed_ref, x_ref, w_ref, out_ref,
                        acc_mean, acc_p, acc_p2, *, inv_nbit: float, nk: int):
    """In-kernel-PRNG variant (TPU only): the Gaussian epilogue noise is
    synthesized from ``pltpu.prng_random_bits`` instead of streaming an
    (M, N) noise tile from HBM — removing one of the four HBM operands
    (EXPERIMENTS §Perf cell-3 iteration 3). Seeded per output tile so every
    (i, j) block draws an independent stream."""
    from jax.experimental.pallas import tpu as pltpu

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_mean[...] = jnp.zeros_like(acc_mean)
        acc_p[...] = jnp.zeros_like(acc_p)
        acc_p2[...] = jnp.zeros_like(acc_p2)

    x = x_ref[...]
    w = w_ref[...]
    acc_mean[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc_p[...] += jnp.dot(jnp.abs(x), jnp.abs(w),
                          preferred_element_type=jnp.float32)
    acc_p2[...] += jnp.dot(x * x, w * w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        pltpu.prng_seed(seed_ref[0], pl.program_id(0), pl.program_id(1))
        shape = acc_mean.shape
        bits_a = pltpu.prng_random_bits(shape)
        bits_b = pltpu.prng_random_bits(shape)
        noise = _box_muller(bits_a.astype(jnp.uint32),
                            bits_b.astype(jnp.uint32))
        var = jnp.maximum(acc_p[...] - acc_p2[...], 0.0) * inv_nbit
        out_ref[...] = acc_mean[...] + noise * jnp.sqrt(var)


@functools.partial(
    jax.jit,
    static_argnames=("nbit", "block_m", "block_n", "block_k", "interpret"))
def sc_mac_fused(x_signed_p, w_signed_p, noise, *, nbit: int = 1024,
                 block_m: int = 128, block_n: int = 128, block_k: int = 512,
                 interpret: bool = True):
    """Fused SC matmul on pre-encoded signed probabilities.

    x_signed_p: (M, K) f32 in [-1, 1]; w_signed_p: (K, N) f32 in [-1, 1];
    noise: (M, N) f32 standard normal. Caller multiplies the output by
    scale_x·scale_w (kept outside so the kernel stays scale-free).
    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, k = x_signed_p.shape
    k2, n = w_signed_p.shape
    assert k == k2 and noise.shape == (m, n)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(_sc_mac_kernel, inv_nbit=1.0 / nbit, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        # three f32 accumulators resident across the k loop
        scratch_shapes=[_vmem(bm, bn), _vmem(bm, bn), _vmem(bm, bn)],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(x_signed_p, w_signed_p, noise)


@functools.partial(
    jax.jit,
    static_argnames=("nbit", "block_m", "block_n", "block_k"))
def sc_mac_fused_prng(seed, x_signed_p, w_signed_p, *, nbit: int = 1024,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 512):
    """TPU-only variant: Gaussian noise generated ON-CHIP per output tile
    (``pltpu.prng_random_bits`` + Box-Muller), cutting HBM traffic from
    (MK + KN + 2MN) to (MK + KN + MN) floats. No CPU interpret path —
    ``pltpu.prng_*`` has no interpreter implementation in this container —
    so correctness is carried by the epilogue-math equivalence with
    ``sc_mac_fused`` (identical accumulators, tested) and the Box-Muller
    transform (unit-tested on CPU directly). seed: (1,) int32."""
    m, k = x_signed_p.shape
    k2, n = w_signed_p.shape
    assert k == k2
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(_sc_mac_kernel_prng, inv_nbit=1.0 / nbit,
                               nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu_smem()),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[_vmem(bm, bn), _vmem(bm, bn), _vmem(bm, bn)],
        compiler_params=_tpu_params(),
        interpret=False,
    )(seed, x_signed_p, w_signed_p)


def pltpu_smem():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.SMEM


def _vmem(bm, bn):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM((bm, bn), jnp.float32)


def _tpu_params():
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=("parallel", "parallel", "arbitrary"))
