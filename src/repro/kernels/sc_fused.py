"""Pallas TPU kernel: FULLY fused bit-exact SC matmul engine.

``pallas_bitexact`` (kernels/sc_mul.py) realizes the paper's packed MUL
faithfully but in three separate stages: the host encodes operands and
materializes the whole per-product uniform stream (O(M·K·N·nbit/32)
words through HBM), the kernel ANDs/pop-counts it, and the host reduces
over K.  This kernel collapses all of it into ONE ``pallas_call``:

* **operand-grid encoding** — tiles arrive as raw signed probabilities
  ``v / max|v|``; the LUT/DTC-grid quantization (§III-A) and the fx16
  bias-word conversion happen in-kernel, with bit-for-bit the formulas of
  ``sc/encoding.py``;
* **counter-based RNG draw** — every uniform word regenerates in-kernel
  from ``sc/ctr_rng.py``'s pinned Threefry-2x32 stream keyed by the
  *global* product coordinates, so the draw is independent of tile shape
  and identical to the stream ``pallas_bitexact`` materializes on the
  host (same key ⇒ same bits, whatever the autotuner picked);
* **MTJ write-probability thresholding** — the Horner bit-ladder of
  ``kernels/sc_mul.py`` turns uniform words into packed Bernoulli cells,
  32 per lane word (the row-parallel stochastic write);
* **pop-count accumulation** — two-pulse AND + SWAR pop-count, then a
  *signed integer* accumulation over the K grid axis in a VMEM scratch
  accumulator.

The bitstreams therefore never leave VMEM/registers — the in-situ-storage
property of the MRAM array mapped all the way down.  Integer accumulation
makes the result exactly associative, so the output is invariant to the
(block_m, block_n, block_k, lane_words) tiling: the autotuner may pick
any config without perturbing a single bit.  (Capacity notes: flat
product indices address 2^32 MULs per call and the signed per-output
accumulator holds |K·nbit| < 2^31 — both far beyond the validation
scales an O(M·K·N·nbit) engine can run at.)

Two key modes, one kernel: per-call mode (one key, product index spans
the whole (M, K, N) grid) and per-row mode (one key per output row, row
term dropped from the product index) — the latter makes each row's bits
a function of its own key alone, which is what the continuous-batching
serve engine needs (`models/layers.py:_dense_rows`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sc_mul import LANE_BITS, NSLICES, popcount32
from repro.sc import ctr_rng, encoding


def encode_fx16(p, levels: int, quantize: bool):
    """|probability| tile -> fx16 bias words, THE host encoding in-kernel.

    Calls the selfsame ``sc/encoding.py`` helpers the packed path uses
    (both are pure jnp, hence kernel-safe), so in-kernel encoding equals
    the host encoding bit-for-bit by construction — one source of truth
    for the clamped grid round (the PR-4 off-by-one territory) and the
    16-bit ladder conversion.
    """
    if quantize:
        p = encoding.quantize_grid(p, levels)
    return encoding.to_fx16(p)


def _sc_fused_kernel(keys_ref, x_ref, w_ref, out_ref, acc_ref, *,
                     n_orig: int, row_stride: int, nbit: int, levels: int,
                     quantize: bool, nk: int, lane_words: int):
    """One (bm, bn) output tile, one K step: draw, AND, pop-count, add.

    keys: (bm, 4) per-row raw threefry keys [kx0, kx1, ky0, ky1];
    x: (bm, bk) / w: (bk, bn) signed probabilities in [-1, 1];
    acc: (bm, bn) int32 signed pop-count accumulator (VMEM scratch).
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    spx = x_ref[...]                       # (bm, bk)
    spw = w_ref[...]                       # (bk, bn)
    bm, bk = spx.shape
    bn = spw.shape[1]
    nwords = nbit // LANE_BITS

    # in-kernel operand-grid encoding (sign beside magnitude, SC practice)
    fxx = encode_fx16(jnp.abs(spx), levels, quantize)      # (bm, bk) u32
    fxw = encode_fx16(jnp.abs(spw), levels, quantize)      # (bk, bn) u32
    sgx = jnp.sign(spx).astype(jnp.int32)
    sgw = jnp.sign(spw).astype(jnp.int32)

    # global product coordinates -> the pinned ctr_rng counter c0
    shape3 = (bm, bk, bn)
    gi = (pl.program_id(0) * bm
          + jax.lax.broadcasted_iota(jnp.uint32, shape3, 0))
    gk = (pl.program_id(2) * bk
          + jax.lax.broadcasted_iota(jnp.uint32, shape3, 1))
    gj = (pl.program_id(1) * bn
          + jax.lax.broadcasted_iota(jnp.uint32, shape3, 2))
    pid = (gi * jnp.uint32(row_stride) + gk * jnp.uint32(n_orig) + gj)

    kx0 = keys_ref[:, 0][:, None, None, None]
    kx1 = keys_ref[:, 1][:, None, None, None]
    ky0 = keys_ref[:, 2][:, None, None, None]
    ky1 = keys_ref[:, 3][:, None, None, None]
    c0 = pid[..., None]                    # (bm, bk, bn, 1)
    px4 = fxx[:, :, None, None]
    pw4 = fxw[None, :, :, None]

    counts = jnp.zeros(shape3, jnp.int32)
    for w0 in range(0, nwords, lane_words):
        wc = min(lane_words, nwords - w0)
        widx = (jnp.uint32(w0)
                + jax.lax.broadcasted_iota(jnp.uint32, (wc,), 0))
        tx = jnp.zeros(shape3 + (wc,), jnp.uint32)
        ty = jnp.zeros(shape3 + (wc,), jnp.uint32)
        for s in range(NSLICES):           # LSB -> MSB Horner bit-ladder
            c1 = (jnp.uint32(s * nwords) + widx)[None, None, None, :]
            ux = ctr_rng.threefry2x32(kx0, kx1, c0, c1)[0]
            uy = ctr_rng.threefry2x32(ky0, ky1, c0, c1)[0]
            mx = jnp.uint32(0) - ((px4 >> jnp.uint32(s)) & jnp.uint32(1))
            my = jnp.uint32(0) - ((pw4 >> jnp.uint32(s)) & jnp.uint32(1))
            tx = (mx & (ux | tx)) | (~mx & (ux & tx))
            ty = (my & (uy | ty)) | (~my & (uy & ty))
        survived = tx & ty                 # two-pulse AND (paper Fig. 5)
        counts += jnp.sum(popcount32(survived).astype(jnp.int32), axis=-1)

    signed = sgx[:, :, None] * sgw[None, :, :] * counts
    acc_ref[...] += jnp.sum(signed, axis=1)

    @pl.when(pl.program_id(2) == nk - 1)
    def _emit():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("k_orig", "n_orig", "nbit", "levels", "quantize",
                     "block_m", "block_n", "block_k", "lane_words",
                     "row_keys", "interpret"))
def sc_fused_popcount(keys, x_signed_p, w_signed_p, *, k_orig: int,
                      n_orig: int, nbit: int, levels: int,
                      quantize: bool = True, block_m: int = 8,
                      block_n: int = 8, block_k: int = 32,
                      lane_words: int = 16, row_keys: bool = False,
                      interpret: bool = True):
    """Fused SC matmul -> (M, N) int32 signed pop-count totals.

    keys: (M, 4) uint32 per-row raw key words [kx0, kx1, ky0, ky1] (the
    caller broadcasts one row in per-call mode); x/w: block-multiple
    signed probabilities.  ``k_orig`` / ``n_orig`` are the UNPADDED
    contraction/output widths — they define the flat product index, so
    padding never shifts a real product's stochastic draw.  With
    ``row_keys=True`` the row term drops out of the product index and
    every output row draws from its own key's stream.  The caller turns
    totals into the SC estimate via ``total / nbit · scale_x·scale_w``.
    """
    m, k = x_signed_p.shape
    k2, n = w_signed_p.shape
    assert k == k2 and keys.shape == (m, 4)
    assert nbit % LANE_BITS == 0, "fused engine packs 32 cells per word"
    assert k * nbit < 2 ** 31, \
        "signed int32 accumulator needs K*nbit < 2^31"
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    row_stride = 0 if row_keys else (k_orig * n_orig) & 0xFFFFFFFF
    kernel = functools.partial(
        _sc_fused_kernel, n_orig=n_orig, row_stride=row_stride, nbit=nbit,
        levels=levels, quantize=quantize, nk=nk,
        lane_words=min(lane_words, max(1, nbit // LANE_BITS)))
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, 4), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[_vmem_i32(bm, bn)],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(keys, x_signed_p, w_signed_p)


def _vmem_i32(bm, bn):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM((bm, bn), jnp.int32)


def _tpu_params():
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=("parallel", "parallel", "arbitrary"))
