"""Pure-jnp oracles for the Pallas kernels.

``sc_mul_popcount_ref`` reproduces kernels/sc_mul.py **bit-for-bit** (same
Horner ladder over the same random words), so tests can assert exact
equality, not just statistics. ``sc_mac_ref`` is the analytic moment-matched
matmul the fused kernel must match to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.sc_mul import NSLICES


def bernoulli_words_ref(p_fx16, u_slices):
    """(m,) bias, (m, NSLICES, w) uniforms -> (m, w) packed Bernoulli words."""
    t = jnp.zeros((u_slices.shape[0], u_slices.shape[2]), jnp.uint32)
    for j in range(NSLICES):
        bit = (p_fx16[:, None] >> j) & jnp.uint32(1)
        mask = jnp.uint32(0) - bit
        u = u_slices[:, j, :]
        t = (mask & (u | t)) | (~mask & (u & t))
    return t


def popcount32_ref(v):
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> 24


def sc_mul_popcount_ref(p_x_fx16, p_y_fx16, rand_x, rand_y):
    """Oracle for sc_mul_popcount: (M,) int32 pop-counts."""
    bx = bernoulli_words_ref(p_x_fx16, rand_x)
    by = bernoulli_words_ref(p_y_fx16, rand_y)
    return jnp.sum(popcount32_ref(bx & by), axis=-1).astype(jnp.int32)


def sc_mac_ref(x_signed_p, w_signed_p, noise, *, nbit: int):
    """Oracle for sc_mac_fused (scale-free, caller applies scale)."""
    mean = jnp.dot(x_signed_p, w_signed_p, preferred_element_type=jnp.float32)
    sum_p = jnp.dot(jnp.abs(x_signed_p), jnp.abs(w_signed_p),
                    preferred_element_type=jnp.float32)
    sum_p2 = jnp.dot(x_signed_p ** 2, w_signed_p ** 2,
                     preferred_element_type=jnp.float32)
    var = jnp.maximum(sum_p - sum_p2, 0.0) / nbit
    return mean + noise * jnp.sqrt(var)
