"""Public jit'd wrappers for the SC Pallas kernels.

Handles everything the kernels do not: probability encoding, entropy-stream
generation, padding to block multiples, and un-padding of the results. These
are the entry points the model stack (models/layers.py) and benchmarks call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import scmac as scmac_core
from repro.kernels import sc_mac as sc_mac_kernel
from repro.kernels import sc_mul as sc_mul_kernel


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def to_fx16(p):
    """Probability in [0, 1] -> 16-bit fixed-point bias word (clamped)."""
    return jnp.minimum(jnp.round(p * 65536.0), 65535.0).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("nbit", "block_m", "interpret"))
def sc_mul_bitexact(key, p_x, p_y, *, nbit: int = 1024, block_m: int = 8,
                    interpret: bool = True):
    """Batched bit-exact SC MUL of probability vectors via the Pallas engine.

    p_x, p_y: (M,) float probabilities. Returns (M,) float estimates of
    p_x·p_y (pop-count / nbit). nbit must be a multiple of 32.
    """
    assert nbit % sc_mul_kernel.LANE_BITS == 0
    w = nbit // sc_mul_kernel.LANE_BITS
    m = p_x.shape[0]
    px = _pad_to(to_fx16(p_x), block_m, 0)
    py = _pad_to(to_fx16(p_y), block_m, 0)
    mp = px.shape[0]
    kx, ky = jax.random.split(key)
    shape = (mp, sc_mul_kernel.NSLICES, w)
    rx = jax.random.bits(kx, shape, jnp.uint32)
    ry = jax.random.bits(ky, shape, jnp.uint32)
    counts = sc_mul_kernel.sc_mul_popcount(px, py, rx, ry,
                                           block_m=block_m,
                                           interpret=interpret)
    return counts[:m].astype(jnp.float32) / nbit


@functools.partial(
    jax.jit,
    static_argnames=("nbit", "block_m", "block_n", "block_k", "interpret"))
def sc_matmul_fused(key, x, w, *, nbit: int = 1024, block_m: int = 128,
                    block_n: int = 128, block_k: int = 512,
                    interpret: bool = True):
    """Moment-matched SC matmul of float tensors via the fused Pallas kernel.

    x: (M, K), w: (K, N) floats. Encodes to signed probabilities (per-tensor
    max-abs scale, paper's 10-bit operand grid), runs the fused kernel, and
    rescales. Drop-in for ``x @ w`` with SC sampling noise.
    """
    cfg = scmac_core.SCMacConfig(mode="moment", nbit=nbit)
    sx, px, scx = scmac_core.encode(x, cfg)
    sw, pw, scw = scmac_core.encode(w, cfg)
    xs = _pad_to(sx * px, max(1, min(block_m, x.shape[0])), 0)
    xs = _pad_to(xs, min(block_k, x.shape[1]), 1)
    ws = _pad_to(sw * pw, min(block_k, x.shape[1]), 0)
    ws = _pad_to(ws, max(1, min(block_n, w.shape[1])), 1)
    noise = jax.random.normal(key, (xs.shape[0], ws.shape[1]), jnp.float32)
    out = sc_mac_kernel.sc_mac_fused(
        xs, ws, noise, nbit=nbit, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interpret)
    return out[: x.shape[0], : w.shape[1]] * (scx * scw)
