"""DEPRECATED shims for the SC Pallas kernels — use :mod:`repro.sc`.

The encoding / padding / entropy-stream plumbing that used to live here is
now part of the unified substrate (``repro.sc.encoding`` and the
``pallas_*`` backends in ``repro.sc.backends``); the model stack reaches
the kernels through ``repro.sc.sc_dot`` rather than these wrappers.

Kept entry points:

* ``sc_mul_bitexact``  — batched probability-vector MUL (not matmul
  shaped; still the direct way to exercise the packed engine on raw
  probabilities, as the quickstart and kernel tests do).
* ``sc_matmul_fused``  — alias for the ``pallas_moment`` backend.
* ``to_fx16``          — re-export of the canonical fx16 bias encoding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sc import ScConfig, encoding
from repro.sc.backends import pallas_moment
from repro.kernels import sc_mul as sc_mul_kernel

to_fx16 = encoding.to_fx16
_pad_to = encoding.pad_to


@functools.partial(jax.jit, static_argnames=("nbit", "block_m", "interpret"))
def sc_mul_bitexact(key, p_x, p_y, *, nbit: int = 1024, block_m: int = 8,
                    interpret: bool = True):
    """Batched bit-exact SC MUL of probability vectors via the Pallas engine.

    p_x, p_y: (M,) float probabilities. Returns (M,) float estimates of
    p_x·p_y (pop-count / nbit). nbit must be a multiple of 32.
    """
    assert nbit % sc_mul_kernel.LANE_BITS == 0
    w = nbit // sc_mul_kernel.LANE_BITS
    m = p_x.shape[0]
    px = _pad_to(to_fx16(p_x), block_m, 0)
    py = _pad_to(to_fx16(p_y), block_m, 0)
    mp = px.shape[0]
    kx, ky = jax.random.split(key)
    shape = (mp, sc_mul_kernel.NSLICES, w)
    rx = jax.random.bits(kx, shape, jnp.uint32)
    ry = jax.random.bits(ky, shape, jnp.uint32)
    counts = sc_mul_kernel.sc_mul_popcount(px, py, rx, ry,
                                           block_m=block_m,
                                           interpret=interpret)
    return counts[:m].astype(jnp.float32) / nbit


@functools.partial(
    jax.jit,
    static_argnames=("nbit", "block_m", "block_n", "block_k", "interpret"))
def sc_matmul_fused(key, x, w, *, nbit: int = 1024, block_m: int = 128,
                    block_n: int = 128, block_k: int = 512,
                    interpret: bool = True):
    """Deprecated alias: ``sc_dot`` with ``backend="pallas_moment"``."""
    cfg = ScConfig(backend="pallas_moment", nbit=nbit, block_m=block_m,
                   block_n=block_n, block_k=block_k, interpret=interpret)
    return pallas_moment(key, x, w, cfg)
