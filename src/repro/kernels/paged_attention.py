"""Pallas kernel: fused paged attention for the decode hot path.

The paged serve path (``models/attention.py:paged_attention_block``)
historically ran block-table gather -> QK^T -> softmax -> V accumulation
as separate XLA ops, materializing every row's full gathered cache view
per tick.  This kernel collapses the sequence into ONE ``pallas_call``
per layer, flash-attention style:

* **block-table gather in-kernel** — the K/V block pools ride in whole
  (one kv-head slice per grid step) and each grid step loads just the
  one ``block_size`` page its block-table entry names, so the
  (b, nb*bs, kv, hd) gathered view is never materialized;
* **online softmax** — a running (max, denominator, accumulator) triple
  lives in VMEM scratch across the KV-block grid axis (the same
  recurrence as ``models/attention.py:blockwise_attention``), so peak
  memory per step is one (block_q, block_size) logits tile;
* **masking identical to the unfused path** — kv position ``t`` is live
  for chunk row ``i`` of request ``r`` iff ``t <= lengths[r] + i``
  (causal within the chunk plus the fill mask), exactly
  ``chunk_decode_attention``'s predicate, so fused and unfused outputs
  agree to float tolerance and greedy-decoded tokens are identical
  (``tests/test_paged_attention.py``).

The SC variant (:func:`paged_attention_fused_sc`) replaces the exact
QK^T with the paper's stochastic MUL: operands quantize onto the DTC
grid in-kernel (``kernels/sc_fused.py:encode_fx16``), Bernoulli cells
come from the Horner bit-ladder, and logits are signed pop-count totals.
Every uniform word draws from ``sc/ctr_rng.py``'s pinned Threefry-2x32
stream with the QUERY TOKEN's key (folded from its request key and
absolute position upstream) and counter

    c0 = (t_abs * n_heads + head) * head_dim + d,   c1 = s * nwords + w

so a logit's bits depend only on (request key, query position, kv
position, head, d) — never on batch composition, chunk boundaries, KV
block size, or eviction/resume.  :func:`sc_qk_logits_host` is the
host-side twin (same jnp body, bit equality by construction) the
invariance tests pin against.

Tile selection (``block_q`` rows per grid step, ``lane_words`` RNG words
per Horner sweep) routes through ``sc/autotune.py``'s versioned cache
under the ``attn`` kernel kind, with a deterministic heuristic fallback.
Tiling never changes bits: each logit's pop-count total is computed
whole within one grid step from globally-addressed counters.

Like every Pallas kernel in this repo the launch defaults to
``interpret=True`` (CPU correctness harness); real TPUs flip it off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sc_fused import encode_fx16
from repro.kernels.sc_mul import LANE_BITS, NSLICES, popcount32
from repro.sc import autotune, ctr_rng

NEG_INF = -1e30  # matches models/attention.py
_DENOM_GUARD = 1e-30  # matches blockwise_attention's divide guard
_SCALE_GUARD = 1e-30  # matches sc/encoding.py's max-abs clamp


def _scale(hd: int):
    # the selfsame construction as chunk_decode_attention, so the fused
    # logits match the unfused path bit-for-bit before the softmax
    return 1.0 / jnp.sqrt(hd).astype(jnp.float32)


def split_keys4(keys):
    """Per-token raw ``(..., 2)`` keys -> ``(..., 4)`` operand key words.

    The same x/y operand-stream split the fused SC matmul uses
    (``sc/backends.py:pallas_fused_rows``): ``jax.random.split`` each
    token key, query stream takes the first half, key stream the second.
    """
    raw = ctr_rng.raw_key(keys)
    flat = raw.reshape(-1, 2)
    split = jax.vmap(jax.random.split)(flat)  # (N, 2, 2)
    keys4 = jnp.concatenate([split[:, 0], split[:, 1]], axis=-1)
    return keys4.reshape(raw.shape[:-1] + (4,)).astype(jnp.uint32)


def _online_softmax_step(logits, v_blk, m_ref, d_ref, a_ref):
    """One flash-attention update of the (m, denom, acc) VMEM carry."""
    m_prev = m_ref[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    m_ref[...] = m_new
    d_ref[...] = d_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    a_ref[...] = a_ref[...] * alpha + jnp.dot(p, v_blk)


def _mask(logits, len_ref, *, j, sc, block_size, block_q):
    """``chunk_decode_attention``'s predicate: t <= lengths[r] + i."""
    shape = (block_q, block_size)
    t_idx = j * block_size + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    row = pl.program_id(2) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, shape, 0
    )
    q_pos = len_ref[0, 0] + row % sc
    return jnp.where(t_idx <= q_pos, logits, NEG_INF)


def _paged_attn_kernel(
    bt_ref,
    len_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    d_ref,
    a_ref,
    *,
    sc: int,
    block_size: int,
    nb: int,
    block_q: int,
):
    """Deterministic fused step: gather one page, QK^T, online softmax."""
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        a_ref[...] = jnp.zeros_like(a_ref)

    page = bt_ref[0, 0]
    k_blk = k_ref[page][:, 0, :].astype(jnp.float32)  # (bs, hd)
    v_blk = v_ref[page][:, 0, :].astype(jnp.float32)  # (bs, hd)
    q_blk = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    logits = jnp.dot(q_blk, k_blk.T) * _scale(q_blk.shape[-1])
    logits = _mask(
        logits, len_ref, j=j, sc=sc, block_size=block_size, block_q=block_q
    )
    _online_softmax_step(logits, v_blk, m_ref, d_ref, a_ref)

    @pl.when(j == nb - 1)
    def _emit():
        out = a_ref[...] / jnp.maximum(d_ref[...], _DENOM_GUARD)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _sc_counts(keys4, fxq, fxk, c0, *, nbit: int, lane_words: int):
    """Signed-magnitude pop-count core shared by kernel and host twin.

    keys4: (bq, 4) per-row operand key words; fxq: (bq, hd) fx16 query
    magnitudes; fxk: (bs, hd) fx16 key magnitudes; c0: (bq, bs, hd)
    uint32 product counters.  Returns int32 (bq, bs, hd) pop-count
    totals.  Integer accumulation over words is associative, so
    ``lane_words`` can never change the result.
    """
    nwords = nbit // LANE_BITS
    kq0 = keys4[:, 0][:, None, None, None]
    kq1 = keys4[:, 1][:, None, None, None]
    kk0 = keys4[:, 2][:, None, None, None]
    kk1 = keys4[:, 3][:, None, None, None]
    c0_4 = c0[..., None]
    pq4 = fxq[:, None, :, None]
    pk4 = fxk[None, :, :, None]
    counts = jnp.zeros(c0.shape, jnp.int32)
    for w0 in range(0, nwords, lane_words):
        wc = min(lane_words, nwords - w0)
        widx = jnp.uint32(w0) + jax.lax.broadcasted_iota(
            jnp.uint32, (wc,), 0
        )
        tq = jnp.zeros(c0.shape + (wc,), jnp.uint32)
        tk = jnp.zeros(c0.shape + (wc,), jnp.uint32)
        for s in range(NSLICES):  # LSB -> MSB Horner bit-ladder
            c1 = (jnp.uint32(s * nwords) + widx)[None, None, None, :]
            uq = ctr_rng.threefry2x32(kq0, kq1, c0_4, c1)[0]
            uk = ctr_rng.threefry2x32(kk0, kk1, c0_4, c1)[0]
            mq = jnp.uint32(0) - ((pq4 >> jnp.uint32(s)) & jnp.uint32(1))
            mk = jnp.uint32(0) - ((pk4 >> jnp.uint32(s)) & jnp.uint32(1))
            tq = (mq & (uq | tq)) | (~mq & (uq & tq))
            tk = (mk & (uk | tk)) | (~mk & (uk & tk))
        survived = tq & tk  # two-pulse AND (paper Fig. 5)
        counts += jnp.sum(popcount32(survived).astype(jnp.int32), axis=-1)
    return counts


def _sc_logits(q_blk, k_blk, keys4, c0, *, nbit, levels, quantize, lane):
    """SC-sampled QK^T logits tile from exact q/k tiles (f32 in/out)."""
    scq = jnp.maximum(jnp.max(jnp.abs(q_blk), axis=1), _SCALE_GUARD)
    sck = jnp.maximum(jnp.max(jnp.abs(k_blk), axis=1), _SCALE_GUARD)
    fxq = encode_fx16(jnp.abs(q_blk) / scq[:, None], levels, quantize)
    fxk = encode_fx16(jnp.abs(k_blk) / sck[:, None], levels, quantize)
    sgq = jnp.sign(q_blk).astype(jnp.int32)
    sgk = jnp.sign(k_blk).astype(jnp.int32)
    counts = _sc_counts(keys4, fxq, fxk, c0, nbit=nbit, lane_words=lane)
    signed = sgq[:, None, :] * sgk[None, :, :] * counts
    total = jnp.sum(signed, axis=-1).astype(jnp.float32)  # (bq, bs)
    est = total / jnp.float32(nbit) * scq[:, None] * sck[None, :]
    return est * _scale(q_blk.shape[-1])


def _paged_attn_sc_kernel(
    bt_ref,
    len_ref,
    q_ref,
    keys_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    d_ref,
    a_ref,
    *,
    sc: int,
    block_size: int,
    nb: int,
    block_q: int,
    n_heads: int,
    group: int,
    nbit: int,
    levels: int,
    quantize: bool,
    lane_words: int,
):
    """SC-sampled fused step: same gather and online softmax, but the
    QK^T tile is the paper's stochastic MUL drawn from each query
    token's pinned counter stream."""
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        a_ref[...] = jnp.zeros_like(a_ref)

    page = bt_ref[0, 0]
    k_blk = k_ref[page][:, 0, :].astype(jnp.float32)  # (bs, hd)
    v_blk = v_ref[page][:, 0, :].astype(jnp.float32)  # (bs, hd)
    q_blk = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    hd = q_blk.shape[-1]

    # global (query row, kv position, lane) -> pinned product counter:
    # the query's identity rides in its KEY, the kv side in the counter,
    # so the draw survives any batch/chunk/block-size/eviction reshuffle
    shape3 = (block_q, block_size, hd)
    t_abs = jnp.uint32(j * block_size) + jax.lax.broadcasted_iota(
        jnp.uint32, shape3, 1
    )
    d_idx = jax.lax.broadcasted_iota(jnp.uint32, shape3, 2)
    row = jnp.uint32(pl.program_id(2) * block_q) + jax.lax.broadcasted_iota(
        jnp.uint32, shape3, 0
    )
    head = (
        jnp.uint32(pl.program_id(1)) * jnp.uint32(group)
        + row // jnp.uint32(sc)
    )
    c0 = (t_abs * jnp.uint32(n_heads) + head) * jnp.uint32(hd) + d_idx

    logits = _sc_logits(
        q_blk,
        k_blk,
        keys_ref[0],
        c0,
        nbit=nbit,
        levels=levels,
        quantize=quantize,
        lane=lane_words,
    )
    logits = _mask(
        logits, len_ref, j=j, sc=sc, block_size=block_size, block_q=block_q
    )
    _online_softmax_step(logits, v_blk, m_ref, d_ref, a_ref)

    @pl.when(j == nb - 1)
    def _emit():
        out = a_ref[...] / jnp.maximum(d_ref[...], _DENOM_GUARD)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _tpu_params():
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    return cls(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
    )


def _rows_layout(q, kvh: int):
    """(b, sc, h, hd) queries -> (b, kvh, g*sc, hd) kernel rows.

    Row ``r`` of a (batch, kv-head) slice holds query head
    ``kvh_index * g + r // sc`` at chunk offset ``r % sc`` — the same
    grouping as ``models/attention.py:_grouped``.
    """
    b, sc, h, hd = q.shape
    g = h // kvh
    qg = q.reshape(b, sc, kvh, g, hd).transpose(0, 2, 3, 1, 4)
    return qg.reshape(b, kvh, g * sc, hd)


def _rows_unlayout(out, *, sc: int, h: int):
    """Inverse of :func:`_rows_layout` (after slicing off row padding)."""
    b, kvh, rows, hd = out.shape
    g = rows // sc
    out = out.reshape(b, kvh, g, sc, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, sc, h, hd)


def _launch(
    kernel,
    *,
    grid,
    block_q,
    hd,
    num_pages,
    bs,
    b,
    kvh,
    rows_p,
    extra_specs,
    operands,
    interpret,
):
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, h_, qi, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, h_, qi, j: (i, 0)),
            pl.BlockSpec(
                (1, 1, block_q, hd), lambda i, h_, qi, j: (i, h_, qi, 0)
            ),
            *extra_specs,
            pl.BlockSpec(
                (num_pages, bs, 1, hd), lambda i, h_, qi, j: (0, 0, h_, 0)
            ),
            pl.BlockSpec(
                (num_pages, bs, 1, hd), lambda i, h_, qi, j: (0, 0, h_, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda i, h_, qi, j: (i, h_, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, rows_p, hd), jnp.float32),
        scratch_shapes=[
            _vmem((block_q, 1)),
            _vmem((block_q, 1)),
            _vmem((block_q, hd)),
        ],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(*operands)


def paged_attention_fused(
    q,
    k_pages,
    v_pages,
    block_table,
    lengths,
    *,
    block_q: int = 0,
    interpret: bool = True,
):
    """Fused paged attention, deterministic QK^T.

    q: (b, sc, h, hd) post-rope queries (chunk token i of row r sits at
    absolute position ``lengths[r] + i``, K/V already scattered);
    k/v_pages: (P, bs, kvh, hd) block pools; block_table: (b, nb);
    lengths: (b,) pre-chunk fill.  Returns (b, sc, h, hd) — the fused
    equivalent of ``chunk_decode_attention(q, paged_gather(k), ...)``.
    ``block_q = 0`` takes the row tile from the autotune cache
    (``attn`` kernel kind; heuristic on miss).
    """
    import functools

    b, sc, h, hd = q.shape
    num_pages, bs, kvh, _ = k_pages.shape
    nb = block_table.shape[1]
    rows = (h // kvh) * sc
    if block_q <= 0:
        block_q = autotune.get_attn_tile(rows, bs, hd, 0).block_q
    qr = _rows_layout(q, kvh)
    pad = (-rows) % block_q
    if pad:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, pad), (0, 0)))
    rows_p = rows + pad
    kernel = functools.partial(
        _paged_attn_kernel, sc=sc, block_size=bs, nb=nb, block_q=block_q
    )
    out = _launch(
        kernel,
        grid=(b, kvh, rows_p // block_q, nb),
        block_q=block_q,
        hd=hd,
        num_pages=num_pages,
        bs=bs,
        b=b,
        kvh=kvh,
        rows_p=rows_p,
        extra_specs=[],
        operands=(
            block_table.astype(jnp.int32),
            lengths.astype(jnp.int32)[:, None],
            qr,
            k_pages,
            v_pages,
        ),
        interpret=interpret,
    )
    return _rows_unlayout(out[:, :, :rows], sc=sc, h=h).astype(q.dtype)


def paged_attention_fused_sc(
    keys,
    q,
    k_pages,
    v_pages,
    block_table,
    lengths,
    *,
    nbit: int,
    operand_bits: int = 10,
    quantize: bool = True,
    block_q: int = 0,
    lane_words: int = 0,
    interpret: bool = True,
):
    """Fused paged attention with the SC-sampled QK^T.

    keys: (b, sc, 2) raw per-token keys — each already folded from its
    request key and ABSOLUTE position upstream (``lm.decode_paged``), so
    the stochastic logits a token draws are a function of (request key,
    position, head, kv position) alone.  Other operands as
    :func:`paged_attention_fused`.  ``block_q`` / ``lane_words`` = 0
    take the ``attn`` autotune entry; the tiling never changes bits.
    """
    import functools

    b, sc, h, hd = q.shape
    num_pages, bs, kvh, _ = k_pages.shape
    nb = block_table.shape[1]
    g = h // kvh
    rows = g * sc
    assert nbit % LANE_BITS == 0, "SC attention packs 32 cells per word"
    tile = autotune.get_attn_tile(rows, bs, hd, nbit)
    if block_q <= 0:
        block_q = tile.block_q
    if lane_words <= 0:
        lane_words = tile.lane_words
    lane_words = min(lane_words, max(1, nbit // LANE_BITS))
    qr = _rows_layout(q, kvh)
    keys4 = split_keys4(keys)  # (b, sc, 4)
    rowk = jnp.broadcast_to(keys4[:, None], (b, g, sc, 4)).reshape(b, rows, 4)
    pad = (-rows) % block_q
    if pad:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, pad), (0, 0)))
        rowk = jnp.pad(rowk, ((0, 0), (0, pad), (0, 0)))
    rows_p = rows + pad
    kernel = functools.partial(
        _paged_attn_sc_kernel,
        sc=sc,
        block_size=bs,
        nb=nb,
        block_q=block_q,
        n_heads=h,
        group=g,
        nbit=nbit,
        levels=1 << operand_bits,
        quantize=quantize,
        lane_words=lane_words,
    )
    out = _launch(
        kernel,
        grid=(b, kvh, rows_p // block_q, nb),
        block_q=block_q,
        hd=hd,
        num_pages=num_pages,
        bs=bs,
        b=b,
        kvh=kvh,
        rows_p=rows_p,
        extra_specs=[
            pl.BlockSpec((1, block_q, 4), lambda i, h_, qi, j: (i, qi, 0)),
        ],
        operands=(
            block_table.astype(jnp.int32),
            lengths.astype(jnp.int32)[:, None],
            qr,
            rowk,
            k_pages,
            v_pages,
        ),
        interpret=interpret,
    )
    return _rows_unlayout(out[:, :, :rows], sc=sc, h=h).astype(q.dtype)


def sc_qk_logits_host(
    key,
    q_row,
    k_rows,
    t_abs,
    head: int,
    n_heads: int,
    *,
    nbit: int,
    operand_bits: int = 10,
    quantize: bool = True,
):
    """Host-side twin of the kernel's SC QK^T for ONE query token.

    key: raw (2,) token key; q_row: (hd,) post-rope query; k_rows:
    (T, hd) cache rows sitting at absolute positions ``t_abs`` (T,);
    ``head`` is the query's flat head index.  Same jnp body as the
    kernel (same counters, same Threefry, same Horner ladder), so the
    returned (T,) logits equal the kernel's pre-mask logits bit-for-bit
    by construction — the anchor the reproducibility tests pin.
    """
    hd = q_row.shape[-1]
    keys4 = split_keys4(key[None])  # (1, 4)
    t_abs = jnp.asarray(t_abs, jnp.uint32)
    d_idx = jnp.arange(hd, dtype=jnp.uint32)
    c0 = (
        t_abs[None, :, None] * jnp.uint32(n_heads) + jnp.uint32(head)
    ) * jnp.uint32(hd) + d_idx[None, None, :]
    logits = _sc_logits(
        q_row[None].astype(jnp.float32),
        k_rows.astype(jnp.float32),
        keys4,
        c0,
        nbit=nbit,
        levels=1 << operand_bits,
        quantize=quantize,
        lane=max(1, nbit // LANE_BITS),
    )
    return logits[0]
