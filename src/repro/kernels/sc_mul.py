"""Pallas TPU kernel: packed bit-exact SC multiplication engine.

This kernel plays the role of one bank of cross-point SOT-MRAM sub-arrays
(paper Fig. 4/5): for a batch of M MULs it materializes the stochastic bit
arrays, applies the two-pulse AND semantics, and pop-counts — all inside one
VMEM-resident pass, so the "data explosion" of SC never touches HBM
(the paper's in-situ-storage property mapped to in-VMEM residency).

Bit representation: 32 stochastic cells per ``uint32`` lane word. Per-bit
Bernoulli(p) draws are synthesized from iid uniform words with the
**bit-sliced Horner ladder** (the classic weighted-bitstream construction):

    t = 0
    for slice j = LSB..MSB of p (16-bit fixed point):
        t = u_j | t   if bit_j(p) else   u_j & t

which yields P(bit of t = 1) = p exactly to 2^-16, for all 32 lanes of every
word in parallel — this is the TPU-native analogue of the row-parallel
stochastic write (every cell sees an independent coin with the same bias).

Pop-count is SWAR (shift-mask-add) on the packed words, fused with the
generation so the bits live and die inside VMEM.

Entropy source: random words are *inputs* (counter-based threefry generated
by the caller) because ``pltpu.prng_random_bits`` has no CPU interpret path
in this container. On real TPU hardware the :func:`sc_mul_bitexact` wrapper
can flip ``inkernel_prng=True`` to generate the words on-chip and shrink the
input stream by 32×; the kernel math is unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NSLICES = 16        # fixed-point precision of the Bernoulli bias (2^-16)
LANE_BITS = 32      # stochastic cells per packed word


def bernoulli_words(p_fx16, u_slices):
    """Packed Bernoulli(p) words from NSLICES uniform words (Horner ladder).

    p_fx16:   (bm, 1)  uint32 — bias in 16-bit fixed point (p·2^16, clamped)
    u_slices: (bm, NSLICES, bw) uint32 — iid uniform random words
    returns:  (bm, bw) uint32 — each bit iid Bernoulli(p) per row
    """
    t = jnp.zeros(u_slices.shape[:1] + u_slices.shape[2:], jnp.uint32)
    for j in range(NSLICES):            # LSB -> MSB of the fixed-point bias
        bit = (p_fx16 >> j) & jnp.uint32(1)          # (bm, 1)
        mask = (jnp.uint32(0) - bit)                 # 0 or 0xFFFFFFFF
        u = u_slices[:, j, :]
        t = (mask & (u | t)) | (~mask & (u & t))
    return t


def popcount32(v):
    """SWAR pop-count of every uint32 word."""
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> 24


def _sc_mul_kernel(px_ref, py_ref, ux_ref, uy_ref, out_ref):
    """One tile: bm MULs × bw packed words.

    px/py: (bm, 1) uint32 biases; ux/uy: (bm, NSLICES, bw) uniform words;
    out: (bm, 1) int32 pop-counts of the surviving cells.
    """
    px = px_ref[...]
    py = py_ref[...]
    bits_x = bernoulli_words(px, ux_ref[...])   # pulse τ_X survival draw
    bits_y = bernoulli_words(py, uy_ref[...])   # pulse τ_Y survival draw
    survived = bits_x & bits_y                  # two-pulse AND (Fig. 5)
    counts = popcount32(survived)               # (bm, bw) per-word counts
    out_ref[...] = jnp.sum(counts, axis=-1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def sc_mul_popcount(p_x_fx16, p_y_fx16, rand_x, rand_y, *,
                    block_m: int = 8, interpret: bool = True):
    """Batched bit-exact SC MUL: returns pop-counts, shape (M,) int32.

    p_*_fx16: (M,) uint32 biases (p·2^16); rand_*: (M, NSLICES, W) uint32.
    nbit = 32·W stochastic cells per MUL. M must be a multiple of block_m
    (:func:`sc_mul_bitexact` pads).
    """
    m, nslices, w = rand_x.shape
    assert nslices == NSLICES and m % block_m == 0
    grid = (m // block_m,)
    out = pl.pallas_call(
        _sc_mul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_m, NSLICES, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_m, NSLICES, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(p_x_fx16.reshape(m, 1), p_y_fx16.reshape(m, 1), rand_x, rand_y)
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("nbit", "block_m", "interpret"))
def sc_mul_bitexact(key, p_x, p_y, *, nbit: int = 1024, block_m: int = 8,
                    interpret: bool = True):
    """Batched bit-exact SC MUL of probability vectors via the Pallas engine.

    The direct way to exercise the packed engine on raw probabilities
    (quickstart / kernel tests); the model stack reaches it through the
    ``pallas_bitexact`` registry backend instead.  p_x, p_y: (M,) float
    probabilities.  Returns (M,) float estimates of p_x·p_y (pop-count /
    nbit).  nbit must be a multiple of 32.
    """
    # local import: repro.sc pulls this module in through the backend
    # registry, so a top-level import would be circular
    from repro.sc import encoding

    assert nbit % LANE_BITS == 0
    w = nbit // LANE_BITS
    m = p_x.shape[0]
    px = encoding.pad_to(encoding.to_fx16(p_x), block_m, 0)
    py = encoding.pad_to(encoding.to_fx16(p_y), block_m, 0)
    mp = px.shape[0]
    kx, ky = jax.random.split(key)
    shape = (mp, NSLICES, w)
    rx = jax.random.bits(kx, shape, jnp.uint32)
    ry = jax.random.bits(ky, shape, jnp.uint32)
    counts = sc_mul_popcount(px, py, rx, ry, block_m=block_m,
                             interpret=interpret)
    return counts[:m].astype(jnp.float32) / nbit
