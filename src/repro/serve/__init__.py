from repro.serve.engine import Request, ServeConfig, ServingEngine  # noqa: F401
