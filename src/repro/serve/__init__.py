from repro.serve.api import (                             # noqa: F401
    ServeOptions, add_cli_args, build_engine, from_cli_args)
from repro.serve.engine import (                          # noqa: F401
    PagedServeConfig, PagedServingEngine, Request, ServeConfig,
    ServingEngine)
from repro.serve.kv_cache import (                        # noqa: F401
    BlockPool, PagedCacheConfig, PagedKVCache, default_num_blocks)
from repro.serve.scheduler import Scheduler, TickPlan     # noqa: F401
