"""Unified serve-engine construction: ONE options dataclass, ONE builder.

Before PR-10 every consumer picked a constructor (``ServingEngine`` vs
``PagedServingEngine``), a config class (``ServeConfig`` vs
``PagedServeConfig``) and a pile of loose kwargs (mesh, shard rules,
fused attention via ``cfg.paged_attn`` edits); the launcher re-declared
all of it as ~17 hand-rolled argparse flags.  This module is the single
source of truth:

* :class:`ServeOptions` — every serve knob as one frozen dataclass.
  Field metadata carries the CLI flag/help, so :func:`add_cli_args`
  DERIVES the launcher's argparse surface from the dataclass (a new
  field, e.g. ``fault_profile``, becomes a flag with zero launcher
  edits).
* :func:`build_engine` — ``(params, cfg, options) -> engine``.  Picks
  the engine class, applies cross-cutting options (fused attention onto
  ``cfg.paged_attn``, a device :class:`~repro.core.physics.DeviceProfile`
  onto the SC substrate), and is the ONLY supported construction path —
  calling ``ServingEngine(...)`` / ``PagedServingEngine(...)`` directly
  still works but emits ``DeprecationWarning``.

    from repro.serve import ServeOptions, build_engine
    engine = build_engine(params, cfg, ServeOptions(paged=True,
                                                    prefix_cache=True))
"""

from __future__ import annotations

import dataclasses

from repro.core import physics


def _opt(default, help="", flag=None, metavar=None, cli=True):  # noqa: A002
    """Field with CLI metadata (flag defaults to ``--field-name``)."""
    return dataclasses.field(default=default, metadata={
        "help": help, "flag": flag, "metavar": metavar, "cli": cli})


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    """Every serve-engine knob in one frozen dataclass.

    Subsumes ``ServeConfig`` (fixed-slot) and ``PagedServeConfig``
    (paged) plus the construction-time extras (mesh, fused attention,
    fault profile).  Fields irrelevant to the selected engine are simply
    unused — ``build_engine`` validates the combinations that would
    silently lie (e.g. ``prefix_cache`` without ``paged``).
    """

    paged: bool = _opt(
        False, "serve through the paged continuous-batching engine "
        "(block-pool KV cache + chunked prefill + eviction-on-OOM; every "
        "family — ssm/hybrid archs carry state slots beside the block "
        "table)")
    slots: int = _opt(4, "concurrent batch rows")
    max_len: int = _opt(128, "max context tokens per request")
    seed: int = _opt(0, "engine base PRNG seed (per-request keys fold "
                        "off it)")
    eos_id: int = _opt(2, "end-of-sequence token id", cli=False)
    block_size: int = _opt(16, "tokens per KV block (--paged)")
    num_blocks: int = _opt(
        0, "pool size in blocks incl. the null block (--paged; 0 = size "
        "for slots x max_len)", flag="--max-blocks")
    prefill_chunk: int = _opt(
        8, "prompt tokens fed per row per tick (--paged)")
    rng_mode: str = _opt(
        "request", "per-token sampling-key derivation: 'request' "
        "(rid-keyed) or 'content' (token-content chain; what "
        "--prefix-cache switches to)", cli=False)
    fused_attention: bool = _opt(
        False, "run the fused paged-attention Pallas kernel instead of "
        "gather+chunk_decode_attention (--paged; see docs/kernels.md)")
    prefix_cache: bool = _opt(
        False, "block-level prefix caching: requests sharing a prompt "
        "prefix adopt cached KV blocks instead of re-prefilling "
        "(--paged; forces content-chain rng — see "
        "docs/prefix_caching.md)")
    speculative: bool = _opt(
        False, "draft/verify speculative decoding on greedy rows: draft "
        "with the paired cheap backend, verify in one multi-token pass "
        "(--paged)")
    spec_k: int = _opt(4, "draft tokens per speculative step "
                          "(--speculative)")
    draft_backend: str = _opt(
        "", "draft backend name (--speculative; default: the registry "
        "pairing for the arch's sc_backend)")
    mesh: bool = _opt(
        False, "shard the SC substrate over a local device mesh (slots "
        "map to data shards; needs a stochastic --arch sc_backend; "
        "fixed-slot engine only)")
    model_parallel: int = _opt(
        1, "model axis size of the local mesh (--mesh)")
    fault_profile: str = _opt(
        "", "serve on a non-ideal device: named "
        "core/physics.py:DeviceProfile (ideal|tiny|calibrated|harsh) "
        "realized by the array backend — per-cell variation + stuck/"
        "retention bit errors, exported as arch_bit_errors_total",
        metavar="NAME")
    chaos: bool = _opt(
        False, "chaos-test fault tolerance: serve a 2-shard paged fleet "
        "under ft.FleetSupervisor, inject a deterministic mid-run shard "
        "degradation, and drain/resume its requests on the healthy "
        "shard (implies --paged)")

    def replace(self, **kw) -> "ServeOptions":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        """Raise ValueError on knob combinations that cannot serve."""
        if self.paged and self.mesh:
            raise ValueError(
                "paged and mesh are mutually exclusive (the paged engine "
                "is single-mesh-slice; see docs/serving.md)")
        if self.fused_attention and not self.paged:
            raise ValueError("fused_attention needs paged=True (it is "
                             "the paged decode path's kernel)")
        if (self.prefix_cache or self.speculative) and not self.paged:
            raise ValueError(
                "prefix_cache/speculative need paged=True (they are "
                "paged-engine features; see docs/prefix_caching.md)")
        if self.chaos and self.mesh:
            raise ValueError("chaos runs a paged fleet; drop mesh=True")
        if self.rng_mode not in ("request", "content"):
            raise ValueError(f"rng_mode must be 'request' or 'content', "
                             f"got {self.rng_mode!r}")
        self.resolve_profile()   # raises ValueError on unknown names

    def resolve_profile(self) -> physics.DeviceProfile | None:
        """``fault_profile`` as a DeviceProfile (None when unset/ideal-
        by-name is kept — an explicit 'ideal' still threads through so
        the bit-identity contract is exercised end to end)."""
        if not self.fault_profile:
            return None
        try:
            return physics.resolve_profile(self.fault_profile)
        except KeyError as e:
            raise ValueError(str(e)) from None


def add_cli_args(ap, skip: tuple = ()) -> None:
    """Derive argparse flags from :class:`ServeOptions` fields — the
    launcher's one-source-of-truth surface.  Booleans become
    ``store_true`` switches; everything else keeps its field default."""
    for f in dataclasses.fields(ServeOptions):
        meta = f.metadata
        if not meta.get("cli", True) or f.name in skip:
            continue
        flag = meta.get("flag") or "--" + f.name.replace("_", "-")
        if isinstance(f.default, bool):
            ap.add_argument(flag, action="store_true", dest=f.name,
                            help=meta.get("help", ""))
        else:
            kw = {}
            if meta.get("metavar"):
                kw["metavar"] = meta["metavar"]
            ap.add_argument(flag, type=type(f.default), default=f.default,
                            dest=f.name, help=meta.get("help", ""), **kw)


def from_cli_args(args, **overrides) -> ServeOptions:
    """Collect parsed :func:`add_cli_args` flags back into options."""
    kw = {f.name: getattr(args, f.name)
          for f in dataclasses.fields(ServeOptions)
          if f.metadata.get("cli", True) and hasattr(args, f.name)}
    kw.update(overrides)
    return ServeOptions(**kw)


def build_engine(params, cfg, options: ServeOptions | None = None, *,
                 collect_arch_trace: bool = False, metrics=None,
                 tracer=None, mesh=None, shard_rules=None):
    """THE serve-engine constructor: options -> the right engine, wired.

    * ``options.paged`` selects ``PagedServingEngine`` vs the fixed-slot
      ``ServingEngine`` (``mesh``/``shard_rules`` ride along for the
      fixed-slot sharded path).
    * ``options.fused_attention`` applies ``cfg.paged_attn='fused'`` —
      callers no longer edit the model config by hand.
    * ``options.fault_profile`` resolves to a DeviceProfile, re-routes an
      exact/unset ``cfg.sc_backend`` onto the ``array`` backend (the only
      backend that realizes non-ideal devices), and arms the engine's
      per-tick ``sc.use_device_profile`` scope.

    Legacy direct construction keeps working for one release but warns;
    this function is the only path the launchers, benches and docs use.
    """
    from repro.serve import engine as engine_mod

    options = options or ServeOptions()
    options.validate()
    if options.fused_attention:
        cfg = cfg.replace(paged_attn="fused")
    profile = options.resolve_profile()
    if profile is not None and not profile.is_ideal \
            and cfg.sc_backend in ("", "exact"):
        # Non-ideal devices exist only on the array backend; exact math
        # cannot carry a fault model.
        cfg = cfg.replace(sc_backend="array")
    rng_mode = options.rng_mode
    with engine_mod._api_construction():
        if options.paged:
            engine = engine_mod.PagedServingEngine(
                params, cfg, engine_mod.PagedServeConfig(
                    slots=options.slots, max_len=options.max_len,
                    eos_id=options.eos_id, seed=options.seed,
                    block_size=options.block_size,
                    num_blocks=options.num_blocks,
                    prefill_chunk=options.prefill_chunk,
                    prefix_cache=options.prefix_cache,
                    rng_mode=rng_mode,
                    speculative=options.speculative,
                    spec_k=options.spec_k,
                    draft_backend=options.draft_backend),
                collect_arch_trace=collect_arch_trace,
                metrics=metrics, tracer=tracer)
        else:
            engine = engine_mod.ServingEngine(
                params, cfg, engine_mod.ServeConfig(
                    slots=options.slots, max_len=options.max_len,
                    eos_id=options.eos_id, seed=options.seed),
                collect_arch_trace=collect_arch_trace,
                mesh=mesh, shard_rules=shard_rules,
                metrics=metrics, tracer=tracer)
    engine.device_profile = profile
    return engine
