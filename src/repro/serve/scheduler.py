"""Continuous-batching scheduler: admission, chunked prefill, eviction,
prefix-cache adoption, and speculative-decode planning.

One scheduler tick produces one :class:`TickPlan` — the padded arrays a
single jitted ``models/lm.py:decode_paged`` call consumes.  Every batch
row is in exactly one phase per tick:

* **prefill** — the row feeds the next ``prefill_chunk`` tokens of its
  pending context (prompt, or prompt + generated after an eviction);
* **decode** — the row feeds its one last sampled token;
* **idle** — no request mapped (or deferred this tick): ``n_valid = 0``,
  K/V writes go to the null block, logits ignored.

Requests admit from a FIFO queue the moment a row and enough pool blocks
free up — mid-batch, not when the tick drains.  With prefix caching on,
admission first ADOPTS the longest cached block chain matching the
request's context (``kv_cache.PagedKVCache.adopt_prefix``): adopted
tokens skip prefill entirely, and only the remainder feeds through
chunks.  When the pool cannot cover a row's next chunk, the most recently
admitted *other* row is evicted (LIFO victim, vLLM's recompute policy):
its block REFERENCES drop (blocks another sequence shares stay put —
release is refcount-aware), and it re-queues at the FRONT of the waiting
queue with ``pending = prompt + generated`` so it re-prefills (or
re-adopts) its full context on re-admission.

Every feed passes the copy-on-write barrier
(``PagedKVCache.make_writable``) before its tokens are consumed: writes
never land in a block that is shared or hash-registered; the barrier's
``(src, dst)`` page copies ride the plan for the engine to apply first.

RNG contract: two modes.

* ``rng_mode="request"`` (default, PR-4 behavior): each request's key is
  folded ONCE at submission (``fold_in(base_key, rid)`` unless the
  request carries its own seed), and every stochastic draw downstream —
  SC bits per token (see ``decode_paged``) and the sampling draw per
  generated token — derives from (that key, absolute position).  Tokens
  are a function of the request alone: identical served solo, batched,
  admitted mid-stream, or evicted and resumed.
* ``rng_mode="content"`` (forced by ``prefix_cache=True``): the SC key
  of CONTEXT token t is a chain over token content —
  ``C_t = fold_in(C_{t-1}, token_t)`` seeded from
  ``fold_in(base_key, _CONTENT_SALT)`` — so two requests sharing a
  prompt prefix draw bitwise-identical SC bits there, which is exactly
  what makes a cached KV block reusable across requests on stochastic
  backends.  SAMPLING keys stay per-request (``sample_key``), so
  temperature>0 requests still draw independently.  Tokens remain a
  function of (content, request key) alone — still invariant to batch
  composition, chunking, and eviction/resume.

Speculative decoding: on a pure-decode tick, greedy rows with pool head-
room are marked ``spec_rows`` — the engine drafts ``spec_k`` tokens with
the paired cheap backend (``sc.draft_backend``) and verifies them in ONE
width-(k+1) ``decode_paged`` call; ``on_tokens`` commits the accepted
run.  The scheduler only PLANS speculation (block reservation + write
barrier over the drafted span); the draft/verify loop lives in
``engine.PagedServingEngine``.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp

from repro.serve.kv_cache import PagedKVCache

_SAMPLE_SALT = 0x5EED       # separates sampling folds from SC-bit folds
_CONTENT_SALT = 0xC047      # seeds the content-chain keys (rng_mode=content)


@dataclasses.dataclass
class Sequence:
    """One admitted request's scheduling state."""

    req: object                     # serve.engine.Request
    key: object                     # raw (2,) uint32 per-request key
    fed: int = 0                    # context tokens already in the cache
    pending: list = dataclasses.field(default_factory=list)
    # True while the row is feeding context (prompt, or prompt+generated
    # after an eviction); flips False once the context is consumed and
    # the row switches to one-token decode feeds.  Pure observability
    # state: it distinguishes prefill-chunk trace events from decode
    # feeds and never influences scheduling.
    prefilling: bool = True
    # Content-chain SC keys, one per context position (rng_mode=content
    # only; extended lazily).  ckeys[t] is a function of tokens[0..t] and
    # the engine seed alone, so it survives eviction/resume unchanged.
    ckeys: list = dataclasses.field(default_factory=list)

    @property
    def context_len(self) -> int:
        return len(self.req.prompt) + len(self.req.generated)

    def context_tokens(self) -> list:
        return list(self.req.prompt) + list(self.req.generated)

    def reset_for_recompute(self) -> None:
        """Eviction: drop cache state, keep tokens; re-prefill everything.
        ``ckeys`` survives — content keys depend on tokens, not on cache
        state, and the tokens are unchanged."""
        self.fed = 0
        self.pending = self.context_tokens()
        self.prefilling = True


@dataclasses.dataclass
class TickPlan:
    """Arrays for one ``decode_paged`` call, plus host bookkeeping."""

    sc: int                         # chunk width of this tick (1 = decode)
    tokens: list                    # (b, sc) int
    lengths: list                   # (b,) pre-feed fill
    n_valid: list                   # (b,) real tokens per row
    tables: list                    # (b, nb) block-table rows
    keys: list                      # (b,) raw per-request keys, or per-row
                                    # (sc, 2) content keys (rng_mode=content)
    sample_rows: list               # [(slot, Sequence)] rows to sample after
    # copy-on-write page copies [(src, dst)] the engine applies BEFORE
    # the step (a write this tick lands in a block that was shared)
    copies: list = dataclasses.field(default_factory=list)
    # [(slot, Sequence)] rows the engine should draft+verify this tick
    # (their pool span through fed + spec_k is reserved and writable)
    spec_rows: list = dataclasses.field(default_factory=list)


class Scheduler:
    """Owns the waiting queue, the row grid, and the block allocator.

    ``metrics`` (a ``repro.obs`` registry) and ``tracer`` are the
    observability hooks: the scheduler owns the request-lifecycle
    counters (submitted/admitted/finished/evicted) and emits the
    lifecycle trace events — ``request.submit`` / ``request.admit`` /
    ``request.evict`` / ``request.finish`` plus one ``prefill.chunk``
    event per context chunk fed.  Both default to always-off stand-ins,
    so an uninstrumented scheduler pays one attribute check per site.
    """

    def __init__(self, scfg, kv: PagedKVCache, base_key, on_finish=None,
                 metrics=None, tracer=None):
        from repro import obs
        self.scfg = scfg
        self.kv = kv
        self.base_key = base_key
        self.on_finish = on_finish
        self.waiting: deque = deque()
        self.rows: list = [None] * scfg.slots        # slot -> Sequence | None
        self.admit_stack: list = []                  # admission order (LIFO)
        self.finished: list = []
        self.evictions = 0
        self._dummy_key = jax.random.PRNGKey(0)
        # content-chain mode: forced by prefix caching (shared KV blocks
        # need content-derived SC bits), or opted into standalone
        self.content_mode = bool(
            getattr(scfg, "prefix_cache", False)
            or getattr(scfg, "rng_mode", "request") == "content")
        self._content_base = jax.random.fold_in(base_key, _CONTENT_SALT)
        self.speculative = bool(getattr(scfg, "speculative", False))
        self.spec_k = int(getattr(scfg, "spec_k", 4))
        m = metrics if metrics is not None else obs.MetricsRegistry(
            enabled=False)
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self._m_submitted = m.counter(
            "serve_requests_submitted_total", "requests entering the queue")
        self._m_admitted = m.counter(
            "serve_requests_admitted_total",
            "admissions onto a batch row (re-admissions after eviction "
            "count again)")
        self._m_finished = m.counter(
            "serve_requests_finished_total", "requests completed")
        self._m_evicted = m.counter(
            "serve_evictions_total", "LIFO recompute evictions")
        self._m_prefill_tok = m.counter(
            "serve_prefill_tokens_total",
            "context tokens fed through prefill chunks (resumes re-count; "
            "prefix-cache hits never reach here)")
        self._m_generated = m.counter(
            "serve_tokens_generated_total", "tokens sampled across requests")
        self._g_queue = m.gauge("serve_queue_depth", "requests waiting")
        self._g_active = m.gauge(
            "serve_active_requests", "requests holding a batch row")

    def _update_gauges(self) -> None:
        self._g_queue.set(len(self.waiting))
        self._g_active.set(self.active_count)

    # ------------------------------------------------------------------
    def submit(self, req) -> None:
        key = getattr(req, "key", None)
        if key is None:
            key = jax.random.fold_in(self.base_key, req.rid)
            req.key = key
        seq = Sequence(req=req, key=key,
                       pending=list(req.prompt) + list(req.generated))
        self.waiting.append(seq)
        self._m_submitted.inc()
        self._update_gauges()
        self.tracer.event("request.submit", rid=req.rid,
                          prompt_tokens=len(req.prompt))

    def adopt(self, seq: Sequence) -> None:
        """Queue a PRE-BUILT sequence at the admission head (warm
        drain/resume): its first ``seq.fed`` positions already hold valid
        KV under its rid's block table, so on admission it resumes
        feeding ``pending`` from there instead of re-prefilling.
        ``_admit``'s bookkeeping handles it unchanged — ``adopt_prefix``
        no-ops on an existing table and ``ensure``/``has_room`` extend
        it."""
        self.waiting.appendleft(seq)
        self._m_submitted.inc()
        self._update_gauges()
        self.tracer.event("request.adopt", rid=seq.req.rid, fed=seq.fed)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.rows)

    @property
    def active_count(self) -> int:
        return sum(r is not None for r in self.rows)

    # ------------------------------------------------------------------
    def _evict_victim(self, keep: Sequence) -> int | None:
        """Free the most recently admitted row other than ``keep``.

        Returns the evicted slot (so an in-flight tick plan can cancel the
        victim's feed), or None when ``keep`` is the only admitted row.
        ``kv.release`` only DEREFERENCES the victim's blocks: blocks a
        prefix-sharing neighbour still maps survive untouched, and
        registered blocks park on the prefix-cache LRU — a resumed victim
        often re-adopts its own blocks instead of re-prefilling."""
        for victim in reversed(self.admit_stack):
            if victim is keep:
                continue
            slot = self.rows.index(victim)
            self.kv.release(victim.req.rid)
            self.rows[slot] = None
            self.admit_stack.remove(victim)
            victim.reset_for_recompute()
            self.waiting.appendleft(victim)
            self.evictions += 1
            self._m_evicted.inc()
            self._update_gauges()
            self.tracer.event("request.evict", rid=victim.req.rid,
                              generated=len(victim.req.generated))
            return slot
        return None

    def _admit(self) -> None:
        for slot in range(self.scfg.slots):
            if self.rows[slot] is not None or not self.waiting:
                continue
            seq = self.waiting[0]
            cached = self.kv.adopt_prefix(seq.req.rid, seq.context_tokens())
            if cached:
                seq.fed = cached
                seq.pending = seq.context_tokens()[cached:]
            first = min(len(seq.pending), self.scfg.prefill_chunk)
            if not self.kv.has_room(seq.req.rid, seq.fed + first):
                if cached:                   # roll the adoption back:
                    self.kv.release(seq.req.rid)   # hits return to the LRU
                    seq.reset_for_recompute()
                break                        # FIFO: don't starve the head
            self.waiting.popleft()
            self.kv.ensure(seq.req.rid, seq.fed + first)
            self.rows[slot] = seq
            self.admit_stack.append(seq)
            self._m_admitted.inc()
            self._update_gauges()
            self.tracer.event("request.admit", rid=seq.req.rid, slot=slot,
                              resumed=bool(seq.req.generated),
                              cached_tokens=cached)

    # ------------------------------------------------------------------
    def _extend_ckeys(self, seq: Sequence, upto: int) -> None:
        """Grow ``seq.ckeys`` to cover positions [0, upto): the content
        chain ``C_t = fold_in(C_{t-1}, token_t)`` over prompt+generated."""
        ctx = seq.context_tokens()
        while len(seq.ckeys) < upto:
            t = len(seq.ckeys)
            prev = seq.ckeys[t - 1] if t else self._content_base
            seq.ckeys.append(jax.random.fold_in(prev, int(ctx[t])))

    def _row_keys(self, seq, n: int, sc: int):
        """One TickPlan.keys row: the raw request key (request mode) or
        the (sc, 2) stack of content keys for the fed span (content
        mode), dummy-padded — dummies key null-block writes only."""
        if not self.content_mode:
            return self._dummy_key if seq is None else seq.key
        if seq is None or n == 0:
            return jnp.stack([self._dummy_key] * sc)
        self._extend_ckeys(seq, seq.fed + n)
        ks = seq.ckeys[seq.fed:seq.fed + n]
        return jnp.stack(ks + [self._dummy_key] * (sc - n))

    # ------------------------------------------------------------------
    def plan(self) -> TickPlan | None:
        """Build the next tick, mutating row state optimistically (the
        engine always executes the returned plan).  None = nothing to do.

        Two passes.  Pass A reserves pool blocks AND copy-on-write copies
        for every row's intended feed, evicting LIFO victims on OOM — and
        CANCELLING a victim's already-granted feed if it was planned
        earlier in this same tick (its block references just dropped, so
        letting it run would alias freshly re-allocated blocks).  After
        pass A, pure-decode ticks nominate speculative rows (greedy,
        post-prefill, pool headroom through ``fed + 1 + spec_k``) —
        opportunistically: a row that cannot reserve its drafted span
        falls back to plain decode, never evicts for it.  Pass B builds
        the padded arrays only for feeds that survived pass A.

        A row always feeds ``min(len(pending), prefill_chunk)`` tokens —
        a request-local quantity — so a request's chunk boundaries never
        depend on its batch neighbours (decode_paged's per-position rng
        makes numerics chunking-invariant anyway; this keeps schedules
        reproducible too).  The tick width ``sc`` is the widest surviving
        feed: pure-decode ticks collapse to ``sc = 1`` so steady-state
        decoding compiles once and pays no chunk-width padding.
        """
        self._admit()
        if not any(r is not None for r in self.rows):
            return None
        planned: dict = {}                    # slot -> granted feed length
        copies: list = []
        for slot in range(self.scfg.slots):
            seq = self.rows[slot]
            if seq is None:                   # may have been evicted above
                continue
            want = min(len(seq.pending), self.scfg.prefill_chunk)
            while want:
                if self.kv.ensure(seq.req.rid, seq.fed + want):
                    # copy-on-write barrier over the write span — shared
                    # or registered blocks copy out before any scatter
                    cw = self.kv.make_writable(seq.req.rid, seq.fed,
                                               seq.fed + want)
                    if cw is not None:
                        copies.extend(cw)
                        break
                victim_slot = self._evict_victim(keep=seq)
                if victim_slot is None:
                    want = 0                  # defer: sole row, pool full
                    break
                planned.pop(victim_slot, None)
            planned[slot] = want
        # Tick width: EXACTLY two shapes ever reach the jitted step —
        # prefill ticks run at the full chunk width (tail chunks pad, the
        # padding is n_valid-masked into the null block) and pure-decode
        # ticks at width 1 — so serving never recompiles mid-traffic
        # however prompt lengths mix.  (Speculation adds two more fixed
        # shapes: the width-1 draft and the width-(k+1) verify.)
        sc = (self.scfg.prefill_chunk
              if any(n > 1 for n in planned.values()) else 1)
        spec_slots: set = set()
        if self.speculative and sc == 1 and self.spec_k > 0:
            for slot in range(self.scfg.slots):
                seq = self.rows[slot]
                if (seq is None or planned.get(slot, 0) != 1
                        or seq.prefilling or seq.req.temperature > 0.0):
                    continue
                # verify writes positions fed .. fed+spec_k
                if seq.fed + 1 + self.spec_k > self.scfg.max_len:
                    continue
                if not self.kv.ensure(seq.req.rid,
                                      seq.fed + 1 + self.spec_k):
                    continue
                cw = self.kv.make_writable(seq.req.rid, seq.fed + 1,
                                           seq.fed + 1 + self.spec_k)
                if cw is None:
                    continue
                copies.extend(cw)
                spec_slots.add(slot)
        tokens, lengths, n_valid, tables, keys = [], [], [], [], []
        sample_rows, spec_rows = [], []
        for slot in range(self.scfg.slots):
            seq = self.rows[slot]
            n = planned.get(slot, 0)
            if seq is None:
                tokens.append([0] * sc)
                lengths.append(0)
                n_valid.append(0)
                tables.append(self.kv.null_row())
                keys.append(self._row_keys(None, 0, sc))
                continue
            feed = seq.pending[:n]
            seq.pending = seq.pending[n:]
            tokens.append(list(feed) + [0] * (sc - n))
            lengths.append(seq.fed)
            n_valid.append(n)
            keys.append(self._row_keys(seq, n, sc))
            seq.fed += n
            tables.append(self.kv.table_row(seq.req.rid))
            if n and seq.prefilling:
                self._m_prefill_tok.inc(n)
                self.tracer.event("prefill.chunk", rid=seq.req.rid,
                                  tokens=n, fed=seq.fed)
                if not seq.pending:
                    seq.prefilling = False
            if n:
                self.kv.note_filled(seq.req.rid, seq.context_tokens(),
                                    seq.fed)
            if n and not seq.pending:
                if slot in spec_slots:
                    spec_rows.append((slot, seq))
                else:
                    sample_rows.append((slot, seq))
        return TickPlan(sc=sc, tokens=tokens, lengths=lengths,
                        n_valid=n_valid, tables=tables, keys=keys,
                        sample_rows=sample_rows, copies=copies,
                        spec_rows=spec_rows)

    # ------------------------------------------------------------------
    def sample_key(self, seq: Sequence):
        """Key for the sampling draw at ``seq``'s current position — a
        function of (request key, position) only, so re-sampling after an
        eviction resume reproduces the same draw."""
        return jax.random.fold_in(
            jax.random.fold_in(seq.key, _SAMPLE_SALT), seq.fed)

    def on_token(self, slot: int, seq: Sequence, token: int) -> None:
        """Record a sampled token and finish or continue the row."""
        self.on_tokens(slot, seq, [token])

    def on_tokens(self, slot: int, seq: Sequence, toks: list) -> int:
        """Commit a run of tokens for one row (len 1 = plain decode;
        longer = a speculative accept run whose first len-1 tokens
        already have verifier-grade KV in the cache).  Finish conditions
        are checked PER TOKEN — an EOS mid-run truncates the commit.
        Returns how many tokens were committed."""
        for i, token in enumerate(toks):
            if i > 0:
                # the PREVIOUS committed token's KV was written by the
                # verify pass at position fed — advance past it
                seq.fed += 1
            seq.req.generated.append(token)
            self._m_generated.inc()
            hit_eos = token == self.scfg.eos_id
            hit_max = len(seq.req.generated) >= seq.req.max_new_tokens
            hit_cap = seq.fed >= self.scfg.max_len - 1
            if hit_eos or hit_max or hit_cap:
                self._finish(slot, seq)
                return i + 1
        seq.pending = [toks[-1]]
        return len(toks)

    def _finish(self, slot: int, seq: Sequence) -> None:
        seq.req.done = True
        self.kv.release(seq.req.rid)
        self.rows[slot] = None
        if seq in self.admit_stack:
            self.admit_stack.remove(seq)
        self.finished.append(seq.req)
        self._m_finished.inc()
        self._update_gauges()
        self.tracer.event("request.finish", rid=seq.req.rid,
                          generated=len(seq.req.generated))
        if self.on_finish is not None:
            self.on_finish(seq.req)
