"""Continuous-batching scheduler: admission, chunked prefill, eviction.

One scheduler tick produces one :class:`TickPlan` — the padded arrays a
single jitted ``models/lm.py:decode_paged`` call consumes.  Every batch
row is in exactly one phase per tick:

* **prefill** — the row feeds the next ``prefill_chunk`` tokens of its
  pending context (prompt, or prompt + generated after an eviction);
* **decode** — the row feeds its one last sampled token;
* **idle** — no request mapped (or deferred this tick): ``n_valid = 0``,
  K/V writes go to the null block, logits ignored.

Requests admit from a FIFO queue the moment a row and enough pool blocks
free up — mid-batch, not when the tick drains.  When the pool cannot
cover a row's next chunk, the most recently admitted *other* row is
evicted (LIFO victim, vLLM's recompute policy): its blocks free
immediately, and it re-queues at the FRONT of the waiting queue with
``pending = prompt + generated`` so it re-prefills its full context on
re-admission.

RNG contract: each request's key is folded ONCE, at submission
(``fold_in(base_key, rid)`` unless the request carries its own seed), and
every stochastic draw downstream — SC bits per token (see
``decode_paged``) and the sampling draw per generated token — derives
from (that key, absolute position).  Tokens are therefore a function of
the request alone: the same request with the same key decodes identically
served solo, batched, admitted mid-stream, or evicted and resumed.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax

from repro.serve.kv_cache import PagedKVCache

_SAMPLE_SALT = 0x5EED       # separates sampling folds from SC-bit folds


@dataclasses.dataclass
class Sequence:
    """One admitted request's scheduling state."""

    req: object                     # serve.engine.Request
    key: object                     # raw (2,) uint32 per-request key
    fed: int = 0                    # context tokens already in the cache
    pending: list = dataclasses.field(default_factory=list)
    # True while the row is feeding context (prompt, or prompt+generated
    # after an eviction); flips False once the context is consumed and
    # the row switches to one-token decode feeds.  Pure observability
    # state: it distinguishes prefill-chunk trace events from decode
    # feeds and never influences scheduling.
    prefilling: bool = True

    @property
    def context_len(self) -> int:
        return len(self.req.prompt) + len(self.req.generated)

    def reset_for_recompute(self) -> None:
        """Eviction: drop cache state, keep tokens; re-prefill everything."""
        self.fed = 0
        self.pending = list(self.req.prompt) + list(self.req.generated)
        self.prefilling = True


@dataclasses.dataclass
class TickPlan:
    """Arrays for one ``decode_paged`` call, plus host bookkeeping."""

    sc: int                         # chunk width of this tick (1 = decode)
    tokens: list                    # (b, sc) int
    lengths: list                   # (b,) pre-feed fill
    n_valid: list                   # (b,) real tokens per row
    tables: list                    # (b, nb) block-table rows
    keys: list                      # (b,) raw per-request keys (dummy if idle)
    sample_rows: list               # [(slot, Sequence)] rows to sample after


class Scheduler:
    """Owns the waiting queue, the row grid, and the block allocator.

    ``metrics`` (a ``repro.obs`` registry) and ``tracer`` are the
    observability hooks: the scheduler owns the request-lifecycle
    counters (submitted/admitted/finished/evicted) and emits the
    lifecycle trace events — ``request.submit`` / ``request.admit`` /
    ``request.evict`` / ``request.finish`` plus one ``prefill.chunk``
    event per context chunk fed.  Both default to always-off stand-ins,
    so an uninstrumented scheduler pays one attribute check per site.
    """

    def __init__(self, scfg, kv: PagedKVCache, base_key, on_finish=None,
                 metrics=None, tracer=None):
        from repro import obs
        self.scfg = scfg
        self.kv = kv
        self.base_key = base_key
        self.on_finish = on_finish
        self.waiting: deque = deque()
        self.rows: list = [None] * scfg.slots        # slot -> Sequence | None
        self.admit_stack: list = []                  # admission order (LIFO)
        self.finished: list = []
        self.evictions = 0
        self._dummy_key = jax.random.PRNGKey(0)
        m = metrics if metrics is not None else obs.MetricsRegistry(
            enabled=False)
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self._m_submitted = m.counter(
            "serve_requests_submitted_total", "requests entering the queue")
        self._m_admitted = m.counter(
            "serve_requests_admitted_total",
            "admissions onto a batch row (re-admissions after eviction "
            "count again)")
        self._m_finished = m.counter(
            "serve_requests_finished_total", "requests completed")
        self._m_evicted = m.counter(
            "serve_evictions_total", "LIFO recompute evictions")
        self._m_prefill_tok = m.counter(
            "serve_prefill_tokens_total",
            "context tokens fed through prefill chunks (resumes re-count)")
        self._m_generated = m.counter(
            "serve_tokens_generated_total", "tokens sampled across requests")
        self._g_queue = m.gauge("serve_queue_depth", "requests waiting")
        self._g_active = m.gauge(
            "serve_active_requests", "requests holding a batch row")

    def _update_gauges(self) -> None:
        self._g_queue.set(len(self.waiting))
        self._g_active.set(self.active_count)

    # ------------------------------------------------------------------
    def submit(self, req) -> None:
        key = getattr(req, "key", None)
        if key is None:
            key = jax.random.fold_in(self.base_key, req.rid)
            req.key = key
        seq = Sequence(req=req, key=key,
                       pending=list(req.prompt) + list(req.generated))
        self.waiting.append(seq)
        self._m_submitted.inc()
        self._update_gauges()
        self.tracer.event("request.submit", rid=req.rid,
                          prompt_tokens=len(req.prompt))

    def has_work(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.rows)

    @property
    def active_count(self) -> int:
        return sum(r is not None for r in self.rows)

    # ------------------------------------------------------------------
    def _evict_victim(self, keep: Sequence) -> int | None:
        """Free the most recently admitted row other than ``keep``.

        Returns the evicted slot (so an in-flight tick plan can cancel the
        victim's feed), or None when ``keep`` is the only admitted row."""
        for victim in reversed(self.admit_stack):
            if victim is keep:
                continue
            slot = self.rows.index(victim)
            self.kv.release(victim.req.rid)
            self.rows[slot] = None
            self.admit_stack.remove(victim)
            victim.reset_for_recompute()
            self.waiting.appendleft(victim)
            self.evictions += 1
            self._m_evicted.inc()
            self._update_gauges()
            self.tracer.event("request.evict", rid=victim.req.rid,
                              generated=len(victim.req.generated))
            return slot
        return None

    def _admit(self) -> None:
        for slot in range(self.scfg.slots):
            if self.rows[slot] is not None or not self.waiting:
                continue
            seq = self.waiting[0]
            first = min(len(seq.pending), self.scfg.prefill_chunk)
            if not self.kv.has_room(seq.req.rid, first):
                break                        # FIFO: don't starve the head
            self.waiting.popleft()
            self.kv.ensure(seq.req.rid, first)
            self.rows[slot] = seq
            self.admit_stack.append(seq)
            self._m_admitted.inc()
            self._update_gauges()
            self.tracer.event("request.admit", rid=seq.req.rid, slot=slot,
                              resumed=bool(seq.req.generated))

    # ------------------------------------------------------------------
    def plan(self) -> TickPlan | None:
        """Build the next tick, mutating row state optimistically (the
        engine always executes the returned plan).  None = nothing to do.

        Two passes.  Pass A reserves pool blocks for every row's intended
        feed, evicting LIFO victims on OOM — and CANCELLING a victim's
        already-granted feed if it was planned earlier in this same tick
        (its blocks just went back to the pool, so letting it run would
        alias freshly re-allocated blocks).  Pass B builds the padded
        arrays only for feeds that survived pass A.

        A row always feeds ``min(len(pending), prefill_chunk)`` tokens —
        a request-local quantity — so a request's chunk boundaries never
        depend on its batch neighbours (decode_paged's per-position rng
        makes numerics chunking-invariant anyway; this keeps schedules
        reproducible too).  The tick width ``sc`` is the widest surviving
        feed: pure-decode ticks collapse to ``sc = 1`` so steady-state
        decoding compiles once and pays no chunk-width padding.
        """
        self._admit()
        if not any(r is not None for r in self.rows):
            return None
        planned: dict = {}                    # slot -> granted feed length
        for slot in range(self.scfg.slots):
            seq = self.rows[slot]
            if seq is None:                   # may have been evicted above
                continue
            want = min(len(seq.pending), self.scfg.prefill_chunk)
            while want and not self.kv.ensure(seq.req.rid, seq.fed + want):
                victim_slot = self._evict_victim(keep=seq)
                if victim_slot is None:
                    want = 0                  # defer: sole row, pool full
                    break
                planned.pop(victim_slot, None)
            planned[slot] = want
        # Tick width: EXACTLY two shapes ever reach the jitted step —
        # prefill ticks run at the full chunk width (tail chunks pad, the
        # padding is n_valid-masked into the null block) and pure-decode
        # ticks at width 1 — so serving never recompiles mid-traffic
        # however prompt lengths mix.
        sc = (self.scfg.prefill_chunk
              if any(n > 1 for n in planned.values()) else 1)
        tokens, lengths, n_valid, tables, keys = [], [], [], [], []
        sample_rows = []
        for slot in range(self.scfg.slots):
            seq = self.rows[slot]
            n = planned.get(slot, 0)
            if seq is None:
                tokens.append([0] * sc)
                lengths.append(0)
                n_valid.append(0)
                tables.append(self.kv.null_row())
                keys.append(self._dummy_key)
                continue
            feed = seq.pending[:n]
            seq.pending = seq.pending[n:]
            tokens.append(list(feed) + [0] * (sc - n))
            lengths.append(seq.fed)
            n_valid.append(n)
            tables.append(self.kv.table_row(seq.req.rid))
            keys.append(seq.key)
            seq.fed += n
            if n and seq.prefilling:
                self._m_prefill_tok.inc(n)
                self.tracer.event("prefill.chunk", rid=seq.req.rid,
                                  tokens=n, fed=seq.fed)
                if not seq.pending:
                    seq.prefilling = False
            if n and not seq.pending:
                sample_rows.append((slot, seq))
        return TickPlan(sc=sc, tokens=tokens, lengths=lengths,
                        n_valid=n_valid, tables=tables, keys=keys,
                        sample_rows=sample_rows)

    # ------------------------------------------------------------------
    def sample_key(self, seq: Sequence):
        """Key for the sampling draw at ``seq``'s current position — a
        function of (request key, position) only, so re-sampling after an
        eviction resume reproduces the same draw."""
        return jax.random.fold_in(
            jax.random.fold_in(seq.key, _SAMPLE_SALT), seq.fed)

    def on_token(self, slot: int, seq: Sequence, token: int) -> None:
        """Record a sampled token and finish or continue the row."""
        seq.req.generated.append(token)
        self._m_generated.inc()
        hit_eos = token == self.scfg.eos_id
        hit_max = len(seq.req.generated) >= seq.req.max_new_tokens
        hit_cap = seq.fed >= self.scfg.max_len - 1
        if hit_eos or hit_max or hit_cap:
            self._finish(slot, seq)
        else:
            seq.pending = [token]

    def _finish(self, slot: int, seq: Sequence) -> None:
        seq.req.done = True
        self.kv.release(seq.req.rid)
        self.rows[slot] = None
        if seq in self.admit_stack:
            self.admit_stack.remove(seq)
        self.finished.append(seq.req)
        self._m_finished.inc()
        self._update_gauges()
        self.tracer.event("request.finish", rid=seq.req.rid,
                          generated=len(seq.req.generated))
        if self.on_finish is not None:
            self.on_finish(seq.req)
