"""Block-pool paged KV cache: fixed-size token blocks + per-sequence
block tables + a freelist allocator + block-level prefix caching.

The paper's throughput argument is utilization — every MRAM cell an
independent MUL engine only pays off if the system above keeps the arrays
fed.  The serving-layer analogue of that argument is KV memory: a
fixed-slot engine reserves ``slots × max_len`` cache rows up front, so a
short request strands the tail of its row and a finished request strands
the whole row until the tick drains.  Here KV memory is a pool of
``num_blocks`` blocks of ``block_size`` tokens (per layer), sequences map
positions through a block table (position t lives in
``pages[table[t // bs], t % bs]``), and blocks alloc/free through a
freelist — a finished request's blocks are recycled into waiting requests
mid-batch.

Block 0 is reserved as the NULL block: chunk padding and idle batch rows
scatter their K/V there (see ``models/attention.py:paged_scatter``), so no
live sequence ever maps it and the allocator never hands it out.

Prefix caching (``enable_prefix_cache=True``) layers vLLM-style sharing
on top of the same pool.  Every FULL block a sequence fills is content-
addressed by a chain hash over its token prefix (``_chain_hash``: hash of
the parent block's hash plus this block's tokens, so equal hashes mean
equal token prefixes from position 0).  Blocks are refcounted: a block
referenced by k live block tables has refcount k, and ``release`` decrefs
instead of freeing — a block another sequence still maps NEVER returns to
the freelist (the PR-4 LIFO eviction assumed sole ownership; that latent
bug is fixed here and pinned by tests).  A block whose refcount drops to
zero but whose hash is registered parks on an LRU list of cached blocks
instead of the freelist; allocation takes freelist blocks first and then
evicts the least-recently-used cached block (unregistering its hash).
The pool therefore partitions at all times into

    freelist ∪ cached (ref 0, hash-registered) ∪ referenced (ref >= 1)

— the invariant the property suite (tests/test_prefix_cache.py) drives
random interleavings against.  Shared or registered blocks are IMMUTABLE:
any write into a block that is shared (ref > 1) or hash-registered goes
through :meth:`make_writable`, which copies it out (copy-on-write) and
hands the engine the device-side copy ops.

The device-side pool tensors live in ``models/lm.py:init_paged_cache``;
this module is the host-side bookkeeping (pure Python, O(1) per alloc).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from collections import OrderedDict


NULL_BLOCK = 0


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Geometry of the paged pool.

    ``num_blocks`` COUNTS the reserved null block, so the allocatable
    capacity is ``(num_blocks - 1) * block_size`` tokens.  ``max_len``
    bounds any single sequence (its block table has
    ``ceil(max_len / block_size)`` entries — the gathered attention view
    is that many blocks wide, padded rows masked).
    """

    num_blocks: int
    block_size: int
    max_len: int

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.num_blocks < 2:
            raise ValueError(
                f"num_blocks must be >= 2 (block 0 is the reserved null "
                f"block), got {self.num_blocks}")

    @property
    def blocks_per_seq(self) -> int:
        return -(-self.max_len // self.block_size)

    @property
    def capacity_tokens(self) -> int:
        return (self.num_blocks - 1) * self.block_size


@dataclasses.dataclass(frozen=True)
class CachePlan:
    """Per-family device-cache layout — which layers page and which carry
    fixed-size recurrent state.

    The paged engine serves every model family through one plan:

    * attention (dense/moe/audio/vlm): every backbone layer owns a paged
      K/V pool; ``state_layers = 0``.
    * ssm: K/V pools don't exist — each backbone layer carries one O(1)
      state + conv-tail row PER BATCH ROW, indexed by slot (not by block
      table).  The block allocator still meters the admission/eviction
      token budget, so scheduling is family-agnostic; the tables simply
      go unread by the model.  ``paged_layers = 0``.
    * hybrid: both — state rows for the Mamba2 backbone layers plus K/V
      pools for each weight-shared attention invocation.

    ``models/lm.py:init_paged_cache`` materializes the device tensors
    this plan describes; ``engine.PagedServingEngine`` consults
    ``has_state`` to gate features that require reconstructible context
    (prefix caching, speculative decoding — recurrent state cannot be
    rewound or spliced from adopted blocks).
    """

    family: str
    paged_layers: int           # layers with paged K/V pools
    state_layers: int           # layers with fixed-size SSM state rows

    @classmethod
    def for_config(cls, cfg) -> "CachePlan":
        from repro.models import lm
        n = lm.n_backbone_layers(cfg)
        if cfg.family == "ssm":
            return cls(cfg.family, 0, n)
        if cfg.family == "hybrid":
            return cls(cfg.family, lm.n_shared_invocations(cfg), n)
        return cls(cfg.family, n, 0)

    @property
    def has_paged(self) -> bool:
        return self.paged_layers > 0

    @property
    def has_state(self) -> bool:
        return self.state_layers > 0


def blocks_for(tokens: int, block_size: int) -> int:
    """How many blocks a sequence of ``tokens`` tokens occupies."""
    return -(-tokens // block_size)


def _chain_hash(parent: str | None, block_tokens) -> str:
    """Content address of one FULL block: hash of (parent hash, tokens).

    Chaining makes the hash a function of the ENTIRE token prefix up to
    and including this block, so two sequences share a block exactly when
    their prompts agree on every position the block covers — the property
    that makes a hit safe to splice into a different request's table.
    """
    h = hashlib.sha1()
    if parent is not None:
        h.update(parent.encode())
    h.update(b"|")
    h.update(",".join(str(int(t)) for t in block_tokens).encode())
    return h.hexdigest()


class BlockPool:
    """Freelist over block ids 1..num_blocks-1 (0 is the null block)."""

    def __init__(self, num_blocks: int):
        # LIFO freelist: recently freed blocks are re-used first (their
        # stale contents are fully overwritten before any masked read).
        self._free = list(range(num_blocks - 1, 0, -1))
        self._num_blocks = num_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int] | None:
        """Pop ``n`` blocks, or None (and no change) if fewer are free."""
        if n > len(self._free):
            return None
        got = self._free[-n:][::-1]
        del self._free[-n:]
        return got

    def free(self, blocks) -> None:
        for b in blocks:
            if not (0 < b < self._num_blocks):
                raise ValueError(f"freeing invalid block id {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
        self._free.extend(blocks)


class PagedKVCache:
    """Host-side paged-cache bookkeeping: pool + per-sequence block tables.

    Device tensors (the per-layer page pools) are owned by the engine —
    this class tracks which blocks belong to which sequence and hands out
    padded block-table rows for the jitted step.

    With a ``metrics`` registry (``repro.obs``), every alloc/free updates
    the block-pool series: ``serve_kv_blocks_allocated_total`` /
    ``serve_kv_blocks_freed_total`` counters plus ``serve_kv_blocks_free``
    and ``serve_kv_block_occupancy`` gauges — the pool-pressure signals
    the eviction policy and the prefix cache are judged by.  With
    ``enable_prefix_cache=True`` the prefix-sharing series record too:
    ``serve_prefix_cache_hit_tokens_total``, ``_lookups_total``,
    ``_evictions_total``, ``_cow_total`` and the ``serve_kv_cached_blocks``
    gauge.
    """

    def __init__(self, cfg: PagedCacheConfig, metrics=None,
                 enable_prefix_cache: bool = False):
        self.cfg = cfg
        self.pool = BlockPool(cfg.num_blocks)
        self.tables: dict[int, list[int]] = {}      # seq id -> block ids
        self.prefix_cache = enable_prefix_cache
        # ---- refcount + content-address state (always maintained; only
        # adopt_prefix creates sharing, so with the cache off every ref
        # is 1 and behavior is exactly the PR-4 allocator) ----
        self.refcounts: dict[int, int] = {}         # block id -> ref
        self.block_hash: dict[int, str] = {}        # block id -> chain hash
        self.hash_to_block: dict[str, int] = {}     # chain hash -> block id
        # ref-0 blocks holding reusable content, oldest first (LRU order)
        self.cached: OrderedDict[int, str] = OrderedDict()
        self._chains: dict[int, list[str]] = {}     # seq id -> block hashes
        self._m_alloc = self._m_freed = None
        if metrics is not None:
            self._m_alloc = metrics.counter(
                "serve_kv_blocks_allocated_total",
                "KV pool blocks handed to sequences")
            self._m_freed = metrics.counter(
                "serve_kv_blocks_freed_total",
                "KV pool blocks returned by finished/evicted sequences")
            self._g_free = metrics.gauge(
                "serve_kv_blocks_free", "allocatable KV blocks currently free")
            self._g_occ = metrics.gauge(
                "serve_kv_block_occupancy",
                "fraction of allocatable KV blocks mapped by sequences")
            self._m_hit_tok = metrics.counter(
                "serve_prefix_cache_hit_tokens_total",
                "context tokens served from cached prefix blocks")
            self._m_lookups = metrics.counter(
                "serve_prefix_cache_lookups_total",
                "prefix-cache lookups at admission")
            self._m_pc_evict = metrics.counter(
                "serve_prefix_cache_evictions_total",
                "cached blocks evicted from the LRU list to satisfy allocs")
            self._m_cow = metrics.counter(
                "serve_prefix_cache_cow_total",
                "copy-on-write block copies (write into a shared or "
                "registered block)")
            self._g_cached = metrics.gauge(
                "serve_kv_cached_blocks",
                "ref-0 blocks parked on the prefix-cache LRU list")
            self._update_gauges()

    def _update_gauges(self) -> None:
        if self._m_alloc is not None:
            self._g_free.set(self.pool.free_blocks)
            self._g_occ.set(round(self.utilization(), 6))
            self._g_cached.set(len(self.cached))

    # ------------------------------------------------------------------
    # Allocation: freelist first, then LRU eviction of cached blocks
    # ------------------------------------------------------------------
    @property
    def allocatable_blocks(self) -> int:
        """Blocks an alloc can obtain: free plus cached-but-unreferenced
        (the LRU list is evictable on demand)."""
        return self.pool.free_blocks + len(self.cached)

    @property
    def free_tokens(self) -> int:
        return self.allocatable_blocks * self.cfg.block_size

    def _unregister(self, bid: int) -> None:
        h = self.block_hash.pop(bid, None)
        if h is not None and self.hash_to_block.get(h) == bid:
            del self.hash_to_block[h]

    def _alloc(self, n: int) -> list[int] | None:
        """All-or-nothing alloc of ``n`` blocks, evicting LRU cached
        blocks (unregistering their hashes) when the freelist runs dry."""
        if n > self.allocatable_blocks:
            return None
        while self.pool.free_blocks < n:
            bid, _h = self.cached.popitem(last=False)      # oldest first
            self._unregister(bid)
            self.pool.free([bid])
            if self._m_alloc is not None:
                self._m_pc_evict.inc()
        got = self.pool.alloc(n)
        assert got is not None
        for b in got:
            self.refcounts[b] = 1
        return got

    def _decref(self, bid: int) -> None:
        self.refcounts[bid] -= 1
        if self.refcounts[bid] > 0:
            return
        del self.refcounts[bid]
        h = self.block_hash.get(bid)
        if h is not None and self.hash_to_block.get(h) == bid:
            # Reusable content: park on the LRU list, most recent last.
            self.cached[bid] = h
            self.cached.move_to_end(bid)
        else:
            self.block_hash.pop(bid, None)
            self.pool.free([bid])

    # ------------------------------------------------------------------
    def has_room(self, seq_id: int, upto_tokens: int) -> bool:
        have = len(self.tables.get(seq_id, []))
        need = blocks_for(min(upto_tokens, self.cfg.max_len),
                          self.cfg.block_size) - have
        return need <= self.allocatable_blocks

    def ensure(self, seq_id: int, upto_tokens: int) -> bool:
        """Grow ``seq_id``'s table to cover ``upto_tokens`` positions.

        Returns False (allocating nothing) when the pool cannot cover the
        growth — the scheduler then evicts or defers.  Never partially
        allocates, so a False return leaves the cache consistent.
        """
        if upto_tokens > self.cfg.max_len:
            raise ValueError(
                f"sequence {seq_id} wants {upto_tokens} tokens > "
                f"max_len {self.cfg.max_len}")
        table = self.tables.setdefault(seq_id, [])
        need = blocks_for(upto_tokens, self.cfg.block_size) - len(table)
        if need <= 0:
            return True
        got = self._alloc(need)
        if got is None:
            return False
        table.extend(got)
        if self._m_alloc is not None:
            self._m_alloc.inc(need)
            self._update_gauges()
        return True

    def release(self, seq_id: int) -> int:
        """Drop every block reference of ``seq_id``; returns how many
        references were dropped.  REFCOUNT-AWARE: a block another live
        sequence still maps stays allocated (the PR-4 LIFO eviction freed
        victims' blocks unconditionally, which would corrupt a
        prefix-sharing neighbour — see tests/test_prefix_cache.py)."""
        table = self.tables.pop(seq_id, [])
        self._chains.pop(seq_id, None)
        for b in table:
            self._decref(b)
        if self._m_freed is not None and table:
            self._m_freed.inc(len(table))
            self._update_gauges()
        return len(table)

    def table_row(self, seq_id: int) -> list[int]:
        """``seq_id``'s block table padded to ``blocks_per_seq`` with the
        null block — one row of the (b, nb) device array."""
        table = self.tables.get(seq_id, [])
        pad = self.cfg.blocks_per_seq - len(table)
        return table + [NULL_BLOCK] * pad

    def null_row(self) -> list[int]:
        return [NULL_BLOCK] * self.cfg.blocks_per_seq

    @property
    def live_blocks(self) -> int:
        return sum(len(t) for t in self.tables.values())

    def utilization(self) -> float:
        """Fraction of allocatable blocks currently mapped by sequences."""
        total = self.cfg.num_blocks - 1
        return self.live_blocks / total if total else 0.0

    # ------------------------------------------------------------------
    # Prefix cache: chain-hash lookup, hit adoption, registration, COW
    # ------------------------------------------------------------------
    def adopt_prefix(self, seq_id: int, tokens) -> int:
        """Splice the longest cached block chain matching ``tokens`` into
        a FRESH table for ``seq_id``; returns how many context tokens the
        hit covers (0 with the cache off or on a miss).

        Walks the chain hash block by block and increfs every hit.  The
        hit is capped at ``len(tokens) - 1`` so at least one token is
        left to feed (the engine needs its logits to sample) — when the
        whole prompt is cached, the final block is still adopted and the
        last token re-fed through :meth:`make_writable`'s copy-on-write,
        never written in place.
        """
        if not self.prefix_cache or self.tables.get(seq_id):
            return 0
        if self._m_alloc is not None:
            self._m_lookups.inc()
        bs = self.cfg.block_size
        hits: list[int] = []
        chain: list[str] = []
        parent = None
        for b0 in range(0, (len(tokens) // bs) * bs, bs):
            h = _chain_hash(parent, tokens[b0:b0 + bs])
            bid = self.hash_to_block.get(h)
            if bid is None:
                break
            hits.append(bid)
            chain.append(h)
            parent = h
        if not hits:
            return 0
        cached_tokens = min(len(hits) * bs, len(tokens) - 1)
        n_blocks = blocks_for(cached_tokens, bs)
        for bid in hits[:n_blocks]:
            self.refcounts[bid] = self.refcounts.get(bid, 0) + 1
            self.cached.pop(bid, None)            # no longer ref-0
        self.tables[seq_id] = list(hits[:n_blocks])
        self._chains[seq_id] = list(chain[:n_blocks])
        if self._m_alloc is not None:
            self._m_hit_tok.inc(cached_tokens)
            self._update_gauges()
        return cached_tokens

    def match_prefix(self, tokens) -> int:
        """Pure lookup: tokens a fresh :meth:`adopt_prefix` would cover."""
        if not self.prefix_cache:
            return 0
        bs = self.cfg.block_size
        parent, n = None, 0
        for b0 in range(0, (len(tokens) // bs) * bs, bs):
            parent = _chain_hash(parent, tokens[b0:b0 + bs])
            if parent not in self.hash_to_block:
                break
            n += 1
        return min(n * bs, max(len(tokens) - 1, 0))

    def note_filled(self, seq_id: int, context_tokens, fed: int) -> None:
        """Register every newly FULL block of ``seq_id`` in the hash map.

        ``context_tokens[:fed]`` is the token content now resident in the
        cache.  Only full blocks are content-addressed (a partial block's
        tail is still being written); a hash already claimed by another
        block leaves this one unregistered (duplicate content frees
        normally instead of colliding).
        """
        if not self.prefix_cache:
            return
        bs = self.cfg.block_size
        table = self.tables.get(seq_id, [])
        chain = self._chains.setdefault(seq_id, [])
        while len(chain) < fed // bs:
            i = len(chain)
            parent = chain[i - 1] if i else None
            h = _chain_hash(parent, context_tokens[i * bs:(i + 1) * bs])
            chain.append(h)
            bid = table[i]
            if h not in self.hash_to_block and bid not in self.block_hash:
                self.hash_to_block[h] = bid
                self.block_hash[bid] = h

    def make_writable(self, seq_id: int, start_tok: int,
                      end_tok: int) -> list[tuple[int, int]] | None:
        """Copy-on-write barrier for writes into positions
        [``start_tok``, ``end_tok``).

        Every block the span touches that is SHARED (ref > 1) or
        hash-REGISTERED is replaced in ``seq_id``'s table by a fresh
        block; the returned ``(src, dst)`` pairs are the device-side page
        copies the engine must apply before scattering.  Returns None
        (changing nothing) when the pool cannot supply the copies — the
        scheduler treats that like any other alloc failure (evict or
        defer).  After a successful call, every block in the span has
        refcount 1 and no registered hash: no shared or cached block is
        ever written in place.
        """
        if end_tok <= start_tok:
            return []
        bs = self.cfg.block_size
        table = self.tables.get(seq_id, [])
        lo, hi = start_tok // bs, blocks_for(end_tok, bs)
        need = [i for i in range(lo, min(hi, len(table)))
                if self.refcounts.get(table[i], 0) > 1
                or table[i] in self.block_hash]
        if not need:
            return []
        fresh = self._alloc(len(need))
        if fresh is None:
            return None
        copies = []
        chain = self._chains.get(seq_id, [])
        for i, dst in zip(need, fresh):
            src = table[i]
            copies.append((src, dst))
            table[i] = dst
            self._decref(src)
            if i < len(chain):
                del chain[i:]         # rewritten span: chain re-derives
        if self._m_alloc is not None:
            self._m_alloc.inc(len(need))
            self._m_cow.inc(len(need))
            self._update_gauges()
        return copies

    # ------------------------------------------------------------------
    # Invariants (driven by the property suite after every operation)
    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Assert the full bookkeeping contract; raises AssertionError
        naming the violated clause.  O(pool + tables) — test/debug use."""
        n = self.cfg.num_blocks
        free = set(self.pool._free)
        cached = set(self.cached)
        referenced = set(self.refcounts)
        assert NULL_BLOCK not in free | cached | referenced, \
            "null block entered the pool"
        # refcounts == live table references, exactly
        counts: dict[int, int] = {}
        for t in self.tables.values():
            for b in t:
                counts[b] = counts.get(b, 0) + 1
        assert counts == self.refcounts, \
            f"refcounts {self.refcounts} != table references {counts}"
        assert all(r >= 1 for r in self.refcounts.values()), \
            "zero/negative refcount retained"
        # freelist ∪ cached ∪ referenced partitions blocks 1..n-1
        assert free | cached | referenced == set(range(1, n)), \
            "pool partition lost blocks"
        assert not (free & cached) and not (free & referenced) \
            and not (cached & referenced), "pool partition overlaps"
        # hash map consistency: registered hashes point at blocks that
        # carry that hash; cached blocks are exactly ref-0 registered ones
        for h, b in self.hash_to_block.items():
            assert self.block_hash.get(b) == h, \
                f"hash_to_block[{h[:8]}]={b} but block_hash={self.block_hash.get(b)}"
        for b, h in self.cached.items():
            assert self.hash_to_block.get(h) == b, \
                f"cached block {b} not registered under its hash"
        for b in self.block_hash:
            assert b in cached or b in referenced, \
                f"registered block {b} is on the freelist"


def default_num_blocks(slots: int, max_len: int, block_size: int) -> int:
    """Pool size matching the fixed-slot engine's reservation: enough
    blocks for every slot at full length, plus the null block.  Passing
    fewer (``--max-blocks``) is how operators trade memory for eviction
    pressure."""
    return 1 + slots * math.ceil(max_len / block_size)
