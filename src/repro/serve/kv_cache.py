"""Block-pool paged KV cache: fixed-size token blocks + per-sequence
block tables + a freelist allocator.

The paper's throughput argument is utilization — every MRAM cell an
independent MUL engine only pays off if the system above keeps the arrays
fed.  The serving-layer analogue of that argument is KV memory: a
fixed-slot engine reserves ``slots × max_len`` cache rows up front, so a
short request strands the tail of its row and a finished request strands
the whole row until the tick drains.  Here KV memory is a pool of
``num_blocks`` blocks of ``block_size`` tokens (per layer), sequences map
positions through a block table (position t lives in
``pages[table[t // bs], t % bs]``), and blocks alloc/free through a
freelist — a finished request's blocks are recycled into waiting requests
mid-batch.

Block 0 is reserved as the NULL block: chunk padding and idle batch rows
scatter their K/V there (see ``models/attention.py:paged_scatter``), so no
live sequence ever maps it and the allocator never hands it out.

The device-side pool tensors live in ``models/lm.py:init_paged_cache``;
this module is the host-side bookkeeping (pure Python, O(1) per alloc).
"""

from __future__ import annotations

import dataclasses
import math


NULL_BLOCK = 0


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Geometry of the paged pool.

    ``num_blocks`` COUNTS the reserved null block, so the allocatable
    capacity is ``(num_blocks - 1) * block_size`` tokens.  ``max_len``
    bounds any single sequence (its block table has
    ``ceil(max_len / block_size)`` entries — the gathered attention view
    is that many blocks wide, padded rows masked).
    """

    num_blocks: int
    block_size: int
    max_len: int

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.num_blocks < 2:
            raise ValueError(
                f"num_blocks must be >= 2 (block 0 is the reserved null "
                f"block), got {self.num_blocks}")

    @property
    def blocks_per_seq(self) -> int:
        return -(-self.max_len // self.block_size)

    @property
    def capacity_tokens(self) -> int:
        return (self.num_blocks - 1) * self.block_size


def blocks_for(tokens: int, block_size: int) -> int:
    """How many blocks a sequence of ``tokens`` tokens occupies."""
    return -(-tokens // block_size)


class BlockPool:
    """Freelist over block ids 1..num_blocks-1 (0 is the null block)."""

    def __init__(self, num_blocks: int):
        # LIFO freelist: recently freed blocks are re-used first (their
        # stale contents are fully overwritten before any masked read).
        self._free = list(range(num_blocks - 1, 0, -1))
        self._num_blocks = num_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int] | None:
        """Pop ``n`` blocks, or None (and no change) if fewer are free."""
        if n > len(self._free):
            return None
        got = self._free[-n:][::-1]
        del self._free[-n:]
        return got

    def free(self, blocks) -> None:
        for b in blocks:
            if not (0 < b < self._num_blocks):
                raise ValueError(f"freeing invalid block id {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
        self._free.extend(blocks)


class PagedKVCache:
    """Host-side paged-cache bookkeeping: pool + per-sequence block tables.

    Device tensors (the per-layer page pools) are owned by the engine —
    this class tracks which blocks belong to which sequence and hands out
    padded block-table rows for the jitted step.

    With a ``metrics`` registry (``repro.obs``), every alloc/free updates
    the block-pool series: ``serve_kv_blocks_allocated_total`` /
    ``serve_kv_blocks_freed_total`` counters plus ``serve_kv_blocks_free``
    and ``serve_kv_block_occupancy`` gauges — the pool-pressure signals
    the eviction policy and ROADMAP item 1's prefix cache are judged by.
    """

    def __init__(self, cfg: PagedCacheConfig, metrics=None):
        self.cfg = cfg
        self.pool = BlockPool(cfg.num_blocks)
        self.tables: dict[int, list[int]] = {}      # seq id -> block ids
        self._m_alloc = self._m_freed = None
        if metrics is not None:
            self._m_alloc = metrics.counter(
                "serve_kv_blocks_allocated_total",
                "KV pool blocks handed to sequences")
            self._m_freed = metrics.counter(
                "serve_kv_blocks_freed_total",
                "KV pool blocks returned by finished/evicted sequences")
            self._g_free = metrics.gauge(
                "serve_kv_blocks_free", "allocatable KV blocks currently free")
            self._g_occ = metrics.gauge(
                "serve_kv_block_occupancy",
                "fraction of allocatable KV blocks mapped by sequences")
            self._update_gauges()

    def _update_gauges(self) -> None:
        if self._m_alloc is not None:
            self._g_free.set(self.pool.free_blocks)
            self._g_occ.set(round(self.utilization(), 6))

    # ------------------------------------------------------------------
    @property
    def free_tokens(self) -> int:
        return self.pool.free_blocks * self.cfg.block_size

    def has_room(self, seq_id: int, upto_tokens: int) -> bool:
        have = len(self.tables.get(seq_id, []))
        need = blocks_for(min(upto_tokens, self.cfg.max_len),
                          self.cfg.block_size) - have
        return need <= self.pool.free_blocks

    def ensure(self, seq_id: int, upto_tokens: int) -> bool:
        """Grow ``seq_id``'s table to cover ``upto_tokens`` positions.

        Returns False (allocating nothing) when the pool cannot cover the
        growth — the scheduler then evicts or defers.  Never partially
        allocates, so a False return leaves the cache consistent.
        """
        if upto_tokens > self.cfg.max_len:
            raise ValueError(
                f"sequence {seq_id} wants {upto_tokens} tokens > "
                f"max_len {self.cfg.max_len}")
        table = self.tables.setdefault(seq_id, [])
        need = blocks_for(upto_tokens, self.cfg.block_size) - len(table)
        if need <= 0:
            return True
        got = self.pool.alloc(need)
        if got is None:
            return False
        table.extend(got)
        if self._m_alloc is not None:
            self._m_alloc.inc(need)
            self._update_gauges()
        return True

    def release(self, seq_id: int) -> int:
        """Free every block of ``seq_id``; returns how many were freed."""
        table = self.tables.pop(seq_id, [])
        self.pool.free(table)
        if self._m_freed is not None and table:
            self._m_freed.inc(len(table))
            self._update_gauges()
        return len(table)

    def table_row(self, seq_id: int) -> list[int]:
        """``seq_id``'s block table padded to ``blocks_per_seq`` with the
        null block — one row of the (b, nb) device array."""
        table = self.tables.get(seq_id, [])
        pad = self.cfg.blocks_per_seq - len(table)
        return table + [NULL_BLOCK] * pad

    def null_row(self) -> list[int]:
        return [NULL_BLOCK] * self.cfg.blocks_per_seq

    @property
    def live_blocks(self) -> int:
        return sum(len(t) for t in self.tables.values())

    def utilization(self) -> float:
        """Fraction of allocatable blocks currently mapped by sequences."""
        total = self.cfg.num_blocks - 1
        return self.live_blocks / total if total else 0.0


def default_num_blocks(slots: int, max_len: int, block_size: int) -> int:
    """Pool size matching the fixed-slot engine's reservation: enough
    blocks for every slot at full length, plus the null block.  Passing
    fewer (``--max-blocks``) is how operators trade memory for eviction
    pressure."""
    return 1 + slots * math.ceil(max_len / block_size)
