"""Batched serving engines.

Two engines share this module (and the arch-trace lifecycle):

* :class:`ServingEngine` — the original fixed-slot engine: contiguous
  full-``max_len`` KV rows, batch=1 admission prefill, lock-step decode.
  Still the only engine for mesh-sharded serving, and the baseline the
  serve benchmark measures against.
* :class:`PagedServingEngine` — continuous batching over a block-pool
  paged KV cache with chunked prefill, eviction-on-OOM, and per-request
  rng, serving EVERY model family through a per-family cache plan
  (``kv_cache.CachePlan``: paged KV for attention layers, fixed-size
  SSM state slots for recurrent layers, both for hybrid — see its
  docstring and ``docs/serving.md``).

Fixed-slot engine
-----------------
The engine owns a KV/SSM cache with ``slots`` batch rows. Each slot holds
one in-flight request; when a request finishes (EOS or max tokens), the slot
is immediately refilled from the queue — decode never stalls on stragglers
in the batch (continuous batching). Admission runs prefill for the incoming
prompt with batch=1 and splices the resulting cache into the slot's batch
row; decode steps run for all slots at once (the serve_step the dry-run
lowers). Sampling is per-slot: each request decodes with its OWN
temperature (greedy slots stay deterministic), and the engine rng folds
once per tick. When ``cfg.sc_backend != "exact"`` every prefill/decode
matmul routes through the SC substrate (repro.sc) with a per-call key.

With ``collect_arch_trace=True`` and ``cfg.sc_backend == "array"``, the
engine keeps an arch trace collector installed: every prefill/decode
COMPILATION records its pulse-schedule cost (one record per compiled
shape — jit caching means steady-state ticks add no new records), and
``arch_report()`` returns the aggregate cycles/energy/utilization of
everything compiled so far. Call ``close()`` to detach the collector.

Cross-device batching: pass ``mesh=`` and the continuous-batching slot
grid maps onto the mesh — the decode batch dimension (slots) shards over
the mesh's data axes and every SC contraction splits over the model axis
(``sc.use_mesh`` is entered around prefill/decode tracing, so
``layers.dense`` routes through ``sc_dot_sharded`` automatically).
Per-slot sampling semantics are unchanged: each request keeps its OWN
temperature and greedy slots stay deterministic whatever their batch
neighbours do.  ``slots`` must be a multiple of the mesh's
data-parallel span so every mesh slice owns a whole number of slots.
With arch tracing on, sharded dispatches record per-shard traces stamped
with their shard multiplicity, and ``arch_report()`` merges them as
concurrent banks (makespan = slowest shard).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import attention, lm

# Depth of serve.api.build_engine construction scopes on this thread.
# Direct ``ServingEngine(...)`` / ``PagedServingEngine(...)`` calls see
# depth 0 and emit a DeprecationWarning; build_engine enters the scope so
# the sanctioned path constructs silently.
_API_DEPTH = 0


@contextlib.contextmanager
def _api_construction():
    global _API_DEPTH
    _API_DEPTH += 1
    try:
        yield
    finally:
        _API_DEPTH -= 1


def _warn_direct(name: str) -> None:
    if _API_DEPTH == 0:
        warnings.warn(
            f"constructing {name} directly is deprecated; use "
            "repro.serve.build_engine(params, cfg, ServeOptions(...)) — "
            "it picks the engine, applies fused attention / device fault "
            "profiles, and validates option combinations",
            DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # Per-request rng key (raw (2,) uint32).  The paged engine folds it
    # from the engine seed + rid at submission unless the caller set one;
    # every stochastic draw for this request (SC bits, sampling) derives
    # from it, making results independent of batch composition.
    key: object = None


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4
    max_len: int = 256
    eos_id: int = 2
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class PagedServeConfig:
    """Knobs of the paged continuous-batching engine.

    ``num_blocks = 0`` sizes the pool to the fixed-slot engine's
    reservation (every slot at full ``max_len``, plus the null block);
    smaller pools trade memory for eviction pressure.  ``prefill_chunk``
    caps how many prompt tokens one tick feeds per row (chunked prefill:
    long prompts admit over several ticks instead of stalling the batch).

    ``prefix_cache=True`` turns on block-level prefix caching
    (``serve/kv_cache.py``): requests sharing a prompt prefix adopt each
    other's full KV blocks instead of re-prefilling them.  It forces
    ``rng_mode="content"`` — SC keys for context tokens derive from token
    CONTENT, not request identity, so shared blocks hold bitwise-valid
    KV for every adopter even on stochastic backends.  ``rng_mode`` can
    also be set to ``"content"`` standalone (e.g. to compare cache
    on/off outputs bit-for-bit).

    ``speculative=True`` drafts ``spec_k`` tokens per greedy decode row
    with the cheap paired backend (``draft_backend``, default the
    registry pairing ``sc.draft_backend(cfg.sc_backend)`` — ``moment``
    for stochastic backends) and verifies them in ONE width-(k+1)
    ``decode_paged`` call on the real backend.  Every emitted token is
    the VERIFIER's greedy token, so outputs are token-identical to
    non-speculative decoding; acceptance only moves throughput.
    """

    slots: int = 4
    max_len: int = 256
    eos_id: int = 2
    seed: int = 0
    block_size: int = 16
    num_blocks: int = 0
    prefill_chunk: int = 8
    prefix_cache: bool = False
    rng_mode: str = "request"       # "request" | "content"
    speculative: bool = False
    spec_k: int = 4
    draft_backend: str = ""         # "" = registry pairing for cfg.sc_backend


class _ArchTracedEngine:
    """Arch-trace collector lifecycle shared by both engines.

    ``close()`` is IDEMPOTENT: the first call detaches the collector from
    the global listener list; every later call (or ``__del__`` after an
    explicit close, or a close racing engine teardown) is a no-op, so the
    listener list can never be corrupted by double-uninstall.  Records
    stay readable after close.  ``step()`` implementations wrap their
    tick in ``_detach_on_error`` so a raise mid-tick also detaches —
    a dead engine must not keep recording every later compilation in the
    process.
    """

    # Non-ideal device realized by the SC substrate while this engine
    # ticks (set by serve.api.build_engine from options.fault_profile;
    # None = ideal).  Entered as an ambient sc.use_device_profile scope
    # around step tracing so layers thread it into every ScConfig.
    device_profile = None

    def _init_arch(self, collect_arch_trace: bool, cfg) -> None:
        self._arch_closed = False
        self.arch_collector = None
        if collect_arch_trace and cfg.sc_backend == "array":
            from repro import arch
            self.arch_collector = arch.TraceCollector().install()

    def _device_scope(self):
        if self.device_profile is None:
            return contextlib.nullcontext()
        from repro import sc
        return sc.use_device_profile(self.device_profile)

    def _init_obs(self, metrics, tracer) -> None:
        """Engine-local telemetry (``repro.obs``): each engine owns its
        own always-on metrics registry (``self.metrics``) unless the
        caller supplies one, so concurrent engines never mix series; the
        tracer defaults to the always-off ``NULL_TRACER``."""
        self.metrics = metrics if metrics is not None \
            else obs.MetricsRegistry()
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self._m_ticks = self.metrics.counter(
            "serve_ticks_total", "engine ticks, labeled kind=prefill|decode")
        self._m_errors = self.metrics.counter(
            "serve_errors_total", "engine ticks that raised")

    def arch_report(self):
        """Aggregate arch cost of everything compiled so far (None when
        trace collection is off or nothing was recorded). NOTE: the
        collector hears every array-backend dispatch in the process while
        installed (same semantics as ``arch.collect()``), not only this
        engine's — run one traced engine at a time for a clean bill."""
        collector = getattr(self, "arch_collector", None)
        if collector is None or not collector.records:
            return None
        return collector.aggregate()

    def arch_request_costs(self):
        """Per-request cost attribution under mixed traffic (None when no
        trace or no finished requests were stamped): the aggregate trace
        cost prorated by each request's token count — see
        ``TraceCollector.cost_per_request``."""
        collector = getattr(self, "arch_collector", None)
        if collector is None or not collector.request_tokens:
            return None
        return collector.cost_per_request()

    def close(self):
        """Detach the arch trace collector (records stay readable).
        Safe to call any number of times, from ``__del__``, or after a
        mid-tick failure — only the first call touches the listener
        list."""
        if getattr(self, "_arch_closed", True):
            return
        self._arch_closed = True
        collector = getattr(self, "arch_collector", None)
        if collector is not None:
            collector.uninstall()

    def __del__(self):
        # A dropped engine must not leave its collector in the global
        # listener list (would leak records and keep tracing active).
        self.close()

    @contextlib.contextmanager
    def _detach_on_error(self):
        try:
            yield
        except Exception:
            self._m_errors.inc()
            self.close()
            raise

    def health_snapshot(self) -> dict:
        """Queue-depth / error-rate view of ``self.metrics`` — the gauges
        ``ft.supervisor.HealthMonitor`` consumes (ROADMAP item 5)."""
        from repro.ft import supervisor
        return dataclasses.asdict(supervisor.engine_health(self.metrics))


class ServingEngine(_ArchTracedEngine):
    def __init__(self, params, cfg, scfg: ServeConfig,
                 collect_arch_trace: bool = False, mesh=None,
                 shard_rules=None, metrics=None, tracer=None):
        _warn_direct("ServingEngine")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.mesh = mesh
        self.shard_rules = shard_rules
        self._stochastic_substrate = cfg.sc_backend != "exact"
        if mesh is not None and self._stochastic_substrate:
            from repro import sc
            batch_axes = (shard_rules or sc.DEFAULT_RULES).batch
            sizes = dict(mesh.shape)
            dp = math.prod(sizes.get(a, 1) for a in batch_axes)
            if dp > 1 and scfg.slots % dp != 0:
                raise ValueError(
                    f"slots={scfg.slots} must be a multiple of the rules' "
                    f"batch span {dp} on this mesh so slots map onto "
                    f"mesh shards")
        self.cache = lm.init_cache(cfg, scfg.slots, scfg.max_len)
        self.lengths = jnp.zeros((scfg.slots,), jnp.int32)
        self.last_token = jnp.zeros((scfg.slots,), jnp.int32)
        self.active = [None] * scfg.slots       # slot -> Request | None
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._rng = jax.random.PRNGKey(scfg.seed)
        self._decode = jax.jit(partial(lm.decode_step, cfg=cfg))
        self._prefill = jax.jit(
            partial(lm.prefill, cfg=cfg, max_len=scfg.max_len))
        self._init_arch(collect_arch_trace, cfg)
        self._init_obs(metrics, tracer)
        self._m_submitted = self.metrics.counter(
            "serve_requests_submitted_total", "requests entering the queue")
        self._m_finished = self.metrics.counter(
            "serve_requests_finished_total", "requests completed")
        self._m_generated = self.metrics.counter(
            "serve_tokens_generated_total", "tokens sampled across requests")
        self._g_queue = self.metrics.gauge(
            "serve_queue_depth", "requests waiting")
        self._g_active = self.metrics.gauge(
            "serve_active_requests", "requests holding a slot")

    def _substrate_scope(self):
        """Mesh scope entered around prefill/decode so their TRACING (the
        first call per shape) routes dense() through sc_dot_sharded."""
        if self.mesh is not None and self._stochastic_substrate:
            from repro import sc
            return sc.use_mesh(self.mesh, self.shard_rules)
        return contextlib.nullcontext()

    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)
        self._m_submitted.inc()
        self._g_queue.set(len(self.queue))
        self.tracer.event("request.submit", rid=req.rid,
                          prompt_tokens=len(req.prompt))

    def _splice_slot(self, slot: int, cache1, length, last_tok):
        """Write a batch=1 prefill cache into batch row ``slot``."""
        def put(full, one):
            # full: (layers, slots, ...); one: (layers, 1, ...)
            return jax.lax.dynamic_update_slice_in_dim(full, one.astype(
                full.dtype), slot, axis=1)
        self.cache = jax.tree.map(put, self.cache, cache1)
        self.lengths = self.lengths.at[slot].set(length)
        self.last_token = self.last_token.at[slot].set(last_tok)

    def _admit(self):
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                prompt = jnp.asarray([req.prompt], jnp.int32)
                with self._substrate_scope():
                    if self._stochastic_substrate:
                        logits, cache1, lens = self._prefill(
                            self.params, prompt, rng=self._next_key())
                    else:
                        logits, cache1, lens = self._prefill(
                            self.params, prompt)
                tok = self._sample(logits, req.temperature)
                req.generated.append(int(tok[0]))
                self.active[slot] = req
                self._splice_slot(slot, cache1, int(lens[0]), int(tok[0]))
                self._m_generated.inc()
                self._g_queue.set(len(self.queue))
                self._g_active.set(sum(r is not None for r in self.active))
                self.tracer.event("request.admit", rid=req.rid, slot=slot,
                                  resumed=False)

    def _sample(self, logits, temperature: float):
        """Sample one admission's tokens (batch=1 prefill logits)."""
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            self._next_key(), logits / temperature, axis=-1).astype(jnp.int32)

    def _sample_slots(self, logits, temperatures):
        """Per-slot sampling: each slot uses its request's own temperature.

        Greedy slots (t <= 0) take the argmax regardless of the rng, so a
        greedy request decodes identically whatever its batch neighbours
        sample.
        """
        temps = jnp.asarray(temperatures, jnp.float32)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if not any(t > 0.0 for t in temperatures):
            return greedy
        safe = jnp.where(temps > 0.0, temps, 1.0)
        sampled = jax.random.categorical(
            self._next_key(), logits / safe[:, None], axis=-1).astype(jnp.int32)
        return jnp.where(temps > 0.0, sampled, greedy)

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit, batched decode, per-slot sample, harvest.
        A raise mid-tick detaches the arch collector before propagating."""
        with self._detach_on_error(), self._device_scope():
            return self._step()

    def _step(self):
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        self._m_ticks.inc(kind="decode")
        with self._substrate_scope():
            if self._stochastic_substrate:
                logits, self.cache = self._decode(
                    self.params, self.cache, self.last_token, self.lengths,
                    rng=self._next_key())
            else:
                logits, self.cache = self._decode(
                    self.params, self.cache, self.last_token, self.lengths)
        self.lengths = self.lengths + jnp.asarray(
            [1 if r is not None else 0 for r in self.active], jnp.int32)
        toks = self._sample_slots(
            logits, [r.temperature if r is not None else 0.0
                     for r in self.active])
        self.last_token = toks
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(toks[slot])
            req.generated.append(tok)
            self._m_generated.inc()
            hit_eos = tok == self.scfg.eos_id
            hit_max = len(req.generated) >= req.max_new_tokens
            hit_cap = int(self.lengths[slot]) >= self.scfg.max_len - 1
            if hit_eos or hit_max or hit_cap:
                req.done = True
                if self.arch_collector is not None:
                    self.arch_collector.note_request(
                        req.rid, len(req.prompt) + len(req.generated))
                self.finished.append(req)
                self.active[slot] = None
                self.lengths = self.lengths.at[slot].set(0)
                self._m_finished.inc()
                self._g_active.set(sum(r is not None for r in self.active))
                self.tracer.event("request.finish", rid=req.rid,
                                  generated=len(req.generated))
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished


# ---------------------------------------------------------------------------
# Paged continuous-batching engine
# ---------------------------------------------------------------------------


def _sample_rows(keys, logits, temperatures):
    """All rows' sampling draws in ONE call: greedy at t <= 0, categorical
    otherwise.  Per-REQUEST keys (``scheduler.Scheduler.sample_key``) —
    vmapped so row i's draw is a function of its own key alone, never of a
    shared engine rng or its neighbours.  Rows not being sampled this tick
    carry dummy keys; the engine discards their slots."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.maximum(temperatures, 1e-6)
    sampled = jax.vmap(jax.random.categorical)(
        keys, logits / safe_t[:, None]).astype(jnp.int32)
    return jnp.where(temperatures > 0.0, sampled, greedy)


class PagedServingEngine(_ArchTracedEngine):
    """Continuous batching over a paged KV cache.

    Differences from the fixed-slot :class:`ServingEngine`:

    * KV memory is a block pool (``serve/kv_cache.py``): sequences own
      just the blocks their fill needs, a finished request's blocks
      recycle into waiting requests mid-batch, and an over-committed pool
      evicts (recompute-style) instead of refusing admission.
    * Prefill is CHUNKED and rides the same jitted step as decode
      (``lm.decode_paged``): one executable at chunk width + one at
      width 1 serve every prompt length — no per-length recompiles and no
      batch=1 admission stalls.
    * RNG is per-request, folded at admission and per absolute token
      position inside the step, so a request's tokens are independent of
      batch composition, chunking, and eviction/resume (the property the
      batch-invariance tests pin).

    ``step()`` is a thin loop over ``scheduler.Scheduler``: plan → one
    jitted call → sample the rows whose pending context emptied.

    Every model family serves here through a per-family cache plan
    (``kv_cache.CachePlan``): attention families page their K/V; SSM
    configs carry fixed-size state rows per batch slot beside the block
    table (the allocator still meters the token budget, so admission /
    chunked prefill / eviction-resume are family-agnostic — the
    recurrent ``ssm_stream`` feed keeps tokens bit-invariant to batch
    composition and chunking); hybrid configs carry both.  Two features
    require RECONSTRUCTIBLE context and are therefore attention-only:
    prefix caching (recurrent state cannot be spliced from adopted
    blocks) and speculative decoding (the verify pass advances state
    past rejected draft positions irreversibly) — both raise at
    construction for state-carrying families.
    """

    def __init__(self, params, cfg, scfg: PagedServeConfig,
                 collect_arch_trace: bool = False, metrics=None,
                 tracer=None):
        _warn_direct("PagedServingEngine")
        from repro.serve import kv_cache as kvc
        from repro.serve import scheduler as sched
        self.cache_plan = kvc.CachePlan.for_config(cfg)
        if self.cache_plan.has_state:
            if scfg.prefix_cache:
                raise ValueError(
                    f"prefix_cache=True needs reconstructible context, but "
                    f"family={cfg.family!r} carries recurrent SSM state — "
                    "adopted KV blocks cannot rebuild a row's state")
            if scfg.speculative:
                raise ValueError(
                    f"speculative=True cannot rewind recurrent state, but "
                    f"family={cfg.family!r} carries SSM state — the verify "
                    "pass would advance it past rejected draft tokens")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        if scfg.rng_mode not in ("request", "content"):
            raise ValueError(
                f"rng_mode must be 'request' or 'content', got "
                f"{scfg.rng_mode!r}")
        self._init_obs(metrics, tracer)
        num_blocks = scfg.num_blocks or kvc.default_num_blocks(
            scfg.slots, scfg.max_len, scfg.block_size)
        pcfg = kvc.PagedCacheConfig(num_blocks=num_blocks,
                                    block_size=scfg.block_size,
                                    max_len=scfg.max_len)
        if num_blocks < 1 + pcfg.blocks_per_seq:
            raise ValueError(
                f"num_blocks={num_blocks} cannot hold even one max_len="
                f"{scfg.max_len} sequence (+1 null block) at block_size="
                f"{scfg.block_size}; need >= {1 + pcfg.blocks_per_seq}")
        self.kv = kvc.PagedKVCache(pcfg, metrics=self.metrics,
                                   enable_prefix_cache=scfg.prefix_cache)
        self.pages = lm.init_paged_cache(cfg, num_blocks, scfg.block_size,
                                         slots=scfg.slots)
        self.scheduler = sched.Scheduler(
            scfg, self.kv, base_key=jax.random.PRNGKey(scfg.seed),
            on_finish=self._on_finish, metrics=self.metrics,
            tracer=self.tracer)
        # fused_sc attention draws per-token stochastic logits even when
        # the dense substrate is exact, so it needs per-request keys too
        self._stochastic_substrate = (
            cfg.sc_backend != "exact"
            or getattr(cfg, "paged_attn", "unfused") == "fused_sc")
        self._step_fn = jax.jit(partial(lm.decode_paged, cfg=cfg))
        self._sample_fn = jax.jit(_sample_rows)
        self._copy_fn = jax.jit(attention.paged_copy_blocks)
        if scfg.speculative:
            from repro import sc
            if scfg.spec_k < 1:
                raise ValueError(
                    f"speculative=True needs spec_k >= 1, got {scfg.spec_k}")
            dname = scfg.draft_backend or sc.draft_backend(cfg.sc_backend)
            sc.get_backend(dname)           # fail fast on unknown names
            # The draft runs the SAME weights on the cheap backend with
            # plain unfused attention: its K/V writes are placeholders the
            # verify pass overwrites, its logits only GUESS tokens.
            dcfg = cfg.replace(sc_backend=dname, paged_attn="unfused")
            self._draft_fn = jax.jit(partial(lm.decode_paged, cfg=dcfg))
            # The verifier is the real model at width spec_k+1 returning
            # logits at EVERY fed position (all_logits) — one pass scores
            # the whole drafted run under the exact same per-position key
            # grid as non-speculative decoding, which is what makes its
            # greedy tokens bitwise the non-speculative tokens.
            self._verify_fn = jax.jit(
                partial(lm.decode_paged, cfg=cfg, all_logits=True))
            self._spec_hist = self.metrics.histogram(
                "spec_accepted_tokens",
                "draft tokens accepted per speculative row-tick (0..k)",
                buckets=tuple(float(i) for i in range(scfg.spec_k + 1)))
            self._m_spec_drafted = self.metrics.counter(
                "serve_spec_drafted_tokens_total",
                "tokens drafted by the cheap backend")
            self._m_spec_accepted = self.metrics.counter(
                "serve_spec_accepted_tokens_total",
                "drafted tokens the verifier accepted")
            # host-side replay log: one entry per speculative row-tick —
            # the counter-arithmetic tests re-derive the counters from it
            self.spec_log: list[dict] = []
        self.ticks = 0
        self._seen_decode_tick = False
        # Per-tick decode wall times (ms per live token, width-1 ticks
        # only — the decode hot path the fused kernel targets) land in a
        # fixed-bucket histogram; ``decode_latency_ms()`` is a view over
        # it.  The first decode tick pays jit compilation and is counted
        # separately instead of polluting the latency series.
        self._decode_hist = self.metrics.histogram(
            "serve_decode_ms_per_token",
            "decode wall ms per live token (width-1 ticks, jit tick "
            "dropped)")
        self._m_jit_ticks = self.metrics.counter(
            "serve_decode_jit_ticks_total",
            "decode ticks excluded from the latency series (compile wall)")
        self._init_arch(collect_arch_trace, cfg)

    # -- queue/active views mirroring the fixed-slot engine's attributes --
    @property
    def queue(self):
        return list(self.scheduler.waiting)

    @property
    def active(self):
        return list(self.scheduler.rows)

    @property
    def finished(self):
        return self.scheduler.finished

    @property
    def evictions(self) -> int:
        return self.scheduler.evictions

    def submit(self, req: Request):
        self.scheduler.submit(req)

    def _on_finish(self, req: Request):
        if self.arch_collector is not None:
            self.arch_collector.note_request(
                req.rid, len(req.prompt) + len(req.generated))

    # ------------------------------------------------------------------
    def step(self):
        """One tick: scheduler plan → one jitted chunked step → sample the
        rows that consumed their pending context.  Returns False when
        idle.  A raise mid-tick detaches the arch collector."""
        with self._detach_on_error(), self._device_scope():
            plan = self.scheduler.plan()
            if plan is None:
                return False
            if not any(plan.n_valid):
                raise RuntimeError(
                    "scheduler produced a no-progress tick (every row "
                    "deferred) — the block pool is mis-sized")
            if plan.copies:
                # copy-on-write: a write this tick lands in a block that
                # was shared/registered — carry its K/V to the fresh block
                # before any scatter touches it
                src = [s for s, _ in plan.copies]
                dst = [d for _, d in plan.copies]
                self.pages = self._copy_fn(self.pages, src, dst)
            spec = bool(plan.spec_rows)
            kind = ("spec" if spec
                    else "decode" if plan.sc == 1 else "prefill")
            live = sum(1 for nv in plan.n_valid if nv)
            self._m_ticks.inc(kind=kind)
            with self.tracer.span("engine.tick", tick=self.ticks,
                                  kind=kind, live=live, width=plan.sc):
                if spec:
                    self._run_spec_plan(plan)
                else:
                    self._run_plan(plan, live)
            self.ticks += 1
            return True

    def _run_plan(self, plan, live: int):
        tokens = jnp.asarray(plan.tokens, jnp.int32)
        lengths = jnp.asarray(plan.lengths, jnp.int32)
        n_valid = jnp.asarray(plan.n_valid, jnp.int32)
        tables = jnp.asarray(plan.tables, jnp.int32)
        rng = jnp.stack(plan.keys) if self._stochastic_substrate else None
        t0 = time.perf_counter()
        logits, self.pages = self._step_fn(
            self.params, self.pages, tables, tokens, lengths, n_valid,
            rng=rng)
        if plan.sc == 1:
            # decode tick: force completion so the wall time covers
            # the device step, then normalize per live row.  The first
            # decode tick is the jit compile — count it, don't time it.
            logits.block_until_ready()
            ms = (time.perf_counter() - t0) * 1e3 / max(live, 1)
            if self._seen_decode_tick:
                self._decode_hist.observe(ms)
            else:
                self._seen_decode_tick = True
                self._m_jit_ticks.inc()
            self.tracer.attr(decode_ms_per_token=round(ms, 4))
        if plan.sample_rows:
            # One batched sampling call + one host sync per tick: the
            # (slots, vocab) shapes are tick-invariant, so this stays
            # a single compiled executable.  Non-sampling slots get
            # dummy keys and their outputs are discarded.
            keys = [self._dummy_sample_key()] * len(plan.tokens)
            temps = [0.0] * len(plan.tokens)
            for slot, seq in plan.sample_rows:
                keys[slot] = self.scheduler.sample_key(seq)
                temps[slot] = seq.req.temperature
            toks = np.asarray(self._sample_fn(
                jnp.stack(keys), logits,
                jnp.asarray(temps, jnp.float32))).tolist()   # one sync
            for slot, seq in plan.sample_rows:
                self.scheduler.on_token(slot, seq, toks[slot])

    def _run_spec_plan(self, plan):
        """One speculative tick: ``spec_k`` cheap draft steps, then ONE
        real verify pass, then commit the accepted run per row.

        The draft loop runs the SAME weights through the paired cheap
        backend on a scratch copy of the page pool (``dpages``): each
        width-1 step feeds the previous token at the next position, takes
        the greedy argmax as the draft, and accumulates its own K/V so
        later draft steps can attend to earlier draft tokens.  The
        scratch pool is DROPPED afterwards — ``self.pages`` never holds
        draft-grade K/V.

        The verify pass is the real model at width ``spec_k + 1`` feeding
        ``[t_F, d_1 .. d_k]`` against the pristine pool: ``paged_scatter``
        writes each position's verify-grade K/V before attention reads
        it, so one call both scores every drafted position
        (``all_logits``) and leaves the cache exactly as ``a + 1``
        non-speculative decode ticks would have (positions beyond the
        accepted run hold stale K/V that is length-masked and overwritten
        on the next feed — same contract as chunk padding).  Its rng is
        the SAME per-position key grid as non-speculative decoding, so
        the verifier's greedy tokens are bitwise the non-speculative
        tokens: acceptance moves throughput, never outputs.

        Rows not speculating this tick (temperature > 0, still
        prefilling, or no pool headroom) ride the verify pass with their
        single token (``n_valid`` from the plan) and sample from its
        position-0 logits — a mixed batch costs no extra dispatch.
        """
        k = self.scheduler.spec_k
        b = len(plan.tokens)
        lengths = jnp.asarray(plan.lengths, jnp.int32)
        tables = jnp.asarray(plan.tables, jnp.int32)
        spec_slots = {slot for slot, _ in plan.spec_rows}
        content = self.scheduler.content_mode
        stoch = self._stochastic_substrate
        dummy = self.scheduler._dummy_key
        base_rng = None
        if stoch and not content:
            base_rng = jnp.stack(plan.keys)            # (b, 2) request keys
        chain = None
        vkeys = None
        if stoch and content:
            chain = [plan.keys[r][0] for r in range(b)]  # (2,) per row
            vkeys = [[chain[r]] for r in range(b)]
        draft_nv = jnp.asarray(
            [1 if r in spec_slots else 0 for r in range(b)], jnp.int32)
        cur = [int(plan.tokens[r][0]) for r in range(b)]
        drafts: list[list[int]] = [[] for _ in range(b)]
        dpages = self.pages
        for i in range(k):
            toks = jnp.asarray([[c] for c in cur], jnp.int32)
            if not stoch:
                rng = None
            elif content:
                rng = jnp.stack(chain)[:, None, :]     # (b, 1, 2)
            else:
                rng = base_rng
            dlogits, dpages = self._draft_fn(
                self.params, dpages, tables, toks, lengths + i, draft_nv,
                rng=rng)
            nxt = np.asarray(jnp.argmax(dlogits, axis=-1)).tolist()  # sync
            for r in range(b):
                if r in spec_slots:
                    drafts[r].append(int(nxt[r]))
                    cur[r] = int(nxt[r])
                    if chain is not None:
                        chain[r] = jax.random.fold_in(chain[r], int(nxt[r]))
                if vkeys is not None:
                    vkeys[r].append(chain[r] if r in spec_slots else dummy)
        vtok, vnv = [], []
        for r in range(b):
            if r in spec_slots:
                vtok.append([int(plan.tokens[r][0])] + drafts[r])
                vnv.append(k + 1)
            else:
                vtok.append([int(plan.tokens[r][0])] + [0] * k)
                vnv.append(plan.n_valid[r])
        if not stoch:
            rng = None
        elif content:
            rng = jnp.stack([jnp.stack(vkeys[r]) for r in range(b)])
        else:
            rng = base_rng
        vlogits, self.pages = self._verify_fn(
            self.params, self.pages, tables, jnp.asarray(vtok, jnp.int32),
            lengths, jnp.asarray(vnv, jnp.int32), rng=rng)
        greedy = np.asarray(jnp.argmax(vlogits, axis=-1))   # (b, k+1), sync
        for slot, seq in plan.spec_rows:
            vrow = [int(t) for t in greedy[slot]]
            a = 0
            while a < k and drafts[slot][a] == vrow[a]:
                a += 1
            committed = self.scheduler.on_tokens(slot, seq, vrow[:a + 1])
            self._spec_hist.observe(float(a))
            self._m_spec_drafted.inc(k)
            self._m_spec_accepted.inc(a)
            self.spec_log.append(dict(
                tick=self.ticks, rid=seq.req.rid, k=k,
                drafted=list(drafts[slot]), verified=vrow,
                accepted=a, committed=committed))
        if plan.sample_rows:
            keys = [self._dummy_sample_key()] * b
            temps = [0.0] * b
            for slot, seq in plan.sample_rows:
                keys[slot] = self.scheduler.sample_key(seq)
                temps[slot] = seq.req.temperature
            toks = np.asarray(self._sample_fn(
                jnp.stack(keys), vlogits[:, 0],
                jnp.asarray(temps, jnp.float32))).tolist()
            for slot, seq in plan.sample_rows:
                self.scheduler.on_token(slot, seq, toks[slot])

    # ------------------------------------------------------------------
    # Drain / resume (ft.FleetSupervisor's shard-failover contract)
    # ------------------------------------------------------------------
    def drain(self) -> list:
        """Checkpoint and release EVERY request this engine holds
        (admitted rows and the waiting queue), returning one checkpoint
        dict per request, admission order first.

        Each checkpoint carries the request identity (rid, prompt,
        generated-so-far, per-request key, sampling knobs) plus — for
        attention-only families — the scheduler position (``fed``,
        ``pending``) and the request's filled KV block payload gathered
        from the page pool, so a healthy shard can resume WARM via
        :meth:`restore` without re-prefilling.  State-carrying families
        (ssm/hybrid) checkpoint cold: their recurrent state is not
        reconstructible from pages, so resume recomputes from tokens —
        which the per-(request key, position) rng contract makes
        token-identical anyway (same property eviction-resume relies
        on).  After ``drain()`` the engine is empty and trivially
        drainable."""
        sched = self.scheduler
        ckpts = []
        for slot in range(self.scfg.slots):
            seq = sched.rows[slot]
            if seq is None:
                continue
            ckpts.append(self._checkpoint_seq(seq, warm=True))
            self.kv.release(seq.req.rid)
            sched.rows[slot] = None
            if seq in sched.admit_stack:
                sched.admit_stack.remove(seq)
            self.tracer.event("request.drain", rid=seq.req.rid,
                              fed=seq.fed, warm=ckpts[-1]["kv"] is not None)
        for seq in list(sched.waiting):
            if seq.fed:                      # pre-seeded resume never admitted
                self.kv.release(seq.req.rid)
                seq.reset_for_recompute()
            ckpts.append(self._checkpoint_seq(seq, warm=False))
            self.tracer.event("request.drain", rid=seq.req.rid,
                              fed=0, warm=False)
        sched.waiting.clear()
        sched._update_gauges()
        return ckpts

    def _checkpoint_seq(self, seq, warm: bool) -> dict:
        req = seq.req
        ckpt = dict(
            rid=req.rid, prompt=list(req.prompt),
            generated=list(req.generated),
            max_new_tokens=req.max_new_tokens,
            temperature=req.temperature,
            key=None if req.key is None else np.asarray(req.key),
            fed=seq.fed, pending=list(seq.pending),
            prefilling=seq.prefilling,
            block_size=self.scfg.block_size, kv=None)
        if warm and seq.fed and not self.cache_plan.has_state:
            from repro.serve.kv_cache import blocks_for
            nblk = blocks_for(seq.fed, self.scfg.block_size)
            ids = jnp.asarray(self.kv.tables[req.rid][:nblk], jnp.int32)
            ckpt["kv"] = {"k": np.asarray(self.pages["k"][:, ids]),
                          "v": np.asarray(self.pages["v"][:, ids])}
        return ckpt

    def restore(self, ckpt: dict) -> bool:
        """Resume one drained request on THIS engine.  With a KV payload
        (and matching block geometry + headroom) the resume is WARM:
        fresh blocks are allocated, the payload scatters into the page
        pool, and the request re-enters the admission queue at its
        drained position.  Otherwise it falls back to a cold recompute
        resume — a plain re-submit carrying generated-so-far, exactly the
        eviction path.  Returns True for a warm resume."""
        req = Request(rid=ckpt["rid"], prompt=list(ckpt["prompt"]),
                      max_new_tokens=ckpt["max_new_tokens"],
                      temperature=ckpt["temperature"])
        req.generated = list(ckpt["generated"])
        if ckpt["key"] is not None:
            req.key = jnp.asarray(ckpt["key"])
        if ckpt["kv"] is not None and self._restore_warm(req, ckpt):
            self.tracer.event("request.resume", rid=req.rid,
                              fed=ckpt["fed"], warm=True)
            return True
        self.submit(req)
        self.tracer.event("request.resume", rid=req.rid, fed=0, warm=False)
        return False

    def _restore_warm(self, req, ckpt: dict) -> bool:
        from repro.serve import scheduler as sched_mod
        from repro.serve.kv_cache import blocks_for
        fed = ckpt["fed"]
        if (ckpt["block_size"] != self.scfg.block_size
                or self.cache_plan.has_state
                or fed == 0 or fed > self.scfg.max_len
                or self.kv.tables.get(req.rid)
                or not self.kv.has_room(req.rid, fed)
                or not self.kv.ensure(req.rid, fed)):
            return False
        nblk = blocks_for(fed, self.scfg.block_size)
        ids = jnp.asarray(self.kv.tables[req.rid][:nblk], jnp.int32)
        self.pages = {
            **self.pages,
            "k": self.pages["k"].at[:, ids].set(
                jnp.asarray(ckpt["kv"]["k"], self.pages["k"].dtype)),
            "v": self.pages["v"].at[:, ids].set(
                jnp.asarray(ckpt["kv"]["v"], self.pages["v"].dtype)),
        }
        seq = sched_mod.Sequence(req=req, key=req.key, fed=fed,
                                 pending=list(ckpt["pending"]),
                                 prefilling=ckpt["prefilling"])
        self.scheduler.adopt(seq)
        return True

    def decode_latency_ms(self):
        """p50/p95 decode wall ms per token — a view over the
        ``serve_decode_ms_per_token`` histogram in ``self.metrics``.

        The first decode tick pays jit compilation and is never
        recorded; with fewer than TWO recorded ticks after that drop the
        result is None (percentiles over zero samples are undefined, and
        over one sample they gate nothing but scheduling noise)."""
        h = self._decode_hist
        if h.count() < 2:
            return None
        return {"decode_p50_ms": round(h.percentile(50), 3),
                "decode_p95_ms": round(h.percentile(95), 3)}

    def _dummy_sample_key(self):
        return self.scheduler._dummy_key

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while self.scheduler.has_work() and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.scheduler.finished
