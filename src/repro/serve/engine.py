"""Batched serving engine: continuous batching over a fixed slot grid.

The engine owns a KV/SSM cache with ``slots`` batch rows. Each slot holds
one in-flight request; when a request finishes (EOS or max tokens), the slot
is immediately refilled from the queue — decode never stalls on stragglers
in the batch (continuous batching). Admission runs prefill for the incoming
prompt with batch=1 and splices the resulting cache into the slot's batch
row; decode steps run for all slots at once (the serve_step the dry-run
lowers). Sampling is per-slot: each request decodes with its OWN
temperature (greedy slots stay deterministic), and the engine rng folds
once per tick. When ``cfg.sc_backend != "exact"`` every prefill/decode
matmul routes through the SC substrate (repro.sc) with a per-call key.

With ``collect_arch_trace=True`` and ``cfg.sc_backend == "array"``, the
engine keeps an arch trace collector installed: every prefill/decode
COMPILATION records its pulse-schedule cost (one record per compiled
shape — jit caching means steady-state ticks add no new records), and
``arch_report()`` returns the aggregate cycles/energy/utilization of
everything compiled so far. Call ``close()`` to detach the collector.

Cross-device batching: pass ``mesh=`` and the continuous-batching slot
grid maps onto the mesh — the decode batch dimension (slots) shards over
the mesh's data axes and every SC contraction splits over the model axis
(``sc.use_mesh`` is entered around prefill/decode tracing, so
``layers.dense`` routes through ``sc_dot_sharded`` automatically).
Per-slot sampling semantics are unchanged: each request keeps its OWN
temperature and greedy slots stay deterministic whatever their batch
neighbours do.  ``slots`` must be a multiple of the mesh's
data-parallel span so every mesh slice owns a whole number of slots.
With arch tracing on, sharded dispatches record per-shard traces stamped
with their shard multiplicity, and ``arch_report()`` merges them as
concurrent banks (makespan = slowest shard).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4
    max_len: int = 256
    eos_id: int = 2
    seed: int = 0


class ServingEngine:
    def __init__(self, params, cfg, scfg: ServeConfig,
                 collect_arch_trace: bool = False, mesh=None,
                 shard_rules=None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.mesh = mesh
        self.shard_rules = shard_rules
        self._stochastic_substrate = cfg.sc_backend != "exact"
        if mesh is not None and self._stochastic_substrate:
            from repro import sc
            batch_axes = (shard_rules or sc.DEFAULT_RULES).batch
            sizes = dict(mesh.shape)
            dp = math.prod(sizes.get(a, 1) for a in batch_axes)
            if dp > 1 and scfg.slots % dp != 0:
                raise ValueError(
                    f"slots={scfg.slots} must be a multiple of the rules' "
                    f"batch span {dp} on this mesh so slots map onto "
                    f"mesh shards")
        self.cache = lm.init_cache(cfg, scfg.slots, scfg.max_len)
        self.lengths = jnp.zeros((scfg.slots,), jnp.int32)
        self.last_token = jnp.zeros((scfg.slots,), jnp.int32)
        self.active = [None] * scfg.slots       # slot -> Request | None
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._rng = jax.random.PRNGKey(scfg.seed)
        self._decode = jax.jit(partial(lm.decode_step, cfg=cfg))
        self._prefill = jax.jit(
            partial(lm.prefill, cfg=cfg, max_len=scfg.max_len))
        self.arch_collector = None
        if collect_arch_trace and cfg.sc_backend == "array":
            from repro import arch
            self.arch_collector = arch.TraceCollector().install()

    def _substrate_scope(self):
        """Mesh scope entered around prefill/decode so their TRACING (the
        first call per shape) routes dense() through sc_dot_sharded."""
        if self.mesh is not None and self._stochastic_substrate:
            from repro import sc
            return sc.use_mesh(self.mesh, self.shard_rules)
        return contextlib.nullcontext()

    def arch_report(self):
        """Aggregate arch cost of everything compiled so far (None when
        trace collection is off or nothing was recorded). NOTE: the
        collector hears every array-backend dispatch in the process while
        installed (same semantics as ``arch.collect()``), not only this
        engine's — run one traced engine at a time for a clean bill."""
        if self.arch_collector is None or not self.arch_collector.records:
            return None
        return self.arch_collector.aggregate()

    def close(self):
        """Detach the arch trace collector (records stay readable)."""
        collector = getattr(self, "arch_collector", None)
        if collector is not None:
            collector.uninstall()

    def __del__(self):
        # A dropped engine must not leave its collector in the global
        # listener list (would leak records and keep tracing active).
        self.close()

    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _splice_slot(self, slot: int, cache1, length, last_tok):
        """Write a batch=1 prefill cache into batch row ``slot``."""
        def put(full, one):
            # full: (layers, slots, ...); one: (layers, 1, ...)
            return jax.lax.dynamic_update_slice_in_dim(full, one.astype(
                full.dtype), slot, axis=1)
        self.cache = jax.tree.map(put, self.cache, cache1)
        self.lengths = self.lengths.at[slot].set(length)
        self.last_token = self.last_token.at[slot].set(last_tok)

    def _admit(self):
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                prompt = jnp.asarray([req.prompt], jnp.int32)
                with self._substrate_scope():
                    if self._stochastic_substrate:
                        logits, cache1, lens = self._prefill(
                            self.params, prompt, rng=self._next_key())
                    else:
                        logits, cache1, lens = self._prefill(
                            self.params, prompt)
                tok = self._sample(logits, req.temperature)
                req.generated.append(int(tok[0]))
                self.active[slot] = req
                self._splice_slot(slot, cache1, int(lens[0]), int(tok[0]))

    def _sample(self, logits, temperature: float):
        """Sample one admission's tokens (batch=1 prefill logits)."""
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            self._next_key(), logits / temperature, axis=-1).astype(jnp.int32)

    def _sample_slots(self, logits, temperatures):
        """Per-slot sampling: each slot uses its request's own temperature.

        Greedy slots (t <= 0) take the argmax regardless of the rng, so a
        greedy request decodes identically whatever its batch neighbours
        sample.
        """
        temps = jnp.asarray(temperatures, jnp.float32)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if not any(t > 0.0 for t in temperatures):
            return greedy
        safe = jnp.where(temps > 0.0, temps, 1.0)
        sampled = jax.random.categorical(
            self._next_key(), logits / safe[:, None], axis=-1).astype(jnp.int32)
        return jnp.where(temps > 0.0, sampled, greedy)

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit, batched decode, per-slot sample, harvest."""
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        with self._substrate_scope():
            if self._stochastic_substrate:
                logits, self.cache = self._decode(
                    self.params, self.cache, self.last_token, self.lengths,
                    rng=self._next_key())
            else:
                logits, self.cache = self._decode(
                    self.params, self.cache, self.last_token, self.lengths)
        self.lengths = self.lengths + jnp.asarray(
            [1 if r is not None else 0 for r in self.active], jnp.int32)
        toks = self._sample_slots(
            logits, [r.temperature if r is not None else 0.0
                     for r in self.active])
        self.last_token = toks
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(toks[slot])
            req.generated.append(tok)
            hit_eos = tok == self.scfg.eos_id
            hit_max = len(req.generated) >= req.max_new_tokens
            hit_cap = int(self.lengths[slot]) >= self.scfg.max_len - 1
            if hit_eos or hit_max or hit_cap:
                req.done = True
                self.finished.append(req)
                self.active[slot] = None
                self.lengths = self.lengths.at[slot].set(0)
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
