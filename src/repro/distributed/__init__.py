from repro.distributed.compression import (  # noqa: F401
    compressed_grads, init_error_feedback)
