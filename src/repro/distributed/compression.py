"""Cross-pod int8 gradient compression with error feedback.

The ``pod`` mesh axis is pure data parallelism over the *slow* inter-pod
links (DCI), while ``data``/``model`` ride fast intra-pod ICI. Gradient
reduction is therefore two-level:

  1. within a pod: XLA's automatic partitioner reduce-scatters gradients
     over the ``data``/``model`` axes (auto axes of the shard_map below);
  2. across pods: WE own the collective — gradients are quantized to int8
     (per-tensor absmax scale) before the ``psum("pod")``, cutting DCI bytes
     4× vs f32 / 2× vs bf16, with **error feedback**: the quantization
     residual is carried to the next step, so the compressed SGD trajectory
     converges to the uncompressed one (Karimireddy et al., 2019).

Implementation: ``jax.shard_map`` manual over ONLY the pod axis
(``axis_names={"pod"}``) — everything inside remains auto-partitioned over
``data``/``model``, so FSDP/TP sharding is untouched. Per-pod error-feedback
residuals live in the optimizer state with a leading pod dimension sharded
over ``pod``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_compat


def _quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_feedback(abstract_grads, n_pods: int):
    """Residual buffers: one per pod (leading pod dim, sharded over pod)."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_pods,) + g.shape, jnp.float32), abstract_grads)


def compressed_grads(grad_fn, mesh, *, has_aux: bool = False):
    """Wrap ``grad_fn(params, batch) -> (loss, grads)`` so gradients cross
    the pod axis int8-compressed with error feedback.

    Returns ``fn(params, batch, ef) -> (loss, grads, new_ef)`` where ``ef``
    comes from :func:`init_error_feedback`. If the mesh has no pod axis the
    wrapper is a transparent pass-through (ef is ignored).
    """
    if "pod" not in mesh.axis_names:
        def passthrough(params, batch, ef):
            loss, grads = grad_fn(params, batch)
            return loss, grads, ef
        return passthrough

    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

    def pod_local(params, batch, ef):
        # batch arrives pod-local; loss/grads are the pod-local mean.
        loss, grads = grad_fn(params, batch)

        def reduce_one(g, r):
            g = g.astype(jnp.float32) + r[0]          # r: (1, ...) this pod
            q, scale = _quantize(g)
            deq = q.astype(jnp.float32) * scale       # what the wire carries
            new_r = g - deq                            # residual -> next step
            summed = jax.lax.psum(deq, "pod") / n_pods
            return summed, new_r[None]

        out = jax.tree.map(reduce_one, grads, ef)
        grads_c = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        loss = jax.lax.psum(loss, "pod") / n_pods
        return loss, grads_c, new_ef

    def wrapped(params, batch, ef):
        return _shard_map(
            pod_local,
            mesh=mesh,
            in_specs=(P(), P("pod"), P("pod")),
            out_specs=(P(), P(), P("pod")),
            manual_axes={"pod"},
        )(params, batch, ef)

    return wrapped


# Version-compat shard_map now lives in repro.compat (it gained a second
# consumer: the mesh-sharded SC substrate in repro.sc.sharded).
_shard_map = shard_map_compat
