"""Logical-axis -> mesh-axis sharding rules (FSDP × TP × EP × SP).

The production mesh is ``(data, model)`` per pod, with an optional leading
``pod`` axis (pure data parallel across pods — slow DCI links, so only
batch and gradient-reduction traffic crosses it).

Parameter rules implement **FSDP ∘ TP**: every weight tensor is sharded on
two independent axes — its "parallelism" axis (heads / mlp / experts /
vocab → ``model``) and its embed axis (→ ``data``), giving full 256-way
sharding of all large tensors. Indivisible dims fall back to replication
per-tensor (params.partition_specs handles that), so e.g. a 2-head KV
projection simply replicates its head dim while staying data-sharded on
embed.

Activation rules implement DP on batch (pod × data), TP on
heads/mlp/experts, and SP on the long-context cache sequence axis.
"""

from __future__ import annotations

from jax.sharding import NamedSharding, PartitionSpec

from repro.models import params as params_lib

# Parameter logical axes.
PARAM_RULES = {
    "embed": "data",          # FSDP shard dimension
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",      # falls back to replicated when indivisible
    "head_dim": None,
    "kv_embed": "model",      # KV proj: TP moves to embed when kv_heads small
    "experts": "model",
    "expert_mlp": None,       # expert-internal FFN dim (EP owns model)
    "layers": None,           # stacked-scan leading axis — never sharded
    "ssm_state": None,
    "ssm_inner": "model",
    "conv": None,
    None: None,
}

# Activation logical axes.
ACT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    # Megatron-style sequence parallelism: the residual stream BETWEEN layers
    # shards its sequence over the TP axis (in-layer tensors keep full
    # sequences and shard heads/mlp instead). This keeps the remat-saved
    # per-layer activation stacks (n_layers, b, s, d) model_parallel-times
    # smaller — EXPERIMENTS §Perf iteration 2. Indivisible lengths fall back
    # to replicated per-tensor via make_constrain's divisibility check.
    "resid_seq": "model",
    "cache_seq": "data",      # SP: long-context KV cache sharded over data
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    None: None,
}


def logical_rules(mesh, kind: str = "param") -> dict:
    """Rules dict + mesh axis sizes (so indivisible dims can replicate)."""
    base = dict(PARAM_RULES if kind == "param" else ACT_RULES)
    sizes = dict(mesh.shape)   # works for Mesh and AbstractMesh
    if "pod" not in sizes:
        # single-pod mesh: batch rule must not reference the pod axis
        if kind == "act":
            base["batch"] = "data"
    base["__sizes__"] = sizes
    return base


def param_partition_specs(specs, mesh):
    return params_lib.partition_specs(specs, logical_rules(mesh, "param"))


def param_shardings(specs, mesh):
    return params_lib.tree_map_specs(
        lambda ps: NamedSharding(mesh, ps),
        param_partition_specs(specs, mesh))


def sc_shard_rules(mesh, *, batch=None, contract=None):
    """SC-substrate sharding rules adapted to ``mesh``.

    The SC contraction splits along the same logical axes the activation
    rules use: rows (flattened batch·seq) over the DP axes
    (``("pod", "data")``), contraction over the TP axis (``"model"``) with
    a psum merge.  Axes absent from the mesh are dropped here; size-1 and
    indivisible axes degrade per-call inside ``sc_dot_sharded``.
    """
    from repro.sc.sharded import DEFAULT_RULES, ScShardRules
    sizes = dict(mesh.shape)
    batch = tuple(batch if batch is not None else DEFAULT_RULES.batch)
    contract = tuple(contract if contract is not None
                     else DEFAULT_RULES.contract)
    return ScShardRules(
        batch=tuple(a for a in batch if a in sizes),
        contract=tuple(a for a in contract if a in sizes))


def act_spec(mesh, *axes) -> PartitionSpec:
    """PartitionSpec for an activation from logical axis names."""
    rules = logical_rules(mesh, "act")
    sizes = rules["__sizes__"]
    entries = []
    for ax in axes:
        mesh_ax = rules.get(ax)
        if isinstance(mesh_ax, tuple):
            mesh_ax = tuple(a for a in mesh_ax if a in sizes) or None
        elif mesh_ax is not None and mesh_ax not in sizes:
            mesh_ax = None
        entries.append(mesh_ax)
    return PartitionSpec(*entries)
