from repro.sharding.rules import (  # noqa: F401
    ACT_RULES, PARAM_RULES, act_spec, logical_rules, param_partition_specs,
    sc_shard_rules)
