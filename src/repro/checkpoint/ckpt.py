"""Atomic, elastic checkpointing.

Layout:  <dir>/step_<N>/  with one ``.npy`` per pytree leaf (keyed by its
flattened path) plus ``META.json`` (step, leaf index, data-pipeline step).
Writes go to ``step_<N>.tmp/`` and are renamed into place only after every
leaf and the metadata have been fsync'd — a crash mid-save can never corrupt
the latest complete checkpoint, and ``latest_step`` only ever sees complete
directories.

Elasticity: leaves are stored as FULL (unsharded) arrays keyed by logical
path, so a restore can re-shard onto *any* mesh — ``restore_resharded``
device_puts every leaf with the NamedSharding derived from the current mesh
and the model's logical axis rules. A job restarted on a different pod
count resumes exactly (the data pipeline is a pure function of the restored
step). On a real multi-host cluster the same layout is written once per
leaf-shard by the host owning it; this container is single-process, so the
full-array path is the live one (noted in DESIGN.md).
"""

from __future__ import annotations

import json
import os
import re
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    from repro.compat import tree_flatten_with_path
    flat, treedef = tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomically write ``tree`` as step ``step``. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    items, _ = _flatten_with_paths(tree)
    manifest = []
    for i, (key, leaf) in enumerate(items):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest.append({"key": key, "file": fname,
                         "dtype": str(arr.dtype), "shape": list(arr.shape)})
    meta = {"step": step, "manifest": manifest, "extra": extra or {}}
    with open(os.path.join(tmp, "META.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "META.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like`` (values ignored).
    Returns (tree, meta_extra, step)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "META.json")) as f:
        meta = json.load(f)
    leaves = [np.load(os.path.join(path, m["file"]))
              for m in meta["manifest"]]
    _, treedef = _flatten_with_paths(tree_like)
    flat_like = jax.tree.leaves(tree_like)
    assert len(flat_like) == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, expected {len(flat_like)}"
    restored = [jnp.asarray(a, dtype=l.dtype) if hasattr(l, "dtype")
                else jnp.asarray(a) for a, l in zip(leaves, flat_like)]
    return (jax.tree.unflatten(jax.tree.structure(tree_like), restored),
            meta["extra"], step)


def restore_resharded(ckpt_dir: str, tree_like, shardings,
                      step: int | None = None):
    """Elastic restore: device_put every leaf with the given shardings tree
    (built from the CURRENT mesh — may differ from the saving mesh)."""
    tree, extra, step = restore(ckpt_dir, tree_like, step)
    flat_t = jax.tree.leaves(tree)
    flat_s = jax.tree.leaves(shardings,
                             is_leaf=lambda x: hasattr(x, "spec"))
    if len(flat_s) == len(flat_t):
        flat_t = [jax.device_put(v, s) for v, s in zip(flat_t, flat_s)]
        tree = jax.tree.unflatten(jax.tree.structure(tree), flat_t)
    return tree, extra, step
