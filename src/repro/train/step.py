"""train_step factory: remat scan over layers (in the model), microbatched
gradient accumulation, FSDP×TP sharding constraints, optional cross-pod
int8 gradient compression, AdamW update.

The returned step is a pure ``(state, batch) -> (state, metrics)`` function
meant for ``jax.jit`` with NamedSharding in/out specs (launch/train.py and
launch/dryrun.py own the jit). Overlap notes: grad accumulation keeps the
per-microbatch backward inside a scan so XLA's latency-hiding scheduler can
overlap the reduce-scatter of microbatch *i* with the compute of *i+1*;
layer-weight all-gathers prefetch inside the layer scan the same way.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.distributed import compression
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding import act_spec


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    cross_pod_compress: bool = False
    seed: int = 0


def make_constrain(mesh):
    """Activation-sharding constraint helper with divisibility fallback.

    Logical axes whose dimension does not divide the mesh axis are dropped
    (replicated) per-tensor — e.g. a 14-head attention on model=16 runs
    head-replicated (data-parallel attention) instead of letting the
    partitioner invent per-chunk all-reduces inside the layer scan.
    """
    if mesh is None:
        return None
    sizes = dict(mesh.shape)   # works for Mesh and AbstractMesh

    def cst(v, *axes):
        spec = act_spec(mesh, *axes[: v.ndim])
        entries = []
        used = set()
        for dim, mesh_ax in zip(v.shape, spec):
            if mesh_ax is None:
                entries.append(None)
                continue
            ax_tuple = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
            if any(a in used for a in ax_tuple):
                entries.append(None)       # one mesh axis per tensor dim
                continue
            total = 1
            for a in ax_tuple:
                total *= sizes.get(a, 1)
            if dim % total == 0:
                entries.append(mesh_ax)
                used.update(ax_tuple)
            else:
                entries.append(None)
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(
            v, NamedSharding(mesh, PartitionSpec(*entries)))
    cst.axis_sizes = sizes                 # model code adapts layouts to mesh
    return cst


def make_param_constrain(mesh, cfg):
    """Per-layer weight constraint applied INSIDE the scan-over-layers body.

    Without it, the FSDP all-gather of the scan-stacked weights is
    loop-invariant and XLA hoists it out of the while loop — materializing
    the ENTIRE depth-stacked, embed-unsharded parameter array as a temp
    (observed: +100 GB/device and ~5x HBM traffic on the 400B config).
    Constraining each layer's sliced weights to their FSDP/TP sharding pins
    the gather inside the iteration: per-layer gather -> use -> discard,
    which is the streaming behaviour FSDP assumes."""
    if mesh is None:
        return None
    from jax.sharding import PartitionSpec
    from repro.models import lm as lm_mod
    from repro.models import params as params_lib
    from repro.sharding import rules as sharding_rules

    def build(specs):
        pspecs = params_lib.partition_specs(
            specs, sharding_rules.logical_rules(mesh))
        flat_ps = jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, PartitionSpec))

        def cstp(layer_tree):
            leaves, treedef = jax.tree.flatten(layer_tree)
            out = [jax.lax.with_sharding_constraint(
                v, NamedSharding(mesh, ps))
                for v, ps in zip(leaves, flat_ps)]
            return jax.tree.unflatten(treedef, out)
        return cstp

    return build(lm_mod.block_specs(cfg))


def train_state_init(key, cfg, tcfg: TrainConfig, abstract: bool = False):
    """Build (or abstractly describe) the full train state."""
    from repro.models import params as P
    specs = lm.lm_param_specs(cfg)
    if abstract:
        params = P.abstract_params(specs, cfg.param_dtype)
    else:
        params = P.init_params(key, specs, cfg.param_dtype)

    if abstract:
        opt = jax.eval_shape(partial(adamw_init, cfg=tcfg.optimizer), params)
    else:
        opt = adamw_init(params, tcfg.optimizer)
    state = {"params": params, "opt": opt}
    if tcfg.cross_pod_compress:
        # residuals are materialized lazily by the first step; store zeros
        state["ef"] = None      # filled by launch/train.py with mesh info
    return state


def make_train_step(cfg, tcfg: TrainConfig, mesh=None):
    """Returns step(state, batch) -> (state, metrics)."""
    cst = make_constrain(mesh)
    cstp = make_param_constrain(mesh, cfg)

    def loss_fn(params, batch, rng):
        return lm.lm_loss(params, batch, cfg, rng=rng, constrain=cst,
                          constrain_params=cstp)

    def grads_of(params, batch, rng):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch, rng)

        n = tcfg.microbatches
        micro = jax.tree.map(
            lambda v: v.reshape((n, v.shape[0] // n) + v.shape[1:]), batch)

        def acc_step(carry, mb):
            loss_acc, g_acc, i = carry
            li, gi = jax.value_and_grad(loss_fn)(
                params, mb, None if rng is None else jax.random.fold_in(rng, i))
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, gi)
            return (loss_acc + li, g_acc, i + 1), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads, _), _ = jax.lax.scan(acc_step, (0.0, g0, 0), micro)
        return loss / n, jax.tree.map(lambda g: g / n, grads)

    def step(state, batch):
        # The SC substrate (repro.sc) is the only rng consumer in the loss;
        # exact-backend runs skip the per-layer key folding entirely.
        rng = None if cfg.sc_backend == "exact" else jax.random.fold_in(
            jax.random.PRNGKey(tcfg.seed), state["opt"]["step"])
        if tcfg.cross_pod_compress and mesh is not None \
                and "pod" in mesh.axis_names:
            fn = compression.compressed_grads(
                lambda p, b: grads_of(p, b, rng), mesh)
            loss, grads, new_ef = fn(state["params"], batch, state["ef"])
        else:
            loss, grads = grads_of(state["params"], batch, rng)
            new_ef = state.get("ef")
        # Materialize gradients in the parameter dtype: the f32 cotangent
        # stacks of the big depth-stacked weights were ~12 GB/device of the
        # 400B HBM peak; the optimizer decodes to f32 per-chunk anyway
        # (EXPERIMENTS §Perf iteration 5).
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                             grads, state["params"])
        new_params, new_opt, metrics = adamw_update(
            grads, state["opt"], state["params"], tcfg.optimizer)
        metrics["loss"] = loss
        new_state = {"params": new_params, "opt": new_opt}
        if "ef" in state:
            new_state["ef"] = new_ef
        return new_state, metrics

    return step


def make_eval_step(cfg, mesh=None):
    cst = make_constrain(mesh)

    def eval_step(params, batch):
        return lm.lm_loss(params, batch, cfg, constrain=cst)

    return eval_step
