"""Bit-exact simulator of the SOT-MRAM stochastic-computing MUL engine (§III-B).

The hardware sequence per MUL (paper Fig. 5):

    1. PRESET    — a long reverse pulse initializes every cell to "1".
    2. PULSE τ_X — each cell independently survives (stays "1") w.p.
                   P_usw(τ_X) = exp(-τ_X) at the operating current.
    3. PULSE τ_Y — surviving cells survive again w.p. P_usw(τ_Y).
    4. READ      — the fraction of "1"s estimates P_X · P_Y ∝ X·Y.

Each MRAM cell is an independent Bernoulli trial; two sequential pulses AND
two independent survival events, so the final per-bit distribution is
Bernoulli(P_X · P_Y) exactly. The simulator reproduces the *sequence*
(preset → pulse → pulse) bit-by-bit so that hardware-variance studies
(per-cell I_c spread, §IV-B) act on each pulse separately, exactly as the
paper's Monte-Carlo does.

Entropy: the container's TPU-kernel PRNG is unavailable on CPU interpret
mode, so random draws are counter-based threefry via ``jax.random`` — the
statistical contract (iid uniforms per cell per pulse) is identical to the
thermal randomness the device supplies.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import conversion, physics


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One MRAM sub-array acting as an SC engine."""

    nbit: int = 1024                 # stochastic bits per MUL (2^n for n-bit operands)
    conv: conversion.ConversionConfig = conversion.ConversionConfig()
    # Cross-point row length limit (§III-D IR-drop discussion): a physical row
    # holds at most this many cells; nbit cells occupy ceil(nbit/row) rows that
    # are written simultaneously (multi-row activation).
    row_length: int = 256

    @property
    def rows_per_mul(self) -> int:
        return -(-self.nbit // self.row_length)


def preset(shape) -> jnp.ndarray:
    """Step 1: all cells to '1' (deterministic strong reverse pulse)."""
    return jnp.ones(shape, dtype=jnp.uint8)


def apply_pulse(key, state, tau_ns, *, i_ua=physics.I_C_UA, i_c_ua=physics.I_C_UA,
                delta=physics.DELTA):
    """One stochastic write pulse applied to every cell in ``state``.

    ``tau_ns`` broadcasts against ``state`` (scalar per-MUL pulse, or per-cell
    when modeling DTC/driver variance). ``i_c_ua`` may be a per-cell array for
    σ(I_c) studies. A cell at "1" survives w.p. P_usw; a cell already at "0"
    stays "0" (the pulse drives toward "0" only — paper Fig. 5 polarity).
    """
    p_survive = physics.p_unswitched(tau_ns, i_ua, delta=delta, i_c_ua=i_c_ua)
    u = jax.random.uniform(key, state.shape)
    survived = (u < p_survive).astype(state.dtype)
    return state * survived


def readout(state) -> jnp.ndarray:
    """Step 4: pop-count → probability estimate (fraction of remaining 1s)."""
    n = state.shape[-1]
    return jnp.sum(state, axis=-1, dtype=jnp.float32) / n


@partial(jax.jit, static_argnums=(3,))
def sc_multiply(key, x_int, y_int, cfg: EngineConfig):
    """Full §III MUL between two unsigned n-bit operands, bit-exact.

    Returns ``(p_est, product_int)`` where ``p_est ≈ P_X·P_Y`` and
    ``product_int`` is the decoded 2n-bit product estimate
    ``round(p_est · 2^{2n})``. Operands may be arrays (batched MULs — each MUL
    gets its own ``nbit`` cells, i.e. its own sub-array).
    """
    x_int = jnp.asarray(x_int, jnp.int32)
    y_int = jnp.asarray(y_int, jnp.int32)
    batch_shape = jnp.broadcast_shapes(x_int.shape, y_int.shape)
    cells = batch_shape + (cfg.nbit,)

    tau_x = conversion.operand_to_tau(x_int, cfg.conv)
    tau_y = conversion.operand_to_tau(y_int, cfg.conv)

    kx, ky = jax.random.split(key)
    state = preset(cells)
    state = apply_pulse(kx, state, tau_x[..., None])
    state = apply_pulse(ky, state, tau_y[..., None])

    p_est = readout(state)
    levels_sq = cfg.conv.levels * cfg.conv.levels
    product = jnp.round(p_est * levels_sq).astype(jnp.int32)
    return p_est, product


def _profile_cells(profile: physics.DeviceProfile, batch_shape, nbit: int):
    """Realized per-cell (delta, i_c) for a batch of MULs: MUL ``q`` of
    the batch occupies virtual cells ``q*nbit ..`` of the profile's
    frozen map, so batched engine runs and the variance studies read the
    SAME manufacturing spread the ``array`` backend does."""
    n_muls = 1
    for d in batch_shape:
        n_muls *= int(d)
    delta_c, ic_c = physics.mul_cell_params(profile, n_muls, nbit)
    shape = tuple(batch_shape) + (nbit,)
    return delta_c.reshape(shape), ic_c.reshape(shape)


@partial(jax.jit, static_argnums=(3,), static_argnames=("profile",))
def sc_multiply_states(key, tau_x, tau_y, cfg: EngineConfig,
                       *, i_c_ua=physics.I_C_UA, profile=None):
    """Lower-level entry: pulses already converted; returns the raw cell states.

    Used by the variance studies (per-cell ``i_c_ua`` arrays) and by tests
    that assert on the distribution of the bits themselves.

    ``profile`` (a :class:`physics.DeviceProfile`) is the one device knob:
    it supplies realized per-cell (Delta, I_c) from the profile's frozen
    variation maps and overrides a loose ``i_c_ua``.  Variation only —
    stuck-at / retention FAULTS are an array-readout phenomenon and are
    injected at the arch backend (``arch/backend.py``), not per-MUL here.
    """
    batch_shape = jnp.broadcast_shapes(jnp.shape(tau_x), jnp.shape(tau_y))
    cells = batch_shape + (cfg.nbit,)
    delta = physics.DELTA
    i_ua = physics.I_C_UA
    if profile is not None:
        delta, i_c_ua = _profile_cells(profile, batch_shape, cfg.nbit)
        i_ua = profile.i_c_ua       # operating current = nominal I_c
    kx, ky = jax.random.split(key)
    state = preset(cells)
    state = apply_pulse(kx, state, jnp.asarray(tau_x)[..., None],
                        i_ua=i_ua, i_c_ua=i_c_ua, delta=delta)
    state = apply_pulse(ky, state, jnp.asarray(tau_y)[..., None],
                        i_ua=i_ua, i_c_ua=i_c_ua, delta=delta)
    return state


def mac_rows(key, w_int, x_int, cfg: EngineConfig):
    """Paper §III-C vectored MAC: ``Σ_i w_i·x_i`` over a column of MULs.

    Performs each MUL in its own sub-array (rows of the same bank), then the
    two-step pop-count (row-wise CSA, column-wise FA) is modeled in
    popcount.py; here we return the raw per-MUL states stacked on axis 0 so
    the pop-count strategies can be applied and compared.
    """
    w_int = jnp.asarray(w_int, jnp.int32)
    x_int = jnp.asarray(x_int, jnp.int32)
    assert w_int.shape == x_int.shape
    _, states = jax.lax.scan(
        lambda carry, wx: (
            jax.random.fold_in(carry, 1),
            sc_multiply_states(carry, conversion.operand_to_tau(wx[0], cfg.conv),
                               conversion.operand_to_tau(wx[1], cfg.conv), cfg),
        ),
        key, (w_int, x_int))
    return states
