"""Data conversion chain (paper Eq. 4 and §III-A).

    X(binary) --LUT--> ln(X)(binary) --DTC--> tau_X (time) --MRAM--> stochastic
    --popcount--> X*Y (binary)

Hardware-faithful pieces modeled here:

* **LUT logarithm** (§III-A): an ``n``-bit operand indexes a 2^n-entry table of
  pre-computed ``-ln(X / 2^n)`` values, themselves quantized to a fixed-point
  grid. We model the table explicitly (it is also what the area model charges
  for in Fig. 11).
* **DTC** (digital-to-time converter, ref [19]): emits a voltage pulse whose
  duration is the LUT output; 22 ps resolution → every tau is quantized to a
  multiple of ``DTC_RESOLUTION_NS``.

Probability encoding: an unsigned n-bit operand ``X`` maps to
``P_X = X / 2^n ∈ [0, 1)``. Signed operands are handled by the canonical
``sc/encoding.py`` via sign/magnitude split (the paper only treats unsigned
operands).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import physics

DTC_RESOLUTION_NS = 0.022  # 22 ps (paper §V-A, ref [19])


@dataclasses.dataclass(frozen=True)
class ConversionConfig:
    n_bits: int = 10                  # operand bit width (paper evaluates 10-bit)
    dtc_resolution_ns: float = DTC_RESOLUTION_NS
    lut_fixedpoint_bits: int = 16     # fixed-point width of the stored -ln values
    max_tau_ns: float = 16.0          # DTC full-scale range

    @property
    def levels(self) -> int:
        return 1 << self.n_bits


def encode_probability(x_int, cfg: ConversionConfig):
    """n-bit unsigned integer -> survival probability P = X / 2^n."""
    return jnp.asarray(x_int, jnp.float32) / cfg.levels


def decode_probability(p, cfg: ConversionConfig):
    """Probability estimate -> nearest n-bit integer (the pop-count readout)."""
    return jnp.clip(jnp.round(p * cfg.levels), 0, cfg.levels - 1).astype(jnp.int32)


def build_lut(cfg: ConversionConfig) -> jnp.ndarray:
    """The -ln LUT actually stored in hardware: entry[i] = -ln(i / 2^n), quantized.

    Entry 0 (P = 0) is clamped to the DTC full-scale pulse — a maximal pulse
    switches the bit (almost) deterministically, representing multiply-by-zero.
    """
    i = jnp.arange(cfg.levels, dtype=jnp.float32)
    p = jnp.where(i == 0, 1.0, i) / cfg.levels          # placeholder for i=0
    tau = -jnp.log(p)
    tau = jnp.where(i == 0, cfg.max_tau_ns, tau)
    # Fixed-point quantization of the table contents.
    scale = (1 << cfg.lut_fixedpoint_bits) / cfg.max_tau_ns
    tau_q = jnp.round(tau * scale) / scale
    return tau_q.astype(jnp.float32)


def dtc_quantize(tau_ns, cfg: ConversionConfig):
    """DTC emits pulses on a 22 ps grid, saturating at full scale."""
    res = cfg.dtc_resolution_ns
    tau = jnp.clip(jnp.asarray(tau_ns), 0.0, cfg.max_tau_ns)
    return jnp.round(tau / res) * res


@partial(jax.jit, static_argnums=(1,))
def operand_to_tau(x_int, cfg: ConversionConfig):
    """Full §III-A chain: n-bit integer -> LUT lookup -> DTC-quantized pulse."""
    lut = build_lut(cfg)
    tau = lut[jnp.asarray(x_int, jnp.int32)]
    return dtc_quantize(tau, cfg)


def tau_to_probability(tau_ns, *, i_ua=physics.I_C_UA):
    """What the device does with the pulse (Eq. 3 at the operating current)."""
    return physics.p_unswitched(tau_ns, i_ua)


def ideal_product_probability(x_int, y_int, cfg: ConversionConfig):
    """Reference: P_X * P_Y with no LUT/DTC quantization (float math)."""
    return encode_probability(x_int, cfg) * encode_probability(y_int, cfg)


def quantized_product_probability(x_int, y_int, cfg: ConversionConfig):
    """P_usw(tau_X) * P_usw(tau_Y) including LUT fixed-point + DTC quantization.

    This is the *deterministic* part of the hardware error (bias); the
    stochastic part (binomial sampling noise) comes from the engine.
    """
    px = tau_to_probability(operand_to_tau(x_int, cfg))
    py = tau_to_probability(operand_to_tau(y_int, cfg))
    return px * py
