"""Analytical cycle / energy / area model (paper §V, Figs. 9-11).

The paper evaluates four designs on 10-bit × 10-bit MUL (2^10 stochastic bits):

  * SC+PIM (APC)  — this work, pop-count via one-cycle APC
  * SC+PIM (CSA)  — this work, pop-count via in-memory CSA+FA, amortized
                    over a 100-MUL MAC
  * SC            — conventional stochastic computing with the
                    state-of-the-art SNG [21] + APC pop-count
  * PIM           — MUL from in-memory bitwise Boolean ops only (DRISA [6])

Like the paper (which has no silicon), this is an *analytical* model built
from published component anchors, with the remaining free constants
calibrated so the published headline ratios emerge:

  anchors: DRISA 143 cycles @ 8-bit MUL, quadratic shift-add scaling;
           DTC: 22 ps resolution, 75×25 µm² [19]; APC one cycle [16];
           SNG = 95 % of conventional-SC area [21]; SC energy 88 % buffering;
  headlines reproduced: ≈4× cycles vs SC, ≈18× vs PIM (10-bit),
           ≈58 % energy saving vs SC, ≈10× area saving vs SC.

Every constant is a field of the frozen :class:`CostParams` dataclass, so a
parameter sweep is ``CostParams(row_length=512)`` — hashable, thread-safe,
usable as a jit static argument and as a dict key. The module-level names
(``ROW_LENGTH`` …) remain as the *default* values for backward
compatibility; every model function takes ``params=DEFAULT_PARAMS``.
The array-level simulator (:mod:`repro.arch`) consumes the same
``CostParams`` to price its command traces, so the closed-form figures here
and the per-workload traces there can never drift apart.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import popcount


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Every §V model knob, frozen and hashable (sweep via ``replace``)."""

    # --------------------------- cycle-model knobs --------------------------
    row_length: int = 256             # cross-point row cells (IR-drop, §III-D)
    sa_read_cycles: int = 2           # sense + latch, parallel across banks
    bank_merge_per_level: int = 1     # adder-tree merge of per-bank APC counts
    preset_cycles: int = 1            # strong reverse pulse, all rows parallel
    pulse_cycles: int = 1             # one stochastic write pulse (row-parallel)
    sng_bits_per_cycle: int = 128     # LFSR bank width of the SNG [21]
    sng_shuffle_factor: float = 2.0   # decorrelation shuffle (both streams) [21]
    drisa_8bit_cycles: int = 143      # DRISA anchor [6] — the PIM baseline

    # --------------------------- energy-model knobs (pJ) --------------------
    r_hml_ohm: float = 250.0          # heavy-metal-layer write-path resistance
    i_c_a: float = 80e-6              # critical current
    pulse_tau_ns: float = 0.5         # mean stochastic pulse duration (P≈0.5)
    preset_tau_ns: float = 3.0        # preset pulse duration
    preset_i_factor: float = 1.25     # preset over-drive
    dtc_energy_pj: float = 0.2        # per conversion [19]
    lut_read_pj: float = 0.1          # per lookup
    apc_energy_pj: float = 0.5        # per pop-count
    csa_op_pj: float = 0.05           # per in-memory bulk bitwise op
    sram_buffer_pj_per_bit: float = 0.0108   # conventional-SC buffering
    sng_gen_pj_per_bit: float = 0.0012       # SNG generation energy [21]
    pim_op_pj: float = 0.10           # DRISA bulk bitwise op energy

    # --------------------------- area-model knobs (µm²) ---------------------
    dtc_area_um2: float = 75.0 * 25.0          # [19]
    apc_area_um2: float = 2100.0      # synthesized 45 nm FreePDK, from [16]
    and_buffer_area_um2: float = 700.0         # SC AND array + latches
    sng_area_fraction: float = 0.95   # SNG share of conventional SC area [21]
    mram_cell_area_um2: float = 0.10  # LUT storage cell
    pim_logic_area_um2: float = 1500.0         # DRISA-style subarray logic

    def replace(self, **kw) -> "CostParams":
        return dataclasses.replace(self, **kw)

    # ------------------------- derived per-event costs ----------------------
    def write_energy_pj(self, tau_ns: float, i_factor: float = 1.0) -> float:
        """Joule heating per cell: I²·R·τ, in pJ."""
        i = self.i_c_a * i_factor
        return (i * i) * self.r_hml_ohm * (tau_ns * 1e-9) * 1e12

    def preset_energy_pj_per_cell(self) -> float:
        return self.write_energy_pj(self.preset_tau_ns, self.preset_i_factor)

    def pulse_energy_pj_per_cell(self) -> float:
        return self.write_energy_pj(self.pulse_tau_ns)

    def conversion_energy_pj_per_operand(self) -> float:
        """One LUT lookup + one DTC launch (§III-A chain, per operand)."""
        return self.dtc_energy_pj + self.lut_read_pj

    def rows_per_mul(self, n_bits: int) -> int:
        """Sub-array rows one 2^n-bit MUL occupies (IR-drop row limit)."""
        return -(-(1 << n_bits) // self.row_length)

    def merge_cycles(self, rows: int) -> int:
        """Log-depth adder tree merging per-row APC counts into one sum."""
        if rows <= 1:
            return 0
        return self.bank_merge_per_level * math.ceil(math.log2(rows))


DEFAULT_PARAMS = CostParams()

# Backward-compatible module-level aliases of the default knob values.
ROW_LENGTH = DEFAULT_PARAMS.row_length
SA_READ_CYCLES = DEFAULT_PARAMS.sa_read_cycles
BANK_MERGE_PER_LEVEL = DEFAULT_PARAMS.bank_merge_per_level
PRESET_CYCLES = DEFAULT_PARAMS.preset_cycles
PULSE_CYCLES = DEFAULT_PARAMS.pulse_cycles
SNG_BITS_PER_CYCLE = DEFAULT_PARAMS.sng_bits_per_cycle
SNG_SHUFFLE_FACTOR = DEFAULT_PARAMS.sng_shuffle_factor
DRISA_8BIT_CYCLES = DEFAULT_PARAMS.drisa_8bit_cycles
R_HML_OHM = DEFAULT_PARAMS.r_hml_ohm
I_C_A = DEFAULT_PARAMS.i_c_a
PULSE_TAU_NS = DEFAULT_PARAMS.pulse_tau_ns
PRESET_TAU_NS = DEFAULT_PARAMS.preset_tau_ns
PRESET_I_FACTOR = DEFAULT_PARAMS.preset_i_factor
DTC_ENERGY_PJ = DEFAULT_PARAMS.dtc_energy_pj
LUT_READ_PJ = DEFAULT_PARAMS.lut_read_pj
APC_ENERGY_PJ = DEFAULT_PARAMS.apc_energy_pj
CSA_OP_PJ = DEFAULT_PARAMS.csa_op_pj
SRAM_BUFFER_PJ_PER_BIT = DEFAULT_PARAMS.sram_buffer_pj_per_bit
SNG_GEN_PJ_PER_BIT = DEFAULT_PARAMS.sng_gen_pj_per_bit
PIM_OP_PJ = DEFAULT_PARAMS.pim_op_pj
DTC_AREA_UM2 = DEFAULT_PARAMS.dtc_area_um2
APC_AREA_UM2 = DEFAULT_PARAMS.apc_area_um2
AND_BUFFER_AREA_UM2 = DEFAULT_PARAMS.and_buffer_area_um2
SNG_AREA_FRACTION = DEFAULT_PARAMS.sng_area_fraction
MRAM_CELL_AREA_UM2 = DEFAULT_PARAMS.mram_cell_area_um2
PIM_LOGIC_AREA_UM2 = DEFAULT_PARAMS.pim_logic_area_um2


@dataclasses.dataclass(frozen=True)
class MulCost:
    cycles: float
    energy_pj: float
    area_um2: float
    breakdown: dict


def _rows(n_bits: int, params: CostParams = DEFAULT_PARAMS) -> int:
    return params.rows_per_mul(n_bits)


# ---------------------------------------------------------------------------
# Cycles (Fig. 9)
# ---------------------------------------------------------------------------


def cycles_scpim_apc(n_bits: int = 10,
                     params: CostParams = DEFAULT_PARAMS) -> float:
    """This work, APC pop-count. LUT+DTC conversion is pipelined (§III-D).

    The 2^n stochastic bits live in ``rows`` sub-array rows written AND
    sensed in parallel (each bank has its own SAs — the multi-row activation
    of §III-D); per-bank APC counts merge through a log-depth adder tree.
    This is what makes Fig. 9b ~flat in operand bit length."""
    rows = _rows(n_bits, params)
    return (params.preset_cycles + 2 * params.pulse_cycles
            + params.sa_read_cycles + popcount.apc_cycles(1)
            + params.merge_cycles(rows))


def cycles_scpim_csa(n_bits: int = 10, n_mac: int = 100,
                     params: CostParams = DEFAULT_PARAMS) -> float:
    """This work, CSA+FA pop-count amortized over an n_mac MAC (Fig. 6):
    constant lock-step fold per MUL + one FA resolve per MAC."""
    nbit = 1 << n_bits
    per_mul_popcount = popcount.csa_fa_cycles_per_mul(
        n_mac, nbit, row_length=params.row_length)
    return (params.preset_cycles + 2 * params.pulse_cycles
            + per_mul_popcount)


def cycles_sc(n_bits: int = 10, params: CostParams = DEFAULT_PARAMS) -> float:
    """Conventional SC: SNG-generated bitstreams + APC.

    Two 2^n-bit streams from the shared SNG bank, plus the decorrelation
    shuffle the paper notes pseudo-random streams need; AND is fused into the
    stream, APC closes.
    """
    nbit = 1 << n_bits
    gen = 2 * nbit / params.sng_bits_per_cycle
    shuffle = params.sng_shuffle_factor * nbit / params.sng_bits_per_cycle
    return gen + shuffle + popcount.apc_cycles(1)


def cycles_pim(n_bits: int = 10, params: CostParams = DEFAULT_PARAMS) -> float:
    """Bitwise-Boolean in-memory MUL (DRISA): quadratic shift-add scaling
    from the published 8-bit / 143-cycle anchor."""
    return math.ceil(params.drisa_8bit_cycles * (n_bits / 8) ** 2)


# ---------------------------------------------------------------------------
# Energy (Fig. 10)
# ---------------------------------------------------------------------------


def _write_energy_pj(tau_ns: float, i_factor: float = 1.0,
                     params: CostParams = DEFAULT_PARAMS) -> float:
    """Joule heating per cell: I²·R·τ, in pJ."""
    return params.write_energy_pj(tau_ns, i_factor)


def energy_scpim(n_bits: int = 10, popcount_kind: str = "apc",
                 n_mac: int = 100,
                 params: CostParams = DEFAULT_PARAMS) -> tuple[float, dict]:
    nbit = 1 << n_bits
    init = nbit * params.preset_energy_pj_per_cell()
    pulses = 2 * nbit * params.pulse_energy_pj_per_cell()
    convert = 2 * params.conversion_energy_pj_per_operand()
    if popcount_kind == "apc":
        pc = params.apc_energy_pj
    else:
        ops = popcount.csa_fa_cycles_per_mul(n_mac, nbit,
                                             row_length=params.row_length)
        pc = ops * params.csa_op_pj
    bd = {"init": init, "sc_pulses": pulses, "conversion": convert, "popcount": pc}
    return sum(bd.values()), bd


def energy_sc(n_bits: int = 10,
              params: CostParams = DEFAULT_PARAMS) -> tuple[float, dict]:
    nbit = 1 << n_bits
    gen = 2 * nbit * params.sng_gen_pj_per_bit
    buffering = 2 * nbit * params.sram_buffer_pj_per_bit   # 88 %-class share
    pc = params.apc_energy_pj
    bd = {"sng_generation": gen, "buffering": buffering, "popcount": pc}
    return sum(bd.values()), bd


def energy_pim(n_bits: int = 10,
               params: CostParams = DEFAULT_PARAMS) -> tuple[float, dict]:
    ops = cycles_pim(n_bits, params)
    bd = {"bitwise_ops": ops * params.pim_op_pj}
    return sum(bd.values()), bd


# ---------------------------------------------------------------------------
# Area (Fig. 11)
# ---------------------------------------------------------------------------


def area_scpim(n_bits: int = 10, popcount_kind: str = "apc",
               params: CostParams = DEFAULT_PARAMS) -> tuple[float, dict]:
    lut_bits = (1 << n_bits) * 16               # 2^n entries × 16-bit fixed point
    lut = lut_bits * params.mram_cell_area_um2
    bd = {"dtc": params.dtc_area_um2, "lut": lut}
    if popcount_kind == "apc":
        bd["apc"] = params.apc_area_um2
    else:
        bd["csa_fa_logic"] = 0.15 * params.apc_area_um2  # FA column + control
    return sum(bd.values()), bd


def area_sc(n_bits: int = 10,
            params: CostParams = DEFAULT_PARAMS) -> tuple[float, dict]:
    non_sng = params.apc_area_um2 + params.and_buffer_area_um2
    sng = non_sng * params.sng_area_fraction / (1.0 - params.sng_area_fraction)
    bd = {"sng": sng, "apc": params.apc_area_um2,
          "and_buffers": params.and_buffer_area_um2}
    return sum(bd.values()), bd


def area_pim(n_bits: int = 10,
             params: CostParams = DEFAULT_PARAMS) -> tuple[float, dict]:
    return params.pim_logic_area_um2, {"subarray_logic": params.pim_logic_area_um2}


# ---------------------------------------------------------------------------
# Summary table (what benchmarks/fig9..11 print)
# ---------------------------------------------------------------------------


def full_comparison(n_bits: int = 10, n_mac: int = 100,
                    params: CostParams = DEFAULT_PARAMS) -> dict[str, MulCost]:
    e_apc, bd_e_apc = energy_scpim(n_bits, "apc", params=params)
    e_csa, bd_e_csa = energy_scpim(n_bits, "csa", n_mac, params=params)
    e_sc, bd_e_sc = energy_sc(n_bits, params)
    e_pim, bd_e_pim = energy_pim(n_bits, params)
    a_apc, bd_a_apc = area_scpim(n_bits, "apc", params)
    a_csa, bd_a_csa = area_scpim(n_bits, "csa", params)
    a_sc, bd_a_sc = area_sc(n_bits, params)
    a_pim, bd_a_pim = area_pim(n_bits, params)
    return {
        "SC+PIM (APC)": MulCost(cycles_scpim_apc(n_bits, params), e_apc, a_apc,
                                {"energy": bd_e_apc, "area": bd_a_apc}),
        "SC+PIM (CSA)": MulCost(cycles_scpim_csa(n_bits, n_mac, params), e_csa,
                                a_csa, {"energy": bd_e_csa, "area": bd_a_csa}),
        "SC": MulCost(cycles_sc(n_bits, params), e_sc, a_sc,
                      {"energy": bd_e_sc, "area": bd_a_sc}),
        "PIM": MulCost(cycles_pim(n_bits, params), e_pim, a_pim,
                       {"energy": bd_e_pim, "area": bd_a_pim}),
    }


def headline_ratios(n_bits: int = 10,
                    params: CostParams = DEFAULT_PARAMS) -> dict[str, float]:
    """The paper's headline comparisons at its own anchor points.

    ``speedup_vs_pim`` follows the paper's framing: their 10-bit SC-MUL
    against the PUBLISHED DRISA number ("143 cycles to calculate an 8-bit
    multiplication") — 143 / ~8 = ~18x. The same-bit-width (10-bit) ratio is
    also reported for honesty; it is LARGER (DRISA scales quadratically)."""
    ours = cycles_scpim_apc(n_bits, params)
    e_ours, _ = energy_scpim(n_bits, "apc", params=params)
    e_sc, _ = energy_sc(n_bits, params)
    a_ours, _ = area_scpim(n_bits, "apc", params)
    a_sc, _ = area_sc(n_bits, params)
    return {
        "speedup_vs_sc": cycles_sc(n_bits, params) / ours,
        "speedup_vs_pim": cycles_pim(8, params) / ours,   # the paper's anchor
        "speedup_vs_pim_same_bits": cycles_pim(n_bits, params) / ours,
        "energy_saving_vs_sc": 1.0 - e_ours / e_sc,
        "area_ratio_sc_over_ours": a_sc / a_ours,
    }
