"""Analytical cycle / energy / area model (paper §V, Figs. 9-11).

The paper evaluates four designs on 10-bit × 10-bit MUL (2^10 stochastic bits):

  * SC+PIM (APC)  — this work, pop-count via one-cycle APC
  * SC+PIM (CSA)  — this work, pop-count via in-memory CSA+FA, amortized
                    over a 100-MUL MAC
  * SC            — conventional stochastic computing with the
                    state-of-the-art SNG [21] + APC pop-count
  * PIM           — MUL from in-memory bitwise Boolean ops only (DRISA [6])

Like the paper (which has no silicon), this is an *analytical* model built
from published component anchors, with the remaining free constants
calibrated so the published headline ratios emerge:

  anchors: DRISA 143 cycles @ 8-bit MUL, quadratic shift-add scaling;
           DTC: 22 ps resolution, 75×25 µm² [19]; APC one cycle [16];
           SNG = 95 % of conventional-SC area [21]; SC energy 88 % buffering;
  headlines reproduced: ≈4× cycles vs SC, ≈18× vs PIM (10-bit),
           ≈58 % energy saving vs SC, ≈10× area saving vs SC.

Every constant is a named module-level knob so the benchmarks can sweep them.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import popcount

# --------------------------- cycle-model knobs ------------------------------
ROW_LENGTH = 256                  # cross-point row cells (IR-drop limit, §III-D)
SA_READ_CYCLES = 2                # sense + latch, parallel across subarray banks
BANK_MERGE_PER_LEVEL = 1          # adder-tree merge of per-bank APC counts
PRESET_CYCLES = 1                 # strong reverse pulse, all rows parallel
PULSE_CYCLES = 1                  # one stochastic write pulse (row-parallel)
SNG_BITS_PER_CYCLE = 128          # LFSR bank width of the SNG [21]
SNG_SHUFFLE_FACTOR = 2.0          # decorrelation shuffle (both streams) [21]
DRISA_8BIT_CYCLES = 143           # DRISA anchor [6] — the paper's PIM baseline

# --------------------------- energy-model knobs (pJ) ------------------------
R_HML_OHM = 250.0                 # heavy-metal-layer write-path resistance
I_C_A = 80e-6                     # critical current
PULSE_TAU_NS = 0.5                # mean stochastic pulse duration (P≈0.5 range)
PRESET_TAU_NS = 3.0               # preset pulse duration
PRESET_I_FACTOR = 1.25            # preset over-drive
DTC_ENERGY_PJ = 0.2               # per conversion [19]
LUT_READ_PJ = 0.1                 # per lookup
APC_ENERGY_PJ = 0.5               # per pop-count
CSA_OP_PJ = 0.05                  # per in-memory bulk bitwise op
SRAM_BUFFER_PJ_PER_BIT = 0.0108   # conventional-SC bitstream buffering
SNG_GEN_PJ_PER_BIT = 0.0012       # SNG generation energy [21]
PIM_OP_PJ = 0.10                  # DRISA bulk bitwise op energy

# --------------------------- area-model knobs (µm²) -------------------------
DTC_AREA_UM2 = 75.0 * 25.0        # [19]
APC_AREA_UM2 = 2100.0             # synthesized 45 nm FreePDK, params from [16]
AND_BUFFER_AREA_UM2 = 700.0       # conventional SC AND array + latches
SNG_AREA_FRACTION = 0.95          # SNG share of conventional SC area [21]
MRAM_CELL_AREA_UM2 = 0.10         # LUT storage cell
PIM_LOGIC_AREA_UM2 = 1500.0       # DRISA-style added subarray logic


@dataclasses.dataclass(frozen=True)
class MulCost:
    cycles: float
    energy_pj: float
    area_um2: float
    breakdown: dict


def _rows(n_bits: int) -> int:
    return -(-(1 << n_bits) // ROW_LENGTH)


# ---------------------------------------------------------------------------
# Cycles (Fig. 9)
# ---------------------------------------------------------------------------


def cycles_scpim_apc(n_bits: int = 10) -> float:
    """This work, APC pop-count. LUT+DTC conversion is pipelined (§III-D).

    The 2^n stochastic bits live in ``rows`` sub-array rows written AND
    sensed in parallel (each bank has its own SAs — the multi-row activation
    of §III-D); per-bank APC counts merge through a log-depth adder tree.
    This is what makes Fig. 9b ~flat in operand bit length."""
    rows = _rows(n_bits)
    merge = BANK_MERGE_PER_LEVEL * math.ceil(math.log2(rows)) if rows > 1 else 0
    return (PRESET_CYCLES + 2 * PULSE_CYCLES + SA_READ_CYCLES
            + popcount.apc_cycles(1) + merge)


def cycles_scpim_csa(n_bits: int = 10, n_mac: int = 100) -> float:
    """This work, CSA+FA pop-count amortized over an n_mac MAC (Fig. 6):
    constant lock-step fold per MUL + one FA resolve per MAC."""
    nbit = 1 << n_bits
    per_mul_popcount = popcount.csa_fa_cycles_per_mul(n_mac, nbit)
    return PRESET_CYCLES + 2 * PULSE_CYCLES + per_mul_popcount


def cycles_sc(n_bits: int = 10) -> float:
    """Conventional SC: SNG-generated bitstreams + APC.

    Two 2^n-bit streams from the shared SNG bank, plus the decorrelation
    shuffle the paper notes pseudo-random streams need; AND is fused into the
    stream, APC closes.
    """
    nbit = 1 << n_bits
    gen = 2 * nbit / SNG_BITS_PER_CYCLE
    shuffle = SNG_SHUFFLE_FACTOR * nbit / SNG_BITS_PER_CYCLE
    return gen + shuffle + popcount.apc_cycles(1)


def cycles_pim(n_bits: int = 10) -> float:
    """Bitwise-Boolean in-memory MUL (DRISA): quadratic shift-add scaling
    from the published 8-bit / 143-cycle anchor."""
    return math.ceil(DRISA_8BIT_CYCLES * (n_bits / 8) ** 2)


# ---------------------------------------------------------------------------
# Energy (Fig. 10)
# ---------------------------------------------------------------------------


def _write_energy_pj(tau_ns: float, i_factor: float = 1.0) -> float:
    """Joule heating per cell: I²·R·τ, in pJ."""
    i = I_C_A * i_factor
    return (i * i) * R_HML_OHM * (tau_ns * 1e-9) * 1e12


def energy_scpim(n_bits: int = 10, popcount_kind: str = "apc",
                 n_mac: int = 100) -> tuple[float, dict]:
    nbit = 1 << n_bits
    init = nbit * _write_energy_pj(PRESET_TAU_NS, PRESET_I_FACTOR)
    pulses = 2 * nbit * _write_energy_pj(PULSE_TAU_NS)
    convert = 2 * (DTC_ENERGY_PJ + LUT_READ_PJ)
    if popcount_kind == "apc":
        pc = APC_ENERGY_PJ
    else:
        ops = popcount.csa_fa_cycles_per_mul(n_mac, nbit)
        pc = ops * CSA_OP_PJ
    bd = {"init": init, "sc_pulses": pulses, "conversion": convert, "popcount": pc}
    return sum(bd.values()), bd


def energy_sc(n_bits: int = 10) -> tuple[float, dict]:
    nbit = 1 << n_bits
    gen = 2 * nbit * SNG_GEN_PJ_PER_BIT
    buffering = 2 * nbit * SRAM_BUFFER_PJ_PER_BIT     # 88 %-class share
    pc = APC_ENERGY_PJ
    bd = {"sng_generation": gen, "buffering": buffering, "popcount": pc}
    return sum(bd.values()), bd


def energy_pim(n_bits: int = 10) -> tuple[float, dict]:
    ops = cycles_pim(n_bits)
    bd = {"bitwise_ops": ops * PIM_OP_PJ}
    return sum(bd.values()), bd


# ---------------------------------------------------------------------------
# Area (Fig. 11)
# ---------------------------------------------------------------------------


def area_scpim(n_bits: int = 10, popcount_kind: str = "apc") -> tuple[float, dict]:
    lut_bits = (1 << n_bits) * 16               # 2^n entries × 16-bit fixed point
    lut = lut_bits * MRAM_CELL_AREA_UM2
    bd = {"dtc": DTC_AREA_UM2, "lut": lut}
    if popcount_kind == "apc":
        bd["apc"] = APC_AREA_UM2
    else:
        bd["csa_fa_logic"] = 0.15 * APC_AREA_UM2   # FA column + control only
    return sum(bd.values()), bd


def area_sc(n_bits: int = 10) -> tuple[float, dict]:
    non_sng = APC_AREA_UM2 + AND_BUFFER_AREA_UM2
    sng = non_sng * SNG_AREA_FRACTION / (1.0 - SNG_AREA_FRACTION)
    bd = {"sng": sng, "apc": APC_AREA_UM2, "and_buffers": AND_BUFFER_AREA_UM2}
    return sum(bd.values()), bd


def area_pim(n_bits: int = 10) -> tuple[float, dict]:
    return PIM_LOGIC_AREA_UM2, {"subarray_logic": PIM_LOGIC_AREA_UM2}


# ---------------------------------------------------------------------------
# Summary table (what benchmarks/fig9..11 print)
# ---------------------------------------------------------------------------


def full_comparison(n_bits: int = 10, n_mac: int = 100) -> dict[str, MulCost]:
    e_apc, bd_e_apc = energy_scpim(n_bits, "apc")
    e_csa, bd_e_csa = energy_scpim(n_bits, "csa", n_mac)
    e_sc, bd_e_sc = energy_sc(n_bits)
    e_pim, bd_e_pim = energy_pim(n_bits)
    a_apc, bd_a_apc = area_scpim(n_bits, "apc")
    a_csa, bd_a_csa = area_scpim(n_bits, "csa")
    a_sc, bd_a_sc = area_sc(n_bits)
    a_pim, bd_a_pim = area_pim(n_bits)
    return {
        "SC+PIM (APC)": MulCost(cycles_scpim_apc(n_bits), e_apc, a_apc,
                                {"energy": bd_e_apc, "area": bd_a_apc}),
        "SC+PIM (CSA)": MulCost(cycles_scpim_csa(n_bits, n_mac), e_csa, a_csa,
                                {"energy": bd_e_csa, "area": bd_a_csa}),
        "SC": MulCost(cycles_sc(n_bits), e_sc, a_sc,
                      {"energy": bd_e_sc, "area": bd_a_sc}),
        "PIM": MulCost(cycles_pim(n_bits), e_pim, a_pim,
                       {"energy": bd_e_pim, "area": bd_a_pim}),
    }


def headline_ratios(n_bits: int = 10) -> dict[str, float]:
    """The paper's headline comparisons at its own anchor points.

    ``speedup_vs_pim`` follows the paper's framing: their 10-bit SC-MUL
    against the PUBLISHED DRISA number ("143 cycles to calculate an 8-bit
    multiplication") — 143 / ~8 = ~18x. The same-bit-width (10-bit) ratio is
    also reported for honesty; it is LARGER (DRISA scales quadratically)."""
    ours = cycles_scpim_apc(n_bits)
    e_ours, _ = energy_scpim(n_bits, "apc")
    e_sc, _ = energy_sc(n_bits)
    a_ours, _ = area_scpim(n_bits, "apc")
    a_sc, _ = area_sc(n_bits)
    return {
        "speedup_vs_sc": cycles_sc(n_bits) / ours,
        "speedup_vs_pim": cycles_pim(8) / ours,          # the paper's anchor
        "speedup_vs_pim_same_bits": cycles_pim(n_bits) / ours,
        "energy_saving_vs_sc": 1.0 - e_ours / e_sc,
        "area_ratio_sc_over_ours": a_sc / a_ours,
    }
