"""Pop-count strategies (§III-C): APC and PIM-based CSA+FA, with cycle models.

The MUL outcome lives as stochastic bits in the MRAM array; converting back to
binary is a pop-count. The paper offers two hardware strategies:

* **APC** (approximate parallel counter, ref [16]) — a fully-parallel counter
  tree synthesized next to the sense amplifiers. One clock cycle, large area.
  We model it *functionally exact* (the paper's "approximate" refers to the
  counter's internal approximation for area; accuracy impact is folded into
  the SC noise floor) and charge its area in the cost model.

* **PIM CSA+FA** (two-step, Fig. 6) — for a MAC of many MULs:
    step 1: row-wise carry-save addition (CSA) compresses the per-MUL bit
            rows in lock-step bitwise ops — 3 rows → 2 rows per pass,
            log_{3/2}(rows) passes, each pass a constant number of in-memory
            bitwise cycles;
    step 2: a final column-wise ripple full-adder (FA) resolves the two
            surviving carry-save rows into a binary sum — costs
            O(result-width) cycles but is incurred ONCE per MAC, so its
            latency amortizes over the MULs (Fig. 6's "converges to CSA").

Both strategies return identical sums (CSA+FA is exact); they differ in the
cycle/area accounting, which costmodel.py consumes.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Functional pop-counts (what the hardware computes)
# ---------------------------------------------------------------------------


def apc_popcount(states) -> jnp.ndarray:
    """APC: parallel counter over the last axis. One cycle in hardware."""
    return jnp.sum(states.astype(jnp.int32), axis=-1)


def csa_compress(rows):
    """One CSA pass: groups of 3 rows -> (sum, carry) pair of rows.

    ``rows``: (R, nbit) uint8/int array of bit-rows. Returns the compressed
    row stack; odd remainders are passed through. Models the in-memory
    lock-step bitwise ops (XOR/AND/shift are the PIM-native instructions).
    """
    r = rows.shape[0]
    groups = r // 3
    out = []
    for g in range(groups):
        a, b, c = rows[3 * g], rows[3 * g + 1], rows[3 * g + 2]
        s = a ^ b ^ c                      # sum bits, weight 1
        carry = (a & b) | (b & c) | (a & c)  # carry bits, weight 2
        out.append(s)
        out.append(carry)                  # carried row is weight-2; tracked below
    for rem in range(3 * groups, r):
        out.append(rows[rem])
    return jnp.stack(out) if out else rows


def csa_fa_popcount(states) -> jnp.ndarray:
    """Exact two-step pop-count over a MAC: states (M, nbit) -> scalar sum.

    The hardware compresses rows with CSA then resolves with a final FA.
    Functionally that equals the exact sum of all bits across all MULs, which
    is what we return (the approximation error of SC lives in the bits
    themselves, not in this adder). Kept separate from apc_popcount so tests
    can assert both strategies agree bit-for-bit.
    """
    return jnp.sum(states.astype(jnp.int32), axis=(-2, -1))


# ---------------------------------------------------------------------------
# Cycle models (what the hardware *costs*) — consumed by costmodel.py
# ---------------------------------------------------------------------------

# In-memory bitwise ops per CSA pass: XOR(2 ops: a^b, ^c) + MAJ(3 AND + 2 OR).
# Each lock-step bulk bitwise op = 1 memory cycle (Pinatubo/DRISA style).
CSA_CYCLES_PER_PASS = 7
# Ripple FA resolve: ~1 cycle per result bit plus carry propagation.
FA_CYCLES_PER_BIT = 2
# Cross-point row length (IR-drop limit §III-D) used to split nbit into rows.
ROW_LENGTH = 256


def apc_cycles(n_mul: int = 1) -> int:
    """APC is fully parallel: 1 cycle per MUL readout."""
    return n_mul


def csa_passes(n_rows: int) -> int:
    """CSA passes to compress n rows to 2 (3->2 per pass on the whole stack)."""
    passes = 0
    r = n_rows
    while r > 2:
        r = r - (r // 3)          # 3k rows -> 2k rows (+ remainder)
        passes += 1
    return passes


def rows_per_mul(nbit: int, row_length: int = ROW_LENGTH) -> int:
    return max(1, -(-nbit // row_length))


def csa_fold_cycles(rows: int) -> int:
    """Cycles to fold one MUL's ``rows`` bit-rows into the bank's running
    carry-save pair: lock-step 3:2 passes on (rows + 2) rows -> 2 rows.

    This is the steady-state per-MUL cost the paper's Fig. 6 converges to
    (the MAC keeps one carry-save pair; each finished MUL folds in)."""
    return csa_passes(rows + 2) * CSA_CYCLES_PER_PASS


def csa_fa_cycles(n_mul: int, nbit: int, result_bits: int | None = None,
                  row_length: int = ROW_LENGTH) -> int:
    """Total cycles for the two-step pop-count of a MAC of ``n_mul`` MULs
    (paper Fig. 6): step 1 row-wise CSA folds every MUL's rows into one
    carry-save pair (constant lock-step cost per MUL — independent of the
    row WIDTH, bulk bitwise ops touch all nbit columns at once); step 2 one
    column-wise FA resolve, paid ONCE per MAC."""
    if result_bits is None:
        result_bits = max(1, math.ceil(math.log2(max(2, n_mul * nbit))))
    compress = n_mul * csa_fold_cycles(rows_per_mul(nbit, row_length))
    resolve = FA_CYCLES_PER_BIT * result_bits
    return compress + resolve


def csa_fa_cycles_per_mul(n_mul: int, nbit: int,
                          row_length: int = ROW_LENGTH) -> float:
    """Amortized per-MUL pop-count cycles. Converges (Fig. 6) to the
    constant CSA fold cost as the FA resolve amortizes away."""
    return csa_fa_cycles(n_mul, nbit, row_length=row_length) / max(n_mul, 1)
