"""Hardware-variance studies (§IV-B) and the logarithm-multiplier baseline.

Two fluctuation sources from the paper:

* **σ(I_c)** — per-cell critical-current spread (manufacturing + thermal,
  ref [18]). Injected as iid Gaussian multipliers on each cell's I_c before
  every pulse; the engine's Eq. 3 then sees per-cell switching rates.
  Paper result (Fig. 8a): MUL accuracy is *insensitive* to σ(I_c) up to 10 %
  — at the operating point I = I_c the inner exponential exp(-Δ(1-I/I_c))
  fluctuates, but fluctuations average out across the nbit cells and, being
  zero-centered in log-rate, largely cancel in the survival fraction.

* **σ(Circuits)** — timing/gain error of the conversion circuits. For our
  design this perturbs the DTC pulse durations (multiplicative Gaussian on
  τ). For the **logarithm multiplier** baseline (ref [15]) the same σ
  perturbs the log and antilog stages; because the antilog *exponentiates*
  its input error, the output error grows ∝ |ln(XY)|·σ — this is why Fig. 8b
  shows the log-multiplier degrading sharply while SC+PIM stays flat (the
  SC average is only linearly sensitive to τ noise, and τ noise is further
  suppressed by the P≈0.5 operating range).
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core import conversion, engine, physics


def sc_mul_with_profile(key, x_int, y_int, cfg: engine.EngineConfig,
                        profile: physics.DeviceProfile):
    """One SC MUL on a realized device: per-cell (Delta, I_c) come from
    the profile's FROZEN Threefry variation maps rather than a fresh iid
    draw per call, so repeated MULs exercise the same manufacturing
    spread the ``array`` backend and the envelope bench see.  Batched
    operands occupy consecutive MUL cell banks of the map.  Returns p_est.
    """
    tau_x = conversion.operand_to_tau(jnp.asarray(x_int, jnp.int32), cfg.conv)
    tau_y = conversion.operand_to_tau(jnp.asarray(y_int, jnp.int32), cfg.conv)
    state = engine.sc_multiply_states(key, tau_x, tau_y, cfg, profile=profile)
    return engine.readout(state)


def sc_mul_with_ic_variance(key, x_int, y_int, cfg: engine.EngineConfig,
                            sigma_ic: float):
    """One SC MUL with per-cell I_c ~ N(I_c, (sigma_ic·I_c)²). Returns p_est.

    .. deprecated:: PR-10
       Describe the spread with ``physics.DeviceProfile(sigma_ic=...)``
       and call :func:`sc_mul_with_profile` — same physics, but the
       per-cell draw is frozen and shared with the arch backend.  This
       wrapper keeps the historical iid-per-call behavior.
    """
    warnings.warn(
        "sc_mul_with_ic_variance is deprecated; use sc_mul_with_profile "
        "with physics.DeviceProfile(sigma_ic=...)", DeprecationWarning,
        stacklevel=2)
    kx, kv = jax.random.split(key)
    batch_shape = jnp.broadcast_shapes(jnp.shape(x_int), jnp.shape(y_int))
    ic = physics.I_C_UA * (
        1.0 + sigma_ic * jax.random.normal(kv, batch_shape + (cfg.nbit,)))
    ic = jnp.maximum(ic, 1e-3)
    tau_x = conversion.operand_to_tau(jnp.asarray(x_int, jnp.int32), cfg.conv)
    tau_y = conversion.operand_to_tau(jnp.asarray(y_int, jnp.int32), cfg.conv)
    state = engine.sc_multiply_states(kx, tau_x, tau_y, cfg, i_c_ua=ic)
    return engine.readout(state)


def sc_mul_with_circuit_variance(key, x_int, y_int, cfg: engine.EngineConfig,
                                 sigma_circ: float):
    """One SC MUL with DTC timing noise: τ -> τ·(1+N(0,σ²)) per pulse."""
    kx, kt1, kt2 = jax.random.split(key, 3)
    tau_x = conversion.operand_to_tau(jnp.asarray(x_int, jnp.int32), cfg.conv)
    tau_y = conversion.operand_to_tau(jnp.asarray(y_int, jnp.int32), cfg.conv)
    tau_x = tau_x * (1.0 + sigma_circ * jax.random.normal(kt1, jnp.shape(tau_x)))
    tau_y = tau_y * (1.0 + sigma_circ * jax.random.normal(kt2, jnp.shape(tau_y)))
    tau_x = jnp.maximum(tau_x, 0.0)
    tau_y = jnp.maximum(tau_y, 0.0)
    state = engine.sc_multiply_states(kx, tau_x, tau_y, cfg)
    return engine.readout(state)


def log_multiplier(key, x_int, y_int, conv_cfg: conversion.ConversionConfig,
                   sigma_circ: float):
    """Logarithm-multiplication baseline (ref [15]) with circuit variance.

    X·Y = antilog(ln X + ln Y). The DTC+MRAM stage is replaced by an
    ANALOG antilogarithm amplifier. The crucial asymmetry vs SC+PIM
    (paper Fig. 8b): an antilog amplifier's component variance (V_T
    mismatch, bias drift) is EXPONENT-REFERRED over the circuit's full
    dynamic range — the amplifier maps a full-scale input voltage onto
    2^n octaves, so a fractional gain/offset error ε shifts the exponent
    by ε·(n·ln2), multiplying the output by exp(ε·n·ln2) regardless of
    operand value. The SC path has no such amplification: DTC timing error
    perturbs τ, which the §III-D normalization keeps at O(ln 2), and the
    MRAM cells average the remaining noise. Returns the estimated product
    probability for comparability with the SC path.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    px = conversion.encode_probability(jnp.asarray(x_int, jnp.int32), conv_cfg)
    py = conversion.encode_probability(jnp.asarray(y_int, jnp.int32), conv_cfg)
    px = jnp.clip(px, 1e-9, 1.0)
    py = jnp.clip(py, 1e-9, 1.0)
    full_scale = conv_cfg.n_bits * jnp.log(2.0)   # exponent dynamic range
    # log stage: each ln output carries amplifier noise referred to full scale
    lx = jnp.log(px) + sigma_circ * full_scale \
        * jax.random.normal(k1, px.shape)
    ly = jnp.log(py) + sigma_circ * full_scale \
        * jax.random.normal(k2, py.shape)
    # antilog amplifier: exponent-referred gain/offset error over full scale
    s = (lx + ly) + sigma_circ * full_scale * jax.random.normal(k3, px.shape)
    return jnp.exp(s)


def mul_uncertainty(p_estimates, p_true) -> jnp.ndarray:
    """σ of the error distribution (the paper's 'MUL uncertainty' metric)."""
    err = p_estimates - p_true
    return jnp.std(err)
