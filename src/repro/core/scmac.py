"""DEPRECATED shim — the SC matmul now lives in :mod:`repro.sc`.

This module used to carry its own three-mode implementation (exact /
bitexact / moment) plus a private copy of the operand encoding. Both moved
into the pluggable backend registry (``repro.sc.backends`` /
``repro.sc.encoding``); what remains here is a thin compatibility layer so
existing callers keep working:

    SCMacConfig(mode=..)      -> ScConfig(backend=..)
    sc_matmul(key, x, w, cfg) -> sc_dot(key, x, w, cfg.to_sc_config())

New code should use ``repro.sc.sc_dot`` directly — it exposes two more
backends (``pallas_moment``, ``pallas_bitexact``) and is the single
dispatch point the model stack routes through. The physics derivation
notes that used to live here are now in ``repro/sc/backends.py``.
"""

from __future__ import annotations

import dataclasses

from repro import sc
from repro.sc import encoding as _encoding

_LEGACY_MODES = ("exact", "bitexact", "moment")


@dataclasses.dataclass(frozen=True)
class SCMacConfig:
    """Legacy config; prefer :class:`repro.sc.ScConfig`."""

    mode: str = "moment"        # exact | bitexact | moment
    nbit: int = 1024            # stochastic bits per scalar product
    operand_bits: int = 10      # quantization of encoded probabilities (paper: 10)
    quantize: bool = True       # apply the LUT/DTC-grid operand quantization

    def __post_init__(self):
        if self.mode not in _LEGACY_MODES:
            raise ValueError(f"unknown SC mode {self.mode!r}")

    def to_sc_config(self) -> sc.ScConfig:
        return sc.ScConfig(backend=self.mode, nbit=self.nbit,
                           operand_bits=self.operand_bits,
                           quantize=self.quantize)


def encode(v, cfg):
    """float tensor -> (sign, probability, scale). See repro.sc.encoding."""
    return _encoding.encode(v, cfg)


def sc_matmul(key, x, w, cfg: SCMacConfig = SCMacConfig()):
    """x @ w through the SC engine. x: (..., K), w: (K, N).

    Deprecated alias for ``repro.sc.sc_dot`` (straight-through gradient
    included — the custom_vjp lives at the registry dispatch boundary).
    """
    return sc.sc_dot(key, x, w, cfg.to_sc_config())


def sc_einsum_bld_df(key, x, w, cfg: SCMacConfig):
    """Convenience for (batch, len, d) @ (d, f) — the NN layer shape."""
    return sc_matmul(key, x, w, cfg)
