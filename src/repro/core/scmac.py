"""SC-MAC: the paper's MUL engine lifted to a framework-level matmul.

The paper's target workload is the vectored multiply-and-accumulate
``Σ_i w_i x_i`` in NN inference (§III-C/D). This module exposes

    sc_matmul(key, x, w, cfg) -> x @ w   (approximately, via SC)

with three interchangeable modes:

* ``exact``    — plain MXU matmul (the deterministic reference).
* ``bitexact`` — paper-faithful Monte-Carlo: every scalar product samples a
                 Binomial(nbit, P_x·P_w) pop-count. Statistically *identical*
                 to materializing nbit MRAM cells and summing them (the
                 binomial IS the distribution of the pop-count), without the
                 O(nbit) memory blow-up. Used for validation and small models.
* ``moment``   — beyond-paper TPU adaptation: by CLT the signed MAC output is
                 Normal(mean, var) with
                   mean = x @ w                         (signed, scaled)
                   var  = scale²·[(p_x @ p_w) − (p_x² @ p_w²)] / nbit
                 so three MXU matmuls + one Gaussian draw reproduce the
                 paper's error statistics at O(1) cost per product instead of
                 O(nbit). First/second moments match bitexact exactly; the
                 binomial→normal deviation is < 1 % KS distance at nbit ≥ 256.

Signed operands: the paper treats unsigned operands; we extend by
sign/magnitude split (the standard SC practice). Magnitudes are encoded as
probabilities against a per-tensor scale (max-abs), signs multiply through
the accumulation — this keeps the device physics identical per MUL.

Training: sc_matmul carries a straight-through custom_vjp (backward uses the
exact product), so SC layers are trainable — the stochastic engine is a
forward-pass substrate, mirroring how the hardware would run inference while
training happens elsewhere.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SCMacConfig:
    mode: str = "moment"        # exact | bitexact | moment
    nbit: int = 1024            # stochastic bits per scalar product
    operand_bits: int = 10      # quantization of encoded probabilities (paper: 10)
    quantize: bool = True       # apply the LUT/DTC-grid operand quantization

    def __post_init__(self):
        if self.mode not in ("exact", "bitexact", "moment"):
            raise ValueError(f"unknown SC mode {self.mode!r}")


# ---------------------------------------------------------------------------
# Probability encoding (sign/magnitude, per-tensor max-abs scale)
# ---------------------------------------------------------------------------


def encode(v, cfg: SCMacConfig):
    """float tensor -> (sign, probability, scale). p ∈ [0,1], v ≈ sign·p·scale."""
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30)
    p = jnp.abs(v) / scale
    if cfg.quantize:
        levels = 1 << cfg.operand_bits
        p = jnp.round(p * levels) / levels   # n-bit operand grid (LUT input)
    return jnp.sign(v), p, scale


# ---------------------------------------------------------------------------
# Modes
# ---------------------------------------------------------------------------


def _matmul_exact(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def _matmul_bitexact(key, x, w, cfg: SCMacConfig):
    """Binomial pop-count per scalar product, signed sum over K.

    x: (..., K), w: (K, N). Memory O(M·K·N) for the per-product probabilities
    — validation-scale only, exactly like running the real arrays would be.
    """
    sx, px, scx = encode(x, cfg)
    sw, pw, scw = encode(w, cfg)
    p_prod = px[..., :, None] * pw[None, ...]        # (..., K, N) = P_x·P_w
    sign = sx[..., :, None] * sw[None, ...]
    counts = jax.random.binomial(key, n=float(cfg.nbit), p=p_prod)
    est = counts.astype(jnp.float32) / cfg.nbit      # ≈ P_x·P_w per product
    return jnp.sum(sign * est, axis=-2) * (scx * scw)


def _matmul_moment(key, x, w, cfg: SCMacConfig):
    """CLT moment-matched SC matmul: 3 dots + 1 Gaussian draw (beyond-paper)."""
    sx, px, scx = encode(x, cfg)
    sw, pw, scw = encode(w, cfg)
    signed_x = sx * px
    signed_w = sw * pw
    mean = _matmul_exact(signed_x, signed_w)
    # Var of each product estimate = p(1-p)/nbit with p = p_x·p_w;
    # Σ_k p_k = px@pw, Σ_k p_k² = px²@pw² (p_x,p_w independent across k).
    sum_p = _matmul_exact(px, pw)
    sum_p2 = _matmul_exact(px * px, pw * pw)
    var = jnp.maximum(sum_p - sum_p2, 0.0) / cfg.nbit
    noise = jax.random.normal(key, mean.shape, dtype=mean.dtype)
    return (mean + noise * jnp.sqrt(var)) * (scx * scw)


# ---------------------------------------------------------------------------
# Public API with straight-through gradient
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def sc_matmul(key, x, w, cfg: SCMacConfig = SCMacConfig()):
    """x @ w through the SC engine. x: (..., K), w: (K, N)."""
    return _sc_matmul_fwd_impl(key, x, w, cfg)


def _sc_matmul_fwd_impl(key, x, w, cfg):
    if cfg.mode == "exact":
        return _matmul_exact(x, w)
    if cfg.mode == "bitexact":
        return _matmul_bitexact(key, x, w, cfg)
    return _matmul_moment(key, x, w, cfg)


def _sc_matmul_fwd(key, x, w, cfg):
    return _sc_matmul_fwd_impl(key, x, w, cfg), (x, w)


def _sc_matmul_bwd(cfg, res, g):
    x, w = res
    # Straight-through: gradients of the exact product. E[SC output] equals
    # the exact product (Fig. 7a zero-centered error), so this is the
    # unbiased pathwise choice.
    gx = jnp.dot(g, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    gw = jnp.dot(
        x.reshape(-1, x.shape[-1]).T, g.reshape(-1, g.shape[-1]),
        preferred_element_type=jnp.float32).astype(w.dtype)
    return None, gx, gw


sc_matmul.defvjp(_sc_matmul_fwd, _sc_matmul_bwd)


def sc_einsum_bld_df(key, x, w, cfg: SCMacConfig):
    """Convenience for (batch, len, d) @ (d, f) — the NN layer shape."""
    b, l, d = x.shape
    y = sc_matmul(key, x.reshape(b * l, d), w, cfg)
    return y.reshape(b, l, -1)
