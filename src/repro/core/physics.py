"""SOT-MRAM stochastic-switching physics (paper Eq. 3) and pulse scaling.

The paper's device model: a MRAM bit under a write-current pulse of strength
``I`` (relative to the critical current ``I_c``) and duration ``tau`` (ns)
remains *unswitched* with probability

    P_usw(tau, I) = exp(-tau * exp(-Delta * (1 - I / I_c)))

with thermal stability ``Delta = 60.9`` and ``I_c = 80 uA`` (PRESCOTT
micromagnetics, paper refs [12][14]).

Operating point used throughout the paper (and here): ``I = I_c`` — the inner
exponential collapses to 1 and ``P_usw = exp(-tau)``, so a desired survival
probability ``P`` is programmed *exactly* by a pulse of duration
``tau = -ln(P)``. That is why the data-conversion chain (paper Eq. 4) takes a
logarithm first: the device supplies the inverse exponential for free.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

# Paper constants (Section II-B).
DELTA = 60.9                 # thermal-stability parameter of the MTJ
I_C_UA = 80.0                # critical switching current, micro-amps
PRESET_TAU_NS = 3.0          # long deterministic pulse for preset (P_usw < 1e-26 @ I=I_c)
PRESET_I_FACTOR = 1.25       # preset uses a stronger reverse current (Fig. 10 discussion)


@dataclasses.dataclass(frozen=True)
class DeviceParams:
    """Per-device physical parameters; fluctuation models perturb these."""

    delta: float = DELTA
    i_c_ua: float = I_C_UA

    def with_ic_fluctuation(self, sigma_frac: float) -> "DeviceParams":
        # Convenience for scalar analyses; array-level fluctuations are applied
        # in variance.py where per-bit i_c tensors are drawn.
        return dataclasses.replace(self, i_c_ua=self.i_c_ua * (1.0 + sigma_frac))


def p_unswitched(tau_ns, i_ua, *, delta=DELTA, i_c_ua=I_C_UA):
    """Paper Eq. 3 — probability the bit survives (remains unswitched).

    Vectorized over any broadcastable combination of ``tau_ns`` / ``i_ua`` /
    per-bit ``i_c_ua`` (hardware-variance studies pass arrays for ``i_c_ua``).
    """
    tau_ns = jnp.asarray(tau_ns)
    i_ua = jnp.asarray(i_ua)
    rate = jnp.exp(-delta * (1.0 - i_ua / i_c_ua))
    return jnp.exp(-tau_ns * rate)


def tau_for_probability(p, *, i_ua=I_C_UA, delta=DELTA, i_c_ua=I_C_UA):
    """Inverse of Eq. 3 in tau: pulse duration that yields survival prob ``p``.

    At the paper's operating point (i = i_c) this is simply ``-ln(p)``.
    ``p`` is clipped away from {0, 1}: a zero-duration pulse cannot be emitted
    by the DTC and an infinite pulse never terminates — both ends are handled
    by the encoding layer (conversion.py) before reaching the device.
    """
    p = jnp.clip(jnp.asarray(p), 1e-30, 1.0 - 1e-12)
    rate = jnp.exp(-delta * (1.0 - i_ua / i_c_ua))
    return -jnp.log(p) / rate


def scale_to_half_switching(tau_ns, *, target_p=0.5):
    """Normalization described in paper §III-D.

    The pulse-duration range is rescaled so the *typical* operand lands near
    ``P_usw ≈ 0.5`` — the bitstream is then "neither sparse nor dense", which
    maximizes the number of informative stochastic bits (and caps the pulse at
    roughly the deterministic switching time, avoiding slowdown). Returns the
    scale factor applied and the scaled durations.
    """
    tau_ns = jnp.asarray(tau_ns)
    tau_half = -jnp.log(jnp.asarray(target_p))  # = ln 2 at i = i_c
    mean_tau = jnp.mean(tau_ns)
    scale = jnp.where(mean_tau > 0, tau_half / jnp.maximum(mean_tau, 1e-30), 1.0)
    return scale, tau_ns * scale


def switching_energy_aj(tau_ns, i_ua, *, r_hml_ohm=250.0):
    """Joule-heating write energy per bit in attojoules: E = I^2 * R * tau.

    Only used by the cost model (Fig. 10 reproduction); the constant HML
    resistance is folded into the calibration there.
    """
    i_a = jnp.asarray(i_ua) * 1e-6
    tau_s = jnp.asarray(tau_ns) * 1e-9
    return (i_a * i_a) * r_hml_ohm * tau_s * 1e18
