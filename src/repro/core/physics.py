"""SOT-MRAM stochastic-switching physics (paper Eq. 3) and pulse scaling.

The paper's device model: a MRAM bit under a write-current pulse of strength
``I`` (relative to the critical current ``I_c``) and duration ``tau`` (ns)
remains *unswitched* with probability

    P_usw(tau, I) = exp(-tau * exp(-Delta * (1 - I / I_c)))

with thermal stability ``Delta = 60.9`` and ``I_c = 80 uA`` (PRESCOTT
micromagnetics, paper refs [12][14]).

Operating point used throughout the paper (and here): ``I = I_c`` — the inner
exponential collapses to 1 and ``P_usw = exp(-tau)``, so a desired survival
probability ``P`` is programmed *exactly* by a pulse of duration
``tau = -ln(P)``. That is why the data-conversion chain (paper Eq. 4) takes a
logarithm first: the device supplies the inverse exponential for free.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax.numpy as jnp
import numpy as np

# Paper constants (Section II-B).
DELTA = 60.9                 # thermal-stability parameter of the MTJ
I_C_UA = 80.0                # critical switching current, micro-amps
PRESET_TAU_NS = 3.0          # long deterministic pulse for preset (P_usw < 1e-26 @ I=I_c)
PRESET_I_FACTOR = 1.25       # preset uses a stronger reverse current (Fig. 10 discussion)


@dataclasses.dataclass(frozen=True)
class DeviceParams:
    """Per-device physical parameters; fluctuation models perturb these."""

    delta: float = DELTA
    i_c_ua: float = I_C_UA

    def with_ic_fluctuation(self, sigma_frac: float) -> "DeviceParams":
        # Convenience for scalar analyses; array-level fluctuations are applied
        # in variance.py where per-bit i_c tensors are drawn.
        warnings.warn(
            "DeviceParams.with_ic_fluctuation is deprecated; describe device "
            "non-ideality with physics.DeviceProfile(sigma_ic=...) instead",
            DeprecationWarning, stacklevel=2)
        return dataclasses.replace(self, i_c_ua=self.i_c_ua * (1.0 + sigma_frac))


# ---------------------------------------------------------------------------
# Device-realism profile (ROADMAP item 4)
# ---------------------------------------------------------------------------

# Salt for the profile's variation/fault stream.  Together with
# ``DeviceProfile.seed`` it forms the Threefry key, so maps never collide
# with the operand bitstream counters (sc/ctr_rng.py keys those off the
# caller's PRNG key).  Part of the bit-reproducibility contract: changing
# it re-rolls every committed variation map.
_MAP_SALT = 0x00DE51CE

# Lane assignment within the map stream (the Threefry counter's second
# word).  Lanes 0/1 feed the Box-Muller pair behind the (Delta, I_c)
# gaussians; lane 2 places the stuck-at faults.
_LANE_BM1, _LANE_BM2, _LANE_STUCK = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Frozen description of one SOT-MRAM array's non-idealities.

    This is THE device knob: every layer that models hardware (the core
    MUL engine, the variance studies, the ``array`` arch backend, the
    serve API's ``--fault-profile``) accepts one of these instead of
    loose ``delta=`` / ``i_c_ua=`` kwargs.

    Calibrated variation: each physical cell ``c`` perturbs the paper's
    nominal parameters with frozen manufacturing spread —
    ``Delta_c = delta * (1 + sigma_delta * g1(c))`` and
    ``I_c,c = i_c_ua * (1 + sigma_ic * g2(c))`` where ``(g1, g2)`` are
    standard gaussians derived from the pinned Threefry counter stream
    (``sc/ctr_rng.py``) at counter ``c``.  Maps are therefore
    bit-reproducible per cell index and identical across processes.

    Fault taxonomy (all rates are per-cell probabilities):

    * ``ber_stuck0`` — cell reads 0 regardless of its write (open device).
    * ``ber_stuck1`` — cell reads 1 regardless of its write (short).
    * ``ber_retention`` — per-read symmetric bit flip (thermal upset
      between write and read); unlike stuck faults this redraws every
      operation.

    ``map_cells`` is the physical cell population; virtual cell ``v``
    (product index x bitstream position) wraps to ``v % map_cells``,
    modeling wave-pipelined reuse of the same subarrays.  The profile is
    hashable, so it rides ``ScConfig`` through jit as a static argument.
    """

    delta: float = DELTA
    i_c_ua: float = I_C_UA
    sigma_delta: float = 0.0
    sigma_ic: float = 0.0
    ber_stuck0: float = 0.0
    ber_stuck1: float = 0.0
    ber_retention: float = 0.0
    seed: int = 0
    map_cells: int = 1 << 18

    def __post_init__(self):
        if self.ber_stuck0 + self.ber_stuck1 > 1.0:
            raise ValueError("ber_stuck0 + ber_stuck1 must be <= 1")
        for f in ("sigma_delta", "sigma_ic", "ber_stuck0", "ber_stuck1",
                  "ber_retention"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0")
        if self.map_cells < 1:
            raise ValueError("map_cells must be >= 1")

    @property
    def is_ideal(self) -> bool:
        """True when the profile changes NOTHING relative to the paper's
        idealized math.  Nominal ``delta``/``i_c_ua`` offsets don't break
        ideality on their own: at the operating point ``I = I_c`` the
        rate multiplier is exactly 1 for every cell when ``sigma_* = 0``.
        """
        return (self.sigma_delta == 0.0 and self.sigma_ic == 0.0
                and not self.has_faults)

    @property
    def has_faults(self) -> bool:
        return (self.ber_stuck0 > 0.0 or self.ber_stuck1 > 0.0
                or self.ber_retention > 0.0)

    def replace(self, **kw) -> "DeviceProfile":
        return dataclasses.replace(self, **kw)

    @classmethod
    def ideal(cls) -> "DeviceProfile":
        return cls()


# Named profiles (--fault-profile on the serve launcher; envelope bench
# rows).  "tiny" keeps map_cells small so chaos smokes and unit tests pay
# milliseconds, not map-build time.
DEVICE_PROFILES: dict[str, DeviceProfile] = {
    "ideal": DeviceProfile(),
    "tiny": DeviceProfile(sigma_delta=0.05, sigma_ic=0.02,
                          ber_stuck0=5e-4, ber_stuck1=5e-4,
                          ber_retention=1e-4, map_cells=1 << 14),
    "calibrated": DeviceProfile(sigma_delta=0.05, sigma_ic=0.03),
    "harsh": DeviceProfile(sigma_delta=0.10, sigma_ic=0.05,
                           ber_stuck0=2e-3, ber_stuck1=2e-3,
                           ber_retention=1e-3),
}


def named_profile(name: str) -> DeviceProfile:
    try:
        return DEVICE_PROFILES[name]
    except KeyError:
        raise KeyError(
            f"unknown device profile {name!r}; available: "
            f"{', '.join(sorted(DEVICE_PROFILES))}") from None


def resolve_profile(profile) -> DeviceProfile | None:
    """None | name | DeviceProfile -> DeviceProfile | None."""
    if profile is None or isinstance(profile, DeviceProfile):
        return profile
    return named_profile(profile)


@dataclasses.dataclass(frozen=True)
class _CellMaps:
    """Realized per-cell state of one profile (host-side numpy).

    ``rate`` is the cell's survival-rate exponent: a pulse programmed for
    survival probability ``p`` on an ideal cell survives with ``p**rate``
    on this one (P' = exp(-tau * r) = P**r), so ``rate == 1`` exactly at
    ``sigma_* = 0``.  ``cum0``/``cum1`` are prefix counts of stuck cells,
    for exact O(1) fault census over any wrapped cell span.
    """

    delta: np.ndarray       # float32 (map_cells,) realized Delta
    i_c_ua: np.ndarray      # float32 (map_cells,) realized I_c
    rate: np.ndarray        # float32 (map_cells,) survival-rate exponent
    stuck0: np.ndarray      # bool    (map_cells,)
    stuck1: np.ndarray      # bool    (map_cells,)
    cum0: np.ndarray        # int64   (map_cells + 1,) prefix stuck0 count
    cum1: np.ndarray        # int64   (map_cells + 1,)


@functools.lru_cache(maxsize=8)
def cell_maps(profile: DeviceProfile) -> _CellMaps:
    """Build (and cache) the profile's frozen variation + fault maps.

    Sampled from the pinned counter-based Threefry stream at key
    ``(seed, _MAP_SALT)``, counter = cell index: bit-reproducible per
    cell, independent of call order, shared by every consumer of the
    profile (core engine, array backend, accounting census).
    """
    import jax

    from repro.sc import ctr_rng     # lazy: core must not import sc at module load

    n = profile.map_cells

    def lane(c1):
        # ensure_compile_time_eval: map realization is host-side constant
        # folding even when first triggered from inside a jit trace (the
        # array backend realizes maps at model-trace time).
        with jax.ensure_compile_time_eval():
            key2 = jnp.asarray([profile.seed & 0xFFFFFFFF, _MAP_SALT],
                               jnp.uint32)
            c0 = jnp.arange(n, dtype=jnp.uint32)
            w = ctr_rng.uniform_words(key2, c0, jnp.uint32(c1))
        # uint32 -> open (0, 1): never 0 (log-safe), never 1
        return (np.asarray(w).astype(np.float64) + 0.5) / 2.0**32

    u1, u2 = lane(_LANE_BM1), lane(_LANE_BM2)
    r = np.sqrt(-2.0 * np.log(u1))
    g_delta = r * np.cos(2.0 * np.pi * u2)
    g_ic = r * np.sin(2.0 * np.pi * u2)

    delta_c = profile.delta * (1.0 + profile.sigma_delta * g_delta)
    delta_c = np.maximum(delta_c, 1.0)
    ic_c = profile.i_c_ua * np.maximum(1.0 + profile.sigma_ic * g_ic, 0.05)
    # Survival-rate exponent at the paper's operating point I = nominal
    # I_c.  sigma_ic shifts the cell's overdrive off zero, sigma_delta
    # amplifies that shift; with sigma_ic = 0 the exponent is exp(0) = 1
    # for EVERY cell, whatever sigma_delta says — the identity behind the
    # bit-identity acceptance tests.
    rate = np.exp(-delta_c * (1.0 - profile.i_c_ua / ic_c))

    uf = lane(_LANE_STUCK)
    stuck0 = uf < profile.ber_stuck0
    stuck1 = (~stuck0) & (uf < profile.ber_stuck0 + profile.ber_stuck1)
    cum0 = np.zeros(n + 1, np.int64)
    cum1 = np.zeros(n + 1, np.int64)
    np.cumsum(stuck0, out=cum0[1:])
    np.cumsum(stuck1, out=cum1[1:])
    return _CellMaps(delta=delta_c.astype(np.float32),
                     i_c_ua=ic_c.astype(np.float32),
                     rate=rate.astype(np.float32),
                     stuck0=stuck0, stuck1=stuck1, cum0=cum0, cum1=cum1)


def cell_span(profile: DeviceProfile, n_cells: int,
              start: int = 0) -> np.ndarray:
    """Physical cell indices backing ``n_cells`` virtual cells from
    ``start``, wrapping round-robin at ``map_cells``."""
    return (start + np.arange(n_cells, dtype=np.int64)) % profile.map_cells


def stuck_counts(profile: DeviceProfile, n_cells: int,
                 start: int = 0) -> tuple[int, int]:
    """EXACT (stuck0, stuck1) reads among ``n_cells`` wrapped cell reads
    starting at virtual cell ``start`` — full map wraps contribute the
    map totals, the remainder reads the prefix sums.  O(1)."""
    if profile.is_ideal or n_cells <= 0:
        return 0, 0
    maps = cell_maps(profile)
    m = profile.map_cells
    start %= m
    wraps, rem = divmod(start + n_cells, m)

    def count(cum):
        total = int(cum[-1])
        return wraps * total - int(cum[start]) + int(cum[rem])

    return count(maps.cum0), count(maps.cum1)


def mul_cell_params(profile: DeviceProfile, n_muls: int, nbit: int):
    """Per-cell (delta, i_c_ua) for a batch of MUL engines, as jnp arrays
    of shape (n_muls, nbit): MUL ``q`` occupies virtual cells
    ``q*nbit .. q*nbit+nbit-1`` of the profile's map.  Feed these to
    ``engine.apply_pulse`` / ``sc_multiply_states`` for realized-device
    core-engine runs (the arch backend derives the same cells itself)."""
    maps = cell_maps(profile)
    idx = cell_span(profile, n_muls * nbit).reshape(n_muls, nbit)
    return jnp.asarray(maps.delta[idx]), jnp.asarray(maps.i_c_ua[idx])


def p_unswitched(tau_ns, i_ua, *, delta=DELTA, i_c_ua=I_C_UA):
    """Paper Eq. 3 — probability the bit survives (remains unswitched).

    Vectorized over any broadcastable combination of ``tau_ns`` / ``i_ua`` /
    per-bit ``i_c_ua`` (hardware-variance studies pass arrays for ``i_c_ua``).
    """
    tau_ns = jnp.asarray(tau_ns)
    i_ua = jnp.asarray(i_ua)
    rate = jnp.exp(-delta * (1.0 - i_ua / i_c_ua))
    return jnp.exp(-tau_ns * rate)


def tau_for_probability(p, *, i_ua=I_C_UA, delta=DELTA, i_c_ua=I_C_UA):
    """Inverse of Eq. 3 in tau: pulse duration that yields survival prob ``p``.

    At the paper's operating point (i = i_c) this is simply ``-ln(p)``.
    ``p`` is clipped away from {0, 1}: a zero-duration pulse cannot be emitted
    by the DTC and an infinite pulse never terminates — both ends are handled
    by the encoding layer (conversion.py) before reaching the device.
    """
    p = jnp.clip(jnp.asarray(p), 1e-30, 1.0 - 1e-12)
    rate = jnp.exp(-delta * (1.0 - i_ua / i_c_ua))
    return -jnp.log(p) / rate


def scale_to_half_switching(tau_ns, *, target_p=0.5):
    """Normalization described in paper §III-D.

    The pulse-duration range is rescaled so the *typical* operand lands near
    ``P_usw ≈ 0.5`` — the bitstream is then "neither sparse nor dense", which
    maximizes the number of informative stochastic bits (and caps the pulse at
    roughly the deterministic switching time, avoiding slowdown). Returns the
    scale factor applied and the scaled durations.
    """
    tau_ns = jnp.asarray(tau_ns)
    tau_half = -jnp.log(jnp.asarray(target_p))  # = ln 2 at i = i_c
    mean_tau = jnp.mean(tau_ns)
    scale = jnp.where(mean_tau > 0, tau_half / jnp.maximum(mean_tau, 1e-30), 1.0)
    return scale, tau_ns * scale


def switching_energy_aj(tau_ns, i_ua, *, r_hml_ohm=250.0):
    """Joule-heating write energy per bit in attojoules: E = I^2 * R * tau.

    Only used by the cost model (Fig. 10 reproduction); the constant HML
    resistance is folded into the calibration there.
    """
    i_a = jnp.asarray(i_ua) * 1e-6
    tau_s = jnp.asarray(tau_ns) * 1e-9
    return (i_a * i_a) * r_hml_ohm * tau_s * 1e18
