"""Metrics registry: counters, gauges, fixed-bucket histograms.

One :class:`MetricsRegistry` owns a flat namespace of metrics; each
metric holds one value (or bucket table) per label set.  Everything is
plain Python + a lock — recording is an O(1) dict update, and a DISABLED
registry short-circuits every recording call on a single attribute
check, so instrumentation can stay in hot paths unconditionally.

Naming follows Prometheus conventions so the exposition is scrapable
as-is: counters end in ``_total``, histograms expose
``<name>_bucket{le=...}`` / ``<name>_sum`` / ``<name>_count``.  The JSON
snapshot (:meth:`MetricsRegistry.snapshot`) flattens label sets into
``name{k=v,...}`` keys — the format ``tools/obs_report.py`` renders and
``tools/bench_compare.py`` diffs (counters compare exactly; gauges are
runtime state and are ignored by default).

Percentiles come from the fixed buckets by linear interpolation inside
the covering bucket, clamped to the observed min/max — an estimate whose
error is bounded by the bucket width, which is what a gate with a
multiplicative tolerance needs (exact order statistics would require
keeping every sample).
"""

from __future__ import annotations

import bisect
import json
import math
import threading


def _label_key(labels: dict) -> tuple:
    """Canonical hashable form of one label set (sorted pairs)."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _series_name(name: str, key: tuple) -> str:
    """Flattened snapshot key: ``name`` or ``name{k=v,...}``."""
    if not key:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in key) + "}"


def _prom_labels(key: tuple, extra: tuple = ()) -> str:
    pairs = list(key) + list(extra)
    if not pairs:
        return ""
    quoted = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in pairs)
    return "{" + quoted + "}"


class _Metric:
    """Shared per-metric state: name, help text, per-label-set series."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str):
        self._registry = registry
        self.name = name
        self.help = help
        self._series: dict = {}          # label key tuple -> value/state

    def _get(self, labels: dict, default):
        key = _label_key(labels)
        with self._registry._lock:
            if key not in self._series:
                self._series[key] = default()
            return key

    def labelsets(self) -> list:
        return sorted(self._series)


class Counter(_Metric):
    """Monotonic accumulator.  ``inc`` is a no-op when the registry is
    disabled; negative increments raise (use a :class:`Gauge`)."""

    kind = "counter"

    def inc(self, n: float = 1, **labels) -> None:
        if not self._registry.enabled:
            return
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        key = _label_key(labels)
        with self._registry._lock:
            self._series[key] = self._series.get(key, 0) + n

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0)


class Gauge(_Metric):
    """Point-in-time value (queue depth, pool occupancy)."""

    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        if not self._registry.enabled:
            return
        with self._registry._lock:
            self._series[_label_key(labels)] = v

    def add(self, n: float, **labels) -> None:
        if not self._registry.enabled:
            return
        key = _label_key(labels)
        with self._registry._lock:
            self._series[key] = self._series.get(key, 0) + n

    def value(self, **labels):
        return self._series.get(_label_key(labels))


# Decode-latency-ish default: sub-0.1ms through 10s, roughly 2x steps.
DEFAULT_BUCKETS = (0.05, 0.1, 0.2, 0.4, 0.8, 1.5, 3.0, 6.0, 12.0, 25.0,
                   50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 10000.0)


class _HistSeries:
    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)   # +1 = overflow (+inf) bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf


class Histogram(_Metric):
    """Fixed-bucket histogram with interpolated percentile estimates.

    ``buckets`` are upper bounds (ascending); samples beyond the last
    bound land in an implicit +inf bucket whose percentile estimates are
    clamped to the observed max.
    """

    kind = "histogram"

    def __init__(self, registry, name, help, buckets=DEFAULT_BUCKETS):
        super().__init__(registry, name, help)
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram {name} needs ascending bucket bounds, "
                f"got {buckets!r}")
        self.buckets = bounds

    def observe(self, v: float, **labels) -> None:
        if not self._registry.enabled:
            return
        v = float(v)
        key = _label_key(labels)
        with self._registry._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _HistSeries(len(self.buckets))
            s.counts[bisect.bisect_left(self.buckets, v)] += 1
            s.count += 1
            s.sum += v
            s.min = min(s.min, v)
            s.max = max(s.max, v)

    def count(self, **labels) -> int:
        s = self._series.get(_label_key(labels))
        return s.count if s else 0

    def sum(self, **labels) -> float:
        s = self._series.get(_label_key(labels))
        return s.sum if s else 0.0

    def percentile(self, p: float, **labels):
        """Interpolated p-th percentile estimate, or None when empty."""
        s = self._series.get(_label_key(labels))
        if s is None or s.count == 0:
            return None
        rank = (p / 100.0) * s.count
        cum = 0
        for i, c in enumerate(s.counts):
            if c == 0:
                continue
            lo = self.buckets[i - 1] if i > 0 else s.min
            hi = self.buckets[i] if i < len(self.buckets) else s.max
            lo = max(min(lo, s.max), s.min)
            hi = max(min(hi, s.max), s.min)
            if cum + c >= rank:
                frac = (rank - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return s.max


class MetricsRegistry:
    """A namespace of metrics.  ``counter``/``gauge``/``histogram`` are
    idempotent: re-requesting a name returns the existing metric (and a
    kind mismatch raises, catching accidental name collisions)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.RLock()
        self._metrics: dict = {}

    # ------------------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def _register(self, cls, name, help, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}")
                return m
            m = cls(self, name, help, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def metrics(self) -> list:
        return [self._metrics[k] for k in sorted(self._metrics)]

    def value(self, name: str, **labels):
        """Convenience read of one counter/gauge series (None if the
        metric is unknown; 0/None per the metric's own default)."""
        m = self._metrics.get(name)
        if m is None:
            return None
        return m.value(**labels)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-ready export: flattened series under their kind.

        ``{"counters": {...}, "gauges": {...}, "histograms": {name:
        {"count", "sum", "p50", "p95", "p99", "min", "max"}}}`` — the
        shape ``tools/obs_report.py`` renders and diffs.
        """
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            for m in self.metrics():
                if isinstance(m, Counter):
                    for key in m.labelsets():
                        out["counters"][_series_name(m.name, key)] = \
                            m._series[key]
                elif isinstance(m, Gauge):
                    for key in m.labelsets():
                        out["gauges"][_series_name(m.name, key)] = \
                            m._series[key]
                elif isinstance(m, Histogram):
                    for key in m.labelsets():
                        s = m._series[key]
                        labels = dict(key)
                        out["histograms"][_series_name(m.name, key)] = {
                            "count": s.count,
                            "sum": round(s.sum, 6),
                            "min": round(s.min, 6),
                            "max": round(s.max, 6),
                            "p50": round(m.percentile(50, **labels), 6),
                            "p95": round(m.percentile(95, **labels), 6),
                            "p99": round(m.percentile(99, **labels), 6),
                        }
        return out

    def snapshot_json(self) -> str:
        return json.dumps(self.snapshot(), indent=2, sort_keys=True) + "\n"

    def exposition(self) -> str:
        """Prometheus-style text exposition of every series."""
        lines = []
        with self._lock:
            for m in self.metrics():
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.kind}")
                if isinstance(m, Histogram):
                    for key in m.labelsets():
                        s = m._series[key]
                        cum = 0
                        for i, bound in enumerate(m.buckets):
                            cum += s.counts[i]
                            lab = _prom_labels(key, (("le", f"{bound:g}"),))
                            lines.append(f"{m.name}_bucket{lab} {cum}")
                        lab = _prom_labels(key, (("le", "+Inf"),))
                        lines.append(f"{m.name}_bucket{lab} {s.count}")
                        lines.append(
                            f"{m.name}_sum{_prom_labels(key)} {s.sum:g}")
                        lines.append(
                            f"{m.name}_count{_prom_labels(key)} {s.count}")
                else:
                    for key in m.labelsets():
                        lines.append(
                            f"{m.name}{_prom_labels(key)} "
                            f"{m._series[key]:g}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# The process-global default registry: substrate-level counters (sc
# dispatch, autotune, arch pricing) record here.  DISABLED by default —
# the "zero cost until an operator opts in" contract.
# ---------------------------------------------------------------------------

_DEFAULT = MetricsRegistry(enabled=False)


def default_registry() -> MetricsRegistry:
    return _DEFAULT


def enable() -> MetricsRegistry:
    """Turn the default registry on (``launch.serve --metrics-out``)."""
    _DEFAULT.enable()
    return _DEFAULT


def disable() -> None:
    _DEFAULT.disable()


def enabled() -> bool:
    return _DEFAULT.enabled
