"""Structured trace spans: name + wall ns + duration + attrs + parent.

A :class:`Tracer` records :class:`Span` rows into memory; serving
engines open a span per tick and emit zero-duration events per request
lifecycle step (submit → admit → prefill chunks → decode ticks →
evict/resume → finish), and trace-time instrumentation (sc dispatch,
arch pricing) annotates the innermost open span via :meth:`Tracer.attr`.
Export is JSONL (one span per line, stable field names) and the rows
convert losslessly to a Chrome ``trace_event`` file
(:func:`to_chrome`) viewable in ``chrome://tracing`` / Perfetto.

Timestamps are ``time.perf_counter_ns()`` — monotonic wall ns, so
durations are exact and ordering holds within one process; spans carry
the recording thread id as ``tid`` so multi-threaded drivers stay
readable in the Chrome view.

The module-global tracer slot (:func:`install_tracer` /
:func:`current_tracer`) mirrors ``arch.trace``'s listener pattern: code
that cannot be handed a tracer (backend dispatch running under a jax
trace) still reaches the active one; when none is installed the lookup
is one global read.  :data:`NULL_TRACER` is an always-off tracer engines
default to, so instrumentation sites need no None checks.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time


@dataclasses.dataclass
class Span:
    """One recorded span.  ``dur_ns == 0`` marks an instant event."""

    name: str
    t0_ns: int
    dur_ns: int
    attrs: dict
    span_id: int
    parent_id: int | None
    tid: int

    def as_dict(self) -> dict:
        return {"name": self.name, "t0_ns": self.t0_ns,
                "dur_ns": self.dur_ns, "attrs": self.attrs,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "tid": self.tid}


class Tracer:
    """Records spans; enabled unless constructed otherwise.

    Thread-safe: the span list is lock-guarded and the open-span stack
    (parentage + ``attr`` targeting) is thread-local.
    """

    def __init__(self, enabled: bool = True, clock=time.perf_counter_ns):
        self.enabled = enabled
        self.spans: list[Span] = []
        self._clock = clock
        self._lock = threading.Lock()
        self._local = threading.local()
        self._next_id = 1

    # ------------------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _alloc(self) -> int:
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            return sid

    def _record(self, span: Span) -> None:
        with self._lock:
            self.spans.append(span)

    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Timed span around a block; yields the open Span (attrs are
        mutable until exit).  Nesting sets ``parent_id``."""
        if not self.enabled:
            yield _NULL_SPAN
            return
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        s = Span(name=name, t0_ns=self._clock(), dur_ns=0, attrs=dict(attrs),
                 span_id=self._alloc(), parent_id=parent,
                 tid=threading.get_ident())
        stack.append(s)
        try:
            yield s
        finally:
            s.dur_ns = self._clock() - s.t0_ns
            stack.pop()
            self._record(s)

    def event(self, name: str, **attrs) -> None:
        """Zero-duration instant event (request lifecycle steps)."""
        if not self.enabled:
            return
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        self._record(Span(name=name, t0_ns=self._clock(), dur_ns=0,
                          attrs=dict(attrs), span_id=self._alloc(),
                          parent_id=parent, tid=threading.get_ident()))

    def attr(self, **attrs) -> None:
        """Fold attrs into the innermost OPEN span (no-op when none is
        open) — how trace-time hooks (arch pricing, autotune) annotate
        the dispatch span that called them."""
        if not self.enabled:
            return
        stack = self._stack()
        if stack:
            stack[-1].attrs.update(attrs)

    # ------------------------------------------------------------------
    def counts(self) -> dict:
        """Span count per name (the lifecycle accounting tests use)."""
        out: dict = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0) + 1
        return out

    def write_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for s in self.spans:
                f.write(json.dumps(s.as_dict(), sort_keys=True) + "\n")
        return path


class _NullSpan:
    """Stand-in yielded by a disabled tracer's ``span()``."""

    attrs: dict = {}

    def __setattr__(self, k, v):      # swallow attr writes
        pass


_NULL_SPAN = _NullSpan()

#: Always-off tracer — engines default to it so call sites skip None
#: checks; every method is a cheap early return.
NULL_TRACER = Tracer(enabled=False)


# ---------------------------------------------------------------------------
# Module-global tracer slot (for trace-time hooks under jax tracing)
# ---------------------------------------------------------------------------

_ACTIVE: Tracer | None = None


def install_tracer(tracer: Tracer) -> Tracer:
    """Make ``tracer`` the process-global tracer (one at a time)."""
    global _ACTIVE
    _ACTIVE = tracer
    return tracer


def uninstall_tracer(tracer: Tracer | None = None) -> None:
    """Clear the global slot (pass the tracer to make it conditional —
    an uninstall racing a newer install then leaves the newer one)."""
    global _ACTIVE
    if tracer is None or _ACTIVE is tracer:
        _ACTIVE = None


def current_tracer() -> Tracer | None:
    return _ACTIVE


# ---------------------------------------------------------------------------
# Exports
# ---------------------------------------------------------------------------


def read_jsonl(path: str) -> list[dict]:
    """Rows of a span JSONL file (skipping blank lines)."""
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def to_chrome(rows, process_name: str = "repro") -> dict:
    """Convert span rows (dicts or Spans) to a Chrome trace_event dict.

    Timed spans become complete (``ph: "X"``) events, instant events
    ``ph: "i"``; timestamps shift to start at 0 and convert to µs (the
    trace_event unit).  ``json.dump`` the result and open it in
    ``chrome://tracing`` or https://ui.perfetto.dev.
    """
    rows = [r.as_dict() if isinstance(r, Span) else r for r in rows]
    t0 = min((r["t0_ns"] for r in rows), default=0)
    events = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
               "args": {"name": process_name}}]
    for r in rows:
        ev = {"pid": 1,
              "tid": r.get("tid", 0),
              "name": r["name"],
              "ts": (r["t0_ns"] - t0) / 1e3,
              "args": dict(r.get("attrs") or {})}
        if r.get("parent_id") is not None:
            ev["args"]["parent_id"] = r["parent_id"]
        if r.get("dur_ns", 0) > 0:
            ev.update(ph="X", dur=r["dur_ns"] / 1e3)
        else:
            ev.update(ph="i", s="t")
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}
