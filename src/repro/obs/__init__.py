"""repro.obs — lightweight, dependency-free observability.

Two primitives, one contract:

* :mod:`repro.obs.metrics` — a **metrics registry**: counters, gauges,
  and fixed-bucket histograms (p50/p95/p99 estimates), optionally
  labeled, thread-safe, with Prometheus-style text exposition and a JSON
  snapshot export.  A process-global default registry exists for
  substrate-level counters (sc dispatch, autotune cache hits, arch
  pricing) and is DISABLED by default — every recording call is a single
  flag check when off, so instrumented hot paths cost nothing until an
  operator opts in (``obs.enable()`` / ``launch.serve --metrics-out``).
  Serving engines own their own always-on registry instance
  (``engine.metrics``) so concurrent engines never mix series.
* :mod:`repro.obs.trace` — **structured trace spans** (name, wall ns,
  duration, attrs, parent) recorded by a :class:`~repro.obs.trace.Tracer`,
  exported as JSONL and convertible to a Chrome ``trace_event`` file
  (``tools/obs_report.py --chrome``).  A module-global tracer slot lets
  trace-time instrumentation (sc dispatch, arch pricing) annotate the
  innermost open span without plumbing handles through jax.

The package imports nothing from the rest of ``repro`` — it sits at the
bottom of the dependency graph so serve, sc, and arch can all report
through it.  See ``docs/observability.md`` for the metric catalog and
span schema.
"""

from repro.obs.metrics import (                           # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, default_registry,
    disable, enable, enabled)
from repro.obs.trace import (                             # noqa: F401
    NULL_TRACER, Span, Tracer, current_tracer, install_tracer,
    read_jsonl, to_chrome, uninstall_tracer)
