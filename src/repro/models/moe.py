"""Mixture-of-Experts FFN with capacity-bounded sort dispatch (EP-ready).

Design notes (these choices are what make the 400B config lower cleanly):

* Routing, sort, and capacity bookkeeping happen **per batch row** (axis 0
  stays the data-sharded batch), so the sort is a local operation per shard —
  no global argsort collectives appear in the HLO.
* Dispatch is gather-based (Megablocks-style capacity buffers), NOT the
  GShard one-hot-einsum formulation: the (tokens × experts × capacity)
  dispatch tensor is never materialized and no fake dispatch-FLOPs pollute
  the roofline (MODEL_FLOPS/HLO_FLOPs stays honest).
* Expert weights carry the "experts" logical axis -> TP/EP over the model
  mesh axis; the capacity buffer gets a sharding constraint on its expert
  axis, which XLA resolves into the canonical MoE all-to-all.
* Top-k gates are renormalized; overflow beyond the capacity factor drops
  tokens (standard capacity semantics; cf defaults to 1.25).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.params import ParamSpec


def moe_specs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    sp = {
        "router": ParamSpec((d, e), ("embed", None), "scaled"),
        # EP owns the model axis via "experts"; the expert-internal FFN dim
        # uses its own logical axis ("expert_mlp" -> replicated) since one
        # mesh axis cannot shard two dims of the same tensor.
        "wi": ParamSpec((e, d, 2 * f), ("experts", "embed", "expert_mlp"),
                        "scaled"),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed"),
                        "scaled"),
    }
    if cfg.shared_expert:
        sp["shared"] = layers.mlp_specs(cfg)
    return sp


def capacity(cfg, tokens_per_row: int) -> int:
    c = int(tokens_per_row * cfg.top_k * cfg.capacity_factor
            / max(cfg.n_experts, 1))
    return max(8, -(-c // 8) * 8)          # round up to a multiple of 8


def moe_ffn(x, p, cfg, key=None, constrain=None):
    """x: (b, s, d) -> (b, s, d). ``constrain(x, *logical_axes)`` optional.

    ``key`` is None (exact substrate), one raw (2,) key, or a per-token
    (b, s, 2) key array (the paged engine's contract): per-token keys are
    GATHERED through the same token->slot dispatch as ``x``, so a token's
    expert matmuls draw from its own (request, position) key whatever
    slot it lands in — MoE outputs stay invariant to batch composition,
    chunking, and eviction/resume like every other site.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(cfg, s)
    cst = constrain or (lambda v, *a: v)

    router_logits = layers.dense(
        x.astype(jnp.float32), p["router"].astype(jnp.float32), cfg,
        layers.site_key(key, "moe_router"), site="moe_router")   # (b, s, e)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                      # (b, s, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- per-row capacity assignment via local sort --------------------
    flat_e = eidx.reshape(b, s * k)                            # (b, sk)
    order = jnp.argsort(flat_e, axis=-1, stable=True)          # (b, sk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)
    rank = jnp.arange(s * k)[None, :] - jnp.take_along_axis(
        first, sorted_e, axis=-1)                              # pos in expert
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)     # drop -> slot E*C
    token = order // k                                         # source token

    # --- dispatch: (b, e, cap, d) capacity buffers ----------------------
    xg = jnp.take_along_axis(x, token[..., None], axis=1)      # (b, sk, d)
    buf = jnp.zeros((b, e * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, sl, xv: bf.at[sl].set(xv))(buf, slot, xg)
    buf = buf[:, : e * cap].reshape(b, e, cap, d)
    buf = cst(buf, "batch", "experts", None, None)             # EP a2a here

    ekey = key
    if key is not None and key.ndim == 3:
        # per-token keys ride the SAME dispatch as x: gather by source
        # token, scatter into capacity slots (empty slots keep zero keys;
        # their x rows are zero so their outputs are zero regardless)
        kg = jnp.take_along_axis(key, token[..., None], axis=1)
        kbuf = jnp.zeros((b, e * cap + 1, 2), key.dtype)
        kbuf = jax.vmap(lambda bf, sl, kv: bf.at[sl].set(kv))(kbuf, slot, kg)
        ekey = kbuf[:, : e * cap].reshape(b, e, cap, 2)

    # --- expert FFN (SwiGLU) through the substrate, per-expert keys -----
    h = layers.expert_dense(buf, p["wi"], cfg, ekey, site="moe_wi")
    gate, up = jnp.split(h, 2, axis=-1)
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(buf.dtype) * up
    y = layers.expert_dense(act, p["wo"], cfg, ekey, site="moe_wo")
    y = cst(y, "batch", "experts", None, None)

    # --- combine: gather back per (token, k) slot, weight, scatter-add --
    yflat = jnp.pad(y.reshape(b, e * cap, d), ((0, 0), (0, 1), (0, 0)))
    ytk = jax.vmap(lambda yf, sl: yf[sl])(yflat, slot)         # (b, sk, d)
    gate_sorted = jnp.take_along_axis(gates.reshape(b, s * k), order, axis=-1)
    ytk = ytk * gate_sorted[..., None].astype(ytk.dtype)
    out = jnp.zeros((b, s, d), x.dtype)
    out = jax.vmap(lambda ob, tk, yv: ob.at[tk].add(yv))(out, token, ytk)

    if cfg.shared_expert:
        out = out + layers.mlp(x, p["shared"], cfg, key)
    return out


def load_balancing_loss(router_probs, eidx, n_experts: int):
    """Switch-style aux loss: E · Σ_e f_e · P_e (optional, train.py wires it)."""
    b, s, k = eidx.shape
    counts = jnp.zeros((n_experts,)).at[eidx.reshape(-1)].add(1.0)
    f = counts / (b * s * k)
    pmean = router_probs.mean(axis=(0, 1))
    return n_experts * jnp.sum(f * pmean)
