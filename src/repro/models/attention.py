"""GQA attention: full and blockwise (flash-style online-softmax) variants,
plus single-token decode over a KV cache.

Blockwise attention scans over KV chunks with a running (max, denominator,
accumulator) triple, so peak memory is O(S·chunk) instead of O(S²) —
this is what lets prefill_32k lower within HBM and is remat-friendly inside the
layer scan. GQA is computed grouped: q heads are reshaped to
(kv_heads, group) so no KV head replication is materialized.

The paged decode path (:func:`paged_attention_block`) additionally routes
through ``cfg.paged_attn``: ``"unfused"`` runs the reference
gather -> :func:`chunk_decode_attention` sequence, ``"fused"`` /
``"fused_sc"`` dispatch to the single-``pallas_call`` kernels in
``kernels/paged_attention.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import paged_attention
from repro.models import layers
from repro.models.params import ParamSpec

NEG_INF = -1e30


def attn_specs(cfg):
    d, h, kv, hd = (
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.resolved_head_dim,
    )
    sp = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads"), "scaled"),
        "wk": ParamSpec((d, kv * hd), ("embed", "kv_embed"), "scaled"),
        "wv": ParamSpec((d, kv * hd), ("embed", "kv_embed"), "scaled"),
        "wo": ParamSpec((h * hd, d), ("heads", "embed"), "scaled"),
    }
    if cfg.qkv_bias:
        sp.update(
            {
                "bq": ParamSpec((h * hd,), ("heads",), "zeros"),
                "bk": ParamSpec((kv * hd,), ("kv_embed",), "zeros"),
                "bv": ParamSpec((kv * hd,), ("kv_embed",), "zeros"),
            }
        )
    if cfg.qk_norm:
        sp.update(
            {
                "q_norm": ParamSpec((hd,), (None,), "ones"),
                "k_norm": ParamSpec((hd,), (None,), "ones"),
            }
        )
    return sp


def _project_qkv(x, p, cfg, positions, key=None):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if key is None:
        keys = [None] * 3
    elif key.ndim > 1:
        # Per-token key arrays (paged/chunked decode): one fold per
        # projection instead of a split, so each token's draw stays a
        # function of its own (request, position) key alone.
        keys = [layers.fold_keys(key, 23 + j) for j in range(3)]
    else:
        keys = list(jax.random.split(key, 3))
    q = layers.dense(x, p["wq"], cfg, keys[0], p.get("bq")).reshape(
        b, s, h, hd
    )
    k = layers.dense(x, p["wk"], cfg, keys[1], p.get("bk")).reshape(
        b, s, kv, hd
    )
    v = layers.dense(x, p["wv"], cfg, keys[2], p.get("bv")).reshape(
        b, s, kv, hd
    )
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _grouped(q, kv_heads):
    b, s, h, hd = q.shape
    return q.reshape(b, s, kv_heads, h // kv_heads, hd)


def full_attention(q, k, v, *, causal: bool = True):
    """Reference O(S²) attention. q: (b,s,h,d), k/v: (b,t,kv,d)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    qg = _grouped(q, kv)  # (b,s,kv,g,d)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = (
        jnp.einsum(
            "bskgd,btkd->bkgst",
            qg.astype(jnp.float32),
            k.astype(jnp.float32),
        )
        * scale
    )
    if causal:
        t = k.shape[1]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def blockwise_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    chunk: int = 1024,
    q_chunk: int | None = None,
):
    """Flash-style attention: q-chunk outer scan x kv-chunk inner scan with
    online softmax. Exact -- matches full_attention to float tolerance.

    Peak intermediate is one (b, kv, g, cq, ckv) logits tile per step. The
    kv step is jax.checkpoint'd so the backward pass (even nested inside the
    per-layer remat scan) recomputes tiles instead of saving every chunk's
    probabilities -- this is what keeps the 32k-prefill cells inside HBM.

    ``q_chunk`` overrides the query-side chunk. Context-parallel attention
    passes q_chunk = s (ONE q block): the q sequence is already sharded over
    the TP axis, and an outer q scan would split the sharded axis across
    sequential scan steps — serializing the devices (EXPERIMENTS §Perf
    cell-2 iteration 2). KV still streams in ``chunk``-sized blocks.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    t_unpadded = k.shape[1]
    t = t_unpadded
    ckv = min(chunk, t)
    if t % ckv != 0:  # pad KV to a chunk multiple with masked slots
        pad = ckv - t % ckv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = t + pad
    cq = min(q_chunk or chunk, s)
    qpad = (-s) % cq
    g = h // kv
    qg = _grouped(q, kv).astype(jnp.float32)  # (b,s,kv,g,d)
    if qpad:
        qg = jnp.pad(qg, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
    nq = (s + qpad) // cq
    nkv = t // ckv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kc = k.reshape(b, nkv, ckv, kv, hd).astype(jnp.float32)
    vc = v.reshape(b, nkv, ckv, kv, hd).astype(jnp.float32)
    qc = qg.reshape(b, nq, cq, kv, g, hd)
    # Queries are the LAST s positions of the (unpadded) kv timeline
    # (prefill: s == original t; one-token decode uses decode_attention).
    q_off = t_unpadded - s

    @jax.checkpoint
    def kv_step(carry, inputs):
        m, denom, acc, qi, qbase = carry
        kc_i, vc_i, base = inputs
        logits = jnp.einsum("bkgsd,btkd->bkgst", qi, kc_i) * scale
        kv_idx = base + jnp.arange(ckv)  # (ckv,)
        q_idx = qbase + jnp.arange(cq) + q_off  # (cq,)
        mask = (
            kv_idx[None, :] <= q_idx[:, None]
            if causal
            else jnp.ones((cq, ckv), bool)
        )
        valid = (kv_idx < t_unpadded)[None, :]
        logits = jnp.where(
            (mask & valid)[None, None, None], logits, NEG_INF
        )
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        denom = denom * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vc_i
        )
        return (m_new, denom, acc, qi, qbase), None

    bases = jnp.arange(nkv) * ckv
    kcm = jnp.moveaxis(kc, 1, 0)
    vcm = jnp.moveaxis(vc, 1, 0)

    def q_step(_, inputs):
        q_i, qbase = inputs  # (b,cq,kv,g,d)
        qi = jnp.einsum("bskgd->bkgsd", q_i)
        m0 = jnp.full((b, kv, g, cq), NEG_INF, jnp.float32)
        d0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, cq, hd), jnp.float32)
        (m, denom, acc, _, _), _ = jax.lax.scan(
            kv_step, (m0, d0, a0, qi, qbase), (kcm, vcm, bases)
        )
        out = acc / jnp.maximum(denom, 1e-30)[..., None]  # (b,kv,g,cq,d)
        return None, out

    qbases = jnp.arange(nq) * cq
    _, outs = jax.lax.scan(q_step, None, (jnp.moveaxis(qc, 1, 0), qbases))
    # outs: (nq, b, kv, g, cq, d) -> (b, s, h, d)
    out = jnp.moveaxis(outs, 0, 3)  # (b,kv,g,nq,cq,d)
    out = out.reshape(b, kv, g, nq * cq, hd)
    out = jnp.moveaxis(out, 3, 1)[:, :s].reshape(b, s, h, hd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length):
    """One-token decode: q (b,1,h,d) against cache (b,L,kv,d); mask > length.
    The single-token special case of :func:`chunk_decode_attention` (the
    query sits at position ``length - 1``, i.e. a chunk of one at fill
    ``length - 1``)."""
    return chunk_decode_attention(q, k_cache, v_cache, length - 1)


def chunk_decode_attention(q, k_cache, v_cache, lengths):
    """Multi-token decode: a chunk of queries against a per-sequence cache.

    q: (b, sc, h, d) — chunk token i of row r sits at ABSOLUTE position
    ``lengths[r] + i`` (its K/V must already be written into the cache);
    k/v_cache: (b, L, kv, d).  Causal within the chunk, masked beyond each
    row's fill.  ``sc = 1`` reproduces :func:`decode_attention` with
    ``length = lengths + 1`` — the single-token decode is the special case.
    This is the lookup the paged serve path drives after a
    ``paged_gather``; it is also what chunked prefill uses, which is why
    one function serves both phases.
    """
    b, sc, h, hd = q.shape
    kv = k_cache.shape[2]
    qg = _grouped(q, kv).astype(jnp.float32)  # (b,sc,kv,g,d)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = (
        jnp.einsum("bskgd,btkd->bkgst", qg, k_cache.astype(jnp.float32))
        * scale
    )
    t_idx = jnp.arange(k_cache.shape[1])  # (L,)
    q_pos = lengths[:, None] + jnp.arange(sc)[None, :]  # (b, sc)
    mask = t_idx[None, None, :] <= q_pos[:, :, None]  # (b, sc, L)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, sc, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged KV lookup: fixed-size token blocks + per-sequence block tables
# ---------------------------------------------------------------------------


def paged_gather(pages, block_table):
    """Materialize each sequence's cache view from the block pool.

    pages: (P, bs, kv, d) — the pool (P blocks of bs tokens, one layer);
    block_table: (b, nb) int32 — block ids per sequence, position t of row
    r lives in ``pages[block_table[r, t // bs], t % bs]``.  Returns the
    gathered (b, nb·bs, kv, d) view — the contiguous-cache layout, which
    is what proves paged == contiguous attention (same downstream math).
    """
    g = jnp.take(pages, block_table, axis=0)  # (b, nb, bs, kv, d)
    b, nb, bs = g.shape[:3]
    return g.reshape(b, nb * bs, *g.shape[3:])


def paged_scatter(pages, block_table, new, lengths, n_valid):
    """Write a chunk's K or V rows into the pool through the block tables.

    new: (b, sc, kv, d) — token i of row r goes to absolute position
    ``lengths[r] + i`` when ``i < n_valid[r]``; tokens beyond a row's
    valid count (chunk padding, idle rows) land in the reserved null
    block 0, which no live sequence ever maps.  Rows' block tables point
    at disjoint pool blocks over the written span (the allocator hands
    each row its own blocks, and prefix-shared blocks are copied out by
    the scheduler's copy-on-write barrier before any write reaches them
    — ``kv_cache.PagedKVCache.make_writable``), so scatters never collide
    except harmlessly inside the null block.
    """
    bs = pages.shape[1]
    b, sc = new.shape[:2]
    nb = block_table.shape[1]
    i = jnp.arange(sc)[None, :]
    t = jnp.clip(lengths[:, None] + i, 0, nb * bs - 1)  # (b, sc)
    valid = i < n_valid[:, None]
    page = jnp.take_along_axis(block_table, t // bs, axis=1)
    page = jnp.where(valid, page, 0)
    off = jnp.where(valid, t % bs, 0)
    flat = new.reshape(b * sc, *new.shape[2:]).astype(pages.dtype)
    return pages.at[page.reshape(-1), off.reshape(-1)].set(flat)


def paged_copy_blocks(pages, src, dst):
    """Copy whole pool blocks ``src[i] -> dst[i]`` on every layer.

    The device half of copy-on-write: when the scheduler's write barrier
    (``kv_cache.PagedKVCache.make_writable``) replaces a shared or
    hash-registered block in a sequence's table, the new block must carry
    the old block's K/V before the next scatter overwrites its tail.
    pages: the pool dict with ``{"k", "v"}`` (layers, P, bs, kv, d)
    arrays (extra non-paged leaves — e.g. a hybrid plan's ``"ssm"`` state
    rows, which are slot- not block-indexed — pass through untouched);
    src/dst: equal-length block-id vectors.  Pure indexed-copy — one
    executable per distinct copy count (COW is rare and counts are tiny).
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cp(pool):
        return pool.at[:, dst].set(pool[:, src])

    return {**pages, "k": cp(pages["k"]), "v": cp(pages["v"])}


def paged_attention_block(
    x,
    p,
    cfg,
    positions,
    key,
    k_pages,
    v_pages,
    block_table,
    lengths,
    n_valid,
):
    """Self-attention over the paged KV cache (chunked decode/prefill).

    x: (b, sc, d) chunk activations; the chunk's K/V scatter into the
    pool first, then attention runs over each row's gathered view —
    write-then-gather keeps the math identical to the contiguous path.
    ``cfg.paged_attn`` selects the lookup: ``"unfused"`` (reference
    gather + :func:`chunk_decode_attention`), ``"fused"`` (one Pallas
    kernel, same math to float tolerance), or ``"fused_sc"`` (fused with
    the SC-sampled QK^T; needs per-token keys and draws them under salt
    29, disjoint from the dense-layer salts).  Returns
    (out, new_k_pages, new_v_pages).
    """
    q, k, v = _project_qkv(x, p, cfg, positions, key)
    k_pages = paged_scatter(k_pages, block_table, k, lengths, n_valid)
    v_pages = paged_scatter(v_pages, block_table, v, lengths, n_valid)
    mode = getattr(cfg, "paged_attn", "unfused")
    if mode == "fused":
        out = paged_attention.paged_attention_fused(
            q, k_pages, v_pages, block_table, lengths
        )
    elif mode == "fused_sc":
        if key is None or key.ndim <= 1:
            raise ValueError(
                "paged_attn='fused_sc' needs per-token rng keys (pass "
                "rng to decode_paged) so attention draws stay pinned to "
                "(request, position)"
            )
        out = paged_attention.paged_attention_fused_sc(
            layers.fold_keys(key, 29),
            q,
            k_pages,
            v_pages,
            block_table,
            lengths,
            nbit=cfg.sc_nbit,
        )
    elif mode == "unfused":
        kc = paged_gather(k_pages, block_table)
        vc = paged_gather(v_pages, block_table)
        out = chunk_decode_attention(q, kc, vc, lengths)
    else:
        raise ValueError(
            f"unknown cfg.paged_attn={mode!r} "
            "(expected 'unfused', 'fused', or 'fused_sc')"
        )
    b, s, _, _ = out.shape
    okey = layers.fold_keys(key, 7)
    return (
        layers.dense(out.reshape(b, s, -1), p["wo"], cfg, okey),
        k_pages,
        v_pages,
    )


def attention_block(
    x,
    p,
    cfg,
    positions,
    key=None,
    *,
    cache=None,
    cache_length=None,
    constrain=None,
):
    """Self-attention sub-block. Returns (out, new_cache).

    Training/prefill: cache is None -> causal attention over the sequence
    (returns the full K/V so prefill can build the cache).
    Decode: cache = (k, v) ring buffers; x is (b, 1, d).
    """
    cst = constrain or (lambda v_, *a: v_)
    q, k, v = _project_qkv(x, p, cfg, positions, key)
    # Layout choice per arch x mesh: TP over heads when the head count
    # divides the model axis; otherwise CONTEXT PARALLELISM — the query
    # sequence shards over `model` (k/v replicate via one cheap gather per
    # layer) so attention FLOPs and the flash tiles stay distributed instead
    # of running head-replicated on every device (16x waste on 40-head
    # configs over a 16-way TP axis — EXPERIMENTS §Perf iteration 3).
    model_size = getattr(cst, "axis_sizes", {}).get("model", 1)
    heads_tp = model_size <= 1 or cfg.n_heads % model_size == 0
    if heads_tp or cache is not None:
        q = cst(q, "batch", "seq", "heads", None)
        k = cst(k, "batch", "seq", "kv_heads", None)
        v = cst(v, "batch", "seq", "kv_heads", None)
    else:
        q = cst(q, "batch", "resid_seq", None, None)
        k = cst(k, "batch", "seq", None, None)
        v = cst(v, "batch", "seq", None, None)
    if cache is not None:
        kc, vc = cache
        pos = positions[:, 0]  # (b,) write index
        kc = jax.vmap(
            lambda c, upd, i: jax.lax.dynamic_update_slice(
                c, upd, (i, 0, 0)
            )
        )(kc, k, pos)
        vc = jax.vmap(
            lambda c, upd, i: jax.lax.dynamic_update_slice(
                c, upd, (i, 0, 0)
            )
        )(vc, v, pos)
        out = decode_attention(q, kc, vc, cache_length)
        new_cache = (kc, vc)
    else:
        if cfg.attn_impl == "full":
            out = full_attention(q, k, v, causal=True)
        else:
            out = blockwise_attention(
                q,
                k,
                v,
                causal=True,
                chunk=cfg.attn_chunk,
                # CP: q already sharded over `model` -> single q block
                q_chunk=None if heads_tp else q.shape[1],
            )
        new_cache = (k, v)
    if heads_tp or cache is not None:
        out = cst(out, "batch", "seq", "heads", None)
    else:
        out = cst(out, "batch", "resid_seq", None, None)
    b, s, _, _ = out.shape
    okey = None if key is None else jax.random.fold_in(key, 7)
    return layers.dense(out.reshape(b, s, -1), p["wo"], cfg, okey), new_cache
