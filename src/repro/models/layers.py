"""Primitive layers: RMSNorm, Linear (SC-routable), SwiGLU MLP, RoPE, embed.

Every matmul in the stack goes through :func:`dense`, which routes to the
SC substrate registry when ``cfg.sc_backend != "exact"`` — any registered
backend (jnp moment/bitexact or the Pallas kernels) is selectable per
model config, and all of them are trainable through the straight-through
custom_vjp at the ``sc_dot`` dispatch boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sc
from repro.models.params import ParamSpec


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * weight


# ----------------------------- Matmul sites ---------------------------------
#
# Every weight matmul in the stack dispatches through :func:`dense` (or
# :func:`expert_dense`) under a NAMED SITE.  A site's salt is folded into
# the caller's key before the stochastic draw, so two sites fed the same
# (request, position) key still draw independent SC bits.  The salts are
# part of the bit-reproducibility contract — per-request rng invariance
# and the committed benchmark baselines both replay them — so an existing
# site must never be renumbered; new sites take fresh salts.  ``None``
# means the site consumes the caller's key unfolded (the pre-table
# convention for the first matmul of a block, kept for bit-compat).
#
# Folds applied OUTSIDE this table (for context when adding salts):
# per-layer index folds at the scan roots, 10_000+idx for the hybrid
# shared block, 11/13 (attn/ffn inside a block), 17/19 (shared
# attn/mlp), 23+j (qkv per-token path), 29 (fused_sc attention draw),
# 0x5EED (sampling), 0xC047 (content chains).

SITES: dict = {
    "mlp_wi": None,          # raw block key (pre-table convention)
    "mlp_wo": 1,
    "attn_qkv": None,        # _project_qkv folds 23+j / splits internally
    "attn_wo": None,         # attention folds its own okey
    "ssm_out": 3,
    "moe_router": 31,
    "moe_wi": 37,
    "moe_wo": 41,
    "ssm_wz": 47,
    "ssm_wx": 53,
    "ssm_wB": 59,
    "ssm_wC": 61,
    "ssm_wdt": 67,
    "unembed": 71,
    "frontend_proj": 73,
}


def site_key(key, site: str, data=None):
    """Per-site key folding: ``key`` folded with ``site``'s registered
    salt, then (optionally) with ``data`` — an extra int or int array for
    sub-site structure such as an expert index or a chunk index.  ``key``
    may be None (passed through), a raw (2,) key, or a (..., 2) array of
    per-row keys (the fold broadcasts — see :func:`fold_keys`)."""
    salt = SITES[site]
    k = key if salt is None else fold_keys(key, salt)
    return k if data is None else fold_keys(k, data)


def fold_keys(key, data):
    """``jax.random.fold_in`` broadcast over an array of raw PRNG keys.

    ``key`` may be None (passed through), one raw uint32 key of shape (2,),
    or an array of keys with leading batch dims, shape (..., 2).  ``data``
    is an int (same fold for every key) or an int array matching the
    leading dims (per-key fold — e.g. per-token positions).
    """
    if key is None:
        return None
    if key.ndim == 1:
        return jax.random.fold_in(key, data)
    flat = key.reshape(-1, key.shape[-1])
    data = jnp.broadcast_to(jnp.asarray(data, jnp.uint32), key.shape[:-1])
    folded = jax.vmap(jax.random.fold_in)(flat, data.reshape(-1))
    return folded.reshape(key.shape)


def _dense_rows(keys, x, w, sc_cfg):
    """Per-row SC dispatch: row i of ``x`` draws its stochastic bits (and
    its max-abs encoding scale) from ``keys[i]`` ALONE, so each row's
    output is independent of its batch neighbours — the property the
    continuous-batching serve engine relies on (same request + same key
    => same values whatever shares the batch).  Routed through
    ``sc.sc_dot_rows``: backends with a native batched rows path
    (``pallas_fused``) run one kernel launch, the rest vmap."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    kf = keys.reshape(-1, keys.shape[-1])
    w32 = w.astype(jnp.float32)
    yf = sc.sc_dot_rows(kf, xf, w32, sc_cfg)
    return yf.reshape(*lead, w.shape[-1]).astype(x.dtype)


def dense(x, w, cfg, key=None, bias=None, site: str = "dense"):
    """x @ w with the configured multiplication substrate.

    x: (..., K); w: (K, N) (or pre-reshaped 2-D view of a fused projection).
    Stochastic backends REQUIRE a PRNG key: a stochastic ``cfg.sc_backend``
    with ``key=None`` raises (naming ``site``) instead of silently falling
    back to the exact path — every caller must thread a key so the whole
    stack actually runs on the substrate it was configured for.  ``key``
    may also be an ARRAY of raw keys whose leading dims match ``x``'s (one
    key per row): the stochastic draw then vmaps per row, making every
    row's output (noise AND encoding scale) a function of its own key and
    data only — what the paged serve engine passes so results are
    invariant to batch composition.  Inside a ``sc.use_mesh(mesh)`` scope
    stochastic matmuls shard over the mesh via ``sc_dot_sharded`` (rows
    over the data axes, contraction over model with a psum merge) — the
    scope is consulted at trace time, so callers scale across devices with
    no signature changes (per-row keys are a single-mesh-slice feature and
    take precedence when both apply).
    """
    if cfg.sc_backend == "exact":
        y = jnp.dot(x, w.astype(x.dtype))
    elif key is None:
        raise ValueError(
            f"layers.dense at site {site!r}: sc_backend="
            f"{cfg.sc_backend!r} is stochastic but key=None — every "
            "stochastic matmul draws from a PRNG key; pass rng= to the "
            "model entry point (or set sc_backend='exact')")
    elif key.ndim > 1:
        # fast_backend upgrades pallas_bitexact to the bit-identical
        # fused engine — same key, same bits, one kernel launch
        sc_cfg = sc.ScConfig(
            backend=sc.fast_backend(cfg.sc_backend, cfg.sc_nbit),
            nbit=cfg.sc_nbit, device=sc.current_device_profile())
        y = _dense_rows(key, x, w, sc_cfg)
    else:
        sc_cfg = sc.ScConfig(
            backend=sc.fast_backend(cfg.sc_backend, cfg.sc_nbit),
            nbit=cfg.sc_nbit, device=sc.current_device_profile())
        scope = sc.active_mesh()
        if scope is not None:
            mesh, rules = scope
            y = sc.sc_dot_sharded(
                key, x.astype(jnp.float32), w.astype(jnp.float32), sc_cfg,
                mesh=mesh, rules=rules).astype(x.dtype)
        else:
            y = sc.sc_dot(key, x.astype(jnp.float32), w.astype(jnp.float32),
                          sc_cfg).astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


# ----------------------------- MLP (SwiGLU) --------------------------------


def mlp_specs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    wi_cols = 2 * f if cfg.mlp_variant == "swiglu" else f
    return {
        "wi": ParamSpec((d, wi_cols), ("embed", "mlp"), "scaled"),
        "wo": ParamSpec((f, d), ("mlp", "embed"), "scaled"),
    }


def mlp(x, p, cfg, key=None, constrain=None):
    cst = constrain or (lambda v, *a: v)
    h = dense(x, p["wi"], cfg, site_key(key, "mlp_wi"), site="mlp_wi")
    # TP over the hidden dim, full sequence inside the block (Megatron
    # pattern): without this pin Shardy reshards the multi-GB hidden between
    # seq-sharded and mlp-sharded layouts per invocation (observed 7.5 GB
    # collective-permutes on zamba2's shared block — EXPERIMENTS §Perf).
    h = cst(h, "batch", "seq", "mlp")
    if cfg.mlp_variant == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        act = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    act = cst(act, "batch", "seq", "mlp")
    return dense(act, p["wo"], cfg, site_key(key, "mlp_wo"), site="mlp_wo")


# ----------------------------- RoPE -----------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (b, s, h, d); positions: (b, s) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------- Embedding ------------------------------------


def embed_specs(cfg):
    return {"table": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"))}


def embed(tokens, p):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(x, p, cfg, key=None):
    return dense(x, p["table"].T, cfg, key, site="unembed")


# ----------------------------- Expert matmul --------------------------------


def expert_dense(x, w, cfg, key=None, site: str = "moe_wi"):
    """Per-expert batched matmul: (b, e, c, d) @ (e, d, f) -> (b, e, c, f).

    The MoE capacity-buffer contraction.  Exact mode is one einsum (the
    Megablocks-style dispatch keeps it dense).  Stochastic backends scan
    over the expert axis — one ``sc_dot_rows`` launch per expert, traced
    ONCE by ``jax.lax.scan`` — so each (c, d)x(d, f) expert shape reaches
    the kernel autotuner as its own (possibly ragged) problem, and every
    capacity slot's draw derives from its own key folded with ``site``'s
    salt and the expert index alone.

    ``key`` is None (exact only — stochastic raises like :func:`dense`),
    one raw (2,) key (broadcast to every slot), or a (b, e, c, 2) buffer
    of per-slot keys the caller dispatched alongside ``x`` (the paged
    engine's per-token keys gathered through the same token->slot
    scatter, so a token keeps its own key whichever expert it lands in).
    """
    if cfg.sc_backend == "exact":
        return jnp.einsum("becd,edf->becf", x, w.astype(x.dtype))
    if key is None:
        raise ValueError(
            f"layers.expert_dense at site {site!r}: sc_backend="
            f"{cfg.sc_backend!r} is stochastic but key=None — pass a key "
            "so expert matmuls draw on the substrate")
    b, e, c, d = x.shape
    if key.ndim == 1:
        key = jnp.broadcast_to(key, (b, e, c, 2))
    eidx = jnp.broadcast_to(jnp.arange(e)[None, :, None], (b, e, c))
    keys = site_key(key, site, eidx)                    # (b, e, c, 2)
    sc_cfg = sc.ScConfig(
        backend=sc.fast_backend(cfg.sc_backend, cfg.sc_nbit),
        nbit=cfg.sc_nbit, device=sc.current_device_profile())

    def one_expert(_, inp):
        we, xe, ke = inp              # (d, f), (b, c, d), (b, c, 2)
        return None, _dense_rows(ke, xe, we, sc_cfg)

    _, y = jax.lax.scan(
        one_expert, None,
        (w, jnp.moveaxis(x, 1, 0), jnp.moveaxis(keys, 1, 0)))
    return jnp.moveaxis(y, 0, 1)                        # (b, e, c, f)
